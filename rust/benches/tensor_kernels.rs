//! Bench: the `tensor::kernels` microkernel GEMM vs its scalar
//! reference, per shape × dispatch level × thread count — the
//! acceptance trail for the SIMD subsystem (`benchmarks/
//! BENCH_tensor_kernels.json` → BENCHMARKS.md §tensor_kernels).
//!
//! Ops are tagged with the dispatch level that actually ran
//! (`gemm_nn[avx2]`, `gemm_nn[avx2fma]`, `gemm_tn[scalar]`, …) so the
//! persisted JSON is its own provenance record; `benchx` resolves
//! `speedup_vs_scalar` against the `[scalar]` twin at flush (same
//! thread count when present, else the 1-thread scalar baseline —
//! scalar is only swept serially to keep the suite bounded). Entries
//! carry GFLOP/s (`2·m·n·k / ns`). When the host has the FMA fast tier
//! it is swept alongside the bit-exact native level, so the trail shows
//! per-tier throughput side by side.
//!
//! Both ops go through the `Mat` entry points (`matmul_with`,
//! `matmul_tn_with`), not raw kernel calls, so the suite measures the
//! exact path compress/apply/exact inherit. Dispatch is swept with
//! `tensor::kernels::force` — safe here because the bench driver owns
//! the process.
//!
//! Run: `cargo bench --bench tensor_kernels` (PAMM_BENCH_QUICK=1 for
//! CI); render with `pamm bench-report`.

use std::time::Duration;

use pamm::benchx::{BenchOpts, BenchSink, Suite};
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::{self, Dispatch};
use pamm::tensor::Mat;

fn opts() -> BenchOpts {
    if std::env::var("PAMM_BENCH_QUICK").is_ok() {
        // The 1024³ scalar baseline runs seconds per iter; keep CI smoke
        // to one measured iteration per slow cell.
        BenchOpts { warmup_iters: 0, min_iters: 1, max_iters: 5, max_total: Duration::from_secs(2) }
    } else {
        BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 15,
            max_total: Duration::from_secs(10),
        }
    }
}

fn main() {
    // (m, k, n): the 256/512/1024 square ladder the acceptance bar
    // speaks about, plus one ragged-tail shape (non-multiples of
    // MR/NR/KC) so edge-tile handling shows up in the trail.
    let shapes: &[(usize, usize, usize)] =
        &[(256, 256, 256), (512, 512, 512), (1024, 1024, 1024), (1021, 1024, 1027)];
    let native = Dispatch::native();
    let threads: &[usize] = &[1, 2, 4];
    let mut sink = BenchSink::new("tensor_kernels");

    let fast = Dispatch::fastest();
    println!(
        "tensor_kernels: native dispatch = {} / fast tier = {} (tiles MR={} NR={}, blocks MC={} KC={} NC={})",
        native.name(),
        if fast != native { fast.name() } else { "none" },
        kernels::MR,
        kernels::NR,
        kernels::mc(),
        kernels::kc(),
        kernels::nc()
    );

    for &(m, k, n) in shapes {
        let shape_s = format!("m={m} k={k} n={n}");
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let mut rng = Xoshiro256::new(7);
        let a = Mat::random_normal(m, k, 1.0, &mut rng);
        let at = a.transpose(); // (k, m): t_matmul's stored layout
        let b = Mat::random_normal(k, n, 1.0, &mut rng);

        let mut suite = Suite::with_opts(&format!("tensor_kernels {shape_s}"), opts());
        suite.header();

        // Scalar reference: serial only (the baseline the speedup bar
        // divides by); native level: full thread sweep.
        let mut plan: Vec<(Dispatch, usize)> = vec![(Dispatch::Scalar, 1)];
        if native != Dispatch::Scalar {
            plan.extend(threads.iter().map(|&t| (native, t)));
        }
        // Fast tier (FMA): tolerance-checked elsewhere; here it gets its
        // own rows so BENCHMARKS.md shows the per-tier GFLOP/s delta.
        if fast != native && fast.available() {
            plan.extend(threads.iter().map(|&t| (fast, t)));
        }
        for &(d, t) in &plan {
            kernels::force(Some(d));
            let tag = d.name();
            let pool = Pool::new(t);
            let r = suite
                .bench(&format!("gemm_nn[{tag}] t={t}"), || {
                    std::hint::black_box(a.matmul_with(&b, &pool));
                })
                .clone();
            sink.record_flops(&format!("gemm_nn[{tag}]"), &shape_s, t, &r, flops);
            let r = suite
                .bench(&format!("gemm_tn[{tag}] t={t}"), || {
                    std::hint::black_box(at.matmul_tn_with(&b, &pool));
                })
                .clone();
            sink.record_flops(&format!("gemm_tn[{tag}]"), &shape_s, t, &r, flops);
        }
        kernels::force(None);

        let mut levels = vec![native];
        if fast != native {
            levels.push(fast);
        }
        for op in ["gemm_nn", "gemm_tn"] {
            for &lvl in &levels {
                if let Some(sp) = suite.ratio(
                    &format!("{op}[{}] t=1", lvl.name()),
                    &format!("{op}[scalar] t=1"),
                ) {
                    println!("  {op}: {} vs scalar (single thread): {sp:.2}x", lvl.name());
                }
            }
        }
    }

    match sink.flush() {
        Ok(path) => {
            println!("\npersisted {} entries to {}", sink.entries().len(), path.display())
        }
        Err(e) => eprintln!("bench persistence failed: {e}"),
    }
}
