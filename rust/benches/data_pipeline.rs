//! Bench: the data substrate — corpus generation, tokenizer training,
//! encoding, batch packing, and the prefetch pipeline. Target (§Perf):
//! the pipeline must sustain ≥ 10× the training loop's token rate so it
//! never sits on the critical path.
//!
//! Run: `cargo bench --bench data_pipeline`

use pamm::benchx::Suite;
use pamm::coordinator::pipeline::BatchPipeline;
use pamm::data::batcher::BatchIterator;
use pamm::data::corpus::{CorpusConfig, CorpusGenerator};
use pamm::data::tokenizer::Tokenizer;

fn main() {
    let mut suite = Suite::new("data pipeline");
    suite.header();

    let mut gen = CorpusGenerator::new(CorpusConfig::default(), 1);
    let r = suite.bench("corpus: 10k-word document", || {
        std::hint::black_box(gen.document(10_000));
    });
    println!("    → {:.0} words/s", r.rate(10_000.0));

    let sample = CorpusGenerator::new(CorpusConfig::default(), 2).document(20_000);
    suite.bench("tokenizer: train vocab=512 on 20k words", || {
        std::hint::black_box(Tokenizer::train(&sample, 512));
    });

    let tok = Tokenizer::train(&sample, 512);
    let text = CorpusGenerator::new(CorpusConfig::default(), 3).document(10_000);
    let r = suite.bench("tokenizer: encode 10k words", || {
        std::hint::black_box(tok.encode(&text));
    });
    println!("    → {:.0} words/s", r.rate(10_000.0));

    let mut it = BatchIterator::from_seed(512, 8, 128, 4);
    let r = suite.bench("batcher: 8×128 packed batch", || {
        std::hint::black_box(it.next_batch());
    });
    println!("    → {:.0} tok/s", r.rate(1024.0));

    let pipe = BatchPipeline::spawn(BatchIterator::from_seed(512, 8, 128, 5), 4);
    let r = suite.bench("prefetch pipeline: next()", || {
        std::hint::black_box(pipe.next());
    });
    println!("    → {:.0} tok/s (prefetched)", r.rate(1024.0));
}
