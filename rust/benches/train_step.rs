//! Bench: training-step latency, two tiers.
//!
//! 1. **Native QKV projection-step twin** (always runs, no artifacts):
//!    fwd `x@W` + PAMM compress + approx-dW apply at a paper-like shape,
//!    swept over 1/2/4/N threads on a shared `poolx::Pool`. Persists to
//!    `benchmarks/BENCH_train_step.json` for the perf trail.
//! 2. **Full PJRT step** — baseline vs PAMM vs PAMM-Pallas and the DDP
//!    grad/apply split (source data for Table 2a/2b). Requires
//!    `make artifacts`; skipped with a note when absent.
//!
//! Run: `cargo bench --bench train_step` (PAMM_BENCH_QUICK=1 for CI).

use std::time::Duration;

use pamm::benchx::{thread_sweep, BenchOpts, BenchSink, Suite};
use pamm::coordinator::session::TrainSession;
use pamm::data::batcher::BatchIterator;
use pamm::pamm as pammc;
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::runtime::Engine;
use pamm::tensor::Mat;

fn native_opts() -> BenchOpts {
    BenchOpts::quick_or(BenchOpts {
        warmup_iters: 1,
        min_iters: 5,
        max_iters: 20,
        max_total: Duration::from_secs(10),
    })
}

/// Native twin of one QKV projection training step: forward `x@W`,
/// compress of the projection input, approx dW via apply — all three
/// contractions on the `tensor::kernels` microkernel GEMM (the header
/// prints the active SIMD dispatch level; steady-state iterations reuse
/// the per-worker kernel workspace, so this loop allocates no scratch).
fn native_sweep(sink: &mut BenchSink) {
    println!("train_step: GEMM dispatch = {}", pamm::tensor::kernels::active().name());
    let (b, n, m, k) = (4096usize, 512usize, 512usize, 16usize);
    let shape_s = format!("b={b} n={n} m={m} k={k}");
    let mut rng = Xoshiro256::new(0x7AB7E);
    let a = Mat::random_normal(b, n, 1.0, &mut rng);
    let w = Mat::random_normal(n, m, 0.05, &mut rng);
    let dz = Mat::random_normal(b, m, 1.0, &mut rng);
    let idx = pammc::sample_generators(&mut rng, b, k);

    let sweep = thread_sweep();

    let mut suite = Suite::with_opts(&format!("train_step native qkv twin {shape_s}"), native_opts());
    suite.header();
    for &t in &sweep {
        let pool = Pool::new(t);
        let r = suite
            .bench(&format!("qkv_step t={t}"), || {
                let z = a.matmul_with(&w, &pool);
                let comp = pammc::compress_with(&a, &idx, Eps::Inf, &pool);
                let dw = pammc::apply_with(&comp, &dz, &pool);
                std::hint::black_box((z, dw));
            })
            .clone();
        sink.record("qkv_step", &shape_s, t, &r);
    }
    if let Some(sp) = suite.ratio("qkv_step t=4", "qkv_step t=1") {
        println!("  qkv_step: 4-thread speedup {sp:.2}x");
    }
}

fn pjrt_steps(engine: &Engine) -> anyhow::Result<()> {
    let mut suite = Suite::new("train_step (nano 4x64)");
    suite.header();

    for name in ["train_nano_baseline_4x64", "train_nano_pamm64_4x64", "train_nano_pamm64pl_4x64"] {
        if engine.meta(name).is_err() {
            println!("  (skipping {name}: not in manifest)");
            continue;
        }
        let mut session = TrainSession::new(engine, name, None, 7)?;
        let mut it = BatchIterator::from_seed(256, 4, 64, 7);
        let batches: Vec<_> = (0..4).map(|_| it.next_batch().to_tensor()).collect();
        let mut i = 0;
        let r = suite.bench(name, || {
            session.step(&batches[i % 4]).expect("step");
            i += 1;
        });
        println!("    -> {:.0} tok/s", r.rate(256.0));
    }

    if let Some(deg) = suite.ratio("train_nano_baseline_4x64", "train_nano_pamm64_4x64") {
        println!("\n  PAMM step-time overhead vs baseline: {:.1}%", (deg - 1.0) * 100.0);
    }

    // Larger config if the full artifact set is present.
    if engine.meta("train_tiny_baseline_8x128").is_ok() {
        let mut suite2 = Suite::new("train_step (tiny 8x128)");
        suite2.header();
        for name in ["train_tiny_baseline_8x128", "train_tiny_pamm512_8x128"] {
            let mut session = TrainSession::new(engine, name, None, 7)?;
            let vocab = engine.manifest.config("tiny").unwrap().vocab;
            let mut it = BatchIterator::from_seed(vocab, 8, 128, 7);
            let batches: Vec<_> = (0..4).map(|_| it.next_batch().to_tensor()).collect();
            let mut i = 0;
            let r = suite2.bench(name, || {
                session.step(&batches[i % 4]).expect("step");
                i += 1;
            });
            println!("    -> {:.0} tok/s", r.rate(1024.0));
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut sink = BenchSink::new("train_step");
    native_sweep(&mut sink);
    match sink.flush() {
        Ok(path) => println!("  persisted {} entries to {}", sink.entries().len(), path.display()),
        Err(e) => eprintln!("  bench persistence failed: {e}"),
    }

    match Engine::load("artifacts") {
        Ok(engine) => pjrt_steps(&engine)?,
        Err(e) => println!("\n(skipping PJRT train_step suites: {e:#})"),
    }
    Ok(())
}
