//! Bench: full training-step latency through the PJRT stack — baseline vs
//! PAMM vs PAMM-Pallas and the DDP grad/apply split (source data for
//! Table 2a/2b). Requires `make artifacts`.
//!
//! Run: `cargo bench --bench train_step` (PAMM_BENCH_QUICK=1 for CI).

use pamm::benchx::Suite;
use pamm::coordinator::session::TrainSession;
use pamm::data::batcher::BatchIterator;
use pamm::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load("artifacts")?;
    let mut suite = Suite::new("train_step (nano 4×64)");
    suite.header();

    for name in ["train_nano_baseline_4x64", "train_nano_pamm64_4x64", "train_nano_pamm64pl_4x64"] {
        if engine.meta(name).is_err() {
            println!("  (skipping {name}: not in manifest)");
            continue;
        }
        let mut session = TrainSession::new(&engine, name, None, 7)?;
        let mut it = BatchIterator::from_seed(256, 4, 64, 7);
        let batches: Vec<_> = (0..4).map(|_| it.next_batch().to_tensor()).collect();
        let mut i = 0;
        let r = suite.bench(name, || {
            session.step(&batches[i % 4]).expect("step");
            i += 1;
        });
        println!("    → {:.0} tok/s", r.rate(256.0));
    }

    if let Some(deg) = suite.ratio("train_nano_baseline_4x64", "train_nano_pamm64_4x64") {
        println!("\n  PAMM step-time overhead vs baseline: {:.1}%", (deg - 1.0) * 100.0);
    }

    // Larger config if the full artifact set is present.
    if engine.meta("train_tiny_baseline_8x128").is_ok() {
        let mut suite2 = Suite::new("train_step (tiny 8×128)");
        suite2.header();
        for name in ["train_tiny_baseline_8x128", "train_tiny_pamm512_8x128"] {
            let mut session = TrainSession::new(&engine, name, None, 7)?;
            let vocab = engine.manifest.config("tiny").unwrap().vocab;
            let mut it = BatchIterator::from_seed(vocab, 8, 128, 7);
            let batches: Vec<_> = (0..4).map(|_| it.next_batch().to_tensor()).collect();
            let mut i = 0;
            let r = suite2.bench(name, || {
                session.step(&batches[i % 4]).expect("step");
                i += 1;
            });
            println!("    → {:.0} tok/s", r.rate(1024.0));
        }
    }
    Ok(())
}
