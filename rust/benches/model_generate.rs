//! Bench: native generation with the PAMM-compressed KV cache —
//! prefill, end-to-end greedy decode, and the continuous-batching
//! serve loop, per dispatch level × thread count. The acceptance trail
//! for the generation subsystem: `benchmarks/BENCH_model_generate.json`
//! → BENCHMARKS.md §model_generate.
//!
//! Ops are dispatch-tagged via `kernels::force` (the sanctioned bench
//! use — single process, rows run serially). GFLOP/s uses the standard
//! parameter-flop model `2·N` per processed token with
//! `N = LmConfig::param_count()` — comparability figures, not absolute
//! kernel throughput (the kernel suites carry those). Prefill/decode
//! rows are annotated with the session's EXACT compressed-vs-dense
//! KV-cache savings (`saved_bytes` column):
//! `dense_kv_cache_bytes - kv_cache_bytes` at the effective k and the
//! session capacity — the inference twin of the training ledger's
//! headline quantity.
//!
//! Run: `cargo bench --bench model_generate` (PAMM_BENCH_QUICK=1 for
//! CI); render with `pamm bench-report`.

use std::time::Duration;

use pamm::benchx::{BenchOpts, BenchSink, Suite};
use pamm::coordinator::{scripted_load, serve, ServeConfig};
use pamm::generate::{self, Decoder, GenConfig};
use pamm::memory::fmt_bytes;
use pamm::model::{LmConfig, TransformerLM};
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::{self, Dispatch};

fn opts() -> BenchOpts {
    if std::env::var("PAMM_BENCH_QUICK").is_ok() {
        BenchOpts { warmup_iters: 0, min_iters: 1, max_iters: 3, max_total: Duration::from_secs(2) }
    } else {
        BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            max_total: Duration::from_secs(12),
        }
    }
}

fn main() {
    // Same block geometry as model_train (heads=4, d=16 → d_model 64,
    // d_ff 256, vocab 256) so the two suites read side by side.
    let cfg = LmConfig { vocab: 256, n_layers: 2, heads: 4, head_dim: 16, d_ff: 256 };
    let (prompt_len, n_new) = (128usize, 32usize);
    let max_tokens = prompt_len + n_new;
    let k = prompt_len / 16; // r = 1/16 over the prompt domain
    let native = Dispatch::native();
    let threads: &[usize] = &[1, 2, 4];
    let mut sink = BenchSink::new("model_generate");

    let n_params = cfg.param_count() as f64;
    let prefill_flops = 2.0 * n_params * prompt_len as f64;
    let e2e_flops = 2.0 * n_params * max_tokens as f64;
    let saved =
        generate::dense_kv_cache_bytes(&cfg, max_tokens) - generate::kv_cache_bytes(&cfg, k, max_tokens);

    let model = TransformerLM::new(cfg.clone(), 11);
    let mut rng = Xoshiro256::new(23);
    let prompt: Vec<i32> =
        (0..prompt_len).map(|_| rng.next_below(cfg.vocab as u64) as i32).collect();

    let shape_s = format!(
        "L={} dm={} ff={} prompt={prompt_len} new={n_new} k={k}",
        cfg.n_layers,
        cfg.d_model(),
        cfg.d_ff
    );
    println!("model_generate: native dispatch = {}", native.name());
    println!(
        "  per-session KV cache: compressed {} vs dense {} (saves {})",
        fmt_bytes(generate::kv_cache_bytes(&cfg, k, max_tokens)),
        fmt_bytes(generate::dense_kv_cache_bytes(&cfg, max_tokens)),
        fmt_bytes(saved)
    );

    let mut suite = Suite::with_opts(&format!("model_generate {shape_s}"), opts());
    suite.header();

    let mut plan: Vec<(Dispatch, usize)> = vec![(Dispatch::Scalar, 1)];
    if native != Dispatch::Scalar {
        plan.extend(threads.iter().map(|&t| (native, t)));
    }
    for &(disp, t) in &plan {
        kernels::force(Some(disp));
        let tag = disp.name();
        let pool = Pool::new(t);
        let gcfg = GenConfig::new(k, Eps::Inf, 5, max_tokens);

        // Prefill: batch-compress the prompt, build every layer cache.
        let r = suite
            .bench(&format!("gen_prefill[{tag}] t={t}"), || {
                let mut dec = Decoder::new(&model, gcfg);
                std::hint::black_box(dec.prefill(&prompt, &pool)[0]);
            })
            .clone();
        sink.record_flops(&format!("gen_prefill[{tag}]"), &shape_s, t, &r, prefill_flops);
        sink.annotate_saved_bytes(saved);

        // End to end: prefill + greedy decode of n_new folded tokens.
        let r = suite
            .bench(&format!("gen_e2e[{tag}] t={t}"), || {
                let mut dec = Decoder::new(&model, gcfg);
                dec.prefill(&prompt, &pool);
                std::hint::black_box(dec.generate(n_new, &pool));
            })
            .clone();
        sink.record_flops(&format!("gen_e2e[{tag}]"), &shape_s, t, &r, e2e_flops);
        sink.annotate_saved_bytes(saved);
        println!("    -> {:.0} tok/s end-to-end", r.rate(max_tokens as f64));

        // Serve loop: 8 scripted requests through continuous batching.
        let reqs = scripted_load(8, cfg.vocab, 7);
        let scfg = ServeConfig::new(4, 4, Eps::Inf, 13);
        let served_tokens: usize = reqs.iter().map(|r| r.max_new).sum();
        let r = suite
            .bench(&format!("serve[{tag}] t={t}"), || {
                std::hint::black_box(serve(&model, &scfg, &reqs, &pool).unwrap().steps);
            })
            .clone();
        sink.record(&format!("serve[{tag}]"), &format!("{shape_s} reqs=8"), t, &r);
        println!("    -> {:.0} served tok/s", r.rate(served_tokens as f64));
    }
    kernels::force(None);

    if let Some(sp) =
        suite.ratio(&format!("gen_e2e[{}] t=1", native.name()), "gen_e2e[scalar] t=1")
    {
        println!("  decode vs scalar (single thread, {}): {sp:.2}x", native.name());
    }

    match sink.flush() {
        Ok(path) => {
            println!("\npersisted {} entries to {}", sink.entries().len(), path.display())
        }
        Err(e) => eprintln!("bench persistence failed: {e}"),
    }
}
