//! Bench: native PAMM ops vs exact matmul across the paper's shape
//! ladder, swept over 1/2/4/N worker threads on a shared `poolx::Pool`
//! (source data for Tables 7/8, the App. J speedup model γ, and the
//! committed perf trajectory in `benchmarks/BENCH_pamm_ops.json` →
//! BENCHMARKS.md).
//!
//! Run: `cargo bench --bench pamm_ops` (PAMM_BENCH_QUICK=1 for CI).
//! Persists entries via `benchx::BenchSink` (dir: PAMM_BENCH_DIR,
//! default `benchmarks/`); render with `pamm bench-report`.
//!
//! All three ops route through the `tensor::kernels` microkernel GEMM
//! (compress = Gram pass + sweep, apply/exact = packed `AᵀB`), so
//! numbers depend on the SIMD dispatch level — the header prints which
//! one ran (also `pamm kernels --probe`); `PAMM_SIMD=scalar` pins the
//! portable baseline. The isolated kernel sweep lives in the
//! `tensor_kernels` suite.

use std::time::Duration;

use pamm::benchx::{thread_sweep, BenchOpts, BenchSink, Suite};
use pamm::pamm as pammc;
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels;
use pamm::tensor::Mat;

fn opts() -> BenchOpts {
    // The 2048² matmul_tn runs seconds per iter single-threaded; keep
    // the sweep bounded while still getting a stable median.
    BenchOpts::quick_or(BenchOpts {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: 15,
        max_total: Duration::from_secs(15),
    })
}

fn main() {
    let shapes: &[(usize, usize, usize, usize)] = &[
        // (b, n, m, k) — paper-like per-GPU shapes scaled to CPU budget;
        // the 2048² row is the acceptance shape for the 4-thread speedup.
        (1024, 128, 128, 8),
        (4096, 256, 256, 32),
        (2048, 2048, 2048, 32),
    ];
    let sweep = thread_sweep();
    let mut sink = BenchSink::new("pamm_ops");
    println!("pamm_ops: GEMM dispatch = {}", kernels::active().name());

    for &(b, n, m, k) in shapes {
        let shape_s = format!("b={b} n={n} m={m} k={k}");
        let mut rng = Xoshiro256::new(1);
        let a = Mat::random_normal(b, n, 1.0, &mut rng);
        let dz = Mat::random_normal(b, m, 1.0, &mut rng);
        let idx = pammc::sample_generators(&mut rng, b, k);

        let mut suite = Suite::with_opts(&format!("pamm_ops {shape_s}"), opts());
        suite.header();

        for &t in &sweep {
            let pool = Pool::new(t);
            let comp = pammc::compress_with(&a, &idx, Eps::Inf, &pool);

            let r = suite
                .bench(&format!("matmul_tn (exact dW) t={t}"), || {
                    std::hint::black_box(pammc::exact_matmul_with(&a, &dz, &pool));
                })
                .clone();
            sink.record("matmul_tn", &shape_s, t, &r);

            let r = suite
                .bench(&format!("pamm compress t={t}"), || {
                    std::hint::black_box(pammc::compress_with(&a, &idx, Eps::Inf, &pool));
                })
                .clone();
            sink.record("compress", &shape_s, t, &r);

            let r = suite
                .bench(&format!("pamm apply (approx dW) t={t}"), || {
                    std::hint::black_box(pammc::apply_with(&comp, &dz, &pool));
                })
                .clone();
            sink.record("apply", &shape_s, t, &r);
        }

        for op in ["matmul_tn (exact dW)", "pamm compress", "pamm apply (approx dW)"] {
            // ratio(a, b) = median(b)/median(a) → t=1 time over t=4 time.
            if let Some(sp) = suite.ratio(&format!("{op} t=4"), &format!("{op} t=1")) {
                println!("  {op}: 4-thread speedup {sp:.2}x");
            }
        }
        let gamma = (b * m) as f64 / (k * (b + m)) as f64;
        if let Some(speedup) =
            suite.ratio("pamm apply (approx dW) t=1", "matmul_tn (exact dW) t=1")
        {
            println!("  apply speedup over exact (serial): {speedup:.1}x  (App. J model γ = {gamma:.1})");
        }
    }

    match sink.flush() {
        Ok(path) => println!("\npersisted {} entries to {}", sink.entries().len(), path.display()),
        Err(e) => eprintln!("bench persistence failed: {e}"),
    }
}
