//! Bench: native PAMM ops vs exact matmul across the paper's shape ladder
//! (source data for Tables 7/8 and the App. J speedup model γ).
//!
//! Run: `cargo bench --bench pamm_ops` (PAMM_BENCH_QUICK=1 for CI).

use pamm::benchx::Suite;
use pamm::pamm as pammc;
use pamm::pamm::Eps;
use pamm::rngx::Xoshiro256;
use pamm::tensor::Mat;

fn main() {
    let shapes: &[(usize, usize, usize, usize)] = &[
        // (b, n, m, k) — paper-like per-GPU shapes scaled to CPU budget
        (1024, 128, 128, 2),
        (1024, 128, 128, 8),
        (4096, 256, 256, 8),
        (4096, 256, 256, 32),
        (8192, 512, 512, 16),
    ];
    for &(b, n, m, k) in shapes {
        let mut rng = Xoshiro256::new(1);
        let a = Mat::random_normal(b, n, 1.0, &mut rng);
        let dz = Mat::random_normal(b, m, 1.0, &mut rng);
        let idx = pammc::sample_generators(&mut rng, b, k);
        let comp = pammc::compress(&a, &idx, Eps::Inf);

        let mut suite = Suite::new(&format!("pamm_ops b={b} n={n} m={m} k={k}"));
        suite.header();
        suite.bench("exact dW = XᵀdZ", || {
            std::hint::black_box(pammc::exact_matmul(&a, &dz));
        });
        suite.bench("pamm compress", || {
            std::hint::black_box(pammc::compress(&a, &idx, Eps::Inf));
        });
        suite.bench("pamm apply (approx dW)", || {
            std::hint::black_box(pammc::apply(&comp, &dz));
        });
        suite.bench("pamm compress+apply", || {
            let c = pammc::compress(&a, &idx, Eps::Inf);
            std::hint::black_box(pammc::apply(&c, &dz));
        });
        let gamma = (b * m) as f64 / (k * (b + m)) as f64;
        if let Some(speedup) = suite.ratio("pamm apply (approx dW)", "exact dW = XᵀdZ") {
            println!("  apply speedup over exact: {speedup:.1}×  (App. J model γ = {gamma:.1})");
        }
    }
}
