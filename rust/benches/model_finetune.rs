//! Bench: native fine-tuning — the full classification train step
//! (trunk fwd + mean pool + head + label xent + tape backward + Adam)
//! and the pure dev evaluation pass, per dispatch level × thread
//! count. The acceptance trail for the quality loop (P17):
//! `benchmarks/BENCH_model_finetune.json` → BENCHMARKS.md
//! §model_finetune.
//!
//! GFLOP/s uses the standard parameter-flop model over the LM trunk +
//! head: step ≈ `6·N·tokens`, eval forward ≈ `2·N·tokens` with
//! `N = LmConfig::param_count() + d_model·n_classes` — comparable to
//! the `model_train` rows, not absolute kernel throughput. Step rows
//! are annotated with the tape's EXACT saved-for-backward bytes: the
//! classification tail adds only the pooled activations on top of the
//! compressed trunk.
//!
//! Run: `cargo bench --bench model_finetune` (PAMM_BENCH_QUICK=1 for
//! CI); render with `pamm bench-report`.

use std::time::Duration;

use pamm::benchx::{BenchOpts, BenchSink, Suite};
use pamm::coordinator::{find_task, FtTrainer, NativeOpt};
use pamm::data::glue::{LabeledStream, TaskCorpus};
use pamm::memory::fmt_bytes;
use pamm::model::LmConfig;
use pamm::poolx::Pool;
use pamm::tensor::kernels::Dispatch;

fn opts() -> BenchOpts {
    if std::env::var("PAMM_BENCH_QUICK").is_ok() {
        BenchOpts { warmup_iters: 0, min_iters: 1, max_iters: 3, max_total: Duration::from_secs(2) }
    } else {
        BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            max_total: Duration::from_secs(12),
        }
    }
}

fn main() {
    // One fine-tuning shape: 2-block trunk (heads=4, d=16 → d_model
    // 64, d_ff 256), SST2 stand-in, k = tokens/16.
    let cfg = LmConfig { vocab: 256, n_layers: 2, heads: 4, head_dim: 16, d_ff: 256 };
    let task = find_task("SST2").expect("SST2 is a known task");
    let (batch, seq) = (4usize, 64usize);
    let tokens = batch * seq;
    let k = tokens / 16;
    let native = Dispatch::native();
    let threads: &[usize] = &[1, 2, 4];
    let mut sink = BenchSink::new("model_finetune");

    let n_params = cfg.param_count() as f64 + (cfg.d_model() * task.n_classes) as f64;
    let step_flops = 6.0 * n_params * tokens as f64;
    let shape_s = format!(
        "task={} L={} b={batch} l={seq} dm={} ff={} k={k}",
        task.name, cfg.n_layers, cfg.d_model(), cfg.d_ff
    );

    println!("model_finetune: native dispatch = {}", native.name());

    let corpus = TaskCorpus::synthetic(task.clone(), cfg.vocab, seq, 64, 7);
    let dev = TaskCorpus::synthetic(task.clone(), cfg.vocab, seq, 32, 9);
    let lb = LabeledStream::new(corpus, batch, 7).next_batch();
    let eval_tokens = (dev.examples.len() / batch) * batch * seq;
    let eval_flops = 2.0 * n_params * eval_tokens as f64;

    let mut suite = Suite::with_opts(&format!("model_finetune {shape_s}"), opts());
    suite.header();

    let mut plan: Vec<(Dispatch, usize)> = vec![(Dispatch::Scalar, 1)];
    if native != Dispatch::Scalar {
        plan.extend(threads.iter().map(|&t| (native, t)));
    }
    for &(disp, t) in &plan {
        let tag = disp.name();
        let pool = Pool::new(t);

        // Full fine-tune step: classify fwd + label xent + backward + Adam.
        let mut trainer =
            FtTrainer::new(cfg.clone(), task.clone(), batch, seq, k, NativeOpt::adam(2e-3), 11);
        let r = suite
            .bench(&format!("ft_step[{tag}] t={t}"), || {
                std::hint::black_box(
                    trainer.step_report(disp, &lb, &pool, None).expect("bench step").loss,
                );
            })
            .clone();
        sink.record_flops(&format!("ft_step[{tag}]"), &shape_s, t, &r, step_flops);
        let rep = trainer.step_report(disp, &lb, &pool, None).expect("saved-bytes probe");
        sink.annotate_saved_bytes(rep.saved_bytes);
        println!(
            "    -> {:.0} tok/s, saved/backward {}",
            r.rate(tokens as f64),
            fmt_bytes(rep.saved_bytes)
        );

        // Dev evaluation: pure forward over the dev corpus.
        let eval_trainer =
            FtTrainer::new(cfg.clone(), task.clone(), batch, seq, k, NativeOpt::adam(2e-3), 11);
        let r = suite
            .bench(&format!("ft_eval[{tag}] t={t}"), || {
                std::hint::black_box(eval_trainer.evaluate(&dev, &pool).hits);
            })
            .clone();
        sink.record_flops(&format!("ft_eval[{tag}]"), &shape_s, t, &r, eval_flops);
    }

    if let Some(sp) =
        suite.ratio(&format!("ft_step[{}] t=1", native.name()), "ft_step[scalar] t=1")
    {
        println!("  step vs scalar (single thread, {}): {sp:.2}x", native.name());
    }

    match sink.flush() {
        Ok(path) => {
            println!("\npersisted {} entries to {}", sink.entries().len(), path.display())
        }
        Err(e) => eprintln!("bench persistence failed: {e}"),
    }
}
