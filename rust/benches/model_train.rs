//! Bench: whole-model native LM pretraining — the forward (tape
//! build) and the full train step (fwd + xent + tape backward + Adam)
//! per layer count × dispatch level × thread count. The acceptance
//! trail for the multi-layer tape: `benchmarks/BENCH_model_train.json`
//! → BENCHMARKS.md §model_train.
//!
//! Ops are dispatch-tagged (`lm_fwd[avx2]`, `lm_step[scalar]`, …) via
//! the explicit-dispatch entry points. GFLOP/s uses the standard
//! parameter-flop model: forward ≈ `2·N·tokens`, full step ≈
//! `6·N·tokens` with `N = LmConfig::param_count()` (attention terms are
//! second-order at these shapes — the figures are for cross-layer-count
//! comparability, not absolute kernel throughput; the kernel suites
//! carry those). Forward rows are annotated with the tape's EXACT
//! saved-for-backward bytes (`saved_bytes` column) — the whole-model
//! version of the paper's headline quantity, growing with the layer
//! count while every block's projection activations stay compressed.
//!
//! Run: `cargo bench --bench model_train` (PAMM_BENCH_QUICK=1 for CI);
//! render with `pamm bench-report`.

use std::time::Duration;

use pamm::benchx::{BenchOpts, BenchSink, Suite};
use pamm::coordinator::{LmTrainer, NativeOpt};
use pamm::data::batcher::BatchIterator;
use pamm::memory::fmt_bytes;
use pamm::model::{self, LmConfig, TransformerLM};
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::Dispatch;

fn opts() -> BenchOpts {
    if std::env::var("PAMM_BENCH_QUICK").is_ok() {
        BenchOpts { warmup_iters: 0, min_iters: 1, max_iters: 3, max_total: Duration::from_secs(2) }
    } else {
        BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            max_total: Duration::from_secs(12),
        }
    }
}

fn main() {
    // Layer-count sweep at a fixed block geometry (heads=4, d=16 →
    // d_model 64, d_ff 256, vocab 256), k = tokens/16.
    let layer_counts: &[usize] = &[2, 4];
    let (batch, seq) = (2usize, 128usize);
    let tokens = batch * seq;
    let k = tokens / 16;
    let native = Dispatch::native();
    let threads: &[usize] = &[1, 2, 4];
    let mut sink = BenchSink::new("model_train");

    println!("model_train: native dispatch = {}", native.name());

    for &layers in layer_counts {
        let cfg = LmConfig { vocab: 256, n_layers: layers, heads: 4, head_dim: 16, d_ff: 256 };
        let shape_s = format!("L={layers} b={batch} l={seq} dm={} ff={} k={k}", cfg.d_model(), cfg.d_ff);
        let n_params = cfg.param_count() as f64;
        let fwd_flops = 2.0 * n_params * tokens as f64;
        let step_flops = 6.0 * n_params * tokens as f64;

        let mut it = BatchIterator::from_seed(cfg.vocab, batch, seq, 7);
        let tok_block = it.next_batch().tokens;
        let mut inputs = Vec::with_capacity(tokens);
        let mut targets = Vec::with_capacity(tokens);
        for r in 0..batch {
            let row = &tok_block[r * (seq + 1)..(r + 1) * (seq + 1)];
            inputs.extend_from_slice(&row[..seq]);
            targets.extend_from_slice(&row[1..]);
        }

        let mut suite = Suite::with_opts(&format!("model_train {shape_s}"), opts());
        suite.header();

        let mut plan: Vec<(Dispatch, usize)> = vec![(Dispatch::Scalar, 1)];
        if native != Dispatch::Scalar {
            plan.extend(threads.iter().map(|&t| (native, t)));
        }
        for &(disp, t) in &plan {
            let tag = disp.name();
            let pool = Pool::new(t);
            let m = TransformerLM::new(cfg.clone(), 11);

            // Forward + tape build (the saved-for-backward producer).
            let mut rng_f = Xoshiro256::new(21);
            let r = suite
                .bench(&format!("lm_fwd[{tag}] t={t}"), || {
                    std::hint::black_box(m.forward(
                        disp, &inputs, &targets, batch, seq, k, Eps::Inf, &mut rng_f, &pool,
                        None,
                    ));
                })
                .clone();
            sink.record_flops(&format!("lm_fwd[{tag}]"), &shape_s, t, &r, fwd_flops);
            let mut rng_s = Xoshiro256::new(21);
            let (_, tape) = m.forward(
                disp, &inputs, &targets, batch, seq, k, Eps::Inf, &mut rng_s, &pool, None,
            );
            sink.annotate_saved_bytes(tape.saved_bytes());

            // Full train step: fwd + xent + tape backward + Adam.
            let mut trainer =
                LmTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(1e-3), 11);
            let r = suite
                .bench(&format!("lm_step[{tag}] t={t}"), || {
                    std::hint::black_box(
                        trainer.step_report(disp, &tok_block, &pool, None).expect("bench step").loss,
                    );
                })
                .clone();
            sink.record_flops(&format!("lm_step[{tag}]"), &shape_s, t, &r, step_flops);
            println!("    -> {:.0} tok/s", r.rate(tokens as f64));
        }

        if let Some(sp) =
            suite.ratio(&format!("lm_step[{}] t=1", native.name()), "lm_step[scalar] t=1")
        {
            println!("  step vs scalar (single thread, {}): {sp:.2}x", native.name());
        }
        let m = TransformerLM::new(cfg.clone(), 11);
        let shape = m.shape_for(batch, seq);
        println!(
            "  dense saved-for-backward baseline: {} over {layers} layers ({} per block) — what the tape never keeps",
            fmt_bytes(model::dense_model_saved_bytes(&cfg, &shape)),
            fmt_bytes(model::dense_block_saved_bytes(&cfg, &shape)),
        );
    }

    match sink.flush() {
        Ok(path) => {
            println!("\npersisted {} entries to {}", sink.entries().len(), path.display())
        }
        Err(e) => eprintln!("bench persistence failed: {e}"),
    }
}
