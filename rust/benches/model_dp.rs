//! Bench: native data-parallel fleet training — one full DP optimizer
//! step (R workers × fwd + xent + tape backward, fixed rank-order
//! all-reduce, Adam) per worker count × dispatch level × thread count.
//! The acceptance trail for `coordinator::dp`:
//! `benchmarks/BENCH_model_dp.json` → BENCHMARKS.md §model_dp.
//!
//! Ops are dispatch-tagged (`dp_step[avx2] w2`, …). GFLOP/s uses the
//! standard parameter-flop model per microbatch, `6·N·tokens` with
//! `N = LmConfig::param_count()`, scaled by the E = R·A microbatches a
//! fleet step consumes — the figures compare worker counts against the
//! R=1 row (which is bit-identical to the single-process trainer), not
//! absolute kernel throughput. Every row is annotated with the fleet's
//! aggregate saved-for-backward bytes (`saved_bytes` column): the
//! paper's headline quantity scales with E while the ranks reduce in
//! fixed order on one pool, so transient peaks stay per-microbatch.
//!
//! Run: `cargo bench --bench model_dp` (PAMM_BENCH_QUICK=1 for CI);
//! render with `pamm bench-report`.

use std::time::Duration;

use pamm::benchx::{BenchOpts, BenchSink, Suite};
use pamm::coordinator::{DpTrainer, NativeOpt};
use pamm::memory::fmt_bytes;
use pamm::model::LmConfig;
use pamm::poolx::Pool;
use pamm::tensor::kernels::Dispatch;

fn opts() -> BenchOpts {
    if std::env::var("PAMM_BENCH_QUICK").is_ok() {
        BenchOpts { warmup_iters: 0, min_iters: 1, max_iters: 3, max_total: Duration::from_secs(2) }
    } else {
        BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            max_total: Duration::from_secs(12),
        }
    }
}

fn main() {
    // Worker-count sweep at a fixed block geometry (2 layers, heads=4,
    // d=16 → d_model 64, d_ff 256, vocab 256), one 1×128 microbatch
    // per rank per step, k = tokens/16.
    let worker_counts: &[usize] = &[1, 2, 4];
    let (batch, seq) = (1usize, 128usize);
    let tokens = batch * seq;
    let k = tokens / 16;
    let cfg = LmConfig { vocab: 256, n_layers: 2, heads: 4, head_dim: 16, d_ff: 256 };
    let native = Dispatch::native();
    let threads: &[usize] = &[1, 2, 4];
    let mut sink = BenchSink::new("model_dp");

    println!("model_dp: native dispatch = {}", native.name());

    for &workers in worker_counts {
        let shape_s = format!(
            "R={workers} A=1 b={batch} l={seq} L={} dm={} ff={} k={k}",
            cfg.n_layers,
            cfg.d_model(),
            cfg.d_ff
        );
        let n_params = cfg.param_count() as f64;
        // E microbatches of `6·N·tokens` per fleet step.
        let step_flops = 6.0 * n_params * tokens as f64 * workers as f64;

        let mut suite = Suite::with_opts(&format!("model_dp {shape_s}"), opts());
        suite.header();

        let mut plan: Vec<(Dispatch, usize)> = vec![(Dispatch::Scalar, 1)];
        if native != Dispatch::Scalar {
            plan.extend(threads.iter().map(|&t| (native, t)));
        }
        let mut fleet_saved = 0usize;
        for &(disp, t) in &plan {
            let tag = disp.name();
            let pool = Pool::new(t);
            let mut trainer =
                DpTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(1e-3), 11, workers, 1);
            let r = suite
                .bench(&format!("dp_step[{tag}] w{workers} t={t}"), || {
                    std::hint::black_box(
                        trainer.step_report(disp, &pool, None).expect("bench step").loss,
                    );
                })
                .clone();
            sink.record_flops(&format!("dp_step[{tag}]"), &shape_s, t, &r, step_flops);
            // Aggregate saved-for-backward of one fleet step (exact,
            // from the tape inventory — identical at every dispatch).
            let mut probe =
                DpTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(1e-3), 11, workers, 1);
            let rep = probe.step_report(disp, &pool, None).expect("probe step");
            sink.annotate_saved_bytes(rep.saved_bytes);
            fleet_saved = rep.saved_bytes;
            println!("    -> {:.0} tok/s", r.rate((tokens * workers) as f64));
        }

        if let Some(sp) = suite.ratio(
            &format!("dp_step[{}] w{workers} t=1", native.name()),
            &format!("dp_step[scalar] w{workers} t=1"),
        ) {
            println!("  fleet step vs scalar (single thread, {}): {sp:.2}x", native.name());
        }
        println!(
            "  aggregate saved-for-backward: {} across E={workers} microbatches (per-rank {})",
            fmt_bytes(fleet_saved),
            fmt_bytes(fleet_saved / workers.max(1)),
        );
    }

    match sink.flush() {
        Ok(path) => {
            println!("\npersisted {} entries to {}", sink.entries().len(), path.display())
        }
        Err(e) => eprintln!("bench persistence failed: {e}"),
    }
}
