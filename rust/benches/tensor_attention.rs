//! Bench: the native attention subsystem — naive materialized-scores
//! baseline vs the flash tile walk vs the PAMM-fused path — per shape ×
//! dispatch level × thread count (the acceptance trail for the
//! attention subsystem: `benchmarks/BENCH_tensor_attention.json` →
//! BENCHMARKS.md §tensor_attention).
//!
//! Ops are dispatch-tagged (`flash[avx2]`, `flash[avx2fma]`,
//! `fused_pamm[scalar]`, …) via explicit-dispatch entry points
//! (`flash_attention_on`, `attend_compressed_on`), so no process-global
//! `kernels::force` state is involved; the FMA fast tier, when the host
//! has it, is swept alongside the bit-exact native level. Entries carry GFLOP/s (`AttnShape::flops`, causal),
//! and the fused rows attach their **measured** peak transient bytes
//! (`memory::MemoryTracker`) — each (level, threads) cell runs on a
//! fresh pool so the cold per-worker scratch growth is what gets
//! measured. `benchx` resolves speedup-vs-serial and speedup-vs-scalar
//! at flush, as with the `tensor_kernels` suite.
//!
//! Run: `cargo bench --bench tensor_attention` (PAMM_BENCH_QUICK=1 for
//! CI); render with `pamm bench-report`.

use std::time::Duration;

use pamm::attention::{self, AttnShape};
use pamm::benchx::{BenchOpts, BenchSink, Suite};
use pamm::memory::{fmt_bytes, MemoryTracker};
use pamm::pamm as pammc;
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::Dispatch;
use pamm::tensor::Mat;

fn opts() -> BenchOpts {
    if std::env::var("PAMM_BENCH_QUICK").is_ok() {
        BenchOpts { warmup_iters: 0, min_iters: 1, max_iters: 5, max_total: Duration::from_secs(2) }
    } else {
        BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 12,
            max_total: Duration::from_secs(10),
        }
    }
}

fn main() {
    // (batch, heads, seq, head_dim, generators k) — causal, the LM hot
    // path; seq sweeps across the Br/Bc tile boundary regimes.
    let shapes: &[(usize, usize, usize, usize, usize)] =
        &[(1, 4, 256, 64, 32), (2, 4, 512, 64, 64)];
    let native = Dispatch::native();
    let threads: &[usize] = &[1, 2, 4];
    let mut sink = BenchSink::new("tensor_attention");

    let fast = Dispatch::fastest();
    println!(
        "tensor_attention: native dispatch = {} / fast tier = {} (tiles Br={} Bc={})",
        native.name(),
        if fast != native { fast.name() } else { "none" },
        attention::br(),
        attention::bc()
    );

    for &(b, h, l, d, k) in shapes {
        let shape = AttnShape::new(b, h, l, d, true);
        let shape_s = format!("b={b} h={h} l={l} d={d} k={k}");
        let flops = shape.flops();
        let dm = shape.d_model();
        let mut rng = Xoshiro256::new(0xA77E);
        let x = Mat::random_normal(shape.tokens(), dm, 1.0, &mut rng);
        let wq = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let wk = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let wv = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let idx = pammc::sample_generators(&mut rng, shape.tokens(), k);
        let comp = pammc::compress(&x, &idx, Eps::Inf);

        // Materialized Q/K/V for the dense attention rows (built once —
        // these rows time attention proper; projection timing lives in
        // the pamm_ops / tensor_kernels suites).
        let q = attention::split_heads(&x.matmul(&wq), &shape);
        let kk = attention::split_heads(&x.matmul(&wk), &shape);
        let v = attention::split_heads(&x.matmul(&wv), &shape);

        let mut suite = Suite::with_opts(&format!("tensor_attention {shape_s}"), opts());
        suite.header();

        let r = suite
            .bench("attn_naive t=1", || {
                std::hint::black_box(attention::naive_attention(&q, &kk, &v, &shape));
            })
            .clone();
        sink.record_flops("attn_naive", &shape_s, 1, &r, flops);

        // Dense flash + fused: scalar serial baseline, then the native
        // level across the thread sweep (mirrors tensor_kernels).
        let mut plan: Vec<(Dispatch, usize)> = vec![(Dispatch::Scalar, 1)];
        if native != Dispatch::Scalar {
            plan.extend(threads.iter().map(|&t| (native, t)));
        }
        // Fast tier (FMA) rows for the per-tier GFLOP/s comparison.
        if fast != native && fast.available() {
            plan.extend(threads.iter().map(|&t| (fast, t)));
        }
        for &(disp, t) in &plan {
            let tag = disp.name();
            let pool = Pool::new(t);
            let r = suite
                .bench(&format!("flash[{tag}] t={t}"), || {
                    std::hint::black_box(attention::flash_attention_on(
                        disp, &q, &kk, &v, &shape, &pool,
                    ));
                })
                .clone();
            sink.record_flops(&format!("flash[{tag}]"), &shape_s, t, &r, flops);

            let fused_pool = Pool::new(t);
            let r = suite
                .bench(&format!("fused_pamm[{tag}] t={t}"), || {
                    std::hint::black_box(attention::attend_compressed_on(
                        disp, &comp, &wq, &wk, &wv, &shape, &fused_pool, None,
                    ));
                })
                .clone();
            sink.record_flops(&format!("fused_pamm[{tag}]"), &shape_s, t, &r, flops);
            // Cold peak for the annotation: a fresh pool AND a fresh
            // caller thread — at t=1 the task grid runs inline on the
            // caller, whose TLS the projections above already warmed,
            // so only a scoped thread observes the real scratch growth.
            let tracker = MemoryTracker::new();
            std::thread::scope(|sc| {
                sc.spawn(|| {
                    let cold = Pool::new(t);
                    attention::attend_compressed_on(
                        disp, &comp, &wq, &wk, &wv, &shape, &cold, Some(&tracker),
                    );
                });
            });
            sink.annotate_peak_bytes(tracker.peak());
        }

        if let Some(sp) = suite.ratio(
            &format!("flash[{}] t=1", native.name()),
            "attn_naive t=1",
        ) {
            println!("  flash vs naive (single thread, {}): {sp:.2}x", native.name());
        }
        println!(
            "  materialized Q/K/V set: {}  (the bytes the fused path never allocates)",
            fmt_bytes(3 * shape.tensor_bytes())
        );
    }

    match sink.flush() {
        Ok(path) => {
            println!("\npersisted {} entries to {}", sink.entries().len(), path.display())
        }
        Err(e) => eprintln!("bench persistence failed: {e}"),
    }
}
