//! Bench: the native compressed-activation training step — forward
//! (with statistics), backward, and the full fwd+bwd+update step — per
//! shape × dispatch level × thread count. The acceptance trail for the
//! autograd subsystem: `benchmarks/BENCH_train_backward.json` →
//! BENCHMARKS.md §train_backward.
//!
//! Ops are dispatch-tagged (`train_fwd[avx2]`, `train_bwd[scalar]`, …)
//! via explicit-dispatch entry points, so no process-global
//! `kernels::force` state is involved. GFLOP/s uses the attention flop
//! model (`AttnShape::flops`; backward = 2.5× for its five tile GEMMs
//! vs the forward's two). Two memory annotations ride the entries:
//!
//! * forward rows carry `saved_bytes` — the EXACT saved-for-backward
//!   set of the step's tape node (`Compressed::stored_bytes` + the
//!   O(seq) log-sum-exp), the paper's headline quantity;
//! * backward rows carry `peak_bytes` — the measured backward-transient
//!   peak under the cold protocol (fresh pool, fresh caller thread).
//!
//! Run: `cargo bench --bench train_backward` (PAMM_BENCH_QUICK=1 for
//! CI); render with `pamm bench-report`.

use std::time::Duration;

use pamm::attention::AttnShape;
use pamm::autograd;
use pamm::benchx::{BenchOpts, BenchSink, Suite};
use pamm::coordinator::{NativeOpt, NativeTrainer};
use pamm::memory::{fmt_bytes, MemoryLedger};
use pamm::pamm as pammc;
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::Dispatch;
use pamm::tensor::Mat;

fn opts() -> BenchOpts {
    if std::env::var("PAMM_BENCH_QUICK").is_ok() {
        BenchOpts { warmup_iters: 0, min_iters: 1, max_iters: 5, max_total: Duration::from_secs(2) }
    } else {
        BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 12,
            max_total: Duration::from_secs(10),
        }
    }
}

fn main() {
    // (batch, heads, seq, head_dim, generators k) — causal, matching
    // the tensor_attention suite so fwd rows line up across suites.
    let shapes: &[(usize, usize, usize, usize, usize)] =
        &[(1, 4, 256, 64, 32), (2, 4, 512, 64, 64)];
    let native = Dispatch::native();
    let threads: &[usize] = &[1, 2, 4];
    let mut sink = BenchSink::new("train_backward");

    println!("train_backward: native dispatch = {}", native.name());

    for &(b, h, l, d, k) in shapes {
        let shape = AttnShape::new(b, h, l, d, true);
        let shape_s = format!("b={b} h={h} l={l} d={d} k={k}");
        let fwd_flops = shape.flops();
        let bwd_flops = 2.5 * fwd_flops;
        let dm = shape.d_model();
        let mut rng = Xoshiro256::new(0xBACD);
        let x = Mat::random_normal(shape.tokens(), dm, 1.0, &mut rng);
        let wq = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let wk = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let wv = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let idx = pammc::sample_generators(&mut rng, shape.tokens(), k);
        let mut target = vec![0f32; shape.qkv_len()];
        rng.fill_normal_f32(&mut target, 1.0);

        let mut suite = Suite::with_opts(&format!("train_backward {shape_s}"), opts());
        suite.header();

        let mut plan: Vec<(Dispatch, usize)> = vec![(Dispatch::Scalar, 1)];
        if native != Dispatch::Scalar {
            plan.extend(threads.iter().map(|&t| (native, t)));
        }
        for &(disp, t) in &plan {
            let tag = disp.name();
            let pool = Pool::new(t);

            // Forward with statistics — the training fwd, whose tape
            // node is the whole saved-for-backward set.
            let r = suite
                .bench(&format!("train_fwd[{tag}] t={t}"), || {
                    std::hint::black_box(autograd::qkv_attn_forward_on(
                        disp, &x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, &pool, None,
                    ));
                })
                .clone();
            sink.record_flops(&format!("train_fwd[{tag}]"), &shape_s, t, &r, fwd_flops);
            let (out, saved) = autograd::qkv_attn_forward_on(
                disp, &x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, &pool, None,
            );
            sink.annotate_saved_bytes(saved.saved_bytes());

            // Backward off the saved node.
            let (_, dout) = autograd::mse_loss(&out, &target);
            let r = suite
                .bench(&format!("train_bwd[{tag}] t={t}"), || {
                    std::hint::black_box(autograd::qkv_attn_backward_on(
                        disp, &saved, &wq, &wk, &wv, &out, &dout, false, &pool, None,
                    ));
                })
                .clone();
            sink.record_flops(&format!("train_bwd[{tag}]"), &shape_s, t, &r, bwd_flops);
            // Cold backward-transient peak: fresh pool AND fresh caller
            // thread (worker TLS on a warm pool reports zero growth —
            // the steady-state point, not what the bound checks).
            let ledger = MemoryLedger::new();
            std::thread::scope(|sc| {
                sc.spawn(|| {
                    let cold = Pool::new(t);
                    autograd::qkv_attn_backward_on(
                        disp,
                        &saved,
                        &wq,
                        &wk,
                        &wv,
                        &out,
                        &dout,
                        false,
                        &cold,
                        Some(&ledger),
                    );
                });
            });
            sink.annotate_peak_bytes(ledger.backward.peak());

            // Full step: fwd + loss + bwd + Adam update.
            let mut trainer = NativeTrainer::new(shape, k, NativeOpt::adam(1e-3), 7);
            let r = suite
                .bench(&format!("train_step[{tag}] t={t}"), || {
                    std::hint::black_box(trainer.step_report(disp, &x, &target, &pool, None).loss);
                })
                .clone();
            sink.record_flops(&format!("train_step[{tag}]"), &shape_s, t, &r, fwd_flops + bwd_flops);
        }

        if let Some(sp) = suite.ratio(
            &format!("train_bwd[{}] t=1", native.name()),
            "train_bwd[scalar] t=1",
        ) {
            println!("  bwd vs scalar (single thread, {}): {sp:.2}x", native.name());
        }
        println!(
            "  dense saved-for-backward baseline: {}  (X + Q/K/V + stats — what the tape never keeps)",
            fmt_bytes(autograd::dense_saved_bytes(dm, &shape))
        );
    }

    match sink.flush() {
        Ok(path) => {
            println!("\npersisted {} entries to {}", sink.entries().len(), path.display())
        }
        Err(e) => eprintln!("bench persistence failed: {e}"),
    }
}
