//! Checkpointing: parameter/optimizer-state save & restore.
//!
//! Format: one flat little-endian binary blob per checkpoint
//! (`<name>.bin`) with a JSON index (`<name>.json`) describing tensor
//! order, names, shapes, dtypes and byte offsets — restorable without the
//! manifest. Used by the coordinator for resume + for capturing
//! activations/params for the analysis harnesses (fig5/6/7).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonx::{self, Value};
use crate::runtime::{Dtype, HostTensor};

const MAGIC: &str = "pamm-ckpt-v1";

/// Save named tensors; order is preserved on load.
pub fn save(dir: impl AsRef<Path>, name: &str, tensors: &[(String, HostTensor)]) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut blob: Vec<u8> = Vec::new();
    let mut entries = Vec::new();

    for (tname, t) in tensors {
        let offset = blob.len();
        let (dtype, bytes): (&str, Vec<u8>) = match t {
            HostTensor::F32 { data, .. } => {
                ("f32", data.iter().flat_map(|v| v.to_le_bytes()).collect())
            }
            HostTensor::I32 { data, .. } => {
                ("i32", data.iter().flat_map(|v| v.to_le_bytes()).collect())
            }
        };
        blob.extend_from_slice(&bytes);
        entries.push(jsonx::obj(vec![
            ("name", jsonx::s(tname.clone())),
            (
                "shape",
                jsonx::arr(t.shape().iter().map(|&d| jsonx::num(d as f64)).collect()),
            ),
            ("dtype", jsonx::s(dtype)),
            ("offset", jsonx::num(offset as f64)),
            ("bytes", jsonx::num(bytes.len() as f64)),
        ]));
    }

    let index = jsonx::obj(vec![
        ("magic", jsonx::s(MAGIC)),
        ("tensors", jsonx::arr(entries)),
        ("blob_bytes", jsonx::num(blob.len() as f64)),
    ]);

    std::fs::File::create(dir.join(format!("{name}.bin")))?.write_all(&blob)?;
    std::fs::write(dir.join(format!("{name}.json")), index.to_string())?;
    Ok(())
}

/// Load a checkpoint saved by [`save`].
pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<Vec<(String, HostTensor)>> {
    let dir = dir.as_ref();
    let index_text = std::fs::read_to_string(dir.join(format!("{name}.json")))
        .with_context(|| format!("checkpoint index {name}.json"))?;
    let index = jsonx::parse(&index_text)?;
    if index.req_str("magic")? != MAGIC {
        bail!("bad checkpoint magic");
    }
    let mut blob = Vec::new();
    std::fs::File::open(dir.join(format!("{name}.bin")))?.read_to_end(&mut blob)?;
    if blob.len() != index.req_usize("blob_bytes")? {
        bail!("checkpoint blob truncated");
    }

    let mut out = Vec::new();
    for e in index.req_arr("tensors")? {
        let tname = e.req_str("name")?.to_string();
        let shape: Vec<usize> = e
            .req_arr("shape")?
            .iter()
            .map(|d| d.as_usize().context("shape dim"))
            .collect::<Result<_>>()?;
        let offset = e.req_usize("offset")?;
        let nbytes = e.req_usize("bytes")?;
        let slice = blob
            .get(offset..offset + nbytes)
            .context("checkpoint entry out of range")?;
        let t = match e.req_str("dtype")? {
            "f32" => HostTensor::f32(
                shape,
                slice
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            "i32" => HostTensor::i32(
                shape,
                slice
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            other => bail!("unknown checkpoint dtype {other}"),
        };
        out.push((tname, t));
    }
    Ok(out)
}

/// Convenience: dtype of a saved tensor without loading the blob.
pub fn peek_dtypes(dir: impl AsRef<Path>, name: &str) -> Result<Vec<(String, Dtype)>> {
    let index_text = std::fs::read_to_string(dir.as_ref().join(format!("{name}.json")))?;
    let index = jsonx::parse(&index_text)?;
    let mut out = Vec::new();
    for e in index.req_arr("tensors")? {
        let d = match e.req_str("dtype")? {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype {other}"),
        };
        out.push((e.req_str("name")?.to_string(), d));
    }
    Ok(out)
}

/// Helper for writing CSV artifacts (fig5/6/7 outputs).
pub fn write_csv(path: impl AsRef<Path>, header: &str, rows: &[String]) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

#[allow(unused_imports)]
use jsonx as _jsonx_used; // (jsonx::Value used via helpers)
#[allow(dead_code)]
fn _type_uses(_: &Value) {}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("pamm_ckpt_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_mixed_dtypes() {
        let dir = tmpdir("rt");
        let tensors = vec![
            ("w".to_string(), HostTensor::f32(vec![2, 3], vec![1.5, -2.0, 0.0, 3.25, 4.0, -0.5])),
            ("ids".to_string(), HostTensor::i32(vec![4], vec![1, -2, 3, 4])),
            ("scalar".to_string(), HostTensor::scalar_f32(42.0)),
        ];
        save(&dir, "test", &tensors).unwrap();
        let loaded = load(&dir, "test").unwrap();
        assert_eq!(loaded.len(), 3);
        for ((n1, t1), (n2, t2)) in tensors.iter().zip(loaded.iter()) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
    }

    #[test]
    fn peek_without_blob_read() {
        let dir = tmpdir("peek");
        save(&dir, "p", &[("x".into(), HostTensor::i32(vec![1], vec![7]))]).unwrap();
        let d = peek_dtypes(&dir, "p").unwrap();
        assert_eq!(d[0].0, "x");
        assert_eq!(d[0].1, Dtype::I32);
    }

    #[test]
    fn detects_truncation() {
        let dir = tmpdir("trunc");
        save(&dir, "t", &[("x".into(), HostTensor::f32(vec![8], vec![0.0; 8]))]).unwrap();
        // Truncate the blob.
        let bin = dir.join("t.bin");
        let data = std::fs::read(&bin).unwrap();
        std::fs::write(&bin, &data[..data.len() - 4]).unwrap();
        assert!(load(&dir, "t").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = tmpdir("magic");
        save(&dir, "m", &[("x".into(), HostTensor::scalar_f32(1.0))]).unwrap();
        let idx = dir.join("m.json");
        let text = std::fs::read_to_string(&idx).unwrap().replace(MAGIC, "nope");
        std::fs::write(&idx, text).unwrap();
        assert!(load(&dir, "m").is_err());
    }

    #[test]
    fn csv_writer() {
        let dir = tmpdir("csv");
        let p = dir.join("out.csv");
        write_csv(&p, "a,b", &["1,2".into(), "3,4".into()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
    }
}
