//! Checkpointing: crash-safe parameter/optimizer-state save & restore.
//!
//! Format: one flat little-endian binary blob per checkpoint
//! (`<name>.bin`) with a JSON index (`<name>.json`) describing tensor
//! order, names, shapes, dtypes, byte offsets and CRC32 checksums —
//! restorable without the manifest. Used by the coordinator for resume
//! + for capturing activations/params for the analysis harnesses.
//!
//! # Crash safety (DESIGN.md §9)
//!
//! Both files are written to a `.tmp` sibling, fsynced, then renamed
//! into place (and the directory fsynced on unix), so a kill at any
//! instant leaves either the previous checkpoint or the new one —
//! never a half-written file under the real name. The `.json` rename
//! is the commit point: a load requires the index, and the index
//! carries a whole-blob CRC32 plus one per tensor, so a stale
//! blob/index pairing or any bitrot is *detected* (contextful error
//! naming the failing tensor), never silently loaded. A
//! [`CheckpointRing`] retains the last N verified checkpoints of a run
//! and [`CheckpointRing::load_latest_good`] falls back newest → oldest
//! past corrupted or truncated entries, reporting each skip.
//!
//! # Sharded entries (DESIGN.md §10)
//!
//! Data-parallel runs write one shard blob per worker rank
//! (`{base}.s{step}.r{rank}`, each itself an atomic CRC-checked
//! checkpoint) and commit the entry with a tiny manifest under the
//! plain entry name **after** every shard has fsynced — the manifest's
//! `.json` rename is the commit point, so a kill between shard writes
//! leaves no committed entry.
//! [`CheckpointRing::load_latest_good_sharded`] requires the manifest
//! and all of its shards to verify, falling back past entries with a
//! missing, truncated or bit-rotted shard.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::jsonx::{self, Value};
use crate::runtime::{Dtype, HostTensor};

/// v2 adds `crc` per tensor entry + `blob_crc`; v1 files (no
/// checksums) are still loadable for backward compatibility.
const MAGIC: &str = "pamm-ckpt-v2";
const MAGIC_V1: &str = "pamm-ckpt-v1";

// -- checksums --------------------------------------------------------------

/// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) — the ubiquitous
/// zlib/PNG polynomial, hand-rolled because the repo takes no deps.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

// -- atomic file writes -----------------------------------------------------

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Write-to-temp + fsync + atomic rename: after this returns, `path`
/// holds either its previous content or exactly `bytes` — a crash
/// mid-call can only leave a stray `.tmp` (ignored by every loader).
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_path(path);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", path.display()))?;
    Ok(())
}

/// Persist the rename itself (directory metadata). Unix-only; on
/// other targets the rename is still atomic within the running system.
fn sync_dir(dir: &Path) {
    #[cfg(unix)]
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    #[cfg(not(unix))]
    let _ = dir;
}

// -- save / load ------------------------------------------------------------

fn encode(tensors: &[(String, HostTensor)]) -> (Vec<u8>, Vec<Value>) {
    let mut blob: Vec<u8> = Vec::new();
    let mut entries = Vec::new();
    for (tname, t) in tensors {
        let offset = blob.len();
        let (dtype, bytes): (&str, Vec<u8>) = match t {
            HostTensor::F32 { data, .. } => {
                ("f32", data.iter().flat_map(|v| v.to_le_bytes()).collect())
            }
            HostTensor::I32 { data, .. } => {
                ("i32", data.iter().flat_map(|v| v.to_le_bytes()).collect())
            }
        };
        blob.extend_from_slice(&bytes);
        entries.push(jsonx::obj(vec![
            ("name", jsonx::s(tname.clone())),
            (
                "shape",
                jsonx::arr(t.shape().iter().map(|&d| jsonx::num(d as f64)).collect()),
            ),
            ("dtype", jsonx::s(dtype)),
            ("offset", jsonx::num(offset as f64)),
            ("bytes", jsonx::num(bytes.len() as f64)),
            ("crc", jsonx::num(crc32(&bytes) as f64)),
        ]));
    }
    (blob, entries)
}

/// Save named tensors crash-safely; order is preserved on load.
pub fn save(dir: impl AsRef<Path>, name: &str, tensors: &[(String, HostTensor)]) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let (blob, entries) = encode(tensors);
    let index = jsonx::obj(vec![
        ("magic", jsonx::s(MAGIC)),
        ("tensors", jsonx::arr(entries)),
        ("blob_bytes", jsonx::num(blob.len() as f64)),
        ("blob_crc", jsonx::num(crc32(&blob) as f64)),
    ]);
    // Blob first, index last: the `.json` rename is the commit point.
    write_atomic(&dir.join(format!("{name}.bin")), &blob)
        .with_context(|| format!("checkpoint `{name}` blob"))?;
    write_atomic(&dir.join(format!("{name}.json")), index.to_string().as_bytes())
        .with_context(|| format!("checkpoint `{name}` index"))?;
    sync_dir(dir);
    Ok(())
}

/// Fault-injection hook (`faultx`): simulate a kill halfway through
/// the blob write — the first `keep_pct`% of the blob lands in the
/// `.bin.tmp` sibling and **nothing is renamed**, exactly the on-disk
/// state a mid-write crash leaves. Loaders never see the tmp file, so
/// the previous checkpoint (if any) stays intact.
pub fn save_interrupted(
    dir: impl AsRef<Path>,
    name: &str,
    tensors: &[(String, HostTensor)],
    keep_pct: u8,
) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let (blob, _) = encode(tensors);
    let keep = blob.len() * (keep_pct.min(100) as usize) / 100;
    let tmp = tmp_path(&dir.join(format!("{name}.bin")));
    let mut f =
        std::fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(&blob[..keep]).with_context(|| format!("writing {}", tmp.display()))?;
    f.sync_all().ok();
    Ok(())
}

/// Load a checkpoint saved by [`save`], verifying length and (for v2
/// files) the whole-blob and per-tensor CRC32s. Any mismatch is a
/// contextful error naming the failing piece — corrupted state is
/// never silently returned.
pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<Vec<(String, HostTensor)>> {
    let dir = dir.as_ref();
    let index_text = std::fs::read_to_string(dir.join(format!("{name}.json")))
        .with_context(|| format!("checkpoint index {name}.json"))?;
    let index =
        jsonx::parse(&index_text).with_context(|| format!("checkpoint `{name}`: index parse"))?;
    let magic = index.req_str("magic")?;
    let checksummed = match magic {
        m if m == MAGIC => true,
        m if m == MAGIC_V1 => false, // legacy: no checksums to verify
        other => bail!("checkpoint `{name}`: bad magic `{other}`"),
    };
    let mut blob = Vec::new();
    std::fs::File::open(dir.join(format!("{name}.bin")))
        .with_context(|| format!("checkpoint blob {name}.bin"))?
        .read_to_end(&mut blob)
        .with_context(|| format!("checkpoint blob {name}.bin"))?;
    let want_len = index.req_usize("blob_bytes")?;
    ensure!(
        blob.len() == want_len,
        "checkpoint `{name}`: blob truncated ({} of {want_len} bytes)",
        blob.len()
    );
    if checksummed {
        let want_crc = index.req_usize("blob_crc")? as u32;
        let got_crc = crc32(&blob);
        ensure!(
            got_crc == want_crc,
            "checkpoint `{name}`: blob checksum mismatch (crc32 {got_crc:08x}, index says {want_crc:08x}) — file is corrupted"
        );
    }

    let mut out = Vec::new();
    for e in index.req_arr("tensors")? {
        let tname = e.req_str("name")?.to_string();
        let shape: Vec<usize> = e
            .req_arr("shape")?
            .iter()
            .map(|d| d.as_usize().context("shape dim"))
            .collect::<Result<_>>()?;
        let offset = e.req_usize("offset")?;
        let nbytes = e.req_usize("bytes")?;
        let slice = blob
            .get(offset..offset + nbytes)
            .with_context(|| format!("checkpoint `{name}`: tensor `{tname}` out of range"))?;
        if checksummed {
            let want = e.req_usize("crc")? as u32;
            let got = crc32(slice);
            ensure!(
                got == want,
                "checkpoint `{name}`: tensor `{tname}` checksum mismatch (crc32 {got:08x}, index says {want:08x})"
            );
        }
        let t = match e.req_str("dtype")? {
            "f32" => HostTensor::f32(
                shape,
                slice
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            "i32" => HostTensor::i32(
                shape,
                slice
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            other => bail!("checkpoint `{name}`: unknown dtype {other}"),
        };
        out.push((tname, t));
    }
    Ok(out)
}

/// Full integrity check without keeping the tensors: Ok(()) iff
/// [`load`] would succeed.
pub fn verify(dir: impl AsRef<Path>, name: &str) -> Result<()> {
    load(dir, name).map(|_| ())
}

/// Convenience: dtype of a saved tensor without loading the blob.
pub fn peek_dtypes(dir: impl AsRef<Path>, name: &str) -> Result<Vec<(String, Dtype)>> {
    let index_text = std::fs::read_to_string(dir.as_ref().join(format!("{name}.json")))?;
    let index = jsonx::parse(&index_text)?;
    let mut out = Vec::new();
    for e in index.req_arr("tensors")? {
        let d = match e.req_str("dtype")? {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype {other}"),
        };
        out.push((e.req_str("name")?.to_string(), d));
    }
    Ok(out)
}

// -- the retained-last-N ring ----------------------------------------------

/// A retained ring of the last `keep` checkpoints of one run: entries
/// are `{base}.s{step:08}` under `dir`, pruned oldest-first after each
/// save, recovered newest-good-first by [`load_latest_good`]
/// (skipping — and reporting — any entry that fails verification).
///
/// [`load_latest_good`]: CheckpointRing::load_latest_good
#[derive(Debug, Clone)]
pub struct CheckpointRing {
    dir: PathBuf,
    base: String,
    keep: usize,
}

impl CheckpointRing {
    /// `keep` is clamped to ≥ 1 (a ring that retains nothing cannot
    /// recover anything).
    pub fn new(dir: impl AsRef<Path>, base: &str, keep: usize) -> CheckpointRing {
        CheckpointRing { dir: dir.as_ref().to_path_buf(), base: base.to_string(), keep: keep.max(1) }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Ring-entry checkpoint name for a boundary step.
    pub fn entry_name(&self, step: usize) -> String {
        format!("{}.s{step:08}", self.base)
    }

    /// Path of an entry's binary blob (bitrot-injection target).
    pub fn blob_path(&self, step: usize) -> PathBuf {
        self.dir.join(format!("{}.bin", self.entry_name(step)))
    }

    /// Save a ring entry for `step`, then prune beyond `keep`.
    pub fn save(&self, step: usize, tensors: &[(String, HostTensor)]) -> Result<()> {
        save(&self.dir, &self.entry_name(step), tensors)
            .with_context(|| format!("ring entry step {step}"))?;
        self.prune()
    }

    /// Committed ring entries (step, name), ascending by step — only
    /// files whose `.json` index landed count (the commit point).
    pub fn entries(&self) -> Vec<(usize, String)> {
        let prefix = format!("{}.s", self.base);
        let mut out = Vec::new();
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return out;
        };
        for entry in rd.flatten() {
            let fname = entry.file_name();
            let Some(fname) = fname.to_str() else { continue };
            let Some(rest) = fname.strip_prefix(&prefix) else { continue };
            let Some(digits) = rest.strip_suffix(".json") else { continue };
            if let Ok(step) = digits.parse::<usize>() {
                out.push((step, format!("{prefix}{digits}")));
            }
        }
        out.sort_unstable();
        out
    }

    fn prune(&self) -> Result<()> {
        let entries = self.entries();
        if entries.len() <= self.keep {
            return Ok(());
        }
        for (_, name) in &entries[..entries.len() - self.keep] {
            // Index first so a kill mid-prune can't leave an index
            // pointing at a deleted blob.
            let _ = std::fs::remove_file(self.dir.join(format!("{name}.json")));
            let _ = std::fs::remove_file(self.dir.join(format!("{name}.bin")));
        }
        Ok(())
    }

    /// Newest ring entry that passes full verification, with the
    /// diagnostics for every newer entry that had to be skipped
    /// (corrupted / truncated / unreadable). `Ok((None, diags))` means
    /// no entry verified — the caller starts from scratch, knowing
    /// exactly why.
    #[allow(clippy::type_complexity)]
    pub fn load_latest_good(
        &self,
    ) -> (Option<(usize, Vec<(String, HostTensor)>)>, Vec<String>) {
        let mut diags = Vec::new();
        for (step, name) in self.entries().into_iter().rev() {
            match load(&self.dir, &name) {
                Ok(tensors) => return (Some((step, tensors)), diags),
                Err(e) => diags.push(format!("ring entry `{name}` failed verification: {e:#}")),
            }
        }
        (None, diags)
    }

    // -- sharded entries (data-parallel training, DESIGN.md §10) ------------

    /// Checkpoint name of worker rank `rank`'s shard of the sharded
    /// entry for `step`. The `.r{rank}` infix makes shard files
    /// invisible to the plain [`CheckpointRing::entries`] scan (the
    /// digits parse fails), so only the manifest — written last —
    /// commits the entry.
    pub fn shard_name(&self, step: usize, rank: usize) -> String {
        format!("{}.r{rank}", self.entry_name(step))
    }

    /// Path of one shard's binary blob (bitrot-injection target).
    pub fn shard_blob_path(&self, step: usize, rank: usize) -> PathBuf {
        self.dir.join(format!("{}.bin", self.shard_name(step, rank)))
    }

    /// Save a sharded ring entry: one full checkpoint blob per worker
    /// rank (each atomically written and CRC-checksummed on its own),
    /// then a tiny manifest under the plain entry name. The manifest's
    /// `.json` rename is the entry's **commit point** — it lands only
    /// after every shard has fsynced, so a crash between shard writes
    /// leaves no committed entry and recovery falls back to the
    /// previous boundary.
    pub fn save_sharded(
        &self,
        step: usize,
        shards: &[Vec<(String, HostTensor)>],
    ) -> Result<()> {
        ensure!(!shards.is_empty(), "sharded ring entry step {step}: no shards");
        for (rank, tensors) in shards.iter().enumerate() {
            save(&self.dir, &self.shard_name(step, rank), tensors)
                .with_context(|| format!("ring shard {rank} of step {step}"))?;
        }
        let manifest = vec![
            ("meta.step".to_string(), HostTensor::i32(vec![1], vec![step as i32])),
            ("meta.shards".to_string(), HostTensor::i32(vec![1], vec![shards.len() as i32])),
        ];
        self.save(step, &manifest)?;
        self.prune_shards()
    }

    /// Shard count recorded in a committed entry's manifest (`None`
    /// for a plain, unsharded entry).
    pub fn manifest_shards(&self, step: usize) -> Option<usize> {
        let tensors = load(&self.dir, &self.entry_name(step)).ok()?;
        let (_, t) = tensors.iter().find(|(n, _)| n == "meta.shards")?;
        t.as_i32().ok().and_then(|v| v.first().map(|&n| n.max(0) as usize))
    }

    /// Remove shard files whose step no longer has a committed
    /// manifest — the retention GC for sharded entries (the manifest
    /// ring itself is pruned by [`CheckpointRing::save`]).
    fn prune_shards(&self) -> Result<()> {
        let live: std::collections::BTreeSet<usize> =
            self.entries().into_iter().map(|(s, _)| s).collect();
        let prefix = format!("{}.s", self.base);
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return Ok(());
        };
        for entry in rd.flatten() {
            let fname = entry.file_name();
            let Some(fname) = fname.to_str() else { continue };
            let Some(rest) = fname.strip_prefix(&prefix) else { continue };
            // Shard files are `{digits}.r{digits}.{bin|json}`.
            let Some((digits, shard_tail)) = rest.split_once(".r") else { continue };
            let Ok(step) = digits.parse::<usize>() else { continue };
            let is_shard = ["bin", "json"].iter().any(|ext| {
                shard_tail
                    .strip_suffix(&format!(".{ext}"))
                    .map(|r| !r.is_empty() && r.bytes().all(|b| b.is_ascii_digit()))
                    .unwrap_or(false)
            });
            if is_shard && !live.contains(&step) {
                let _ = std::fs::remove_file(entry.path());
            }
        }
        Ok(())
    }

    /// Newest sharded entry whose manifest **and every shard** verify,
    /// fully loaded per rank — falling back past (and reporting) any
    /// entry with a corrupted manifest or a missing/corrupt/truncated
    /// shard. One bad shard disqualifies the whole entry: resuming a
    /// fleet from a mixed-boundary state would break bitwise recovery.
    #[allow(clippy::type_complexity)]
    pub fn load_latest_good_sharded(
        &self,
    ) -> (Option<(usize, Vec<Vec<(String, HostTensor)>>)>, Vec<String>) {
        let mut diags = Vec::new();
        'entry: for (step, name) in self.entries().into_iter().rev() {
            let manifest = match load(&self.dir, &name) {
                Ok(t) => t,
                Err(e) => {
                    diags.push(format!("ring manifest `{name}` failed verification: {e:#}"));
                    continue;
                }
            };
            let n = manifest
                .iter()
                .find(|(k, _)| k == "meta.shards")
                .and_then(|(_, t)| t.as_i32().ok().and_then(|v| v.first().copied()));
            let Some(n) = n.map(|n| n.max(0) as usize).filter(|&n| n > 0) else {
                diags.push(format!("ring entry `{name}` carries no `meta.shards` — skipping"));
                continue;
            };
            let mut shards = Vec::with_capacity(n);
            for rank in 0..n {
                match load(&self.dir, &self.shard_name(step, rank)) {
                    Ok(t) => shards.push(t),
                    Err(e) => {
                        diags.push(format!(
                            "ring entry step {step}: shard {rank}/{n} failed verification: {e:#}"
                        ));
                        continue 'entry;
                    }
                }
            }
            return (Some((step, shards)), diags);
        }
        (None, diags)
    }
}

/// Helper for writing CSV artifacts (fig5/6/7 outputs).
pub fn write_csv(path: impl AsRef<Path>, header: &str, rows: &[String]) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    let mut f =
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("pamm_ckpt_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn one(v: f32) -> Vec<(String, HostTensor)> {
        vec![("x".to_string(), HostTensor::f32(vec![4], vec![v; 4]))]
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE CRC32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn roundtrip_mixed_dtypes() {
        let dir = tmpdir("rt");
        let tensors = vec![
            ("w".to_string(), HostTensor::f32(vec![2, 3], vec![1.5, -2.0, 0.0, 3.25, 4.0, -0.5])),
            ("ids".to_string(), HostTensor::i32(vec![4], vec![1, -2, 3, 4])),
            ("scalar".to_string(), HostTensor::scalar_f32(42.0)),
        ];
        save(&dir, "test", &tensors).unwrap();
        let loaded = load(&dir, "test").unwrap();
        assert_eq!(loaded.len(), 3);
        for ((n1, t1), (n2, t2)) in tensors.iter().zip(loaded.iter()) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
        verify(&dir, "test").unwrap();
        // No stray tmp files after a clean save.
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(stray.is_empty(), "atomic save must clean up its temp files");
    }

    #[test]
    fn peek_without_blob_read() {
        let dir = tmpdir("peek");
        save(&dir, "p", &[("x".into(), HostTensor::i32(vec![1], vec![7]))]).unwrap();
        let d = peek_dtypes(&dir, "p").unwrap();
        assert_eq!(d[0].0, "x");
        assert_eq!(d[0].1, Dtype::I32);
    }

    #[test]
    fn detects_truncation() {
        let dir = tmpdir("trunc");
        save(&dir, "t", &[("x".into(), HostTensor::f32(vec![8], vec![0.0; 8]))]).unwrap();
        let bin = dir.join("t.bin");
        let data = std::fs::read(&bin).unwrap();
        std::fs::write(&bin, &data[..data.len() - 4]).unwrap();
        let err = load(&dir, "t").unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn detects_single_bit_flip() {
        let dir = tmpdir("flip");
        save(&dir, "b", &[("x".into(), HostTensor::f32(vec![16], vec![1.0; 16]))]).unwrap();
        let bin = dir.join("b.bin");
        let mut data = std::fs::read(&bin).unwrap();
        data[17] ^= 0x04; // one bit, mid-blob
        std::fs::write(&bin, &data).unwrap();
        let err = load(&dir, "b").unwrap_err();
        assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");
    }

    #[test]
    fn loads_legacy_v1_files_without_checksums() {
        let dir = tmpdir("v1");
        save(&dir, "l", &one(2.5)).unwrap();
        // Rewrite the index as a v1 file: old magic, no crc fields.
        let idx = dir.join("l.json");
        let text = std::fs::read_to_string(&idx).unwrap();
        let v = jsonx::parse(&text).unwrap();
        let entries: Vec<Value> = v
            .req_arr("tensors")
            .unwrap()
            .iter()
            .map(|e| {
                jsonx::obj(vec![
                    ("name", jsonx::s(e.req_str("name").unwrap())),
                    ("shape", Value::Arr(e.req_arr("shape").unwrap().to_vec())),
                    ("dtype", jsonx::s(e.req_str("dtype").unwrap())),
                    ("offset", jsonx::num(e.req_usize("offset").unwrap() as f64)),
                    ("bytes", jsonx::num(e.req_usize("bytes").unwrap() as f64)),
                ])
            })
            .collect();
        let v1 = jsonx::obj(vec![
            ("magic", jsonx::s(MAGIC_V1)),
            ("tensors", jsonx::arr(entries)),
            ("blob_bytes", jsonx::num(v.req_usize("blob_bytes").unwrap() as f64)),
        ]);
        std::fs::write(&idx, v1.to_string()).unwrap();
        let loaded = load(&dir, "l").unwrap();
        assert_eq!(loaded, one(2.5));
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = tmpdir("magic");
        save(&dir, "m", &[("x".into(), HostTensor::scalar_f32(1.0))]).unwrap();
        let idx = dir.join("m.json");
        let text = std::fs::read_to_string(&idx).unwrap().replace(MAGIC, "nope");
        std::fs::write(&idx, text).unwrap();
        assert!(load(&dir, "m").is_err());
    }

    #[test]
    fn interrupted_save_leaves_only_a_tmp_and_previous_state() {
        let dir = tmpdir("mid");
        save(&dir, "r", &one(1.0)).unwrap();
        save_interrupted(&dir, "r", &one(9.0), 50).unwrap();
        // The committed checkpoint still loads — with the OLD value.
        let loaded = load(&dir, "r").unwrap();
        assert_eq!(loaded, one(1.0));
        assert!(dir.join("r.bin.tmp").exists(), "mid-write crash leaves a partial tmp");
    }

    #[test]
    fn ring_keeps_last_n_and_falls_back_past_corruption() {
        let dir = tmpdir("ring");
        let ring = CheckpointRing::new(&dir, "run", 2);
        ring.save(2, &one(2.0)).unwrap();
        ring.save(4, &one(4.0)).unwrap();
        ring.save(6, &one(6.0)).unwrap();
        let steps: Vec<usize> = ring.entries().iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, vec![4, 6], "keep-last-2 must prune step 2");

        // Newest good first…
        let (found, diags) = ring.load_latest_good();
        let (step, tensors) = found.unwrap();
        assert_eq!((step, tensors), (6, one(6.0)));
        assert!(diags.is_empty());

        // …corrupt the newest: fall back to step 4 with a diagnostic.
        let mut data = std::fs::read(ring.blob_path(6)).unwrap();
        data[3] ^= 0x40;
        std::fs::write(ring.blob_path(6), &data).unwrap();
        let (found, diags) = ring.load_latest_good();
        let (step, tensors) = found.unwrap();
        assert_eq!((step, tensors), (4, one(4.0)));
        assert_eq!(diags.len(), 1);
        assert!(diags[0].contains("checksum mismatch"), "{}", diags[0]);

        // …corrupt everything: None + two diagnostics, no panic.
        let p = ring.blob_path(4);
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..2]).unwrap();
        let (found, diags) = ring.load_latest_good();
        assert!(found.is_none());
        assert_eq!(diags.len(), 2);
    }

    #[test]
    fn csv_writer() {
        let dir = tmpdir("csv");
        let p = dir.join("out.csv");
        write_csv(&p, "a,b", &["1,2".into(), "3,4".into()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
    }

    fn shards_for(step: usize, n: usize) -> Vec<Vec<(String, HostTensor)>> {
        (0..n)
            .map(|r| {
                vec![(
                    format!("p{r}"),
                    HostTensor::f32(vec![2], vec![step as f32, r as f32]),
                )]
            })
            .collect()
    }

    #[test]
    fn sharded_entry_roundtrips_and_hides_shards_from_the_plain_scan() {
        let dir = tmpdir("sharded_roundtrip");
        let ring = CheckpointRing::new(&dir, "run", 3);
        ring.save_sharded(2, &shards_for(2, 3)).unwrap();
        // The plain scan sees only the manifest entry; `.r{rank}`
        // files fail the digits parse.
        assert_eq!(ring.entries().iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![2]);
        assert_eq!(ring.manifest_shards(2), Some(3));
        let (found, diags) = ring.load_latest_good_sharded();
        assert!(diags.is_empty(), "{diags:?}");
        let (step, shards) = found.unwrap();
        assert_eq!(step, 2);
        assert_eq!(shards.len(), 3);
        for (r, shard) in shards.iter().enumerate() {
            assert_eq!(shard[0].0, format!("p{r}"));
            assert_eq!(shard[0].1.as_f32().unwrap(), &[2.0, r as f32]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_or_corrupt_shard_falls_back_to_the_previous_entry() {
        let dir = tmpdir("sharded_fallback");
        let ring = CheckpointRing::new(&dir, "run", 3);
        ring.save_sharded(2, &shards_for(2, 2)).unwrap();
        ring.save_sharded(4, &shards_for(4, 2)).unwrap();
        // Delete one shard of the newest entry: the manifest still
        // commits it, but recovery must fall back to step 2 with a
        // diagnostic naming the missing shard.
        std::fs::remove_file(ring.shard_blob_path(4, 1)).unwrap();
        let (found, diags) = ring.load_latest_good_sharded();
        assert_eq!(found.unwrap().0, 2);
        assert!(
            diags.iter().any(|d| d.contains("shard 1/2")),
            "diagnostic must name the bad shard: {diags:?}"
        );
        // Same for bitrot inside a shard blob.
        ring.save_sharded(6, &shards_for(6, 2)).unwrap();
        let p = ring.shard_blob_path(6, 0);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[0] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let (found, diags) = ring.load_latest_good_sharded();
        assert_eq!(found.unwrap().0, 4);
        assert!(diags.iter().any(|d| d.contains("shard 0/2")), "{diags:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_retention_prunes_shard_files_with_their_manifest() {
        let dir = tmpdir("sharded_prune");
        let ring = CheckpointRing::new(&dir, "run", 2);
        for step in [2usize, 4, 6] {
            ring.save_sharded(step, &shards_for(step, 2)).unwrap();
        }
        assert_eq!(ring.entries().iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![4, 6]);
        assert!(!ring.shard_blob_path(2, 0).exists(), "pruned entry's shards must go too");
        assert!(!ring.shard_blob_path(2, 1).exists());
        assert!(ring.shard_blob_path(4, 0).exists());
        assert!(ring.shard_blob_path(6, 1).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn uncommitted_shards_without_a_manifest_are_invisible() {
        // A crash between shard writes leaves shard files but no
        // manifest: the entry must not exist for either loader.
        let dir = tmpdir("sharded_uncommitted");
        let ring = CheckpointRing::new(&dir, "run", 3);
        ring.save_sharded(2, &shards_for(2, 2)).unwrap();
        save(&dir, &ring.shard_name(4, 0), &shards_for(4, 2)[0]).unwrap();
        assert_eq!(ring.entries().iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![2]);
        let (found, _) = ring.load_latest_good_sharded();
        assert_eq!(found.unwrap().0, 2, "uncommitted step-4 shards must be ignored");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
