//! Baseline compressors from the paper's §4.6 comparison (Fig. 4a).
//!
//! * **Uniform-CRS** — column-row sampling with uniform pairs (Adelman et
//!   al., 2021 family): keep only the k sampled rows, scale by b/k.
//!   Equivalent to PAMM with ε = 0 up to which rows count as "kept".
//! * **CompAct** (Shamshoum et al., 2025) — stores the Gaussian sketch
//!   `X̃ = XP`, `P ∈ R^{n×k}` iid N(0, 1/k) so `E[PPᵀ] = I_n`; the gradient
//!   estimate is the unbiased-but-noisy `P(X̃ᵀB)`.
//!
//! Both are implemented exactly as the JAX twins in
//! `python/compile/kernels/ref.py` (cross-checked in integration tests).
//!
//! Like the PAMM kernels, each estimator has a default entry point on the
//! process-wide pool and a `*_with` twin taking an explicit
//! [`Pool`] for the fig4a equal-memory comparison and the benches;
//! results are bit-identical at any thread count. All contractions here
//! (`matmul_with`, `matmul_tn_with`) route through the
//! `tensor::kernels` microkernel GEMM, so the fig4a wall-clock
//! comparison pits every estimator against PAMM on the same SIMD
//! footing — CompAct's sketch/unsketch matmuls in particular are pure
//! dense GEMMs and inherit the full speedup.

use crate::poolx::{self, Pool};
use crate::rngx::Xoshiro256;
use crate::tensor::Mat;

/// Uniform-CRS estimate of `O = AᵀB`: `(b/k)·A[idx]ᵀ·B[idx]`.
pub fn crs_matmul(a: &Mat, b_mat: &Mat, gen_idx: &[usize]) -> Mat {
    crs_matmul_with(a, b_mat, gen_idx, poolx::global())
}

/// [`crs_matmul`] on an explicit pool.
pub fn crs_matmul_with(a: &Mat, b_mat: &Mat, gen_idx: &[usize], pool: &Pool) -> Mat {
    assert_eq!(a.rows(), b_mat.rows());
    let b = a.rows();
    let k = gen_idx.len();
    let a_sub = a.gather_rows(gen_idx);
    let b_sub = b_mat.gather_rows(gen_idx);
    let mut out = a_sub.matmul_tn_with(&b_sub, pool);
    out.scale(b as f32 / k as f32);
    out
}

/// CRS stored bytes: the k sampled rows of A *and* their indices.
pub fn crs_stored_bytes(k: usize, n: usize) -> usize {
    k * n * 4 + k * 4
}

/// CompAct compression state: the sketch plus the seed that regenerates P.
#[derive(Debug, Clone)]
pub struct CompactSketch {
    pub sketch: Mat, // (b, k)
    pub seed: u64,
    pub n: usize,
}

fn projection(n: usize, k: usize, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    let std = 1.0 / (k as f32).sqrt();
    Mat::random_normal(n, k, std, &mut rng)
}

/// Forward-time compression: `X̃ = XP` (only X̃ + seed are stored).
pub fn compact_compress(a: &Mat, k: usize, seed: u64) -> CompactSketch {
    compact_compress_with(a, k, seed, poolx::global())
}

/// [`compact_compress`] on an explicit pool.
pub fn compact_compress_with(a: &Mat, k: usize, seed: u64, pool: &Pool) -> CompactSketch {
    let p = projection(a.cols(), k, seed);
    CompactSketch { sketch: a.matmul_with(&p, pool), seed, n: a.cols() }
}

/// Backward-time estimate: `Õ = P·(X̃ᵀB)` (P regenerated from the seed).
pub fn compact_matmul(s: &CompactSketch, b_mat: &Mat) -> Mat {
    compact_matmul_with(s, b_mat, poolx::global())
}

/// [`compact_matmul`] on an explicit pool.
pub fn compact_matmul_with(s: &CompactSketch, b_mat: &Mat, pool: &Pool) -> Mat {
    assert_eq!(s.sketch.rows(), b_mat.rows());
    let p = projection(s.n, s.sketch.cols(), s.seed);
    let inner = s.sketch.matmul_tn_with(b_mat, pool); // (k, m)
    p.matmul_with(&inner, pool) // (n, m)
}

/// CompAct stored bytes: the (b, k) sketch + the 8-byte seed.
pub fn compact_stored_bytes(b: usize, k: usize) -> usize {
    b * k * 4 + 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pamm::{pamm_matmul, sample_generators, Eps};
    use crate::tensor::Mat;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        Mat::random_normal(rows, cols, 1.0, &mut rng)
    }

    #[test]
    fn crs_is_unbiased() {
        let a = rand_mat(64, 6, 1);
        let b = rand_mat(64, 5, 2);
        let exact = a.t_matmul(&b);
        let mut rng = Xoshiro256::new(3);
        let mut acc = Mat::zeros(6, 5);
        let trials = 4000;
        for _ in 0..trials {
            let idx = sample_generators(&mut rng, 64, 8);
            acc.add_assign(&crs_matmul(&a, &b, &idx));
        }
        acc.scale(1.0 / trials as f32);
        let rel = acc.sub(&exact).frob_norm() / exact.frob_norm();
        assert!(rel < 0.05, "relative bias {rel}");
    }

    #[test]
    fn compact_is_unbiased_over_projections() {
        let a = rand_mat(32, 8, 4);
        let b = rand_mat(32, 6, 5);
        let exact = a.t_matmul(&b);
        let mut acc = Mat::zeros(8, 6);
        let trials = 3000;
        for t in 0..trials {
            let s = compact_compress(&a, 4, 1000 + t as u64);
            acc.add_assign(&compact_matmul(&s, &b));
        }
        acc.scale(1.0 / trials as f32);
        let rel = acc.sub(&exact).frob_norm() / exact.frob_norm();
        assert!(rel < 0.08, "relative bias {rel}");
    }

    #[test]
    fn compact_recovers_exactly_when_k_ge_n_in_expectation_shape() {
        // Not exact per-sample, but error should shrink markedly as k grows.
        let a = rand_mat(64, 8, 6);
        let b = rand_mat(64, 5, 7);
        let exact = a.t_matmul(&b);
        let err_at = |k: usize| {
            let mut tot = 0.0;
            for t in 0..40 {
                let s = compact_compress(&a, k, 7000 + t);
                tot += compact_matmul(&s, &b).sub(&exact).frob_norm() / exact.frob_norm();
            }
            tot / 40.0
        };
        let e2 = err_at(2);
        let e32 = err_at(32);
        assert!(e32 < e2 * 0.5, "e2={e2} e32={e32}");
    }

    #[test]
    fn crs_matches_pamm_eps0_on_generator_rows() {
        // PAMM(eps=0) keeps exactly the generator self-pairs for generic
        // (gaussian) data, so both estimators use the same row set; they
        // differ only in alpha bookkeeping (all 1 here) — outputs match.
        let a = rand_mat(40, 7, 8);
        let b = rand_mat(40, 3, 9);
        let idx = vec![1, 5, 17, 33];
        let crs = crs_matmul(&a, &b, &idx);
        let pamm = pamm_matmul(&a, &b, &idx, Eps::Val(0.0));
        assert!(crs.max_abs_diff(&pamm) < 1e-4, "{}", crs.max_abs_diff(&pamm));
    }

    #[test]
    fn stored_bytes_ordering_matches_paper_fig4a() {
        // At equal r, PAMM stores k·n + 2b; CompAct stores b·k. For b ≫ n
        // (the paper's regime) CompAct's sketch dominates — this size gap
        // is why Fig. 4a's x-axis favors PAMM.
        let (b, n) = (16384, 512);
        let k = 32; // r = 1/512
        let pamm_bytes = k * n * 4 + 2 * b * 4 + 4;
        assert_eq!(crs_stored_bytes(k, n), k * n * 4 + k * 4);
        assert!(compact_stored_bytes(b, k) > pamm_bytes);
    }
}
