//! Analysis tooling for the paper's Appendix H figures.
//!
//! * [`error_sweep`] — relative L2 error `E(r, ε)` grid (Fig. 6).
//! * [`coverage_sweep`] — coverage grid (Fig. 7).
//! * [`pca_project`] — top-2 principal components via power iteration +
//!   deflation, used to regenerate Fig. 5's colored-cluster EDA (CSV out).
//!
//! These run on *captured activations*: the harness trains a model for a
//! few thousand steps through the PJRT stack, captures the K-projection
//! input of a middle layer (paper uses layer 3 at step 3000), and feeds it
//! here.
//!
//! The PAMM calls inside the sweeps ([`error_sweep`]'s exact/approx
//! products, [`coverage_sweep`]'s compress) route through the
//! `tensor::kernels` microkernel GEMM like every other native path, so
//! the full Fig. 6/7 grids — hundreds of compress+apply+exact cells —
//! run at kernel speed; only the per-cell bookkeeping here is scalar.

use crate::pamm::{self, Eps};
use crate::rngx::Xoshiro256;
use crate::tensor::Mat;

/// Relative L2 error `‖O − Õ‖_F / ‖O‖_F` for one (r, ε) cell.
pub fn relative_error(
    a: &Mat,
    b_mat: &Mat,
    r: f64,
    eps: Eps,
    rng: &mut Xoshiro256,
) -> f64 {
    let b = a.rows();
    let k = ((r * b as f64).ceil() as usize).max(1);
    let idx = pamm::sample_generators(rng, b, k);
    let exact = pamm::exact_matmul(a, b_mat);
    let approx = pamm::pamm_matmul(a, b_mat, &idx, eps);
    (approx.sub(&exact).frob_norm() / exact.frob_norm().max(1e-12)) as f64
}

/// One row of the Fig. 6 / Fig. 7 grids.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub r: f64,
    pub eps: Option<f64>, // None = ∞
    pub value: f64,
}

fn eps_of(e: Option<f64>) -> Eps {
    match e {
        None => Eps::Inf,
        Some(v) => Eps::Val(v as f32),
    }
}

/// Fig. 6 grid: relative error over (r, ε), averaged over `trials` seeds.
pub fn error_sweep(
    a: &Mat,
    b_mat: &Mat,
    rs: &[f64],
    epss: &[Option<f64>],
    trials: usize,
    seed: u64,
) -> Vec<SweepCell> {
    let mut out = Vec::new();
    for &r in rs {
        for &e in epss {
            let mut acc = 0.0;
            for t in 0..trials {
                let mut rng = Xoshiro256::fold_in(seed, 0xE44, t as u64);
                acc += relative_error(a, b_mat, r, eps_of(e), &mut rng);
            }
            out.push(SweepCell { r, eps: e, value: acc / trials as f64 });
        }
    }
    out
}

/// Fig. 7 grid: coverage over (r, ε).
pub fn coverage_sweep(
    a: &Mat,
    rs: &[f64],
    epss: &[Option<f64>],
    trials: usize,
    seed: u64,
) -> Vec<SweepCell> {
    let b = a.rows();
    let mut out = Vec::new();
    for &r in rs {
        let k = ((r * b as f64).ceil() as usize).max(1);
        for &e in epss {
            let mut acc = 0.0;
            for t in 0..trials {
                let mut rng = Xoshiro256::fold_in(seed, 0xC0F, t as u64);
                let idx = pamm::sample_generators(&mut rng, b, k);
                acc += pamm::compress(a, &idx, eps_of(e)).coverage();
            }
            out.push(SweepCell { r, eps: e, value: acc / trials as f64 });
        }
    }
    out
}

/// Top-`ncomp` principal components by power iteration with deflation on
/// the covariance (never materializes the b×b Gram). Returns (components
/// (ncomp, n), projected (b, ncomp)).
pub fn pca_project(a: &Mat, ncomp: usize, iters: usize, seed: u64) -> (Mat, Mat) {
    let (b, n) = (a.rows(), a.cols());
    // Column means for centering.
    let mut mean = vec![0f64; n];
    for i in 0..b {
        for (j, &v) in a.row(i).iter().enumerate() {
            mean[j] += v as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= b as f64;
    }

    let mut comps = Mat::zeros(ncomp, n);
    let mut rng = Xoshiro256::new(seed);

    // cov·v computed as Aᵀ(Av) with centering folded in.
    let cov_mul = |v: &[f32], comps: &Mat, upto: usize| -> Vec<f32> {
        // deflate: v ← v − Σ (v·cᵢ)cᵢ before multiplying
        let mut vd = v.to_vec();
        for c in 0..upto {
            let cr = comps.row(c);
            let d: f32 = crate::tensor::dot(&vd, cr);
            for j in 0..n {
                vd[j] -= d * cr[j];
            }
        }
        let mut av = vec![0f32; b];
        for i in 0..b {
            let mut acc = 0f64;
            for (j, &x) in a.row(i).iter().enumerate() {
                acc += (x as f64 - mean[j]) * vd[j] as f64;
            }
            av[i] = acc as f32;
        }
        let mut out = vec![0f32; n];
        for i in 0..b {
            let s = av[i];
            if s == 0.0 {
                continue;
            }
            for (j, &x) in a.row(i).iter().enumerate() {
                out[j] += s * (x - mean[j] as f32);
            }
        }
        out
    };

    for c in 0..ncomp {
        let mut v: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
        for _ in 0..iters {
            let mut w = cov_mul(&v, &comps, c);
            let norm = crate::tensor::dot(&w, &w).sqrt().max(1e-12);
            for x in w.iter_mut() {
                *x /= norm;
            }
            v = w;
        }
        comps.row_mut(c).copy_from_slice(&v);
    }

    // Project the (centered) data.
    let mut proj = Mat::zeros(b, ncomp);
    for i in 0..b {
        for c in 0..ncomp {
            let mut acc = 0f64;
            let cr = comps.row(c);
            for (j, &x) in a.row(i).iter().enumerate() {
                acc += (x as f64 - mean[j]) * cr[j] as f64;
            }
            proj.set(i, c, acc as f32);
        }
    }
    (comps, proj)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Clustered synthetic data: `nclust` line-shaped clusters in R^n —
    /// the structure Appendix H observes in real attention inputs.
    pub fn clustered_data(b: usize, n: usize, nclust: usize, noise: f32, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let centers = Mat::random_normal(nclust, n, 1.0, &mut rng);
        let mut a = Mat::zeros(b, n);
        for i in 0..b {
            let c = rng.next_below(nclust as u64) as usize;
            let scale = 0.5 + 1.5 * rng.next_f32();
            let row = a.row_mut(i);
            let cr = centers.row(c);
            for j in 0..n {
                row[j] = scale * cr[j] + noise * rng.next_normal() as f32;
            }
        }
        a
    }

    #[test]
    fn error_decreases_with_eps_on_clustered_data() {
        // Fig. 6a shape: larger ε (more coverage) → lower relative error.
        let a = clustered_data(512, 24, 8, 0.05, 1);
        let mut rng = Xoshiro256::new(2);
        let b_mat = Mat::random_normal(512, 16, 1.0, &mut rng);
        let cells = error_sweep(
            &a,
            &b_mat,
            &[1.0 / 32.0],
            &[Some(0.1), Some(0.5), None],
            3,
            7,
        );
        assert!(cells[0].value >= cells[1].value - 0.02, "{cells:?}");
        assert!(cells[1].value >= cells[2].value - 0.02, "{cells:?}");
    }

    #[test]
    fn error_grows_slowly_as_r_shrinks() {
        // Fig. 6b shape: error scales ~log in 1/r on clustered data.
        let a = clustered_data(1024, 32, 8, 0.05, 3);
        let mut rng = Xoshiro256::new(4);
        let b_mat = Mat::random_normal(1024, 16, 1.0, &mut rng);
        let cells = error_sweep(
            &a,
            &b_mat,
            &[1.0 / 8.0, 1.0 / 64.0, 1.0 / 512.0],
            &[None],
            3,
            11,
        );
        // Error must grow monotonically but stay O(1) even at r = 1/512 —
        // the paper's App. H reports relative errors of 0.5–1.0 there.
        assert!(cells[0].value <= cells[1].value + 0.02, "{cells:?}");
        assert!(cells[1].value <= cells[2].value + 0.02, "{cells:?}");
        assert!(cells[2].value < 1.5, "{cells:?}");
    }

    #[test]
    fn coverage_sweep_shapes() {
        let a = clustered_data(256, 16, 4, 0.1, 5);
        let cells = coverage_sweep(&a, &[1.0 / 16.0], &[Some(0.0), Some(0.5), None], 2, 13);
        assert!(cells[0].value <= cells[1].value + 1e-9);
        assert!(cells[1].value <= cells[2].value + 1e-9);
        assert!((cells[2].value - 1.0).abs() < 1e-9); // ε=∞ covers all
    }

    #[test]
    fn pca_finds_dominant_direction() {
        // Data stretched along e0 — first component must align with it.
        let mut rng = Xoshiro256::new(6);
        let mut a = Mat::zeros(400, 8);
        for i in 0..400 {
            a.set(i, 0, 10.0 * rng.next_normal() as f32);
            for j in 1..8 {
                a.set(i, j, 0.1 * rng.next_normal() as f32);
            }
        }
        let (comps, proj) = pca_project(&a, 2, 30, 7);
        assert!(comps.get(0, 0).abs() > 0.99, "c0 = {:?}", comps.row(0));
        // Projected variance along comp0 ≫ comp1.
        let var = |c: usize| {
            (0..400).map(|i| (proj.get(i, c) as f64).powi(2)).sum::<f64>() / 400.0
        };
        assert!(var(0) > 50.0 * var(1), "{} vs {}", var(0), var(1));
    }

    #[test]
    fn pca_components_orthonormal() {
        let a = clustered_data(300, 12, 5, 0.2, 8);
        let (comps, _) = pca_project(&a, 2, 40, 9);
        let c0 = comps.row(0);
        let c1 = comps.row(1);
        assert!((crate::tensor::dot(c0, c0) - 1.0).abs() < 1e-3);
        assert!((crate::tensor::dot(c1, c1) - 1.0).abs() < 1e-3);
        assert!(crate::tensor::dot(c0, c1).abs() < 0.05);
    }
}
