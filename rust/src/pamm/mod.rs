//! Native PAMM: the paper's Algorithm 1 in pure Rust.
//!
//! This is the L3-resident twin of the Pallas kernels — used by:
//!
//! * the runtime-independent benches (t7/t8 runtime breakdowns, fig4a-style
//!   microbenchmarks) where we need per-op timers the HLO path can't expose,
//! * the analysis harnesses (fig5 PCA, fig6 relative-error, fig7 coverage),
//! * property tests (`propx`) of PAMM's invariants (Lemma 1, β-unbiasedness,
//!   the error bound of §3.2.1),
//! * cross-validation against the AOT kernel artifacts (integration tests
//!   assert native == Pallas == jnp on identical inputs).
//!
//! Numerics follow python/compile/kernels/ref.py exactly, including the
//! `err² = ‖A_i‖²(1 − csim²)` closed form for the neighborhood condition.
//!
//! Every hot entry point comes in two forms: `compress` / `apply` /
//! `exact_matmul` run on the process-wide [`crate::poolx::global`] pool
//! (sized by `--threads` / `PAMM_THREADS`), and the `*_with` twins take
//! an explicit [`Pool`] — the benches use those to sweep thread counts.
//! All decompositions are row-blocked (compress) or column-stripped
//! (apply, exact) so outputs are **bit-identical at any thread count**;
//! `rust/tests/prop_pamm.rs` asserts this for 1/2/4 threads.
//!
//! Both stages lean on the `tensor::kernels` microkernel GEMM: Stage 1
//! computes the similarity scores as one Gram pass `S = A·Cᵀ` (then
//! sweeps `S` for the Lemma-1 argmax/α/β bookkeeping), and Stage 2's
//! `Cᵀ·B̃` contraction is the same kernel with the transposed read
//! packed in. Per-worker scratch (`S` strips, `B̃`, packed panels)
//! comes from the kernel's thread-local `Workspace`, so steady-state
//! train-step iterations don't allocate scratch.

pub mod analysis;
pub mod baselines;

use crate::poolx::{self, Pool};
use crate::rngx::Xoshiro256;
use crate::tensor::kernels::{self, Workspace};
use crate::tensor::{dot, Mat};

const NORM_EPS: f32 = 1e-12;

/// Compressed representation of a (b, n) activation matrix (paper Fig. 1):
/// generators `C`, assignment `f`, scales `α`, drop-correction `β`.
#[derive(Debug, Clone)]
pub struct Compressed {
    pub generators: Mat,
    pub assign: Vec<u32>,
    pub alpha: Vec<f32>,
    pub beta: f32,
}

impl Compressed {
    pub fn k(&self) -> usize {
        self.generators.rows()
    }

    pub fn b(&self) -> usize {
        self.alpha.len()
    }

    /// Stored bytes: C + α + f + β (the memory the paper's tables report
    /// for PAMM, vs `b·n·4` for the raw activation).
    pub fn stored_bytes(&self) -> usize {
        self.generators.rows() * self.generators.cols() * 4
            + self.alpha.len() * 4
            + self.assign.len() * 4
            + 4
    }

    /// Fraction of rows with a surviving representative (Fig. 7 metric).
    pub fn coverage(&self) -> f64 {
        let kept = self.alpha.iter().filter(|a| **a != 0.0).count();
        kept as f64 / self.alpha.len().max(1) as f64
    }

    /// Project the generators through a weight matrix: `G = C·W`
    /// (k × m). The fused attention forward (`crate::attention`) leans
    /// on the identity `Ã·W = diag(α)·1_f·(C·W)`: once `G` exists, any
    /// row of the projected activation is just `α_i · G[f(i)]`, so
    /// Q/K/V tiles can be produced straight from the compressed
    /// representation — `G` is the only projection-side state that
    /// stays resident, and it is k rows, not b.
    pub fn project_generators(&self, w: &Mat) -> Mat {
        self.generators.matmul(w)
    }

    /// Materialize Ã (Eq. 3) — analysis/tests only, never on hot paths.
    pub fn reconstruct(&self) -> Mat {
        let n = self.generators.cols();
        let mut out = Mat::zeros(self.b(), n);
        for i in 0..self.b() {
            let a = self.alpha[i];
            if a != 0.0 {
                let c = self.generators.row(self.assign[i] as usize);
                let row = out.row_mut(i);
                for j in 0..n {
                    row[j] = a * c[j];
                }
            }
        }
        out
    }
}

/// ε policy for the neighborhood condition (paper §3.2 / §4.6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Eps {
    /// No condition — every row keeps its best representative (paper's
    /// best-performing setting, "ε = ∞").
    Inf,
    /// `‖A_i − Ã_i‖ ≤ ε‖A_i‖`; 0 keeps only exactly-collinear rows.
    Val(f32),
}

impl Eps {
    /// The keep test in csim² form: `csim² ≥ 1 − ε²` (ε ≥ 1 keeps all).
    #[inline]
    fn keeps(self, csim_sq: f32) -> bool {
        match self {
            Eps::Inf => true,
            Eps::Val(e) if e >= 1.0 => true,
            // Small float slack so exactly-collinear rows (csim = 1 up
            // to rounding) survive eps = 0 — without it the generators
            // themselves get dropped and Uniform-CRS degenerates.
            Eps::Val(e) => csim_sq >= 1.0 - e * e - 1e-6,
        }
    }
}

/// Uniformly sample k generator row indices without replacement.
pub fn sample_generators(rng: &mut Xoshiro256, b: usize, k: usize) -> Vec<usize> {
    rng.sample_without_replacement(b, k)
}

/// Row-range worker for [`compress`]: fills `assign[start..end]` /
/// `alpha[start..end]`, returns the local drop count.
///
/// The old per-row 4-way generator scan is gone: the scores for the
/// whole range come from one Gram pass `S = A[start..end) · Cᵀ` through
/// the blocked `tensor::kernels` GEMM (`ct` is the pre-transposed
/// generator matrix, shared by all workers), followed by a cheap
/// Lemma-1 argmax/α sweep over `S`. The `S` strip lives in the worker's
/// thread-local [`Workspace`], so repeated compress calls allocate no
/// scratch. The kernel's per-element accumulation order is invariant to
/// the row partition and to the SIMD dispatch level, so `S` — and
/// therefore assignment, α and β — is bit-identical at any thread
/// count and under `PAMM_SIMD=scalar` vs `native`.
fn compress_range(
    a: &Mat,
    ct: &Mat,
    nc: &[f32],
    eps: Eps,
    start: usize,
    end: usize,
    assign: &mut [u32],
    alpha: &mut [f32],
) -> usize {
    let rows = end - start;
    let k = ct.cols();
    let n = a.cols();
    kernels::with_workspace(|ws| {
        let Workspace { packs, s, .. } = ws;
        s.clear();
        s.resize(rows * k, 0.0);
        kernels::gemm_into(
            kernels::active(),
            false,
            rows,
            k,
            n,
            &a.data()[start * n..end * n],
            n,
            ct.data(),
            k,
            s,
            k,
            packs,
        );
        let mut dropped = 0usize;
        for i in start..end {
            let ai = a.row(i);
            let na = dot(ai, ai).sqrt();
            if na <= NORM_EPS {
                dropped += 1;
                continue;
            }
            // Lemma 1: argmax_j |csim(A_i, C_j)| over the Gram row
            // (strictly-greater keeps the lowest index on ties, like the
            // scan it replaces).
            let srow = &s[(i - start) * k..(i - start + 1) * k];
            let mut best_j = 0usize;
            let mut best_abs = -1.0f32;
            let mut best_cs = 0.0f32;
            for (j, &d) in srow.iter().enumerate() {
                let cs = d / (na * nc[j]).max(NORM_EPS);
                if cs.abs() > best_abs {
                    best_abs = cs.abs();
                    best_cs = cs;
                    best_j = j;
                }
            }
            let csim_sq = best_cs * best_cs;
            if eps.keeps(csim_sq) {
                assign[i - start] = best_j as u32;
                alpha[i - start] = best_cs * na / nc[best_j].max(NORM_EPS);
            } else {
                dropped += 1; // α stays 0 — the row is dropped (Eq. 3)
            }
        }
        dropped
    })
}

/// Stage 1 (Algorithm 1 `Compress`) on the process-wide pool. See
/// [`compress_with`].
pub fn compress(a: &Mat, gen_idx: &[usize], eps: Eps) -> Compressed {
    compress_with(a, gen_idx, eps, poolx::global())
}

/// Stage 1 (Algorithm 1 `Compress`): assignment + scales for given
/// generator indices, scored via a Gram-matrix GEMM (see
/// `compress_range`). Parallel over row blocks of `pool` (rows are
/// independent — the same decomposition the Pallas grid uses), serial
/// below the pool's chunk threshold. Output is bit-identical at any
/// thread count.
pub fn compress_with(a: &Mat, gen_idx: &[usize], eps: Eps, pool: &Pool) -> Compressed {
    let b = a.rows();
    let k = gen_idx.len();
    assert!(k >= 1, "need at least one generator");
    let c = a.gather_rows(gen_idx);
    let nc = c.row_norms();
    // One transpose shared by every worker: the Gram pass computes
    // `A_block · Cᵀ`, and pre-materializing Cᵀ keeps the kernel's B
    // packing on contiguous rows (k×n copy, negligible next to the
    // b×k×n contraction).
    let ct = c.transpose();

    let mut assign = vec![0u32; b];
    let mut alpha = vec![0f32; b];
    let mut dropped = 0usize;
    if pool.chunks_for(b) <= 1 {
        // Serial fast path: write assign/alpha in place, no per-chunk
        // temporaries.
        dropped = compress_range(a, &ct, &nc, eps, 0, b, &mut assign, &mut alpha);
    } else {
        for (start, _end, (ac, lc, d)) in pool.map_chunks(b, |s, e| {
            let mut ac = vec![0u32; e - s];
            let mut lc = vec![0f32; e - s];
            let d = compress_range(a, &ct, &nc, eps, s, e, &mut ac, &mut lc);
            (ac, lc, d)
        }) {
            assign[start..start + ac.len()].copy_from_slice(&ac);
            alpha[start..start + lc.len()].copy_from_slice(&lc);
            dropped += d;
        }
    }

    // β = b / (b − η) so that E[Õ] = O (Eq. 5).
    let kept = b - dropped;
    let beta = if kept > 0 { b as f32 / kept as f32 } else { 1.0 };
    Compressed { generators: c, assign, alpha, beta }
}

/// Stage 2 (Algorithm 1 `ApproxMM`) on the process-wide pool. See
/// [`apply_with`].
pub fn apply(comp: &Compressed, b_mat: &Mat) -> Mat {
    apply_with(comp, b_mat, poolx::global())
}

/// Which generators received at least one surviving row — the rows of
/// B̃ that can be nonzero. Derived from `assign`/`alpha` alone, so the
/// mask is identical for every column strip (a per-strip content scan
/// would let the dense/sparse choice differ between strips and break
/// thread-count bit-identity).
fn generator_live(comp: &Compressed) -> (Vec<bool>, usize) {
    let mut live = vec![false; comp.k()];
    let mut count = 0usize;
    for i in 0..comp.b() {
        if comp.alpha[i] != 0.0 {
            let j = comp.assign[i] as usize;
            if !live[j] {
                live[j] = true;
                count += 1;
            }
        }
    }
    (live, count)
}

/// One column strip `[j0, j1)` of [`apply`]: the B̃ index-accumulate
/// over the strip's columns, then `Cᵀ·B̃` and the β scale. Both phases
/// sweep source rows in ascending order, so the per-element
/// accumulation order never depends on the strip bounds (bit-identical
/// at any thread count; the full-width call
/// `apply_strip(comp, b, …, 0, m)` *is* the serial algorithm).
///
/// The `Cᵀ·B̃` contraction picks its variant from the shared `live`
/// mask: with every generator live (the ε = ∞ hot path) it is one
/// dense microkernel GEMM — no zero tests anywhere in the inner loops;
/// with dead generators (tight ε) it takes a scalar loop whose
/// zero-row skip is hoisted to **one branch per generator**, never
/// inside the j-loop. B̃ scratch comes from the worker's thread-local
/// [`Workspace`].
fn apply_strip(comp: &Compressed, b_mat: &Mat, live: &[bool], all_live: bool, j0: usize, j1: usize) -> Mat {
    let (k, w) = (comp.k(), j1 - j0);
    let n = comp.generators.cols();
    kernels::with_workspace(|ws| {
        let Workspace { packs, btilde, .. } = ws;
        btilde.clear();
        btilde.resize(k * w, 0.0);
        for i in 0..comp.b() {
            let a = comp.alpha[i];
            if a == 0.0 {
                continue;
            }
            let src = &b_mat.row(i)[j0..j1];
            let dst = &mut btilde[comp.assign[i] as usize * w..comp.assign[i] as usize * w + w];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += a * s;
            }
        }
        let mut strip = Mat::zeros(n, w);
        if all_live {
            kernels::gemm_into(
                kernels::active(),
                true,
                n,
                w,
                k,
                comp.generators.data(),
                n,
                btilde,
                w,
                strip.data_mut(),
                w,
                packs,
            );
        } else {
            // Plain ascending-r accumulation (no KC grouping) so the
            // skipped rows are the only difference from a flat sweep —
            // the order every strip and the serial path share.
            for (r, &is_live) in live.iter().enumerate() {
                if !is_live {
                    continue;
                }
                let crow = comp.generators.row(r);
                let brow = &btilde[r * w..(r + 1) * w];
                for (i2, &cv) in crow.iter().enumerate() {
                    let orow = &mut strip.data_mut()[i2 * w..(i2 + 1) * w];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += cv * bv;
                    }
                }
            }
        }
        strip.scale(comp.beta);
        strip
    })
}

/// Stage 2 (Algorithm 1 `ApproxMM`): `Õ = β·Cᵀ·B̃` with
/// `B̃_j = Σ_{i:f(i)=j} α_i B_i` via index-accumulate (the CUDA-flavored
/// schedule; the Pallas twin uses a one-hot matmul — same numbers).
/// Parallel over column strips of the output on `pool`; bit-identical at
/// any thread count. The dense-vs-sparse `Cᵀ·B̃` choice is made once
/// here from the assignment (see `apply_strip`).
pub fn apply_with(comp: &Compressed, b_mat: &Mat, pool: &Pool) -> Mat {
    let m = b_mat.cols();
    assert_eq!(comp.b(), b_mat.rows(), "assignment/B row mismatch");
    let n = comp.generators.cols();
    let (live, nlive) = generator_live(comp);
    let all_live = nlive == comp.k();
    let strip_pool = pool.for_columns();
    if strip_pool.chunks_for(m) <= 1 {
        return apply_strip(comp, b_mat, &live, all_live, 0, m);
    }
    let mut out = Mat::zeros(n, m);
    for (j0, j1, strip) in
        strip_pool.map_chunks(m, |j0, j1| apply_strip(comp, b_mat, &live, all_live, j0, j1))
    {
        out.paste_cols(j0, j1, &strip);
    }
    out
}

/// Incremental Stage-1 state: fold rows one at a time into an existing
/// [`Compressed`] against its **fixed** generators — the decode-time
/// KV-cache recurrence of `crate::generate` (DESIGN.md §8). Holds the
/// pre-transposed generator matrix and the generator norms so each fold
/// is one `1×k` Gram GEMM plus the Lemma-1 argmax/α sweep, replicating
/// [`compress_with`]'s per-row arithmetic exactly: the microkernel's
/// per-element accumulation order depends only on the depth blocking,
/// never on how many rows share the call, so a row folded here is
/// bit-identical to the same row scored inside a batch compress.
///
/// β bookkeeping: `new` counts the existing dropped rows as
/// `α == 0` (exactly the rows `compress_range` left at zero), and every
/// fold re-derives `β = b/kept` from the same integer counts the batch
/// path divides — so the running [`Compressed`] stays field-for-field
/// bit-equal to a one-shot compression of all rows seen so far.
#[derive(Debug, Clone)]
pub struct IncrementalCompressor {
    ct: Mat,
    nc: Vec<f32>,
    dropped: usize,
}

impl IncrementalCompressor {
    /// Build the fold state from a compressed prefix (generators fixed
    /// from here on).
    pub fn new(comp: &Compressed) -> Self {
        IncrementalCompressor {
            ct: comp.generators.transpose(),
            nc: comp.generators.row_norms(),
            dropped: comp.alpha.iter().filter(|a| **a == 0.0).count(),
        }
    }

    /// Bytes of the incremental state (Cᵀ + generator norms) — counted
    /// by `generate::kv_cache_bytes` next to the `Compressed` itself.
    pub fn stored_bytes(&self) -> usize {
        self.ct.rows() * self.ct.cols() * 4 + self.nc.len() * 4
    }

    /// Fold one row on the active dispatch level.
    pub fn fold(&mut self, comp: &mut Compressed, row: &[f32], eps: Eps) {
        self.fold_on(kernels::active(), comp, row, eps)
    }

    /// Fold one row: append its assignment and scale to `comp` and
    /// refresh β. One Gram GEMM against the fixed Cᵀ, then the exact
    /// `compress_range` sweep (strict argmax, lowest index on ties).
    pub fn fold_on(
        &mut self,
        d: kernels::Dispatch,
        comp: &mut Compressed,
        row: &[f32],
        eps: Eps,
    ) {
        let (n, k) = (self.ct.rows(), self.ct.cols());
        assert_eq!(row.len(), n, "fold: row width vs generator width");
        assert_eq!(comp.generators.rows(), k, "fold: comp/state generator mismatch");
        let (assign_v, alpha_v, is_dropped) = kernels::with_workspace(|ws| {
            let Workspace { packs, s, .. } = ws;
            s.clear();
            s.resize(k, 0.0);
            kernels::gemm_into(d, false, 1, k, n, row, n, self.ct.data(), k, s, k, packs);
            let na = dot(row, row).sqrt();
            if na <= NORM_EPS {
                return (0u32, 0f32, true);
            }
            let mut best_j = 0usize;
            let mut best_abs = -1.0f32;
            let mut best_cs = 0.0f32;
            for (j, &dv) in s[..k].iter().enumerate() {
                let cs = dv / (na * self.nc[j]).max(NORM_EPS);
                if cs.abs() > best_abs {
                    best_abs = cs.abs();
                    best_cs = cs;
                    best_j = j;
                }
            }
            if eps.keeps(best_cs * best_cs) {
                (best_j as u32, best_cs * na / self.nc[best_j].max(NORM_EPS), false)
            } else {
                (0u32, 0f32, true)
            }
        });
        comp.assign.push(assign_v);
        comp.alpha.push(alpha_v);
        if is_dropped {
            self.dropped += 1;
        }
        let b = comp.alpha.len();
        let kept = b - self.dropped;
        comp.beta = if kept > 0 { b as f32 / kept as f32 } else { 1.0 };
    }
}

/// Backward entry point of the compressed projection (the native twin
/// of `python/compile/pamm_layer.py`'s `_pamm_bwd`): the VJP of
/// `Z = Ã·W` with respect to `W`, treating the assignment `f` and the
/// scales `α` as constants of the forward (straight-through — the
/// argmax is not differentiated, per the paper). Because
/// `Ã = diag(α)·1_f·C`,
///
/// ```text
/// dW = β·Ãᵀ·dZ = β·Cᵀ·(1_fᵀ·diag(α)·dZ) = β·Cᵀ·B̃,
///      B̃_j = Σ_{i: f(i)=j} α_i·dZ_i
/// ```
///
/// — exactly Algorithm 1 `ApproxMM`, so this is [`apply`] under its
/// VJP name: the gather-scaled index-accumulate plus one k-row GEMM,
/// never a `b×n` contraction. β rescales the estimate to be unbiased
/// for the *dense* gradient `Xᵀ·dZ` (Eq. 5); with ε = ∞ and no zero
/// rows, β = 1 and the result is the exact gradient of the compressed
/// forward. `dX = dZ·Wᵀ` stays exact and needs no PAMM state — it is a
/// plain dense matmul composed by the caller (`crate::autograd`).
pub fn grad_w(comp: &Compressed, dz: &Mat) -> Mat {
    grad_w_with(comp, dz, poolx::global())
}

/// [`grad_w`] on an explicit pool — bit-identical at any thread count,
/// like the [`apply_with`] it wraps.
pub fn grad_w_with(comp: &Compressed, dz: &Mat, pool: &Pool) -> Mat {
    apply_with(comp, dz, pool)
}

/// End-to-end PAMM approximation of `O = AᵀB`.
pub fn pamm_matmul(a: &Mat, b_mat: &Mat, gen_idx: &[usize], eps: Eps) -> Mat {
    pamm_matmul_with(a, b_mat, gen_idx, eps, poolx::global())
}

/// End-to-end PAMM approximation of `O = AᵀB` on an explicit pool.
pub fn pamm_matmul_with(
    a: &Mat,
    b_mat: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    pool: &Pool,
) -> Mat {
    apply_with(&compress_with(a, gen_idx, eps, pool), b_mat, pool)
}

/// Exact `O = AᵀB` — the baseline PAMM replaces (t7/t8 comparison row).
/// Runs on the process-wide pool; see [`exact_matmul_with`].
pub fn exact_matmul(a: &Mat, b_mat: &Mat) -> Mat {
    exact_matmul_with(a, b_mat, poolx::global())
}

/// Exact `O = AᵀB` on an explicit pool: a column-strip
/// [`Mat::matmul_tn_with`], chosen over per-thread partial accumulators
/// because the strip reduction keeps f32 summation order fixed — the
/// result is bit-identical at any thread count (and there is no n×m
/// scratch allocation per worker).
pub fn exact_matmul_with(a: &Mat, b_mat: &Mat, pool: &Pool) -> Mat {
    a.matmul_tn_with(b_mat, pool)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        Mat::random_normal(rows, cols, 1.0, &mut rng)
    }

    #[test]
    fn self_generators_reconstruct_exactly() {
        // If every row is a generator, Ã = A and Õ = O exactly.
        let a = rand_mat(16, 8, 1);
        let b = rand_mat(16, 5, 2);
        let idx: Vec<usize> = (0..16).collect();
        let approx = pamm_matmul(&a, &b, &idx, Eps::Inf);
        let exact = exact_matmul(&a, &b);
        assert!(approx.max_abs_diff(&exact) < 1e-4, "{}", approx.max_abs_diff(&exact));
    }

    #[test]
    fn lemma1_assignment_minimizes_distance() {
        // The chosen generator must give the smallest reconstruction error
        // over all generators (Lemma 1: argmax |csim| == argmin distance).
        let a = rand_mat(64, 12, 3);
        let mut rng = Xoshiro256::new(4);
        let idx = sample_generators(&mut rng, 64, 6);
        let comp = compress(&a, &idx, Eps::Inf);
        let c = &comp.generators;
        for i in 0..a.rows() {
            let ai = a.row(i);
            let dist = |j: usize| -> f32 {
                // closest point on span{C_j}: α* = <a,c>/‖c‖²
                let cj = c.row(j);
                let al = dot(ai, cj) / dot(cj, cj).max(NORM_EPS);
                (0..ai.len()).map(|t| (ai[t] - al * cj[t]).powi(2)).sum::<f32>()
            };
            let chosen = dist(comp.assign[i] as usize);
            for j in 0..comp.k() {
                assert!(chosen <= dist(j) + 1e-4, "row {i}: {chosen} > dist({j})={}", dist(j));
            }
        }
    }

    #[test]
    fn eps_zero_keeps_only_collinear() {
        let a = rand_mat(32, 8, 5);
        let idx = vec![0, 7, 13];
        let comp = compress(&a, &idx, Eps::Val(0.0));
        // Generators themselves are exactly collinear with themselves.
        for (pos, &g) in idx.iter().enumerate() {
            assert_eq!(comp.assign[g] as usize, pos);
            assert!((comp.alpha[g] - 1.0).abs() < 1e-5, "alpha[{g}]={}", comp.alpha[g]);
        }
        // Random gaussian rows are a.s. not collinear with another row.
        let kept = comp.alpha.iter().filter(|a| **a != 0.0).count();
        assert_eq!(kept, idx.len());
        // β must then be b/k.
        assert!((comp.beta - 32.0 / 3.0).abs() < 1e-4);
    }

    #[test]
    fn beta_corrects_expectation() {
        // With eps=0 and k generators, Õ = (b/k)·Σ_{gen} A_gᵀB_g — an
        // unbiased estimator of O over the generator sampling. Check that
        // averaging over many samples approaches O.
        let a = rand_mat(64, 6, 8);
        let b = rand_mat(64, 4, 9);
        let exact = exact_matmul(&a, &b);
        let mut rng = Xoshiro256::new(10);
        let mut acc = Mat::zeros(6, 4);
        let trials = 4000;
        for _ in 0..trials {
            let idx = sample_generators(&mut rng, 64, 8);
            acc.add_assign(&pamm_matmul(&a, &b, &idx, Eps::Val(0.0)));
        }
        acc.scale(1.0 / trials as f32);
        let rel = acc.sub(&exact).frob_norm() / exact.frob_norm();
        assert!(rel < 0.05, "relative bias {rel}");
    }

    #[test]
    fn error_bound_of_section_321() {
        // ‖O − Õ_unscaled‖_F ≤ ‖B‖₂(ε²‖A_I‖² + ‖A_Ī‖²)^{1/2}; we check the
        // looser Frobenius form ‖B‖_F · ‖A − Ã‖_F which upper-bounds it.
        let a = rand_mat(48, 10, 11);
        let b = rand_mat(48, 7, 12);
        let mut rng = Xoshiro256::new(13);
        let idx = sample_generators(&mut rng, 48, 12);
        for eps in [Eps::Val(0.3), Eps::Val(0.7), Eps::Inf] {
            let comp = compress(&a, &idx, eps);
            // Unscaled estimate (β=1) is what the bound speaks about.
            let mut unscaled = comp.clone();
            unscaled.beta = 1.0;
            let otilde = apply(&unscaled, &b);
            let exact = exact_matmul(&a, &b);
            let lhs = exact.sub(&otilde).frob_norm();
            let a_err = a.sub(&comp.reconstruct()).frob_norm();
            let rhs = b.frob_norm() * a_err;
            assert!(lhs <= rhs + 1e-3, "lhs={lhs} rhs={rhs} eps={eps:?}");
        }
    }

    #[test]
    fn coverage_monotone_in_eps() {
        let a = rand_mat(128, 16, 14);
        let mut rng = Xoshiro256::new(15);
        let idx = sample_generators(&mut rng, 128, 8);
        let cov = |e: Eps| compress(&a, &idx, e).coverage();
        let c0 = cov(Eps::Val(0.0));
        let c05 = cov(Eps::Val(0.5));
        let c09 = cov(Eps::Val(0.9));
        let cinf = cov(Eps::Inf);
        assert!(c0 <= c05 && c05 <= c09 && c09 <= cinf);
        assert!((cinf - 1.0).abs() < 1e-9);
        assert!(c0 >= 8.0 / 128.0); // generators always self-cover
    }

    #[test]
    fn stored_bytes_matches_formula() {
        let a = rand_mat(256, 32, 16);
        let idx: Vec<usize> = (0..4).collect();
        let comp = compress(&a, &idx, Eps::Inf);
        assert_eq!(comp.stored_bytes(), 4 * 32 * 4 + 256 * 4 + 256 * 4 + 4);
        // vs raw activation: 256·32·4 = 32 KiB → ~12.6× smaller already at k=4.
        assert!(comp.stored_bytes() * 8 < 256 * 32 * 4);
    }

    #[test]
    fn thread_count_never_changes_the_compressed_output() {
        // Acceptance invariant: same seed ⇒ identical Compressed
        // (generators, assign, alpha, beta) at 1, 2 and 4 threads.
        let a = rand_mat(96, 12, 21);
        let mut rng = Xoshiro256::new(22);
        let idx = sample_generators(&mut rng, 96, 7);
        let dz = rand_mat(96, 9, 23);
        let serial = Pool::serial();
        let base = compress_with(&a, &idx, Eps::Inf, &serial);
        let base_dw = apply_with(&base, &dz, &serial);
        for threads in [2usize, 4] {
            let pool = Pool::new(threads).with_min_chunk(1);
            let comp = compress_with(&a, &idx, Eps::Inf, &pool);
            assert_eq!(comp.generators, base.generators, "t={threads}");
            assert_eq!(comp.assign, base.assign, "t={threads}");
            assert_eq!(comp.alpha, base.alpha, "t={threads}");
            assert_eq!(comp.beta.to_bits(), base.beta.to_bits(), "t={threads}");
            assert_eq!(apply_with(&comp, &dz, &pool), base_dw, "apply t={threads}");
            assert_eq!(
                exact_matmul_with(&a, &dz, &pool),
                exact_matmul_with(&a, &dz, &serial),
                "exact t={threads}"
            );
        }
    }

    #[test]
    fn dead_generator_takes_sparse_apply_and_matches_reconstruct() {
        // Duplicate a generator row: the later copy never wins the
        // strict argmax, so that generator receives no assignments under
        // ε = 0 → B̃ has a zero row → apply takes the hoisted-skip
        // sparse path. It must still match the reconstruct-then-multiply
        // identity, serial and parallel alike.
        let mut a = rand_mat(24, 6, 31);
        for j in 0..6 {
            let v = a.get(3, j);
            a.set(9, j, v);
        }
        let idx = vec![3, 9, 17];
        let comp = compress(&a, &idx, Eps::Val(0.0));
        assert_eq!(comp.assign[9], 0, "duplicate row must resolve to the first generator");
        assert!(comp.alpha[9] != 0.0);
        let (live, nlive) = generator_live(&comp);
        assert!(!live[1] && nlive == 2, "generator 1 must be dead: {live:?}");

        let bm = rand_mat(24, 5, 32);
        let mut want = comp.reconstruct().t_matmul(&bm);
        want.scale(comp.beta);
        let serial = apply_with(&comp, &bm, &Pool::serial());
        assert!(serial.max_abs_diff(&want) < 1e-4 * want.frob_norm().max(1.0));
        let pool = Pool::new(4).with_min_chunk(1);
        assert_eq!(apply_with(&comp, &bm, &pool), serial, "sparse apply parallel parity");
    }

    #[test]
    fn projected_generators_factor_the_reconstruction() {
        // Ã·W == diag(α)·1_f·(C·W): gather-scaling rows of G must match
        // reconstruct-then-multiply up to GEMM rounding.
        let a = rand_mat(40, 10, 41);
        let w = rand_mat(10, 6, 42);
        let mut rng = Xoshiro256::new(43);
        let idx = sample_generators(&mut rng, 40, 5);
        let comp = compress(&a, &idx, Eps::Val(0.6)); // some dropped rows
        let g = comp.project_generators(&w);
        assert_eq!((g.rows(), g.cols()), (5, 6));
        let want = comp.reconstruct().matmul(&w);
        for i in 0..40 {
            let al = comp.alpha[i];
            for j in 0..6 {
                let got = al * g.get(comp.assign[i] as usize, j);
                assert!(
                    (got - want.get(i, j)).abs() <= 1e-4 * want.get(i, j).abs().max(1.0),
                    "row {i} col {j}: {got} vs {}",
                    want.get(i, j)
                );
            }
        }
    }

    #[test]
    fn grad_w_is_the_apply_estimator_and_exact_at_full_rank() {
        let a = rand_mat(32, 8, 61);
        let dz = rand_mat(32, 5, 62);
        let mut rng = Xoshiro256::new(63);
        let idx = sample_generators(&mut rng, 32, 6);
        let comp = compress(&a, &idx, Eps::Inf);
        // The VJP name is the estimator: grad_w ≡ apply, bitwise.
        assert_eq!(grad_w(&comp, &dz), apply(&comp, &dz));
        // All-generators ⇒ Ã = A, β = 1 ⇒ grad_w == the exact dense
        // gradient AᵀdZ up to Lemma-1 rounding of α.
        let full: Vec<usize> = (0..32).collect();
        let comp = compress(&a, &full, Eps::Inf);
        assert_eq!(comp.beta, 1.0);
        let exact = exact_matmul(&a, &dz);
        let got = grad_w(&comp, &dz);
        assert!(got.max_abs_diff(&exact) < 1e-4 * exact.frob_norm().max(1.0));
    }

    #[test]
    fn incremental_fold_matches_batch_compress_bitwise() {
        // Compress a 16-row prefix, fold the remaining rows one at a
        // time, and demand the running Compressed is field-for-field
        // bit-equal to a one-shot compression of all rows — the
        // decode-cache recurrence contract.
        let mut a = rand_mat(48, 12, 71);
        for j in 0..12 {
            a.set(30, j, 0.0); // a dropped row in the folded region
        }
        let mut rng = Xoshiro256::new(72);
        let idx = sample_generators(&mut rng, 16, 5);
        for eps in [Eps::Inf, Eps::Val(0.6)] {
            let pool = Pool::serial();
            let full = compress_with(&a, &idx, eps, &pool);
            let prefix = Mat::from_fn(16, 12, |i, j| a.get(i, j));
            let mut comp = compress_with(&prefix, &idx, eps, &pool);
            let mut inc = IncrementalCompressor::new(&comp);
            for i in 16..48 {
                inc.fold(&mut comp, a.row(i), eps);
            }
            assert_eq!(comp.generators, full.generators, "{eps:?}");
            assert_eq!(comp.assign, full.assign, "{eps:?}");
            let got: Vec<u32> = comp.alpha.iter().map(|v| v.to_bits()).collect();
            let want: Vec<u32> = full.alpha.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "{eps:?}");
            assert_eq!(comp.beta.to_bits(), full.beta.to_bits(), "{eps:?}");
        }
    }

    #[test]
    fn incremental_fold_thread_and_stored_bytes_invariants() {
        // The batch side of the parity can run on any pool; folds are
        // serial by construction. Also pin the incremental state bytes.
        let a = rand_mat(40, 8, 81);
        let mut rng = Xoshiro256::new(82);
        let idx = sample_generators(&mut rng, 20, 6);
        let pool = Pool::new(4).with_min_chunk(1);
        let full = compress_with(&a, &idx, Eps::Inf, &pool);
        let prefix = Mat::from_fn(20, 8, |i, j| a.get(i, j));
        let mut comp = compress_with(&prefix, &idx, Eps::Inf, &pool);
        let mut inc = IncrementalCompressor::new(&comp);
        assert_eq!(inc.stored_bytes(), 6 * 8 * 4 + 6 * 4);
        for i in 20..40 {
            inc.fold(&mut comp, a.row(i), Eps::Inf);
        }
        assert_eq!(comp.assign, full.assign);
        assert_eq!(comp.alpha, full.alpha);
        assert_eq!(comp.beta.to_bits(), full.beta.to_bits());
    }

    #[test]
    fn zero_rows_are_dropped_and_beta_adjusts() {
        let mut a = rand_mat(10, 4, 17);
        for j in 0..4 {
            a.set(3, j, 0.0);
            a.set(7, j, 0.0);
        }
        let comp = compress(&a, &[0, 1], Eps::Inf);
        assert_eq!(comp.alpha[3], 0.0);
        assert_eq!(comp.alpha[7], 0.0);
        assert!((comp.beta - 10.0 / 8.0).abs() < 1e-6);
    }
}
