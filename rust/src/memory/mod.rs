//! Activation-memory accountant — the paper's memory story, made exact.
//!
//! Fig. 3b, Table 1, Table 4 and Table 5 all report "memory consumed by
//! the activations of the Q, K, V projection layers". That quantity is an
//! exact analytic function of the model geometry and batch shape, so we
//! reproduce it *at the paper's own scales* (LLaMA-60M…7B, RoBERTa-base)
//! analytically, and cross-validate the formulas at runnable scales
//! against the native `pamm::Compressed::stored_bytes` of real tensors
//! (integration tests).
//!
//! Accounting conventions (documented, because the paper is implicit):
//!
//! * Q, K and V projections of one attention block read the *same*
//!   RMSNorm output; a framework that saves tensors by storage keeps ONE
//!   copy per block. `qkv_saved_bytes` therefore counts `n_layers` copies
//!   (not 3×). The paper's Table 5 numbers for full-rank LLaMA match this
//!   convention at fp32 for 60M (b=131072: 8·b·512·4 ≈ 2 GB global ⇒
//!   256 MB per GPU at 8-way DDP — exactly the table's "256 MB").
//! * PAMM replaces that tensor with C (k×n) + α (b f32) + f (b i32) + β,
//!   per block — `pamm_saved_bytes` (the paper's App. D "this includes
//!   the α and f(·)").

use crate::runtime::ConfigMeta;

pub const BYTES_F32: usize = 4;

/// Model geometry needed by the accountant (mirrors python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelGeometry {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl ModelGeometry {
    pub fn from_meta(m: &ConfigMeta) -> Self {
        Self {
            name: m.name.clone(),
            vocab: m.vocab,
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            d_ff: m.d_ff,
        }
    }

    /// The paper-scale zoo (matches python/compile/model.py CONFIGS).
    pub fn zoo() -> Vec<ModelGeometry> {
        let mk = |name: &str, vocab, d_model, n_layers, n_heads, d_ff| ModelGeometry {
            name: name.into(),
            vocab,
            d_model,
            n_layers,
            n_heads,
            d_ff,
        };
        vec![
            mk("nano", 256, 64, 2, 2, 176),
            mk("tiny", 512, 128, 4, 4, 344),
            mk("small", 1024, 256, 6, 8, 688),
            mk("medium", 2048, 512, 8, 8, 1376),
            mk("llama60m", 32000, 512, 8, 8, 1376),
            mk("llama350m", 32000, 1024, 24, 16, 2736),
            mk("llama1b", 32000, 2048, 24, 32, 5461),
            mk("llama7b", 32000, 4096, 32, 32, 11008),
        ]
    }

    pub fn by_name(name: &str) -> Option<ModelGeometry> {
        Self::zoo().into_iter().find(|g| g.name == name)
    }

    /// Exact trainable-parameter count (must equal the manifest's
    /// `param_count` — cross-checked in integration tests).
    pub fn param_count(&self) -> usize {
        let (d, f, v, l) = (self.d_model, self.d_ff, self.vocab, self.n_layers);
        let per_layer = 4 * d * d + 3 * d * f + 2 * d;
        v * d + l * per_layer + d + d * v
    }

    /// FLOPs per token for one fwd+bwd step (standard 6·N approximation
    /// plus exact attention terms) — used by throughput projections.
    pub fn train_flops_per_token(&self, seq: usize) -> f64 {
        let n = self.param_count() as f64;
        let attn = (self.n_layers * seq * self.d_model) as f64 * 2.0; // scores+mix
        6.0 * n + 6.0 * attn
    }
}

/// Memory report row for one (model, batch-shape, variant) cell.
#[derive(Debug, Clone)]
pub struct MemoryReport {
    pub model: String,
    pub batch: usize,
    pub seq: usize,
    /// Full-rank saved activations of all QKV projections (bytes).
    pub baseline_bytes: usize,
    /// PAMM replacement (bytes), if a ratio was given.
    pub pamm_bytes: Option<usize>,
    pub r: Option<f64>,
}

impl MemoryReport {
    pub fn savings_pct(&self) -> Option<f64> {
        self.pamm_bytes
            .map(|p| 100.0 * (1.0 - p as f64 / self.baseline_bytes.max(1) as f64))
    }
}

/// Bytes saved-for-backward by all QKV projections, full baseline.
/// One shared input per block (see module docs), `n_layers` blocks.
pub fn qkv_saved_bytes(g: &ModelGeometry, batch: usize, seq: usize, bytes_per: usize) -> usize {
    g.n_layers * batch * seq * g.d_model * bytes_per
}

/// PAMM's replacement: per block C (k×n) + α (b×f32) + f (b×i32) + β.
pub fn pamm_saved_bytes(
    g: &ModelGeometry,
    batch: usize,
    seq: usize,
    r: f64,
    bytes_per: usize,
) -> usize {
    let b = batch * seq;
    let k = ((r * b as f64).ceil() as usize).max(1);
    let per_proj = k * g.d_model * bytes_per + b * bytes_per + b * 4 + 4;
    g.n_layers * 3 * per_proj
}

/// Uniform-CRS replacement: the k sampled rows + indices, per block.
pub fn crs_saved_bytes(g: &ModelGeometry, batch: usize, seq: usize, r: f64) -> usize {
    let b = batch * seq;
    let k = ((r * b as f64).ceil() as usize).max(1);
    g.n_layers * (k * g.d_model * BYTES_F32 + k * 4)
}

/// CompAct replacement: the (b, k) sketch per block.
pub fn compact_saved_bytes(g: &ModelGeometry, batch: usize, seq: usize, r: f64) -> usize {
    let b = batch * seq;
    let k = ((r * b as f64).ceil() as usize).max(1);
    g.n_layers * (b * k * BYTES_F32 + 8)
}

pub fn report(g: &ModelGeometry, batch: usize, seq: usize, r: Option<f64>) -> MemoryReport {
    MemoryReport {
        model: g.name.clone(),
        batch,
        seq,
        baseline_bytes: qkv_saved_bytes(g, batch, seq, BYTES_F32),
        pamm_bytes: r.map(|r| pamm_saved_bytes(g, batch, seq, r, BYTES_F32)),
        r,
    }
}

pub fn fmt_bytes(b: usize) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.1} MB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.1} KB", b / KB)
    } else {
        format!("{b} B")
    }
}

/// Measured-bytes tracker for transient allocations on the native hot
/// paths — the runtime counterpart of the analytic accountant above.
/// Callers report what they actually allocate
/// ([`MemoryTracker::alloc`] / [`MemoryTracker::free`]); the tracker
/// maintains the live total and its high-water mark. All counters are
/// atomic, so one tracker can be shared across pool workers and a
/// parallel kernel's per-thread scratch folds into a single peak
/// figure. `attention::pamm_qkv_attention` uses it to *measure* that
/// the fused path never materializes full Q/K/V (asserted in
/// `rust/tests/prop_attention.rs` against `attention::fused_peak_bound`)
/// instead of trusting the analytic `qkv_saved_bytes` model.
#[derive(Debug, Default)]
pub struct MemoryTracker {
    live: std::sync::atomic::AtomicUsize,
    peak: std::sync::atomic::AtomicUsize,
}

impl MemoryTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `bytes` newly allocated; advances the peak when the live
    /// total now exceeds it.
    pub fn alloc(&self, bytes: usize) {
        use std::sync::atomic::Ordering::Relaxed;
        let live = self.live.fetch_add(bytes, Relaxed) + bytes;
        self.peak.fetch_max(live, Relaxed);
    }

    /// Record `bytes` released (saturates at zero so an over-reported
    /// free cannot wrap the counter).
    pub fn free(&self, bytes: usize) {
        use std::sync::atomic::Ordering::Relaxed;
        let _ = self.live.fetch_update(Relaxed, Relaxed, |v| Some(v.saturating_sub(bytes)));
    }

    /// Bytes currently accounted live.
    pub fn live(&self) -> usize {
        self.live.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// High-water mark of the live total since construction/reset.
    pub fn peak(&self) -> usize {
        self.peak.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn reset(&self) {
        use std::sync::atomic::Ordering::Relaxed;
        self.live.store(0, Relaxed);
        self.peak.store(0, Relaxed);
    }
}

/// Per-phase **memory ledger** of one native train step — the measured
/// counterpart of the paper's Table 7 memory story, split the way a
/// training framework experiences it:
///
/// * **forward** — transients live only while the forward runs
///   (compress Gram strips, projected generators, per-worker tile
///   scratch growth). Peak tracked by a [`MemoryTracker`].
/// * **saved** — bytes that persist *between* forward and backward:
///   for the PAMM path, `Compressed::stored_bytes()` plus the O(seq)
///   flash softmax statistics — the quantity the paper's ×512 claim is
///   about. An exact running total, not a peak (nothing transient
///   here by definition).
/// * **backward** — transients of the backward (recomputed `G = C·W`,
///   the dQ/dK/dV grid buffer, merged projection gradients). Peak
///   tracked by a second [`MemoryTracker`].
///
/// `crate::autograd` fills one of these per tracked step and asserts
/// `saved` against both the analytic inventory and the dense baseline
/// (`autograd::dense_saved_bytes`); `pamm ledger` renders it.
#[derive(Debug, Default)]
pub struct MemoryLedger {
    /// Forward-pass transient tracker.
    pub forward: MemoryTracker,
    /// Backward-pass transient tracker.
    pub backward: MemoryTracker,
    saved: std::sync::atomic::AtomicUsize,
}

impl MemoryLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record bytes that persist from forward to backward (additive —
    /// a multi-layer tape calls this once per layer).
    pub fn record_saved(&self, bytes: usize) {
        self.saved.fetch_add(bytes, std::sync::atomic::Ordering::Relaxed);
    }

    /// Saved-for-backward bytes recorded so far.
    pub fn saved(&self) -> usize {
        self.saved.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.forward.reset();
        self.backward.reset();
        self.saved.store(0, std::sync::atomic::Ordering::Relaxed);
    }

    /// Render the ledger as the `pamm ledger` table, against a dense
    /// saved-activation baseline for the compression-factor row.
    pub fn render(&self, dense_saved: usize) -> String {
        let saved = self.saved();
        let factor = dense_saved as f64 / saved.max(1) as f64;
        let mut out = String::new();
        out.push_str(&format!("{:<28} {:>12}\n", "phase", "bytes"));
        out.push_str(&format!(
            "{:<28} {:>12}\n",
            "forward transient peak",
            fmt_bytes(self.forward.peak())
        ));
        out.push_str(&format!("{:<28} {:>12}\n", "saved for backward", fmt_bytes(saved)));
        out.push_str(&format!(
            "{:<28} {:>12}\n",
            "backward transient peak",
            fmt_bytes(self.backward.peak())
        ));
        out.push_str(&format!(
            "{:<28} {:>12}\n",
            "dense saved baseline",
            fmt_bytes(dense_saved)
        ));
        out.push_str(&format!("{:<28} {:>11.1}x\n", "saved compression factor", factor));
        out
    }
}

/// Peak-memory *tracker* for live runs: the coordinator feeds it per-step
/// allocation observations (activation bytes are analytic; host-side
/// buffers are measured) and it keeps high-water marks per tag.
#[derive(Debug, Default)]
pub struct PeakTracker {
    peaks: std::collections::BTreeMap<String, usize>,
}

impl PeakTracker {
    pub fn observe(&mut self, tag: &str, bytes: usize) {
        let e = self.peaks.entry(tag.to_string()).or_insert(0);
        if bytes > *e {
            *e = bytes;
        }
    }
    pub fn peak(&self, tag: &str) -> usize {
        self.peaks.get(tag).copied().unwrap_or(0)
    }
    pub fn rows(&self) -> impl Iterator<Item = (&String, &usize)> {
        self.peaks.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(name: &str) -> ModelGeometry {
        ModelGeometry::by_name(name).unwrap()
    }

    #[test]
    fn paper_table5_full_rank_60m() {
        // Paper setup: global batch 512 × seq 256 on 8 GPUs ⇒ per-GPU
        // b = 64·256 = 16384 tokens. LLaMA-60M: 8 layers, d=512, fp32.
        // 8 · 16384 · 512 · 4 B = 256 MB — exactly Table 5's "256 MB".
        let bytes = qkv_saved_bytes(&g("llama60m"), 64, 256, BYTES_F32);
        assert_eq!(bytes, 256 * 1024 * 1024);
    }

    #[test]
    fn paper_table5_full_rank_1b() {
        // LLaMA-1B: 24 layers, d=2048, per-GPU b = 16384, fp32 ⇒ 3 GB
        // (Table 5's "3 GB").
        let bytes = qkv_saved_bytes(&g("llama1b"), 64, 256, BYTES_F32);
        assert_eq!(bytes, 3 * 1024 * 1024 * 1024);
    }

    #[test]
    fn paper_table5_pamm_is_a_few_mb() {
        // Table 5 reports 3.5 MB at r=1/512 for 60M (incl. α and f).
        let bytes = pamm_saved_bytes(&g("llama60m"), 64, 256, 1.0 / 512.0, BYTES_F32);
        let mb = bytes as f64 / (1024.0 * 1024.0);
        assert!((2.0..6.0).contains(&mb), "got {mb} MB");
        // And savings > 97% at every size (Fig. 3b claim).
        for name in ["llama60m", "llama350m", "llama1b", "llama7b"] {
            let rep = report(&g(name), 64, 256, Some(1.0 / 512.0));
            assert!(rep.savings_pct().unwrap() > 97.0, "{name}: {:?}", rep.savings_pct());
        }
    }

    #[test]
    fn savings_monotone_in_r() {
        let gm = g("llama350m");
        let s512 = pamm_saved_bytes(&gm, 64, 256, 1.0 / 512.0, BYTES_F32);
        let s128 = pamm_saved_bytes(&gm, 64, 256, 1.0 / 128.0, BYTES_F32);
        let s16 = pamm_saved_bytes(&gm, 64, 256, 1.0 / 16.0, BYTES_F32);
        assert!(s512 < s128 && s128 < s16);
    }

    #[test]
    fn compact_dominates_pamm_at_equal_r() {
        // The Fig. 4a x-axis gap: CompAct's (b,k) sketch ≫ PAMM's k·n + 2b
        // whenever k > n/b·k + 2 — true for every paper setting.
        let gm = g("llama60m");
        let r = 1.0 / 128.0;
        assert!(
            compact_saved_bytes(&gm, 64, 256, r) > pamm_saved_bytes(&gm, 64, 256, r, BYTES_F32)
        );
    }

    #[test]
    fn param_counts_are_in_the_advertised_ballpark() {
        // Names are nominal; counts should land within ~35% of the label
        // (the paper's own "60M/350M/1B/7B" are similarly nominal).
        let expect = [
            ("llama60m", 58e6),
            ("llama350m", 345e6),
            ("llama1b", 1.2e9),
            ("llama7b", 6.8e9),
        ];
        for (name, approx) in expect {
            let n = g(name).param_count() as f64;
            assert!(
                (n / approx - 1.0).abs() < 0.35,
                "{name}: {n:.2e} vs nominal {approx:.1e}"
            );
        }
    }

    #[test]
    fn fmt_bytes_readable() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3.00 GB");
        assert!(fmt_bytes(256 * 1024 * 1024).starts_with("256"));
    }

    #[test]
    fn memory_tracker_alloc_free_peak() {
        let t = MemoryTracker::new();
        assert_eq!((t.live(), t.peak()), (0, 0));
        t.alloc(100);
        t.alloc(50);
        t.free(120);
        t.alloc(10);
        assert_eq!(t.live(), 40);
        assert_eq!(t.peak(), 150, "peak is the high-water mark, not the final live total");
        t.free(1000); // saturates, never wraps
        assert_eq!(t.live(), 0);
        t.reset();
        assert_eq!((t.live(), t.peak()), (0, 0));
    }

    #[test]
    fn memory_tracker_is_shareable_across_threads() {
        let t = MemoryTracker::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        t.alloc(3);
                        t.free(3);
                    }
                });
            }
        });
        assert_eq!(t.live(), 0);
        assert!(t.peak() >= 3 && t.peak() <= 12);
    }

    #[test]
    fn memory_ledger_phases_are_independent_and_render() {
        let l = MemoryLedger::new();
        l.forward.alloc(1000);
        l.forward.free(1000);
        l.record_saved(64);
        l.record_saved(36); // second layer of a tape adds on
        l.backward.alloc(500);
        assert_eq!(l.forward.peak(), 1000);
        assert_eq!(l.saved(), 100);
        assert_eq!(l.backward.peak(), 500);
        let table = l.render(100 * 64);
        assert!(table.contains("saved for backward"), "{table}");
        assert!(table.contains("64.0x"), "factor row: {table}");
        l.reset();
        assert_eq!((l.forward.peak(), l.saved(), l.backward.peak()), (0, 0, 0));
    }

    #[test]
    fn peak_tracker_high_water() {
        let mut t = PeakTracker::default();
        t.observe("qkv", 100);
        t.observe("qkv", 50);
        t.observe("qkv", 120);
        assert_eq!(t.peak("qkv"), 120);
        assert_eq!(t.peak("missing"), 0);
    }

    #[test]
    fn k_floor_of_one_generator() {
        // Finetuning can have r·b < 1 (paper App. G: k = 1); the formula
        // must floor at one generator, never zero.
        let gm = g("tiny");
        let bytes = pamm_saved_bytes(&gm, 1, 8, 1.0 / 512.0, BYTES_F32);
        // k=1 ⇒ per projection: 1·128·4 + 8·4 + 8·4 + 4 = 580; ×3 per block.
        assert_eq!(bytes, gm.n_layers * 3 * (128 * 4 + 8 * 4 + 8 * 4 + 4));
    }
}
