//! Minimal dense f32 matrix used by the native substrates.
//!
//! This is deliberately *not* a general ndarray: the PAMM hot paths need
//! exactly 2-D row-major matrices with a handful of contractions
//! (`a @ b`, `aᵀ @ b`, row gathers, row norms). Model compute runs inside
//! PJRT executables; this type exists for the native PAMM twin
//! (rust/src/pamm), the data pipeline, metrics, and tests.
//!
//! Both matmuls route through the [`kernels`] subsystem: a
//! register-blocked, panel-packed GEMM micro-kernel with runtime SIMD
//! dispatch (`PAMM_SIMD=scalar|sse2|avx2|native`). Transposition is
//! absorbed by the packing step, so `t_matmul` (`AᵀB`) never
//! materializes the transpose, and every dispatch level produces
//! bit-identical output (see the determinism contract in
//! [`kernels`]). The dense paths carry no zero-skip branches — sparse
//! structure is exploited one level up, where the caller knows it
//! exists (`pamm::apply`'s dead-generator mask).
//!
//! Each hot contraction comes in two forms: a serial reference
//! ([`Mat::matmul`], [`Mat::t_matmul`], [`Mat::row_norms`]) and a
//! pool-parallel twin ([`Mat::matmul_with`], [`Mat::matmul_tn_with`],
//! [`Mat::row_norms_with`]) that row-blocks (or column-strips) the work
//! over a shared [`Pool`]. The parallel decompositions partition only M
//! or N — never the contraction dim — and the serial and parallel
//! entry points share one kernel, so outputs are bit-identical at every
//! thread count; below the pool's serial-fallback threshold they run
//! inline with zero synchronization cost. Parallel results are stitched
//! by their chunk offsets, never by iteration order, so a reordered
//! `map_chunks` could not scramble output rows.

pub mod kernels;

use std::fmt;

use crate::poolx::Pool;

#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Gather the given rows into a new matrix (PAMM's `C = A[idx]`).
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Per-row L2 norms.
    pub fn row_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|x| x * x).sum::<f32>().sqrt())
            .collect()
    }

    /// Parallel [`Mat::row_norms`] over row blocks of the shared pool.
    /// Rows are independent, so this is bit-identical at any thread
    /// count. Stitched through [`Pool::map_chunks_flat`]: each block
    /// lands at its `(start, end)` offset — correctness does not depend
    /// on `map_chunks` returning chunks in range order.
    pub fn row_norms_with(&self, pool: &Pool) -> Vec<f32> {
        pool.map_chunks_flat(self.rows, 1, |s, e, out| {
            for (i, o) in (s..e).zip(out.iter_mut()) {
                *o = self.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            }
        })
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Output rows `[s, e)` of `self @ other` into `block` (row-major
    /// `(e-s) × m`, zero-initialized by the caller) via the blocked
    /// [`kernels`] GEMM. Shared by the serial and parallel entry points,
    /// and the kernel's accumulation order is invariant to the row
    /// partition, so the bit-identity of the row-block decomposition
    /// holds by construction.
    fn matmul_rows(&self, other: &Mat, s: usize, e: usize, block: &mut [f32]) {
        let (k, m) = (self.cols, other.cols);
        kernels::gemm_auto(false, e - s, m, k, &self.data[s * k..e * k], k, &other.data, m, block, m);
    }

    /// `self @ other` through the microkernel GEMM (dense — no
    /// zero-skip branches; see the module docs).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (n, m) = (self.rows, other.cols);
        let mut out = Mat::zeros(n, m);
        self.matmul_rows(other, 0, n, &mut out.data);
        out
    }

    /// Parallel [`Mat::matmul`] over row blocks of `self`. Each worker
    /// runs the same `matmul_rows` kernel on a contiguous block of
    /// output rows, so the result is bit-identical to `matmul` at any
    /// thread count; blocks are stitched by [`Pool::map_chunks_flat`]
    /// at their `(start, end)` offsets (exactly-once asserted), not
    /// appended in chunk-iteration order. Falls back to the serial path
    /// below the pool's chunk threshold.
    pub fn matmul_with(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (n, m) = (self.rows, other.cols);
        if pool.chunks_for(n) <= 1 {
            return self.matmul(other);
        }
        let data =
            pool.map_chunks_flat(n, m, |s, e, block| self.matmul_rows(other, s, e, block));
        Mat::from_vec(n, m, data)
    }

    /// `selfᵀ @ other` without materializing the transpose — the exact
    /// `∇W = Xᵀ∇Z` contraction PAMM replaces (the baseline in t7/t8).
    ///
    /// Dense by design: the transposed read is absorbed by the kernel's
    /// packing step, and there is no per-element zero test in the inner
    /// loops (the old `a == 0.0` skip poisoned vectorization of this
    /// exact path). Callers that *know* whole source rows are zero —
    /// `pamm::apply` with dead generators — hoist that test above the
    /// kernel instead.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (b, n, m) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(n, m);
        kernels::gemm_auto(true, n, m, b, &self.data, n, &other.data, m, &mut out.data, m);
        out
    }

    /// Copy columns `[j0, j1)` into a new matrix. The column-parallel
    /// kernels no longer need this (they read strips in place through
    /// the GEMM's row stride); kept as a utility for callers that want
    /// an owned slice.
    pub fn slice_cols(&self, j0: usize, j1: usize) -> Mat {
        let w = j1 - j0;
        let mut out = Mat::zeros(self.rows, w);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[j0..j1]);
        }
        out
    }

    /// Paste a `rows × (j1-j0)` strip into columns `[j0, j1)` of `self`
    /// — the inverse of [`Mat::slice_cols`], shared by the column-strip
    /// kernels' stitch loops.
    pub fn paste_cols(&mut self, j0: usize, j1: usize, strip: &Mat) {
        let w = j1 - j0;
        assert_eq!((strip.rows, strip.cols), (self.rows, w), "paste_cols shape mismatch");
        let m = self.cols;
        for i in 0..self.rows {
            self.data[i * m + j0..i * m + j1].copy_from_slice(&strip.data[i * w..(i + 1) * w]);
        }
    }

    /// Parallel [`Mat::t_matmul`] (`selfᵀ @ other`, "tn" = transposed ×
    /// normal) over column strips of the output: each strip is one
    /// kernel GEMM reading its B columns *in place* (offset `j0`,
    /// stride `m` — no materialized slice), so every output element
    /// accumulates over the b rows in the same ascending order as the
    /// serial path — bit-identical at any thread count by construction.
    /// Column strips (not per-thread partial sums) are what make the
    /// reduction deterministic.
    pub fn matmul_tn_with(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (b, n, m) = (self.rows, self.cols, other.cols);
        let strip_pool = pool.for_columns();
        if b == 0 || strip_pool.chunks_for(m) <= 1 {
            return self.t_matmul(other);
        }
        let strips = strip_pool.map_chunks(m, |j0, j1| {
            let w = j1 - j0;
            let mut strip = Mat::zeros(n, w);
            kernels::gemm_auto(true, n, w, b, &self.data, n, &other.data[j0..], m, &mut strip.data, w);
            strip
        });
        let mut out = Mat::zeros(n, m);
        for (j0, j1, strip) in strips {
            out.paste_cols(j0, j1, &strip);
        }
        out
    }

    pub fn scale(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(other.data.iter()).map(|(a, b)| a - b).collect();
        Mat::from_vec(self.rows, self.cols, data)
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn random_normal(
        rows: usize,
        cols: usize,
        std: f32,
        rng: &mut crate::rngx::Xoshiro256,
    ) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal_f32(&mut m.data, std);
        m
    }
}

/// Dot product of two equal-length slices (hot helper for csim rows).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Xoshiro256;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let id = Mat::from_fn(3, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&id), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Xoshiro256::new(1);
        let a = Mat::random_normal(17, 5, 1.0, &mut rng);
        let b = Mat::random_normal(17, 7, 1.0, &mut rng);
        let direct = a.t_matmul(&b);
        let via_t = a.transpose().matmul(&b);
        assert!(direct.max_abs_diff(&via_t) < 1e-5);
    }

    #[test]
    fn gather_and_norms() {
        let a = Mat::from_vec(3, 2, vec![3., 4., 0., 0., 1., 0.]);
        let g = a.gather_rows(&[2, 0]);
        assert_eq!(g.data(), &[1., 0., 3., 4.]);
        let norms = a.row_norms();
        assert!((norms[0] - 5.0).abs() < 1e-6);
        assert_eq!(norms[1], 0.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256::new(2);
        let a = Mat::random_normal(4, 9, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn parallel_kernels_match_serial_bitwise() {
        let mut rng = Xoshiro256::new(3);
        let a = Mat::random_normal(67, 23, 1.0, &mut rng);
        let b = Mat::random_normal(23, 31, 1.0, &mut rng);
        let c = Mat::random_normal(67, 29, 1.0, &mut rng);
        for threads in [2usize, 4] {
            // min_chunk 1 forces a real parallel split even at test sizes.
            let pool = Pool::new(threads).with_min_chunk(1);
            assert_eq!(a.matmul_with(&b, &pool), a.matmul(&b), "matmul t={threads}");
            assert_eq!(a.matmul_tn_with(&c, &pool), a.t_matmul(&c), "matmul_tn t={threads}");
            assert_eq!(a.row_norms_with(&pool), a.row_norms(), "row_norms t={threads}");
        }
    }

    #[test]
    fn small_matrices_take_the_serial_fallback() {
        // Below the pool's min_chunk threshold the parallel entry points
        // must degrade to the serial kernels (still exact, no workers).
        let pool = Pool::new(4).with_min_chunk(256);
        assert_eq!(pool.chunks_for(8), 1);
        let mut rng = Xoshiro256::new(4);
        let a = Mat::random_normal(8, 6, 1.0, &mut rng);
        let b = Mat::random_normal(6, 5, 1.0, &mut rng);
        let c = Mat::random_normal(8, 7, 1.0, &mut rng);
        assert_eq!(a.matmul_with(&b, &pool), a.matmul(&b));
        assert_eq!(a.matmul_tn_with(&c, &pool), a.t_matmul(&c));
        assert_eq!(a.row_norms_with(&pool), a.row_norms());
    }

    #[test]
    fn slice_and_paste_cols_roundtrip() {
        let mut rng = Xoshiro256::new(9);
        let a = Mat::random_normal(5, 7, 1.0, &mut rng);
        let s = a.slice_cols(2, 6);
        assert_eq!((s.rows(), s.cols()), (5, 4));
        let mut b = Mat::zeros(5, 7);
        b.paste_cols(2, 6, &s);
        for i in 0..5 {
            for j in 2..6 {
                assert_eq!(b.get(i, j), a.get(i, j));
            }
        }
    }

    #[test]
    fn mat_entry_points_match_explicit_scalar_kernel() {
        // Whatever dispatch level is active (env-dependent in CI), the
        // Mat entry points must agree bit-for-bit with an explicit
        // scalar-dispatch kernel call — the determinism contract.
        let mut rng = Xoshiro256::new(7);
        let a = Mat::random_normal(21, 13, 1.0, &mut rng);
        let b = Mat::random_normal(13, 11, 1.0, &mut rng);
        let mut want = Mat::zeros(21, 11);
        let mut packs = kernels::PackBufs::default();
        kernels::gemm_into(
            kernels::Dispatch::Scalar,
            false,
            21,
            11,
            13,
            a.data(),
            13,
            b.data(),
            11,
            &mut want.data,
            11,
            &mut packs,
        );
        let got = a.matmul(&b);
        for (g, w) in got.data().iter().zip(want.data()) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        // And the transposed read: t_matmul == transpose-then-matmul
        // numerically (different packing path, same accumulation order).
        let c = Mat::random_normal(13, 9, 1.0, &mut rng);
        let tm = b.t_matmul(&c); // (11, 9) from (13,11)ᵀ·(13,9)
        let via_t = b.transpose().matmul(&c);
        for (g, w) in tm.data().iter().zip(via_t.data()) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn empty_matmuls_have_empty_or_zero_results() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        assert_eq!(a.matmul(&b).rows(), 0);
        let c = Mat::zeros(4, 0);
        let d = Mat::zeros(0, 3);
        // k = 0: the product is defined and all-zero.
        assert_eq!(c.matmul(&d), Mat::zeros(4, 3));
        assert_eq!(a.t_matmul(&Mat::zeros(0, 2)), Mat::zeros(5, 2));
    }

    #[test]
    fn frob_and_diff() {
        let a = Mat::from_vec(1, 2, vec![3., 4.]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-6);
        let b = Mat::from_vec(1, 2, vec![3., 4.5]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
    }
}
