//! Register-blocked, panel-packed f32 GEMM microkernel with runtime
//! SIMD dispatch — the single contraction engine under every native
//! PAMM hot path.
//!
//! One kernel serves every call site: `Mat::matmul` (A·B),
//! `Mat::t_matmul` (Aᵀ·B without materializing the transpose), the
//! Gram pass `S = A·Cᵀ` inside `pamm::compress`, the `Cᵀ·B̃`
//! contraction inside `pamm::apply`, and the per-tile `Q·Kᵀ` / `P·V`
//! contractions of the flash-attention walk (`crate::attention`).
//! Transposition is absorbed by the packing step, so there is exactly
//! one inner loop to optimize and one accumulation order to keep
//! deterministic — which is how the bit-identity ladder extends from
//! GEMM to attention for free.
//!
//! # Blocking scheme (BLIS-style)
//!
//! ```text
//! for jc in N by NC:                 // B block column  → L3
//!   for pc in K by KC:               // panel depth     → pb: KC×NC
//!     pack_b  (NR-wide column strips, zero-padded tails)
//!     for ic in M by MC:             // A block row     → pa: MC×KC, L2
//!       pack_a (MR-tall row strips, zero-padded tails)
//!       for each (MR × NR) micro-tile: micro-kernel over kc
//! ```
//!
//! The micro-kernel holds an MR×NR accumulator tile in registers,
//! broadcasts one A value per row and multiplies it against an NR-wide
//! B vector — `MR` reuses of every B load, `NR` of every A load. Tile
//! sizes: MR = NR = 8 keeps the AVX2 variant at 8 ymm accumulators +
//! 2 operand registers (half the 16-register file, room for the loop
//! machinery), and one 8-float vector is exactly one ymm / two xmm.
//! KC = 256 puts a B strip (KC×NR×4 = 8 KiB) well inside L1 and an A
//! panel (MC×KC×4 = 128 KiB at MC = 128) inside L2; NC = 2048 bounds
//! the packed B panel at 2 MiB.
//!
//! # Dispatch ladder
//!
//! `scalar → sse2 → avx2`, highest available level wins
//! ([`Dispatch::native`]). Selection order: a programmatic [`force`]
//! override (benches / `pamm kernels --probe`), else the `PAMM_SIMD`
//! env var (`scalar|sse2|avx2|avx2fma|avx512|native`, parsed once),
//! else native. The SIMD paths are `std::arch` behind
//! `#[target_feature]` with CPU support checked at selection time;
//! non-x86_64 hosts always take the scalar path. "Scalar" means
//! portable Rust — LLVM may still autovectorize it, which is fine
//! because…
//!
//! # Fast tier (opt-in, tolerance-checked)
//!
//! Above the bit-exact ladder sit [`Dispatch::Avx2Fma`]
//! (`_mm256_fmadd_ps` microkernel) and the AVX-512-ready
//! [`Dispatch::Avx512`] slot. They are **never** selected by default:
//! [`Dispatch::native`] stays the best *no-FMA* level, so an unset
//! `PAMM_SIMD` keeps the whole repo bit-identical to the scalar
//! oracle. Opting in (`PAMM_SIMD=avx2fma` or [`force`]) trades bit
//! equality for one rounding per fused multiply-add; correctness is
//! then stated by the relative-tolerance oracle [`tol_check`], whose
//! bound [`tol_bound`] is derived from the k-panel accumulation depth.
//! Requesting a fast level the host lacks clamps cleanly down the
//! ladder ([`Dispatch::clamp_available`]) — the AVX-512 slot currently
//! resolves to the 256-bit FMA microkernel even where AVX-512 is
//! detected, until a toolchain-equipped runner can validate true
//! 512-bit intrinsics.
//!
//! # Runtime tiles
//!
//! `KC`/`MC`/`NC` are compiled-in *defaults*; the live block sizes are
//! process-wide atomics ([`tiles`]/[`set_tiles`]) so `pamm kernels
//! --probe --tune` can sweep them per machine and the config
//! `[kernels]` section can persist the winners. They are mutated only
//! at startup or inside `--tune`: changing `kc` regroups the k-panel
//! accumulation and therefore changes result *bits*, so a mid-run
//! mutation would break the determinism ladder (tests that need
//! non-default tiles call [`gemm_into_tiled`] instead of touching the
//! globals). `mc`/`nc` changes never alter any per-element
//! accumulation order — they only re-schedule which C tiles are
//! visited when — so those two are bit-neutral.
//!
//! # Determinism contract
//!
//! Every dispatch level produces **bit-identical** output:
//!
//! * All levels share one blocking scheme and one per-element
//!   accumulation order: k ascending, grouped into KC panels (zeroed
//!   register tile per panel, then one add into C).
//! * Lanes never mix: each output element is a pure chain of
//!   `acc = acc + a*b` in that fixed order, and the SIMD kernels use
//!   separate multiply and add (**no FMA**) so each step rounds exactly
//!   like the scalar reference. The ~15% FMA win is deliberately traded
//!   for `PAMM_SIMD=scalar` being a bit-exact oracle for every lane.
//! * Parallelism (poolx row blocks / column strips) only ever
//!   partitions M and N, never K, so thread count cannot change any
//!   per-element order either. `rust/tests/prop_kernels.rs` asserts
//!   both invariants (dispatch levels × 1/2/4 threads) on ragged-tail
//!   shapes.
//!
//! # Workspace
//!
//! Packing buffers (and the Gram/B̃ scratch of the PAMM stages) live in
//! a per-thread [`Workspace`] reached via [`with_workspace`]. poolx
//! workers are long-lived, so after warm-up the steady-state train-step
//! iterations reuse the same buffers and the packing path allocates
//! nothing. The workspace is not re-entrant: kernels are leaf
//! computations and must not nest `with_workspace` calls.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Micro-tile rows (A values broadcast per k step).
pub const MR: usize = 8;
/// Micro-tile columns (one 8-float SIMD vector).
pub const NR: usize = 8;
/// Default k-panel depth: B strip (KC·NR·4 = 8 KiB) stays L1-resident.
pub const KC: usize = 256;
/// Default m-block height: packed A panel (MC·KC·4 = 128 KiB) in L2.
pub const MC: usize = 128;
/// Default n-block width: bounds the packed B panel at NC·KC·4 = 2 MiB.
pub const NC: usize = 2048;

// ---------------------------------------------------------------------------
// Runtime tiles
// ---------------------------------------------------------------------------

/// One set of GEMM block sizes — the compiled-in defaults, a config
/// `[kernels]` overlay, or a `--tune` winner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiles {
    /// k-panel depth (bit-relevant: regroups the panel accumulation).
    pub kc: usize,
    /// m-block height (bit-neutral scheduling).
    pub mc: usize,
    /// n-block width (bit-neutral scheduling).
    pub nc: usize,
}

impl Tiles {
    /// The compiled-in defaults (`KC`/`MC`/`NC`).
    pub fn defaults() -> Tiles {
        Tiles { kc: KC, mc: MC, nc: NC }
    }

    /// Reject degenerate block sizes before they reach the driver.
    pub fn validate(self) -> Result<(), String> {
        for (name, v) in [("kc", self.kc), ("mc", self.mc), ("nc", self.nc)] {
            if v < 1 {
                return Err(format!("kernel tile {name} must be ≥ 1, got {v}"));
            }
        }
        if self.nc < NR {
            return Err(format!("kernel tile nc must be ≥ NR = {NR}, got {}", self.nc));
        }
        Ok(())
    }
}

static KC_RT: AtomicUsize = AtomicUsize::new(KC);
static MC_RT: AtomicUsize = AtomicUsize::new(MC);
static NC_RT: AtomicUsize = AtomicUsize::new(NC);

/// Live k-panel depth (default [`KC`]).
pub fn kc() -> usize {
    KC_RT.load(Ordering::Relaxed)
}

/// Live m-block height (default [`MC`]).
pub fn mc() -> usize {
    MC_RT.load(Ordering::Relaxed)
}

/// Live n-block width (default [`NC`]).
pub fn nc() -> usize {
    NC_RT.load(Ordering::Relaxed)
}

/// The block sizes [`gemm_into`] uses right now.
pub fn tiles() -> Tiles {
    Tiles { kc: kc(), mc: mc(), nc: nc() }
}

/// Install process-wide block sizes. Startup/`--tune` only — a `kc`
/// change alters result bits (see the module docs), so flipping this
/// mid-computation would break the determinism contract.
pub fn set_tiles(t: Tiles) -> Result<(), String> {
    t.validate()?;
    KC_RT.store(t.kc, Ordering::Relaxed);
    MC_RT.store(t.mc, Ordering::Relaxed);
    NC_RT.store(t.nc, Ordering::Relaxed);
    Ok(())
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// A SIMD dispatch level. Variants exist on every architecture; levels
/// the host cannot run fall back to [`Dispatch::Scalar`] at selection
/// time, so a `Dispatch` value is always safe to pass around.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Portable Rust reference — the bit-exact oracle for all lanes.
    Scalar,
    /// 128-bit `std::arch` path (baseline on x86_64).
    Sse2,
    /// 256-bit `std::arch` path (requires AVX2 at runtime).
    Avx2,
    /// 256-bit fused-multiply-add path — the opt-in fast tier. One
    /// rounding per `a·b + acc` instead of two, so it is **not**
    /// bit-identical to the ladder; validated by [`tol_check`].
    Avx2Fma,
    /// AVX-512-ready fast-tier slot. Detection requires `avx512f`;
    /// the microkernel currently resolves to the 256-bit FMA variant
    /// (see [`micro_kernel`]) until a toolchain-equipped runner can
    /// validate 512-bit intrinsics. Same tolerance contract as
    /// [`Dispatch::Avx2Fma`].
    Avx512,
}

/// The bit-exact ladder, lowest to highest — every level here is
/// bit-identical to the scalar oracle.
pub const LADDER: [Dispatch; 3] = [Dispatch::Scalar, Dispatch::Sse2, Dispatch::Avx2];

/// The opt-in fast tier (FMA; tolerance-checked, not bit-exact).
pub const FAST_TIER: [Dispatch; 2] = [Dispatch::Avx2Fma, Dispatch::Avx512];

/// Every dispatch level, lowest to highest (`LADDER` then
/// `FAST_TIER`) — the order [`Dispatch::clamp_available`] walks down.
pub const ALL_LEVELS: [Dispatch; 5] = [
    Dispatch::Scalar,
    Dispatch::Sse2,
    Dispatch::Avx2,
    Dispatch::Avx2Fma,
    Dispatch::Avx512,
];

/// Valid `PAMM_SIMD` spellings, for error messages.
pub const SIMD_VALUES: &str = "scalar|sse2|avx2|avx2fma|avx512|native";

fn sse2_detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    return is_x86_feature_detected!("sse2");
    #[cfg(not(target_arch = "x86_64"))]
    return false;
}

fn avx2_detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    return is_x86_feature_detected!("avx2");
    #[cfg(not(target_arch = "x86_64"))]
    return false;
}

fn fma_detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    return is_x86_feature_detected!("fma");
    #[cfg(not(target_arch = "x86_64"))]
    return false;
}

fn avx512_detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    return is_x86_feature_detected!("avx512f");
    #[cfg(not(target_arch = "x86_64"))]
    return false;
}

impl Dispatch {
    /// Alias for the module-level [`ALL_LEVELS`], for call sites that
    /// already have `Dispatch` in scope.
    pub const ALL_LEVELS: [Dispatch; 5] = ALL_LEVELS;

    pub fn name(self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Sse2 => "sse2",
            Dispatch::Avx2 => "avx2",
            Dispatch::Avx2Fma => "avx2fma",
            Dispatch::Avx512 => "avx512",
        }
    }

    /// Whether this level can run on the current host.
    pub fn available(self) -> bool {
        match self {
            Dispatch::Scalar => true,
            Dispatch::Sse2 => sse2_detected(),
            Dispatch::Avx2 => avx2_detected(),
            Dispatch::Avx2Fma => avx2_detected() && fma_detected(),
            Dispatch::Avx512 => avx512_detected() && fma_detected(),
        }
    }

    /// Whether this level sits in the fast tier — FMA kernels whose
    /// correctness contract is [`tol_check`] rather than bit equality.
    pub fn is_fast(self) -> bool {
        matches!(self, Dispatch::Avx2Fma | Dispatch::Avx512)
    }

    /// Highest available **bit-exact** level on this host. Fast-tier
    /// levels are never chosen implicitly: an unset `PAMM_SIMD` must
    /// keep every run bit-identical to the scalar oracle.
    pub fn native() -> Dispatch {
        LADDER.iter().rev().copied().find(|d| d.available()).unwrap_or(Dispatch::Scalar)
    }

    /// Highest available level *including* the fast tier — what
    /// `--probe`/`--tune` and the benches sweep up to.
    pub fn fastest() -> Dispatch {
        ALL_LEVELS.iter().rev().copied().find(|d| d.available()).unwrap_or(Dispatch::Scalar)
    }

    /// This level if the host supports it, else the next lower
    /// available one — the clean-fallback contract of the fast-tier
    /// slots (`avx512` on an AVX2+FMA host runs as `avx2fma`; on a
    /// no-FMA host, as `avx2`; and so on down to scalar).
    pub fn clamp_available(self) -> Dispatch {
        if self.available() {
            return self;
        }
        let rank = ALL_LEVELS.iter().position(|&d| d == self).unwrap_or(0);
        ALL_LEVELS[..rank]
            .iter()
            .rev()
            .copied()
            .find(|d| d.available())
            .unwrap_or(Dispatch::Scalar)
    }

    /// Parse a `PAMM_SIMD` value (one of [`SIMD_VALUES`]).
    pub fn parse(s: &str) -> Option<Dispatch> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Dispatch::Scalar),
            "sse2" => Some(Dispatch::Sse2),
            "avx2" => Some(Dispatch::Avx2),
            "avx2fma" => Some(Dispatch::Avx2Fma),
            "avx512" => Some(Dispatch::Avx512),
            "native" => Some(Dispatch::native()),
            _ => None,
        }
    }
}

/// Process-wide forced override (0 = none); see [`force`].
static FORCED: AtomicU8 = AtomicU8::new(0);

/// Force a dispatch level for the whole process (`None` restores the
/// `PAMM_SIMD`/native default). For benches and the `--probe`
/// subcommand, which sweep levels inside one process; regular code
/// should rely on [`active`].
pub fn force(d: Option<Dispatch>) {
    let code = match d {
        None => 0,
        Some(Dispatch::Scalar) => 1,
        Some(Dispatch::Sse2) => 2,
        Some(Dispatch::Avx2) => 3,
        Some(Dispatch::Avx2Fma) => 4,
        Some(Dispatch::Avx512) => 5,
    };
    FORCED.store(code, Ordering::Relaxed);
}

/// The `PAMM_SIMD` request, if any, with a friendly error for unknown
/// spellings (the CLI rejects these at startup instead of silently
/// falling back). A *known* level the host lacks is not an error — it
/// clamps down the ladder at selection time.
pub fn env_request() -> Result<Option<Dispatch>, String> {
    match std::env::var("PAMM_SIMD") {
        Err(_) => Ok(None),
        Ok(v) => match Dispatch::parse(&v) {
            Some(d) => Ok(Some(d)),
            None => Err(format!(
                "PAMM_SIMD={v}: unknown dispatch level; valid levels are {SIMD_VALUES} \
                 (scalar|sse2|avx2 are bit-identical; avx2fma|avx512 are the \
                 tolerance-checked fast tier)"
            )),
        },
    }
}

fn env_default() -> Dispatch {
    static ENV: OnceLock<Dispatch> = OnceLock::new();
    *ENV.get_or_init(|| match env_request() {
        Ok(Some(d)) => d.clamp_available(),
        Ok(None) => Dispatch::native(),
        Err(msg) => {
            // Non-CLI entry (tests/benches): report and fall back.
            // `pamm` itself rejects the value before getting here.
            eprintln!("{msg}; using {}", Dispatch::native().name());
            Dispatch::native()
        }
    })
}

/// The dispatch level the `Mat` entry points use right now:
/// [`force`] override, else `PAMM_SIMD`, else [`Dispatch::native`] —
/// always clamped to an available level.
pub fn active() -> Dispatch {
    let d = match FORCED.load(Ordering::Relaxed) {
        1 => Dispatch::Scalar,
        2 => Dispatch::Sse2,
        3 => Dispatch::Avx2,
        4 => Dispatch::Avx2Fma,
        5 => Dispatch::Avx512,
        _ => env_default(),
    };
    d.clamp_available()
}

// ---------------------------------------------------------------------------
// Fast-tier tolerance oracle
// ---------------------------------------------------------------------------

/// Relative-tolerance bound for a fast-tier result against the scalar
/// oracle, derived from the k-panel accumulation depth: each output
/// element is a length-`kdim` chain of `acc + a·b` steps (grouped into
/// k-panels), and replacing separate mul/add rounding with one fused
/// rounding perturbs each step by ≤ ε relative — worst case the
/// divergence grows linearly in the depth. The factor 8 absorbs the
/// panel regrouping and intermediate-magnitude slack; at `kdim = 512`
/// the bound is ≈ 5e-4 relative, orders of magnitude above observed
/// FMA divergence on normal data yet far below any training signal.
pub fn tol_bound(kdim: usize) -> f32 {
    8.0 * f32::EPSILON * kdim.max(1) as f32
}

/// Check a fast-tier result element-wise against the bit-exact oracle:
/// `|g − w| ≤ tol_bound(kdim) · max(|w|, 1)`. Returns the first
/// offending element on failure. This is the acceptance oracle of the
/// fast tier — the property suites and `--tune` validation all route
/// through here. NaN/Inf in `got` always fail (the comparison is
/// written so a non-finite difference cannot satisfy `≤`).
pub fn tol_check(got: &[f32], want: &[f32], kdim: usize) -> Result<(), String> {
    assert_eq!(got.len(), want.len(), "tol_check: length mismatch");
    let tol = tol_bound(kdim);
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let lim = tol * w.abs().max(1.0);
        if !((g - w).abs() <= lim) {
            return Err(format!(
                "elem {i}: {g} vs oracle {w} (|Δ| = {:e} > {lim:e} at kdim {kdim})",
                (g - w).abs()
            ));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Workspace
// ---------------------------------------------------------------------------

/// Packing buffers for one GEMM invocation (reused across calls).
#[derive(Default)]
pub struct PackBufs {
    pa: Vec<f32>,
    pb: Vec<f32>,
}

impl PackBufs {
    /// Currently reserved pack bytes (capacities, not live lengths) —
    /// the figure the attention peak-memory tracking charges per
    /// worker thread.
    pub fn capacity_bytes(&self) -> usize {
        (self.pa.capacity() + self.pb.capacity()) * std::mem::size_of::<f32>()
    }
}

/// Grow `v` to exactly `need` elements, avoiding `Vec::resize`'s
/// amortized over-allocation: the attention peak-bytes bound counts
/// capacities, so scratch growth must be no bigger than requested.
fn fit(v: &mut Vec<f32>, need: usize) {
    if v.capacity() < need {
        v.reserve_exact(need - v.len());
    }
    v.resize(need, 0.0);
}

/// [`fit`] plus zeroing of the retained prefix — the packing buffers
/// rely on every element starting at 0.0 (ragged-tail padding). Exact
/// growth matters here too: `PackBufs` capacities are part of the
/// attention peak-bytes model (`attention::tile_scratch_bytes`), and an
/// amortized doubling (e.g. pa growing 3072 → 4096 elements would jump
/// to 6144) would make a measured peak exceed the analytic bound.
fn zero_fit(v: &mut Vec<f32>, need: usize) {
    v.clear();
    if v.capacity() < need {
        v.reserve_exact(need);
    }
    v.resize(need, 0.0);
}

/// Per-thread scratch of the flash-attention tile walk
/// (`crate::attention`): Q/K/V strips, the transposed K panel, the
/// score tile, and the online-softmax state. Lives in [`Workspace`]
/// beside the PAMM stage scratch so the same long-lived pool workers
/// warm it up once and reuse it for every later (batch, head) task.
/// The backward walk ([`AttnScratch::ensure_bwd`]) adds three buffers
/// of its own — the transposed V panel, the dS tile and the per-row
/// `D = Σ_c dO·O` vector — which stay at zero capacity on
/// forward-only threads, so the forward peak-bytes model is untouched.
#[derive(Default)]
pub struct AttnScratch {
    /// Br×d query strip (pre-scaled by 1/√d).
    pub qs: Vec<f32>,
    /// Bc×d key strip.
    pub ks: Vec<f32>,
    /// Bc×d value strip.
    pub vs: Vec<f32>,
    /// d×Bc transposed key strip (the GEMM B operand of `Q·Kᵀ`).
    pub kt: Vec<f32>,
    /// Br×Bc score tile, exponentiated in place into the P tile.
    pub s: Vec<f32>,
    /// Br×d output accumulator of the online softmax.
    pub acc: Vec<f32>,
    /// Br running row maxima (online-softmax `m`).
    pub m: Vec<f32>,
    /// Br running row sums (online-softmax `l`).
    pub l: Vec<f32>,
    /// d×Bc transposed value strip (the GEMM B operand of the
    /// backward's `dP = dO·Vᵀ`) — backward only.
    pub vt: Vec<f32>,
    /// Br×Bc dS tile of the backward walk — backward only.
    pub ds: Vec<f32>,
    /// Per-row `D_i = Σ_c dO[i,c]·O[i,c]` of one head (seq entries) —
    /// backward only.
    pub dvec: Vec<f32>,
}

impl AttnScratch {
    /// Size every forward buffer for a `(br, bc, d)` tile walk. Returns
    /// the number of bytes this call grew the scratch by — zero in the
    /// warm steady state, which is what the attention memory tracker
    /// charges per worker.
    pub fn ensure(&mut self, br: usize, bc: usize, d: usize) -> usize {
        let before = self.bytes();
        fit(&mut self.qs, br * d);
        fit(&mut self.ks, bc * d);
        fit(&mut self.vs, bc * d);
        fit(&mut self.kt, d * bc);
        fit(&mut self.s, br * bc);
        fit(&mut self.acc, br * d);
        fit(&mut self.m, br);
        fit(&mut self.l, br);
        self.bytes().saturating_sub(before)
    }

    /// [`AttnScratch::ensure`] plus the backward-only buffers (`vt`,
    /// `ds`, and the seq-long `D` vector). Returns the total growth in
    /// bytes — the figure the backward memory tracking charges per
    /// worker, exact because every buffer grows via `reserve_exact`.
    pub fn ensure_bwd(&mut self, br: usize, bc: usize, d: usize, seq: usize) -> usize {
        let grew = self.ensure(br, bc, d);
        let before = self.bytes();
        fit(&mut self.vt, d * bc);
        fit(&mut self.ds, br * bc);
        fit(&mut self.dvec, seq);
        grew + self.bytes().saturating_sub(before)
    }

    /// Reserved bytes across all buffers (capacities).
    pub fn bytes(&self) -> usize {
        (self.qs.capacity()
            + self.ks.capacity()
            + self.vs.capacity()
            + self.kt.capacity()
            + self.s.capacity()
            + self.acc.capacity()
            + self.m.capacity()
            + self.l.capacity()
            + self.vt.capacity()
            + self.ds.capacity()
            + self.dvec.capacity())
            * std::mem::size_of::<f32>()
    }
}

/// Per-thread scratch shared by the kernel and the stages built on it:
/// packed panels, the compress Gram strip `S`, the apply `B̃`
/// accumulator, and the attention tile scratch. Reach it through
/// [`with_workspace`]; pool workers are long-lived threads, so
/// steady-state iterations allocate nothing.
#[derive(Default)]
pub struct Workspace {
    /// GEMM packing buffers.
    pub packs: PackBufs,
    /// `compress` Gram strip (chunk rows × k), row-major.
    pub s: Vec<f32>,
    /// `apply` B̃ accumulator (k × strip width), row-major.
    pub btilde: Vec<f32>,
    /// Flash-attention tile scratch (`crate::attention`).
    pub attn: AttnScratch,
}

thread_local! {
    static WORKSPACE: RefCell<Workspace> = RefCell::new(Workspace::default());
}

/// Run `f` with the calling thread's [`Workspace`]. Not re-entrant:
/// kernels are leaf computations, so nothing on the shipped paths nests
/// this call (a nested borrow would panic loudly, not corrupt).
pub fn with_workspace<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    WORKSPACE.with(|ws| f(&mut ws.borrow_mut()))
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Pack `kc×nc` of B (row-major, stride `ldb`, origin `(pc, jc)`) into
/// NR-wide column strips: `pb[strip][p][t] = B[pc+p][jc+strip*NR+t]`,
/// zero-padding the ragged last strip so the micro-kernel never needs a
/// width branch in its k-loop.
fn pack_b(pb: &mut Vec<f32>, b: &[f32], ldb: usize, pc: usize, kc: usize, jc: usize, nc: usize) {
    let nstrips = nc.div_ceil(NR);
    zero_fit(pb, nstrips * kc * NR);
    for js in 0..nstrips {
        let j0 = jc + js * NR;
        let w = NR.min(jc + nc - j0);
        let base = js * kc * NR;
        for p in 0..kc {
            let src = &b[(pc + p) * ldb + j0..(pc + p) * ldb + j0 + w];
            pb[base + p * NR..base + p * NR + w].copy_from_slice(src);
        }
    }
}

/// Pack `mc×kc` of op(A) into MR-tall row strips:
/// `pa[strip][p][i] = A[ic+strip*MR+i][pc+p]`, zero-padding the ragged
/// last strip. `trans` selects how storage is read — `false`: `a` is
/// row-major m×k (`A[i][p] = a[i·lda+p]`); `true`: `a` is row-major
/// k×m and we read its transpose (`A[i][p] = a[p·lda+i]`), which is
/// what lets `t_matmul` skip materializing Aᵀ.
fn pack_a(
    pa: &mut Vec<f32>,
    a: &[f32],
    lda: usize,
    trans: bool,
    ic: usize,
    mc: usize,
    pc: usize,
    kc: usize,
) {
    let mstrips = mc.div_ceil(MR);
    zero_fit(pa, mstrips * kc * MR);
    for is in 0..mstrips {
        let i0 = ic + is * MR;
        let h = MR.min(ic + mc - i0);
        let base = is * kc * MR;
        if trans {
            // Contiguous reads: row p of storage holds A[·][p].
            for p in 0..kc {
                let src = &a[(pc + p) * lda + i0..(pc + p) * lda + i0 + h];
                pa[base + p * MR..base + p * MR + h].copy_from_slice(src);
            }
        } else {
            for ii in 0..h {
                let src = &a[(i0 + ii) * lda + pc..(i0 + ii) * lda + pc + kc];
                for (p, &v) in src.iter().enumerate() {
                    pa[base + p * MR + ii] = v;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Micro-kernels
// ---------------------------------------------------------------------------

/// One micro-tile: `C[0..mr][0..nr] += Σ_p pa[p][·] ⊗ pb[p][·]`.
///
/// # Safety
/// `pa`/`pb` must point at `kc·MR` / `kc·NR` packed floats; `c` must be
/// valid for `mr` rows of stride `ldc` with `nr` writable columns. SIMD
/// variants additionally require the matching CPU feature (checked once
/// at selection in [`micro_kernel`]).
type MicroKernel =
    unsafe fn(kc: usize, pa: *const f32, pb: *const f32, c: *mut f32, ldc: usize, mr: usize, nr: usize);

/// Portable reference micro-kernel — the accumulation order every SIMD
/// variant must reproduce bit-for-bit: zeroed MR×NR tile, `+= a*b` with
/// p ascending, one final add into C. The full tile is computed even at
/// ragged edges (padded lanes multiply packed zeros) so the k-loop is
/// branch-free; only `mr×nr` is stored.
unsafe fn mkernel_scalar(
    kc: usize,
    pa: *const f32,
    pb: *const f32,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let pav = std::slice::from_raw_parts(pa.add(p * MR), MR);
        let pbv = std::slice::from_raw_parts(pb.add(p * NR), NR);
        for ii in 0..MR {
            let av = pav[ii];
            for jj in 0..NR {
                acc[ii][jj] += av * pbv[jj];
            }
        }
    }
    for ii in 0..mr {
        for jj in 0..nr {
            *c.add(ii * ldc + jj) += acc[ii][jj];
        }
    }
}

/// SSE2 micro-kernel: two passes of 4 rows × (2×4-lane) accumulators —
/// 8 xmm accumulators per pass stay in registers (a single 8×2 pass
/// would need 16 and spill). Separate `mul`/`add` (no FMA) keeps every
/// lane bit-identical to [`mkernel_scalar`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn mkernel_sse2(
    kc: usize,
    pa: *const f32,
    pb: *const f32,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    use std::arch::x86_64::*;
    let mut half = 0usize;
    while half < MR {
        let mut acc = [[_mm_setzero_ps(); 2]; 4];
        for p in 0..kc {
            let b0 = _mm_loadu_ps(pb.add(p * NR));
            let b1 = _mm_loadu_ps(pb.add(p * NR + 4));
            let pap = pa.add(p * MR + half);
            for ii in 0..4 {
                let av = _mm_set1_ps(*pap.add(ii));
                acc[ii][0] = _mm_add_ps(acc[ii][0], _mm_mul_ps(av, b0));
                acc[ii][1] = _mm_add_ps(acc[ii][1], _mm_mul_ps(av, b1));
            }
        }
        if mr == MR && nr == NR {
            for ii in 0..4 {
                let cp = c.add((half + ii) * ldc);
                _mm_storeu_ps(cp, _mm_add_ps(_mm_loadu_ps(cp), acc[ii][0]));
                _mm_storeu_ps(cp.add(4), _mm_add_ps(_mm_loadu_ps(cp.add(4)), acc[ii][1]));
            }
        } else {
            let mut buf = [0.0f32; 4 * NR];
            for ii in 0..4 {
                _mm_storeu_ps(buf.as_mut_ptr().add(ii * NR), acc[ii][0]);
                _mm_storeu_ps(buf.as_mut_ptr().add(ii * NR + 4), acc[ii][1]);
            }
            let top = mr.min(half + 4);
            for ii in half..top {
                for jj in 0..nr {
                    *c.add(ii * ldc + jj) += buf[(ii - half) * NR + jj];
                }
            }
        }
        half += 4;
    }
}

/// AVX2 micro-kernel: 8 ymm accumulators (one per tile row), one B
/// vector load + 8 broadcast-multiply-adds per k step. Separate
/// `mul`/`add` (no FMA) keeps every lane bit-identical to
/// [`mkernel_scalar`]; ragged edges spill the register tile to a stack
/// buffer and store `mr×nr` scalar-wise.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mkernel_avx2(
    kc: usize,
    pa: *const f32,
    pb: *const f32,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    use std::arch::x86_64::*;
    let mut acc = [_mm256_setzero_ps(); MR];
    for p in 0..kc {
        let bv = _mm256_loadu_ps(pb.add(p * NR));
        let pap = pa.add(p * MR);
        for (ii, a) in acc.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*pap.add(ii));
            *a = _mm256_add_ps(*a, _mm256_mul_ps(av, bv));
        }
    }
    if mr == MR && nr == NR {
        for (ii, a) in acc.iter().enumerate() {
            let cp = c.add(ii * ldc);
            _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), *a));
        }
    } else {
        let mut buf = [0.0f32; MR * NR];
        for (ii, a) in acc.iter().enumerate() {
            _mm256_storeu_ps(buf.as_mut_ptr().add(ii * NR), *a);
        }
        for ii in 0..mr {
            for jj in 0..nr {
                *c.add(ii * ldc + jj) += buf[ii * NR + jj];
            }
        }
    }
}

/// Fast-tier micro-kernel: the AVX2 loop with the separate
/// multiply/add pair fused into `_mm256_fmadd_ps` — one rounding per
/// step instead of two, which is exactly why this level is validated
/// by [`tol_check`] instead of bit equality. Also serves the
/// [`Dispatch::Avx512`] slot until 512-bit intrinsics can be
/// validated on a real runner.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mkernel_avx2fma(
    kc: usize,
    pa: *const f32,
    pb: *const f32,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    use std::arch::x86_64::*;
    let mut acc = [_mm256_setzero_ps(); MR];
    for p in 0..kc {
        let bv = _mm256_loadu_ps(pb.add(p * NR));
        let pap = pa.add(p * MR);
        for (ii, a) in acc.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*pap.add(ii));
            *a = _mm256_fmadd_ps(av, bv, *a);
        }
    }
    if mr == MR && nr == NR {
        for (ii, a) in acc.iter().enumerate() {
            let cp = c.add(ii * ldc);
            _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), *a));
        }
    } else {
        let mut buf = [0.0f32; MR * NR];
        for (ii, a) in acc.iter().enumerate() {
            _mm256_storeu_ps(buf.as_mut_ptr().add(ii * NR), *a);
        }
        for ii in 0..mr {
            for jj in 0..nr {
                *c.add(ii * ldc + jj) += buf[ii * NR + jj];
            }
        }
    }
}

/// Resolve the micro-kernel for a dispatch level, re-checking CPU
/// support so an unavailable request degrades to scalar instead of
/// executing illegal instructions. The AVX-512 slot intentionally
/// resolves to the 256-bit FMA kernel for now (same tolerance
/// contract; see [`Dispatch::Avx512`]).
fn micro_kernel(d: Dispatch) -> MicroKernel {
    match d {
        Dispatch::Scalar => mkernel_scalar,
        #[cfg(target_arch = "x86_64")]
        Dispatch::Sse2 if sse2_detected() => mkernel_sse2,
        #[cfg(target_arch = "x86_64")]
        Dispatch::Avx2 if avx2_detected() => mkernel_avx2,
        #[cfg(target_arch = "x86_64")]
        Dispatch::Avx2Fma | Dispatch::Avx512 if avx2_detected() && fma_detected() => {
            mkernel_avx2fma
        }
        _ => mkernel_scalar,
    }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// `C += op(A) · B` on dispatch level `d` — the one blocked GEMM every
/// hot contraction routes through.
///
/// * `trans_a = false`: `a` is row-major `m×kdim`, stride `lda`.
/// * `trans_a = true`: `a` is row-major `kdim×m`, stride `lda`, read as
///   its transpose (no materialization).
/// * `b` is row-major `kdim×n`, stride `ldb`; `c` row-major `m×n`,
///   stride `ldc`, **accumulated into** (callers start from zeros).
///
/// Single-threaded by design: poolx parallelism partitions M (row
/// blocks) or N (column strips) *above* this call, which is exactly why
/// thread count can never change the per-element accumulation order.
#[allow(clippy::too_many_arguments)]
pub fn gemm_into(
    d: Dispatch,
    trans_a: bool,
    m: usize,
    n: usize,
    kdim: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    packs: &mut PackBufs,
) {
    gemm_into_tiled(d, tiles(), trans_a, m, n, kdim, a, lda, b, ldb, c, ldc, packs)
}

/// [`gemm_into`] with explicit block sizes — how the autotune sweep
/// and the tiled property tests try candidate tiles without mutating
/// the process-wide [`tiles`] state (which would race with concurrent
/// tests and, for `kc`, change bits under everyone else's feet).
#[allow(clippy::too_many_arguments)]
pub fn gemm_into_tiled(
    d: Dispatch,
    t: Tiles,
    trans_a: bool,
    m: usize,
    n: usize,
    kdim: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    packs: &mut PackBufs,
) {
    if m == 0 || n == 0 || kdim == 0 {
        return;
    }
    t.validate().expect("gemm: invalid tiles");
    let (t_kc, t_mc, t_nc) = (t.kc, t.mc, t.nc);
    if trans_a {
        assert!(a.len() >= (kdim - 1) * lda + m, "gemm: Aᵀ storage too small");
        assert!(lda >= m, "gemm: Aᵀ row stride below row width");
    } else {
        assert!(a.len() >= (m - 1) * lda + kdim, "gemm: A storage too small");
        assert!(lda >= kdim, "gemm: A row stride below row width");
    }
    assert!(b.len() >= (kdim - 1) * ldb + n, "gemm: B storage too small");
    assert!(c.len() >= (m - 1) * ldc + n, "gemm: C storage too small");
    assert!(ldc >= n && ldb >= n, "gemm: row stride below row width");

    let kern = micro_kernel(d);
    for jc in (0..n).step_by(t_nc) {
        let nc = t_nc.min(n - jc);
        let nstrips = nc.div_ceil(NR);
        for pc in (0..kdim).step_by(t_kc) {
            let kc = t_kc.min(kdim - pc);
            pack_b(&mut packs.pb, b, ldb, pc, kc, jc, nc);
            for ic in (0..m).step_by(t_mc) {
                let mc = t_mc.min(m - ic);
                let mstrips = mc.div_ceil(MR);
                pack_a(&mut packs.pa, a, lda, trans_a, ic, mc, pc, kc);
                for js in 0..nstrips {
                    let j0 = js * NR;
                    let nr = NR.min(nc - j0);
                    for is in 0..mstrips {
                        let i0 = is * MR;
                        let mr = MR.min(mc - i0);
                        let coff = (ic + i0) * ldc + jc + j0;
                        // SAFETY: packed panels hold kc·MR / kc·NR
                        // floats per strip (asserted sizes above); the
                        // C tile stays inside `c` because
                        // (ic+i0+mr-1)·ldc + jc+j0+nr ≤ (m-1)·ldc + n.
                        unsafe {
                            kern(
                                kc,
                                packs.pa.as_ptr().add(is * kc * MR),
                                packs.pb.as_ptr().add(js * kc * NR),
                                c.as_mut_ptr().add(coff),
                                ldc,
                                mr,
                                nr,
                            );
                        }
                    }
                }
            }
        }
    }
}

/// [`gemm_into`] on the [`active`] dispatch level with the calling
/// thread's workspace — the form the `Mat` entry points use. Must not
/// be called while already inside [`with_workspace`] (use
/// [`gemm_into`] with the borrowed `packs` there instead).
#[allow(clippy::too_many_arguments)]
pub fn gemm_auto(
    trans_a: bool,
    m: usize,
    n: usize,
    kdim: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) {
    with_workspace(|ws| {
        gemm_into(active(), trans_a, m, n, kdim, a, lda, b, ldb, c, ldc, &mut ws.packs)
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Xoshiro256;

    /// f64-accumulated reference (order-independent up to f64 rounding).
    fn naive(trans_a: bool, m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for p in 0..k {
                    let av = if trans_a { a[p * m + i] } else { a[i * k + p] };
                    acc += av as f64 * b[p * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        let mut v = vec![0f32; len];
        rng.fill_normal_f32(&mut v, 1.0);
        v
    }

    fn run(d: Dispatch, trans_a: bool, m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        let mut packs = PackBufs::default();
        let (lda, stored_a_rows) = if trans_a { (m, k) } else { (k, m) };
        assert_eq!(a.len(), stored_a_rows * lda);
        gemm_into(d, trans_a, m, n, k, a, lda, b, n, &mut c, n, &mut packs);
        c
    }

    #[test]
    fn matches_naive_on_edge_shapes() {
        // Ragged tails around MR/NR and a KC-crossing k.
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (MR - 1, NR + 1, 5),
            (MR, NR, KC),
            (MR + 1, NR - 1, KC + 1),
            (17, 13, 19),
            (3, 2, 2 * KC + 5),
        ] {
            for trans_a in [false, true] {
                let a = rand_vec(m * k, 1 + m as u64);
                let b = rand_vec(k * n, 2 + n as u64);
                let got = run(Dispatch::Scalar, trans_a, m, n, k, &a, &b);
                let want = naive(trans_a, m, n, k, &a, &b);
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                        "m={m} n={n} k={k} trans={trans_a}: {g} vs {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn every_available_level_is_bit_identical_to_scalar() {
        for &(m, n, k) in &[(5usize, 9usize, 7usize), (MR, NR, KC), (23, 17, KC + 3), (64, 40, 33)]
        {
            for trans_a in [false, true] {
                let a = rand_vec(m * k, 11);
                let b = rand_vec(k * n, 13);
                let base = run(Dispatch::Scalar, trans_a, m, n, k, &a, &b);
                for d in LADDER {
                    if !d.available() {
                        continue;
                    }
                    let got = run(d, trans_a, m, n, k, &a, &b);
                    for (i, (g, w)) in got.iter().zip(&base).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "{}: elem {i} differs (m={m} n={n} k={k} trans={trans_a})",
                            d.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn empty_dims_are_noops() {
        let mut packs = PackBufs::default();
        let mut c = vec![7.0f32; 6];
        gemm_into(Dispatch::Scalar, false, 0, 3, 4, &[], 4, &[0.0; 12], 3, &mut c, 3, &mut packs);
        gemm_into(Dispatch::Scalar, false, 2, 0, 4, &[0.0; 8], 4, &[], 0, &mut c, 0, &mut packs);
        // kdim = 0 leaves C untouched (empty sum).
        gemm_into(Dispatch::Scalar, false, 2, 3, 0, &[], 0, &[], 3, &mut c, 3, &mut packs);
        assert!(c.iter().all(|&x| x == 7.0));
    }

    #[test]
    fn accumulates_into_c() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let mut c = vec![10.0f32];
        let mut packs = PackBufs::default();
        gemm_into(Dispatch::Scalar, false, 1, 1, 2, &a, 2, &b, 1, &mut c, 1, &mut packs);
        assert_eq!(c[0], 10.0 + 11.0);
    }

    #[test]
    fn dispatch_parse_and_ladder() {
        assert_eq!(Dispatch::parse("scalar"), Some(Dispatch::Scalar));
        assert_eq!(Dispatch::parse("AVX2"), Some(Dispatch::Avx2));
        assert_eq!(Dispatch::parse("avx2fma"), Some(Dispatch::Avx2Fma));
        assert_eq!(Dispatch::parse("AVX512"), Some(Dispatch::Avx512));
        assert_eq!(Dispatch::parse(" native "), Some(Dispatch::native()));
        assert_eq!(Dispatch::parse("mmx"), None);
        assert!(Dispatch::Scalar.available());
        assert!(Dispatch::native().available());
        // The implicit default never opts into the fast tier.
        assert!(!Dispatch::native().is_fast());
        assert!(LADDER.iter().all(|d| !d.is_fast()));
        assert!(FAST_TIER.iter().all(|d| d.is_fast()));
        // Clamp walks down to an available level, never up.
        let c = Dispatch::Avx512.clamp_available();
        assert!(c.available());
        if !Dispatch::Avx512.available() {
            assert_ne!(c, Dispatch::Avx512);
        }
        assert_eq!(Dispatch::Scalar.clamp_available(), Dispatch::Scalar);
        assert!(Dispatch::fastest().available());
    }

    #[test]
    fn fast_tier_passes_the_tolerance_oracle() {
        for d in FAST_TIER {
            if !d.available() {
                continue;
            }
            // Ragged MR±1 tails and a KC-crossing k — the shapes where
            // a fused-rounding bug would hide.
            for &(m, n, k) in &[(MR + 1, NR - 1, KC + 1), (MR - 1, NR + 1, KC - 1), (23, 17, 2 * KC + 3)] {
                for trans_a in [false, true] {
                    let a = rand_vec(m * k, 21);
                    let b = rand_vec(k * n, 22);
                    let want = run(Dispatch::Scalar, trans_a, m, n, k, &a, &b);
                    let got = run(d, trans_a, m, n, k, &a, &b);
                    tol_check(&got, &want, k).unwrap_or_else(|e| {
                        panic!("{} m={m} n={n} k={k} trans={trans_a}: {e}", d.name())
                    });
                }
            }
        }
    }

    #[test]
    fn tol_bound_grows_with_depth_and_tol_check_rejects_garbage() {
        assert!(tol_bound(512) > tol_bound(8));
        assert!(tol_bound(0) > 0.0, "empty depth still has a positive bound");
        tol_check(&[1.0, 2.0], &[1.0, 2.0], 4).unwrap();
        assert!(tol_check(&[1.0, 2.5], &[1.0, 2.0], 4).is_err());
        assert!(tol_check(&[f32::NAN], &[0.0], 4).is_err(), "NaN can never pass");
    }

    #[test]
    fn tiles_accessors_and_validation() {
        // The live tiles default to the compiled-in constants, and a
        // defaults round-trip through set_tiles is a no-op (tests must
        // not install non-default tiles: the globals are
        // startup-mutate-only by contract).
        assert_eq!(tiles(), Tiles::defaults());
        set_tiles(Tiles::defaults()).unwrap();
        assert_eq!((kc(), mc(), nc()), (KC, MC, NC));
        assert!(Tiles { kc: 0, mc: MC, nc: NC }.validate().is_err());
        assert!(Tiles { kc: KC, mc: MC, nc: NR - 1 }.validate().is_err());
        assert!(Tiles { kc: 1, mc: 1, nc: NR }.validate().is_ok());
    }

    #[test]
    fn mc_nc_tiles_are_bit_neutral_and_kc_is_tolerance_equal() {
        // mc/nc only re-schedule which C tiles are visited when — the
        // per-element accumulation order is untouched, so any mc/nc
        // choice is bit-identical to the defaults. kc regroups the
        // k-panel accumulation: different bits, same math under the
        // tolerance oracle.
        let (m, n, k) = (MC + 3, 37, 2 * KC + 5);
        let a = rand_vec(m * k, 31);
        let b = rand_vec(k * n, 32);
        let mut packs = PackBufs::default();
        let mut base = vec![0f32; m * n];
        gemm_into_tiled(
            Dispatch::Scalar, Tiles::defaults(), false, m, n, k, &a, k, &b, n, &mut base, n,
            &mut packs,
        );
        for t in [Tiles { kc: KC, mc: 48, nc: 24 }, Tiles { kc: KC, mc: 1, nc: NR }] {
            let mut c = vec![0f32; m * n];
            gemm_into_tiled(Dispatch::Scalar, t, false, m, n, k, &a, k, &b, n, &mut c, n, &mut packs);
            for (i, (g, w)) in c.iter().zip(&base).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "mc/nc retile: elem {i} with {t:?}");
            }
        }
        for t in [Tiles { kc: KC - 1, mc: MC, nc: NC }, Tiles { kc: KC + 1, mc: MC, nc: NC }, Tiles { kc: 100, mc: 64, nc: 512 }] {
            let mut c = vec![0f32; m * n];
            gemm_into_tiled(Dispatch::Scalar, t, false, m, n, k, &a, k, &b, n, &mut c, n, &mut packs);
            tol_check(&c, &base, k).unwrap_or_else(|e| panic!("kc retile {t:?}: {e}"));
        }
    }

    #[test]
    fn workspace_buffers_are_reused() {
        // Second identical call must not regrow the packing buffers.
        let a = rand_vec(40 * 30, 3);
        let b = rand_vec(30 * 20, 4);
        let mut c = vec![0f32; 40 * 20];
        let mut packs = PackBufs::default();
        gemm_into(Dispatch::Scalar, false, 40, 20, 30, &a, 30, &b, 20, &mut c, 20, &mut packs);
        let (cap_a, cap_b) = (packs.pa.capacity(), packs.pb.capacity());
        c.fill(0.0);
        gemm_into(Dispatch::Scalar, false, 40, 20, 30, &a, 30, &b, 20, &mut c, 20, &mut packs);
        assert_eq!(packs.pa.capacity(), cap_a);
        assert_eq!(packs.pb.capacity(), cap_b);
    }

    #[test]
    fn attn_scratch_growth_is_exact_and_warm_calls_are_free() {
        let mut a = AttnScratch::default();
        let grew = a.ensure(64, 64, 32);
        // Exact sizing: qs/ks/vs/kt/acc = 64·32 or 32·64, s = 64·64, m/l = 64.
        let want = (5 * 64 * 32 + 64 * 64 + 2 * 64) * 4;
        assert_eq!(grew, want);
        assert_eq!(a.bytes(), want);
        // Warm re-ensure at the same (or smaller) shape grows nothing.
        assert_eq!(a.ensure(64, 64, 32), 0);
        assert_eq!(a.ensure(63, 48, 32), 0);
        assert_eq!(a.bytes(), want, "capacities never shrink");
        // A bigger shape grows by exactly the delta.
        let grew2 = a.ensure(64, 64, 64);
        assert_eq!(a.bytes(), want + grew2);
    }

    #[test]
    fn attn_scratch_bwd_buffers_grow_exactly_and_leave_fwd_alone() {
        let mut a = AttnScratch::default();
        let fwd = a.ensure(64, 64, 32);
        // Backward adds exactly vt (d·bc) + ds (br·bc) + dvec (seq).
        let grew = a.ensure_bwd(64, 64, 32, 200);
        let want = (32 * 64 + 64 * 64 + 200) * 4;
        assert_eq!(grew, want);
        assert_eq!(a.bytes(), fwd + want);
        // Warm backward re-ensure at the same shape grows nothing.
        assert_eq!(a.ensure_bwd(64, 64, 32, 200), 0);
        // A forward-only scratch never pays for the backward buffers.
        let mut f = AttnScratch::default();
        assert_eq!(f.ensure(64, 64, 32), fwd);
    }

    #[test]
    fn forced_dispatch_round_trip() {
        force(Some(Dispatch::Scalar));
        assert_eq!(active(), Dispatch::Scalar);
        force(None);
        assert!(active().available());
    }
}
