//! Append-only, commit-keyed benchmark history.
//!
//! The single-snapshot `BENCH_*.json` files say where the repo *is*;
//! this module keeps where it has *been*: one JSON document holding,
//! per suite, a list of entries keyed by commit — the
//! `window.BENCHMARK_DATA` schema of github-action-benchmark's
//! published `dev/bench/data.js` (pijama's trail is the exemplar), so
//! the file drops straight into that ecosystem's charting page:
//!
//! ```json
//! {
//!   "lastUpdate": 1719930300000,
//!   "repoUrl": "…",
//!   "entries": {
//!     "tensor_kernels": [
//!       { "commit": { "id": "…", "message": "…", "timestamp": "…" },
//!         "date": 1719930300000,
//!         "tool": "cargo",
//!         "benches": [
//!           { "name": "gemm_nn[avx2] m=512 k=512 n=512 t=1",
//!             "value": 123456.0, "range": "± 0", "unit": "ns/iter" } ] } ] }
//! }
//! ```
//!
//! Bench names are the flattened `op shape t=N` key, so one history
//! line is one (op, shape, threads) series over commits. Re-recording
//! under the same commit id *replaces* that commit's entry (renders are
//! idempotent); different commits append.
//!
//! [`gate`] is the CI regression check: a fresh snapshot directory vs
//! the newest committed entry per suite, failing on configurable
//! ns/iter regressions — unless the baseline's `tool` is
//! [`BOOTSTRAP_TOOL`], in which case the gate *skips with a visible
//! notice* (comparing real timings against hand-estimated ones would
//! gate on noise; see ROADMAP item 5).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::load_dir;
use crate::jsonx::{self, Value};

/// The `tool` tag marking entries whose timings were *not* produced by
/// a real toolchain run (the standing-caveat bootstrap estimates).
/// Real runs set `PAMM_BENCH_TOOL=cargo`.
pub const BOOTSTRAP_TOOL: &str = "bootstrap-estimate";

/// Commit identity of one history entry.
#[derive(Debug, Clone, PartialEq)]
pub struct CommitInfo {
    pub id: String,
    pub message: String,
    pub timestamp: String,
}

impl CommitInfo {
    /// Resolve from `PAMM_COMMIT` (CI sets it), else `git rev-parse` /
    /// `git log -1` on the working tree, else `"unknown"` throughout.
    pub fn detect() -> Self {
        if let Ok(id) = std::env::var("PAMM_COMMIT") {
            return Self {
                id,
                message: std::env::var("PAMM_COMMIT_MESSAGE").unwrap_or_default(),
                timestamp: std::env::var("PAMM_COMMIT_TIMESTAMP").unwrap_or_default(),
            };
        }
        let git = |args: &[&str]| {
            std::process::Command::new("git")
                .args(args)
                .output()
                .ok()
                .filter(|o| o.status.success())
                .and_then(|o| String::from_utf8(o.stdout).ok())
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
        };
        Self {
            id: git(&["rev-parse", "HEAD"]).unwrap_or_else(|| "unknown".into()),
            message: git(&["log", "-1", "--format=%s"]).unwrap_or_default(),
            timestamp: git(&["log", "-1", "--format=%cI"]).unwrap_or_default(),
        }
    }
}

/// One measured series point: the flattened `op shape t=N` name plus
/// its ns/iter value.
#[derive(Debug, Clone, PartialEq)]
pub struct HistBench {
    pub name: String,
    pub value: f64,
    pub range: String,
    pub unit: String,
}

/// One commit's measurement of one suite.
#[derive(Debug, Clone, PartialEq)]
pub struct HistEntry {
    pub commit: CommitInfo,
    /// Milliseconds since the epoch at record time.
    pub date: f64,
    pub tool: String,
    pub benches: Vec<HistBench>,
}

/// The whole trail: suite name → entries, oldest first.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub last_update: f64,
    pub repo_url: String,
    pub entries: BTreeMap<String, Vec<HistEntry>>,
}

fn now_ms() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as f64)
        .unwrap_or(0.0)
}

impl History {
    /// Parse `path`; a missing file is the empty trail.
    pub fn load(path: impl AsRef<Path>) -> Result<History> {
        let path = path.as_ref();
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(_) => return Ok(History::default()),
        };
        let doc = jsonx::parse(&text)
            .with_context(|| format!("parsing history {}", path.display()))?;
        let mut entries = BTreeMap::new();
        if let Some(suites) = doc.get("entries").as_obj() {
            for (suite, list) in suites {
                let mut parsed = Vec::new();
                for e in list.as_arr().unwrap_or(&[]) {
                    let c = e.get("commit");
                    let mut benches = Vec::new();
                    for b in e.get("benches").as_arr().unwrap_or(&[]) {
                        benches.push(HistBench {
                            name: b.req_str("name")?.to_string(),
                            value: b.req_f64("value")?,
                            range: b.get("range").as_str().unwrap_or("± 0").to_string(),
                            unit: b.get("unit").as_str().unwrap_or("ns/iter").to_string(),
                        });
                    }
                    parsed.push(HistEntry {
                        commit: CommitInfo {
                            id: c.get("id").as_str().unwrap_or("unknown").to_string(),
                            message: c.get("message").as_str().unwrap_or("").to_string(),
                            timestamp: c.get("timestamp").as_str().unwrap_or("").to_string(),
                        },
                        date: e.get("date").as_f64().unwrap_or(0.0),
                        tool: e.get("tool").as_str().unwrap_or(BOOTSTRAP_TOOL).to_string(),
                        benches,
                    });
                }
                entries.insert(suite.clone(), parsed);
            }
        }
        Ok(History {
            last_update: doc.get("lastUpdate").as_f64().unwrap_or(0.0),
            repo_url: doc.get("repoUrl").as_str().unwrap_or("").to_string(),
            entries,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let suites: BTreeMap<String, Value> = self
            .entries
            .iter()
            .map(|(suite, list)| {
                let arr = list
                    .iter()
                    .map(|e| {
                        jsonx::obj(vec![
                            (
                                "commit",
                                jsonx::obj(vec![
                                    ("id", jsonx::s(e.commit.id.clone())),
                                    ("message", jsonx::s(e.commit.message.clone())),
                                    ("timestamp", jsonx::s(e.commit.timestamp.clone())),
                                ]),
                            ),
                            ("date", jsonx::num(e.date)),
                            ("tool", jsonx::s(e.tool.clone())),
                            (
                                "benches",
                                jsonx::arr(
                                    e.benches
                                        .iter()
                                        .map(|b| {
                                            jsonx::obj(vec![
                                                ("name", jsonx::s(b.name.clone())),
                                                ("value", jsonx::num(b.value)),
                                                ("range", jsonx::s(b.range.clone())),
                                                ("unit", jsonx::s(b.unit.clone())),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect();
                (suite.clone(), jsonx::arr(arr))
            })
            .collect();
        let doc = jsonx::obj(vec![
            ("lastUpdate", jsonx::num(self.last_update)),
            ("repoUrl", jsonx::s(self.repo_url.clone())),
            ("entries", Value::Obj(suites)),
        ]);
        std::fs::write(path, format!("{doc}\n"))?;
        Ok(())
    }

    /// Record one suite measurement under `commit`: the same commit id
    /// replaces its previous entry, a new one appends.
    pub fn record(&mut self, suite: &str, entry: HistEntry) {
        let list = self.entries.entry(suite.to_string()).or_default();
        match list.iter_mut().find(|e| e.commit.id == entry.commit.id) {
            Some(slot) => *slot = entry,
            None => list.push(entry),
        }
        self.last_update = now_ms();
    }

    /// Resolve `key` against one suite's entry list: `latest` (newest),
    /// `prev` (one before newest), or a commit-id prefix.
    pub fn resolve<'a>(list: &'a [HistEntry], key: &str) -> Result<&'a HistEntry> {
        match key {
            "latest" => list.last().context("history is empty"),
            "prev" => {
                (list.len() >= 2).then(|| &list[list.len() - 2]).context("no previous entry")
            }
            prefix => {
                let hits: Vec<_> =
                    list.iter().filter(|e| e.commit.id.starts_with(prefix)).collect();
                match hits.len() {
                    0 => bail!("no history entry matches commit prefix `{prefix}`"),
                    1 => Ok(hits[0]),
                    n => bail!("commit prefix `{prefix}` is ambiguous ({n} entries)"),
                }
            }
        }
    }
}

/// Build one suite's [`HistEntry`] from its freshly-flushed snapshot
/// entries (names flattened to `op shape t=N`).
fn entry_from_suite(rec: &super::SuiteRecord, commit: &CommitInfo, tool: &str) -> HistEntry {
    HistEntry {
        commit: commit.clone(),
        date: now_ms(),
        tool: tool.to_string(),
        benches: rec
            .entries
            .iter()
            .map(|e| HistBench {
                name: format!("{} {} t={}", e.op, e.shape, e.threads),
                value: e.ns_per_iter,
                range: "± 0".into(),
                unit: "ns/iter".into(),
            })
            .collect(),
    }
}

/// The `tool` tag for new entries: `PAMM_BENCH_TOOL` (CI sets `cargo`
/// when a real toolchain ran the suite), else [`BOOTSTRAP_TOOL`].
pub fn bench_tool() -> String {
    std::env::var("PAMM_BENCH_TOOL").unwrap_or_else(|_| BOOTSTRAP_TOOL.into())
}

/// Fold every `BENCH_*.json` under `dir` into the history at
/// `history_path` (commit/tool resolved from env/git — see
/// [`CommitInfo::detect`] and [`bench_tool`]). Returns the number of
/// suites recorded.
pub fn append_from_dir(dir: impl AsRef<Path>, history_path: impl AsRef<Path>) -> Result<usize> {
    append_from_dir_as(dir, history_path, &CommitInfo::detect(), &bench_tool())
}

/// [`append_from_dir`] with explicit commit/tool (what the tests use —
/// no env or subprocess reliance).
pub fn append_from_dir_as(
    dir: impl AsRef<Path>,
    history_path: impl AsRef<Path>,
    commit: &CommitInfo,
    tool: &str,
) -> Result<usize> {
    let suites = load_dir(dir)?;
    if suites.is_empty() {
        bail!("no BENCH_*.json snapshots to record");
    }
    let mut hist = History::load(&history_path)?;
    let n = suites.len();
    for rec in &suites {
        let entry = entry_from_suite(rec, commit, tool);
        hist.record(&rec.suite, entry);
    }
    hist.save(&history_path)?;
    Ok(n)
}

/// Markdown diff of two history entries (`a`, `b`: commit prefixes or
/// `latest`/`prev`), per suite, per flattened bench name present in
/// both. Positive delta = `b` is slower than `a`.
pub fn compare_report(history_path: impl AsRef<Path>, a: &str, b: &str) -> Result<String> {
    let hist = History::load(&history_path)?;
    if hist.entries.is_empty() {
        bail!("history {} has no entries", history_path.as_ref().display());
    }
    let mut out = String::new();
    for (suite, list) in &hist.entries {
        let (ea, eb) = (History::resolve(list, a)?, History::resolve(list, b)?);
        out.push_str(&format!(
            "## {suite}\n\n`{}` ({}) → `{}` ({})\n\n",
            short(&ea.commit.id),
            ea.tool,
            short(&eb.commit.id),
            eb.tool
        ));
        out.push_str("| bench | a (ns/iter) | b (ns/iter) | Δ |\n|---|---:|---:|---:|\n");
        for ba in &ea.benches {
            if let Some(bb) = eb.benches.iter().find(|x| x.name == ba.name) {
                let delta = (bb.value - ba.value) / ba.value.max(1.0) * 100.0;
                out.push_str(&format!(
                    "| {} | {:.0} | {:.0} | {:+.1}% |\n",
                    ba.name, ba.value, bb.value, delta
                ));
            }
        }
        out.push('\n');
    }
    Ok(out)
}

fn short(id: &str) -> &str {
    &id[..id.len().min(12)]
}

/// Outcome of [`gate`]: a human-readable report plus the hard verdict
/// the CLI turns into a non-zero exit.
#[derive(Debug)]
pub struct GateVerdict {
    pub report: String,
    pub failed: bool,
    /// True when the gate could not arm (bootstrap baseline / missing
    /// history) and was skipped with a notice instead of failing.
    pub skipped: bool,
}

/// Regression gate: compare a fresh snapshot directory against the
/// newest history entry of each suite; any matched bench more than
/// `pct`% slower fails. Skips (with a notice, `failed == false`) when
/// the baseline entry's tool is [`BOOTSTRAP_TOOL`] or the suite has no
/// history yet — estimates are not a gating baseline.
pub fn gate(dir: impl AsRef<Path>, history_path: impl AsRef<Path>, pct: f64) -> Result<GateVerdict> {
    let suites = load_dir(dir)?;
    let hist = History::load(&history_path)?;
    let mut report = String::new();
    let mut failed = false;
    let mut skipped = true;
    for rec in &suites {
        let Some(list) = hist.entries.get(&rec.suite) else {
            report.push_str(&format!("gate: {}: SKIPPED (no history entry yet)\n", rec.suite));
            continue;
        };
        let Ok(base) = History::resolve(list, "latest") else {
            report.push_str(&format!("gate: {}: SKIPPED (empty history)\n", rec.suite));
            continue;
        };
        if base.tool == BOOTSTRAP_TOOL {
            report.push_str(&format!(
                "gate: {}: SKIPPED — baseline {} is a bootstrap estimate, not a measured \
                 run; the gate arms once a real-toolchain runner records the suite \
                 (PAMM_BENCH_TOOL=cargo)\n",
                rec.suite,
                short(&base.commit.id)
            ));
            continue;
        }
        skipped = false;
        let mut checked = 0usize;
        let mut suite_failed = false;
        for e in &rec.entries {
            let name = format!("{} {} t={}", e.op, e.shape, e.threads);
            if let Some(b) = base.benches.iter().find(|x| x.name == name) {
                checked += 1;
                let delta = (e.ns_per_iter - b.value) / b.value.max(1.0) * 100.0;
                if delta > pct {
                    suite_failed = true;
                    report.push_str(&format!(
                        "gate: {}: FAIL {} — {:.0} ns/iter vs baseline {:.0} ({:+.1}% > {pct}%)\n",
                        rec.suite, name, e.ns_per_iter, b.value, delta
                    ));
                }
            }
        }
        failed |= suite_failed;
        report.push_str(&format!(
            "gate: {}: {} ({checked} benches vs {})\n",
            rec.suite,
            if suite_failed { "checked with failures" } else { "OK" },
            short(&base.commit.id)
        ));
    }
    if skipped && !failed {
        report.push_str("gate: all suites skipped — nothing gated this run\n");
    }
    Ok(GateVerdict { report, failed, skipped })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchx::{BenchResult, BenchSink};
    use std::time::Duration;

    fn mk(us: u64) -> BenchResult {
        BenchResult {
            name: "x".into(),
            iters: 5,
            median: Duration::from_micros(us),
            p10: Duration::from_micros(us),
            p90: Duration::from_micros(us),
            mean: Duration::from_micros(us),
        }
    }

    fn commit(id: &str) -> CommitInfo {
        CommitInfo { id: id.into(), message: format!("commit {id}"), timestamp: "t".into() }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pamm_hist_{tag}_{}", std::process::id()))
    }

    fn snapshot(dir: &std::path::Path, us: u64) {
        let mut sink = BenchSink::new("unit_kernels");
        sink.record("gemm_nn[avx2]", "m=64 k=64 n=64", 1, &mk(us));
        sink.flush_to(dir).unwrap();
    }

    #[test]
    fn append_replaces_same_commit_and_appends_new() {
        let dir = tmp("append");
        let hist_path = dir.join("history.json");
        snapshot(&dir, 100);
        assert_eq!(append_from_dir_as(&dir, &hist_path, &commit("aaa111"), "cargo").unwrap(), 1);
        // Same commit again → replaced, not duplicated.
        snapshot(&dir, 120);
        append_from_dir_as(&dir, &hist_path, &commit("aaa111"), "cargo").unwrap();
        let h = History::load(&hist_path).unwrap();
        assert_eq!(h.entries["unit_kernels"].len(), 1);
        assert_eq!(h.entries["unit_kernels"][0].benches[0].value, 120_000.0);
        assert_eq!(h.entries["unit_kernels"][0].benches[0].name, "gemm_nn[avx2] m=64 k=64 n=64 t=1");
        // New commit → appended; latest/prev/prefix resolution works.
        snapshot(&dir, 90);
        append_from_dir_as(&dir, &hist_path, &commit("bbb222"), "cargo").unwrap();
        let h = History::load(&hist_path).unwrap();
        let list = &h.entries["unit_kernels"];
        assert_eq!(list.len(), 2);
        assert_eq!(History::resolve(list, "latest").unwrap().commit.id, "bbb222");
        assert_eq!(History::resolve(list, "prev").unwrap().commit.id, "aaa111");
        assert_eq!(History::resolve(list, "aaa").unwrap().commit.id, "aaa111");
        assert!(History::resolve(list, "zzz").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compare_reports_the_delta() {
        let dir = tmp("cmp");
        let hist_path = dir.join("history.json");
        snapshot(&dir, 100);
        append_from_dir_as(&dir, &hist_path, &commit("aaa111"), "cargo").unwrap();
        snapshot(&dir, 150);
        append_from_dir_as(&dir, &hist_path, &commit("bbb222"), "cargo").unwrap();
        let rep = compare_report(&hist_path, "prev", "latest").unwrap();
        assert!(rep.contains("unit_kernels"), "{rep}");
        assert!(rep.contains("+50.0%"), "{rep}");
        let rev = compare_report(&hist_path, "latest", "prev").unwrap();
        assert!(rev.contains("-33.3%"), "{rev}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gate_skips_bootstrap_and_fails_real_regressions() {
        let dir = tmp("gate");
        let hist_path = dir.join("history.json");
        // Bootstrap baseline → skip, never fail.
        snapshot(&dir, 100);
        append_from_dir_as(&dir, &hist_path, &commit("aaa111"), BOOTSTRAP_TOOL).unwrap();
        snapshot(&dir, 500);
        let v = gate(&dir, &hist_path, 15.0).unwrap();
        assert!(!v.failed && v.skipped, "{}", v.report);
        assert!(v.report.contains("SKIPPED"), "{}", v.report);
        // Real baseline → within threshold passes, beyond fails.
        snapshot(&dir, 100);
        append_from_dir_as(&dir, &hist_path, &commit("aaa111"), "cargo").unwrap();
        snapshot(&dir, 110);
        let v = gate(&dir, &hist_path, 15.0).unwrap();
        assert!(!v.failed && !v.skipped, "{}", v.report);
        snapshot(&dir, 130);
        let v = gate(&dir, &hist_path, 15.0).unwrap();
        assert!(v.failed, "{}", v.report);
        assert!(v.report.contains("FAIL"), "{}", v.report);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn history_roundtrips_through_disk() {
        let dir = tmp("rt");
        let path = dir.join("history.json");
        let mut h = History { repo_url: "https://example.invalid/pamm".into(), ..Default::default() };
        h.record(
            "s",
            HistEntry {
                commit: commit("c0ffee"),
                date: 1.0,
                tool: "cargo".into(),
                benches: vec![HistBench {
                    name: "op shape t=1".into(),
                    value: 42.0,
                    range: "± 0".into(),
                    unit: "ns/iter".into(),
                }],
            },
        );
        h.save(&path).unwrap();
        let h2 = History::load(&path).unwrap();
        assert_eq!(h2.repo_url, h.repo_url);
        assert_eq!(h2.entries["s"][0], h.entries["s"][0]);
        // Missing file loads as the empty trail.
        assert!(History::load(dir.join("absent.json")).unwrap().entries.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
