//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Provides warmup, timed iterations, and robust statistics (median +
//! percentiles). `cargo bench` runs the suites under `rust/benches/`
//! which are plain `harness = false` binaries built on this module; the
//! experiment harness (t2/t7/t8) reuses [`bench_fn`] for its per-op
//! timers.
//!
//! Results also persist across PRs: [`BenchSink`] appends
//! machine-readable entries (op, shape, threads, ns/iter,
//! speedup-vs-serial — plus GFLOP/s, speedup-vs-scalar, measured peak
//! bytes and exact saved-for-backward bytes where a suite records
//! them) and writes one `BENCH_<suite>.json` per suite
//! under `benchmarks/` (override with `PAMM_BENCH_DIR`). The [`report`]
//! module loads every `BENCH_*.json` back and renders the committed
//! `BENCHMARKS.md` via `pamm bench-report` — the repo's perf trajectory
//! is a diffable artifact, not folklore.

pub mod history;
pub mod report;

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::jsonx::{self, Value};

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// items/sec at the median (e.g. tokens/sec when items = tokens).
    pub fn rate(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median_secs().max(1e-12)
    }

    pub fn display_row(&self) -> String {
        format!(
            "{:<44} {:>10} iters   median {:>12?}   p10 {:>12?}   p90 {:>12?}",
            self.name, self.iters, self.median, self.p10, self.p90
        )
    }
}

/// Benchmark configuration: bounded by both iteration count and wall time.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub max_total: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 200,
            max_total: Duration::from_secs(10),
        }
    }
}

impl BenchOpts {
    /// Fast profile for CI/tests.
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            max_total: Duration::from_secs(2),
        }
    }

    /// `full`, unless `PAMM_BENCH_QUICK` is set (the CI profile) — the
    /// one switch every bench binary shares.
    pub fn quick_or(full: BenchOpts) -> BenchOpts {
        if std::env::var("PAMM_BENCH_QUICK").is_ok() {
            BenchOpts::quick()
        } else {
            full
        }
    }
}

/// The thread sweep the bench binaries persist: 1/2/4/host parallelism,
/// sorted and deduped. Shared so every `BENCH_*.json` suite stays
/// comparable.
pub fn thread_sweep() -> Vec<usize> {
    let max_t = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1);
    let mut sweep = vec![1, 2, 4, max_t];
    sweep.sort_unstable();
    sweep.dedup();
    sweep
}

/// Nearest-rank percentile of an ascending-sorted, non-empty sample
/// (`p` in `[0, 1]`): index `round((len-1)·p)`. Shared by the bench
/// summaries and `coordinator::serve`'s latency table;
/// `rust/tests/prop_serve.rs` checks it against a sorted reference.
pub fn percentile(sorted: &[Duration], p: f64) -> Duration {
    let ix = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[ix]
}

/// Time `f` under `opts`; `f` must perform one full operation per call.
/// Use `std::hint::black_box` inside `f` to defeat dead-code elimination.
pub fn bench_fn(name: &str, opts: &BenchOpts, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < opts.min_iters
        || (samples.len() < opts.max_iters && start.elapsed() < opts.max_total)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median: percentile(&samples, 0.5),
        p10: percentile(&samples, 0.1),
        p90: percentile(&samples, 0.9),
        mean,
    }
}

/// A named group of benches with uniform reporting (bench-binary helper).
pub struct Suite {
    pub title: String,
    opts: BenchOpts,
    results: Vec<BenchResult>,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        // Honor PAMM_BENCH_QUICK=1 to keep `cargo bench` CI-friendly.
        Self::with_opts(title, BenchOpts::quick_or(BenchOpts::default()))
    }

    pub fn with_opts(title: &str, opts: BenchOpts) -> Self {
        Self { title: title.to_string(), opts, results: Vec::new() }
    }

    pub fn bench(&mut self, name: &str, f: impl FnMut()) -> &BenchResult {
        let r = bench_fn(name, &self.opts, f);
        println!("  {}", r.display_row());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn header(&self) {
        println!("\n=== {} ===", self.title);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Ratio of two named benches' medians (speedup factor tables).
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?;
        let fb = self.results.iter().find(|r| r.name == b)?;
        Some(fb.median_secs() / fa.median_secs())
    }
}

/// Host fingerprint stored alongside persisted entries so BENCHMARKS.md
/// can say where a number came from (rvr-style provenance: CPU model,
/// the SIMD levels `Dispatch` actually detected, thread count,
/// toolchain).
#[derive(Debug, Clone, PartialEq)]
pub struct HostInfo {
    pub os: String,
    pub arch: String,
    pub cpus: usize,
    pub cpu_model: String,
    /// Space-separated dispatch levels available on this host
    /// (`scalar sse2 avx2 avx2fma …`) — detected, not configured.
    pub features: String,
    /// `rustc --version` of the toolchain that built/ran the suite, or
    /// `unknown` when no toolchain is on PATH (the bootstrap-estimate
    /// case).
    pub toolchain: String,
}

impl HostInfo {
    pub fn detect() -> Self {
        let cpus = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1);
        let cpu_model = std::fs::read_to_string("/proc/cpuinfo")
            .ok()
            .and_then(|t| {
                t.lines()
                    .find(|l| l.starts_with("model name"))
                    .and_then(|l| l.split(':').nth(1).map(|s| s.trim().to_string()))
            })
            .unwrap_or_else(|| "unknown".into());
        let features = crate::tensor::kernels::Dispatch::ALL_LEVELS
            .iter()
            .filter(|d| d.available())
            .map(|d| d.name())
            .collect::<Vec<_>>()
            .join(" ");
        let toolchain = std::process::Command::new("rustc")
            .arg("--version")
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| "unknown".into());
        Self {
            os: std::env::consts::OS.into(),
            arch: std::env::consts::ARCH.into(),
            cpus,
            cpu_model,
            features,
            toolchain,
        }
    }
}

/// One persisted benchmark entry (the schema of `BENCH_*.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchEntry {
    pub op: String,
    /// Free-form shape label, e.g. `b=2048 n=2048 m=2048 k=32`.
    pub shape: String,
    pub threads: usize,
    /// Median wall time per iteration in nanoseconds.
    pub ns_per_iter: f64,
    /// `serial_ns / ns` against the `threads == 1` entry of the same
    /// (op, shape); filled in by [`BenchSink::flush_to`].
    pub speedup_vs_serial: Option<f64>,
    pub iters: usize,
    /// Median throughput, for ops with a known flop count
    /// ([`BenchSink::record_flops`]).
    pub gflops: Option<f64>,
    /// For dispatch-tagged ops (`name[sse2]`, `name[avx2]`, …):
    /// `scalar_ns / ns` against the `name[scalar]` entry of the same
    /// shape (same thread count if present, else the 1-thread scalar
    /// baseline); filled in by [`BenchSink::flush_to`].
    pub speedup_vs_scalar: Option<f64>,
    /// Measured peak transient bytes of the op (attention's fused rows
    /// attach their `memory::MemoryTracker` reading here), so the
    /// persisted trail carries the memory claim next to the timing —
    /// not just the analytic model.
    pub peak_bytes: Option<f64>,
    /// Exact saved-for-backward bytes of a training-step op (the
    /// `train_backward` suite's forward rows attach
    /// `autograd::QkvAttnSaved::saved_bytes` here) — the paper's
    /// headline quantity, persisted beside the timing.
    pub saved_bytes: Option<f64>,
}

/// The `name[scalar]` twin of a dispatch-tagged op name, if `op` is
/// tagged with a non-scalar dispatch level.
fn scalar_twin(op: &str) -> Option<String> {
    let rest = op.strip_suffix(']')?;
    let (base, disp) = rest.rsplit_once('[')?;
    if disp == "scalar" {
        return None;
    }
    Some(format!("{base}[scalar]"))
}

/// A persisted suite: host + entries, as loaded from one `BENCH_*.json`.
#[derive(Debug, Clone)]
pub struct SuiteRecord {
    pub suite: String,
    pub host: HostInfo,
    pub entries: Vec<BenchEntry>,
}

/// Accumulates [`BenchEntry`] rows and writes `BENCH_<suite>.json`.
pub struct BenchSink {
    suite: String,
    host: HostInfo,
    entries: Vec<BenchEntry>,
}

/// Directory the bench binaries persist to (`PAMM_BENCH_DIR` override).
pub fn bench_dir() -> PathBuf {
    std::env::var("PAMM_BENCH_DIR").map(PathBuf::from).unwrap_or_else(|_| "benchmarks".into())
}

impl BenchSink {
    pub fn new(suite: &str) -> Self {
        Self { suite: suite.to_string(), host: HostInfo::detect(), entries: Vec::new() }
    }

    /// Record one measured result under an op/shape/threads key.
    pub fn record(&mut self, op: &str, shape: &str, threads: usize, r: &BenchResult) {
        self.entries.push(BenchEntry {
            op: op.to_string(),
            shape: shape.to_string(),
            threads,
            ns_per_iter: r.median.as_nanos() as f64,
            speedup_vs_serial: None,
            iters: r.iters,
            gflops: None,
            speedup_vs_scalar: None,
            peak_bytes: None,
            saved_bytes: None,
        });
    }

    /// [`BenchSink::record`] for an op with a known flop count: also
    /// persists median GFLOP/s (`flops / ns_per_iter` — flops per
    /// nanosecond *is* GFLOP/s). Used by the `tensor_kernels` suite so
    /// the trail states absolute kernel throughput, not just ratios.
    pub fn record_flops(
        &mut self,
        op: &str,
        shape: &str,
        threads: usize,
        r: &BenchResult,
        flops: f64,
    ) {
        self.record(op, shape, threads, r);
        let e = self.entries.last_mut().expect("just recorded");
        e.gflops = Some(flops / e.ns_per_iter.max(1.0));
    }

    /// Attach a measured peak-bytes figure to the most recently
    /// recorded entry (the attention suite's fused rows carry their
    /// `MemoryTracker` reading this way).
    pub fn annotate_peak_bytes(&mut self, bytes: usize) {
        if let Some(e) = self.entries.last_mut() {
            e.peak_bytes = Some(bytes as f64);
        }
    }

    /// Attach an exact saved-for-backward byte count to the most
    /// recently recorded entry (the `train_backward` suite's forward
    /// rows carry their tape node's figure this way).
    pub fn annotate_saved_bytes(&mut self, bytes: usize) {
        if let Some(e) = self.entries.last_mut() {
            e.saved_bytes = Some(bytes as f64);
        }
    }

    /// Entries recorded so far (speedups not yet resolved).
    pub fn entries(&self) -> &[BenchEntry] {
        &self.entries
    }

    /// Write `BENCH_<suite>.json` into [`bench_dir`], resolving
    /// speedup-vs-serial against each (op, shape)'s 1-thread entry and
    /// speedup-vs-scalar against each dispatch-tagged op's
    /// `name[scalar]` twin.
    pub fn flush(&self) -> std::io::Result<PathBuf> {
        self.flush_to(bench_dir())
    }

    /// Like [`BenchSink::flush`] with an explicit directory.
    pub fn flush_to(&self, dir: impl AsRef<Path>) -> std::io::Result<PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let mut resolved = self.entries.clone();
        for e in resolved.iter_mut() {
            if e.threads != 1 {
                e.speedup_vs_serial = self
                    .entries
                    .iter()
                    .find(|s| s.threads == 1 && s.op == e.op && s.shape == e.shape)
                    .map(|s| s.ns_per_iter / e.ns_per_iter.max(1.0));
            }
            if let Some(twin) = scalar_twin(&e.op) {
                // Prefer a same-thread-count scalar baseline; suites
                // that only bench scalar serially fall back to its
                // 1-thread entry.
                e.speedup_vs_scalar = self
                    .entries
                    .iter()
                    .find(|s| s.op == twin && s.shape == e.shape && s.threads == e.threads)
                    .or_else(|| {
                        self.entries
                            .iter()
                            .find(|s| s.op == twin && s.shape == e.shape && s.threads == 1)
                    })
                    .map(|s| s.ns_per_iter / e.ns_per_iter.max(1.0));
            }
        }
        let doc = jsonx::obj(vec![
            ("suite", jsonx::s(self.suite.clone())),
            (
                "host",
                jsonx::obj(vec![
                    ("os", jsonx::s(self.host.os.clone())),
                    ("arch", jsonx::s(self.host.arch.clone())),
                    ("cpus", jsonx::num(self.host.cpus as f64)),
                    ("cpu_model", jsonx::s(self.host.cpu_model.clone())),
                    ("features", jsonx::s(self.host.features.clone())),
                    ("toolchain", jsonx::s(self.host.toolchain.clone())),
                ]),
            ),
            ("entries", jsonx::arr(resolved.iter().map(entry_json).collect())),
        ]);
        let path = dir.join(format!("BENCH_{}.json", self.suite));
        std::fs::write(&path, format!("{doc}\n"))?;
        Ok(path)
    }
}

fn entry_json(e: &BenchEntry) -> Value {
    let mut pairs = vec![
        ("op", jsonx::s(e.op.clone())),
        ("shape", jsonx::s(e.shape.clone())),
        ("threads", jsonx::num(e.threads as f64)),
        ("ns_per_iter", jsonx::num(e.ns_per_iter)),
        ("iters", jsonx::num(e.iters as f64)),
    ];
    if let Some(sp) = e.speedup_vs_serial {
        pairs.push(("speedup_vs_serial", jsonx::num(sp)));
    }
    if let Some(g) = e.gflops {
        pairs.push(("gflops", jsonx::num(g)));
    }
    if let Some(sp) = e.speedup_vs_scalar {
        pairs.push(("speedup_vs_scalar", jsonx::num(sp)));
    }
    if let Some(pb) = e.peak_bytes {
        pairs.push(("peak_bytes", jsonx::num(pb)));
    }
    if let Some(sb) = e.saved_bytes {
        pairs.push(("saved_bytes", jsonx::num(sb)));
    }
    jsonx::obj(pairs)
}

/// Parse one `BENCH_*.json` file.
pub fn load_file(path: impl AsRef<Path>) -> anyhow::Result<SuiteRecord> {
    let text = std::fs::read_to_string(path.as_ref())?;
    let doc = jsonx::parse(&text)?;
    let host = doc.get("host");
    let mut entries = Vec::new();
    for e in doc.req_arr("entries")? {
        entries.push(BenchEntry {
            op: e.req_str("op")?.to_string(),
            shape: e.req_str("shape")?.to_string(),
            threads: e.req_usize("threads")?,
            ns_per_iter: e.req_f64("ns_per_iter")?,
            speedup_vs_serial: e.get("speedup_vs_serial").as_f64(),
            iters: e.req_usize("iters")?,
            gflops: e.get("gflops").as_f64(),
            speedup_vs_scalar: e.get("speedup_vs_scalar").as_f64(),
            peak_bytes: e.get("peak_bytes").as_f64(),
            saved_bytes: e.get("saved_bytes").as_f64(),
        });
    }
    Ok(SuiteRecord {
        suite: doc.req_str("suite")?.to_string(),
        host: HostInfo {
            os: host.get("os").as_str().unwrap_or("unknown").to_string(),
            arch: host.get("arch").as_str().unwrap_or("unknown").to_string(),
            cpus: host.get("cpus").as_usize().unwrap_or(0),
            cpu_model: host.get("cpu_model").as_str().unwrap_or("unknown").to_string(),
            // Pre-PR-10 files carry neither field — "unknown" keeps the
            // committed trail loadable.
            features: host.get("features").as_str().unwrap_or("unknown").to_string(),
            toolchain: host.get("toolchain").as_str().unwrap_or("unknown").to_string(),
        },
        entries,
    })
}

/// Load every `BENCH_*.json` under `dir`, sorted by file name.
pub fn load_dir(dir: impl AsRef<Path>) -> anyhow::Result<Vec<SuiteRecord>> {
    let dir = dir.as_ref();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("cannot read bench dir {}: {e}", dir.display()))?
        .filter_map(|entry| entry.ok().map(|d| d.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|f| f.to_str())
                .map(|f| f.starts_with("BENCH_") && f.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    paths.sort();
    paths.iter().map(load_file).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep_roughly() {
        let opts = BenchOpts {
            warmup_iters: 0,
            min_iters: 5,
            max_iters: 5,
            max_total: Duration::from_secs(5),
        };
        let r = bench_fn("sleep", &opts, || {
            std::thread::sleep(Duration::from_millis(5));
        });
        assert_eq!(r.iters, 5);
        assert!(r.median >= Duration::from_millis(4), "{:?}", r.median);
        assert!(r.median < Duration::from_millis(60), "{:?}", r.median);
    }

    #[test]
    fn respects_max_iters() {
        let opts = BenchOpts {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 7,
            max_total: Duration::from_secs(100),
        };
        let r = bench_fn("noop", &opts, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters <= 7);
    }

    #[test]
    fn suite_ratio() {
        let opts = BenchOpts {
            warmup_iters: 0,
            min_iters: 3,
            max_iters: 3,
            max_total: Duration::from_secs(5),
        };
        let mut s = Suite::with_opts("t", opts);
        s.bench("fast", || std::thread::sleep(Duration::from_micros(100)));
        s.bench("slow", || std::thread::sleep(Duration::from_micros(1000)));
        let ratio = s.ratio("fast", "slow").unwrap();
        assert!(ratio > 2.0, "slow/fast = {ratio}");
    }

    #[test]
    fn sink_roundtrip_and_speedup_resolution() {
        let mut sink = BenchSink::new("unit_suite");
        let mk = |ms: u64| BenchResult {
            name: "x".into(),
            iters: 5,
            median: Duration::from_millis(ms),
            p10: Duration::from_millis(ms),
            p90: Duration::from_millis(ms),
            mean: Duration::from_millis(ms),
        };
        sink.record("matmul_tn", "b=2048 n=2048 m=2048 k=32", 1, &mk(400));
        sink.record("matmul_tn", "b=2048 n=2048 m=2048 k=32", 4, &mk(100));
        sink.record("compress", "b=2048 n=2048 m=2048 k=32", 1, &mk(80));

        let dir = std::env::temp_dir().join(format!("pamm_benchx_{}", std::process::id()));
        let path = sink.flush_to(&dir).unwrap();
        assert!(path.ends_with("BENCH_unit_suite.json"));

        let loaded = load_dir(&dir).unwrap();
        assert_eq!(loaded.len(), 1);
        let rec = &loaded[0];
        assert_eq!(rec.suite, "unit_suite");
        assert_eq!(rec.entries.len(), 3);
        let par = rec
            .entries
            .iter()
            .find(|e| e.op == "matmul_tn" && e.threads == 4)
            .expect("4-thread entry");
        let sp = par.speedup_vs_serial.expect("speedup resolved at flush");
        assert!((sp - 4.0).abs() < 1e-6, "speedup {sp}");
        // Serial entries never get a speedup field.
        assert!(rec
            .entries
            .iter()
            .filter(|e| e.threads == 1)
            .all(|e| e.speedup_vs_serial.is_none()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gflops_and_vs_scalar_resolution() {
        let mut sink = BenchSink::new("kern_suite");
        let mk = |us: u64| BenchResult {
            name: "x".into(),
            iters: 5,
            median: Duration::from_micros(us),
            p10: Duration::from_micros(us),
            p90: Duration::from_micros(us),
            mean: Duration::from_micros(us),
        };
        let flops = 2.0 * 64.0 * 64.0 * 64.0;
        sink.record_flops("gemm_nn[scalar]", "m=64 k=64 n=64", 1, &mk(800), flops);
        sink.record_flops("gemm_nn[avx2]", "m=64 k=64 n=64", 1, &mk(100), flops);
        // 2-thread avx2 has no 2-thread scalar twin → falls back to t=1.
        sink.record_flops("gemm_nn[avx2]", "m=64 k=64 n=64", 2, &mk(50), flops);

        let dir = std::env::temp_dir().join(format!("pamm_benchx_k_{}", std::process::id()));
        sink.flush_to(&dir).unwrap();
        let rec = &load_dir(&dir).unwrap()[0];

        let scalar = rec.entries.iter().find(|e| e.op == "gemm_nn[scalar]").unwrap();
        assert!(scalar.speedup_vs_scalar.is_none(), "scalar op has no scalar twin");
        let g = scalar.gflops.expect("gflops persisted");
        assert!((g - flops / 800_000.0).abs() < 1e-9, "gflops {g}");

        let avx1 = rec
            .entries
            .iter()
            .find(|e| e.op == "gemm_nn[avx2]" && e.threads == 1)
            .unwrap();
        assert!((avx1.speedup_vs_scalar.unwrap() - 8.0).abs() < 1e-9);
        let avx2t = rec
            .entries
            .iter()
            .find(|e| e.op == "gemm_nn[avx2]" && e.threads == 2)
            .unwrap();
        assert!((avx2t.speedup_vs_scalar.unwrap() - 16.0).abs() < 1e-9, "fallback to t=1 scalar");
        assert!((avx2t.speedup_vs_serial.unwrap() - 2.0).abs() < 1e-9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn peak_bytes_annotation_round_trips() {
        let mut sink = BenchSink::new("attn_suite");
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            median: Duration::from_micros(500),
            p10: Duration::from_micros(500),
            p90: Duration::from_micros(500),
            mean: Duration::from_micros(500),
        };
        sink.record_flops("fused_pamm[avx2]", "b=1 h=4 l=256 d=64", 1, &r, 1e6);
        sink.annotate_peak_bytes(264_708);
        sink.annotate_saved_bytes(6_148);
        sink.record("flash[avx2]", "b=1 h=4 l=256 d=64", 1, &r);

        let dir = std::env::temp_dir().join(format!("pamm_benchx_pk_{}", std::process::id()));
        sink.flush_to(&dir).unwrap();
        let rec = &load_dir(&dir).unwrap()[0];
        let fused = rec.entries.iter().find(|e| e.op == "fused_pamm[avx2]").unwrap();
        assert_eq!(fused.peak_bytes, Some(264_708.0));
        assert_eq!(fused.saved_bytes, Some(6_148.0));
        let flash = rec.entries.iter().find(|e| e.op == "flash[avx2]").unwrap();
        assert!(flash.peak_bytes.is_none(), "annotation attaches to the last entry only");
        assert!(flash.saved_bytes.is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scalar_twin_parsing() {
        assert_eq!(scalar_twin("gemm_nn[avx2]").as_deref(), Some("gemm_nn[scalar]"));
        assert_eq!(scalar_twin("gemm_tn[sse2]").as_deref(), Some("gemm_tn[scalar]"));
        assert_eq!(scalar_twin("gemm_nn[scalar]"), None);
        assert_eq!(scalar_twin("matmul_tn"), None);
    }

    #[test]
    fn rate_computation() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median: Duration::from_secs(2),
            p10: Duration::from_secs(2),
            p90: Duration::from_secs(2),
            mean: Duration::from_secs(2),
        };
        assert!((r.rate(1000.0) - 500.0).abs() < 1e-9);
    }
}
