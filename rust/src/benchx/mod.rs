//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Provides warmup, timed iterations, and robust statistics (median +
//! percentiles, MAD-based noise estimate). `cargo bench` runs the suites
//! under `rust/benches/` which are plain `harness = false` binaries built
//! on this module; the experiment harness (t2/t7/t8) reuses [`bench_fn`]
//! for its per-op timers.

use std::time::{Duration, Instant};

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// items/sec at the median (e.g. tokens/sec when items = tokens).
    pub fn rate(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median_secs().max(1e-12)
    }

    pub fn display_row(&self) -> String {
        format!(
            "{:<44} {:>10} iters   median {:>12?}   p10 {:>12?}   p90 {:>12?}",
            self.name, self.iters, self.median, self.p10, self.p90
        )
    }
}

/// Benchmark configuration: bounded by both iteration count and wall time.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub max_total: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 200,
            max_total: Duration::from_secs(10),
        }
    }
}

impl BenchOpts {
    /// Fast profile for CI/tests.
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            max_total: Duration::from_secs(2),
        }
    }
}

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    let ix = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[ix]
}

/// Time `f` under `opts`; `f` must perform one full operation per call.
/// Use `std::hint::black_box` inside `f` to defeat dead-code elimination.
pub fn bench_fn(name: &str, opts: &BenchOpts, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < opts.min_iters
        || (samples.len() < opts.max_iters && start.elapsed() < opts.max_total)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median: percentile(&samples, 0.5),
        p10: percentile(&samples, 0.1),
        p90: percentile(&samples, 0.9),
        mean,
    }
}

/// A named group of benches with uniform reporting (bench-binary helper).
pub struct Suite {
    pub title: String,
    opts: BenchOpts,
    results: Vec<BenchResult>,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        // Honor PAMM_BENCH_QUICK=1 to keep `cargo bench` CI-friendly.
        let opts = if std::env::var("PAMM_BENCH_QUICK").is_ok() {
            BenchOpts::quick()
        } else {
            BenchOpts::default()
        };
        Self { title: title.to_string(), opts, results: Vec::new() }
    }

    pub fn with_opts(title: &str, opts: BenchOpts) -> Self {
        Self { title: title.to_string(), opts, results: Vec::new() }
    }

    pub fn bench(&mut self, name: &str, f: impl FnMut()) -> &BenchResult {
        let r = bench_fn(name, &self.opts, f);
        println!("  {}", r.display_row());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn header(&self) {
        println!("\n=== {} ===", self.title);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Ratio of two named benches' medians (speedup factor tables).
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?;
        let fb = self.results.iter().find(|r| r.name == b)?;
        Some(fb.median_secs() / fa.median_secs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep_roughly() {
        let opts = BenchOpts {
            warmup_iters: 0,
            min_iters: 5,
            max_iters: 5,
            max_total: Duration::from_secs(5),
        };
        let r = bench_fn("sleep", &opts, || {
            std::thread::sleep(Duration::from_millis(5));
        });
        assert_eq!(r.iters, 5);
        assert!(r.median >= Duration::from_millis(4), "{:?}", r.median);
        assert!(r.median < Duration::from_millis(60), "{:?}", r.median);
    }

    #[test]
    fn respects_max_iters() {
        let opts = BenchOpts {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 7,
            max_total: Duration::from_secs(100),
        };
        let r = bench_fn("noop", &opts, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters <= 7);
    }

    #[test]
    fn suite_ratio() {
        let opts = BenchOpts {
            warmup_iters: 0,
            min_iters: 3,
            max_iters: 3,
            max_total: Duration::from_secs(5),
        };
        let mut s = Suite::with_opts("t", opts);
        s.bench("fast", || std::thread::sleep(Duration::from_micros(100)));
        s.bench("slow", || std::thread::sleep(Duration::from_micros(1000)));
        let ratio = s.ratio("fast", "slow").unwrap();
        assert!(ratio > 2.0, "slow/fast = {ratio}");
    }

    #[test]
    fn rate_computation() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median: Duration::from_secs(2),
            p10: Duration::from_secs(2),
            p90: Duration::from_secs(2),
            mean: Duration::from_secs(2),
        };
        assert!((r.rate(1000.0) - 500.0).abs() < 1e-9);
    }
}
