//! `pamm` — leader entrypoint.
//!
//! Subcommands (see `cli::USAGE`): train / generate / serve-sim /
//! chaos / finetune / ablate / reproduce / ledger / memory / kernels / list. Python
//! never runs here: the native substrates are self-contained, and the
//! artifact commands (`artifacts/*.hlo.txt` via the PJRT engine) are
//! gated behind the `pjrt` cargo feature — without it they fail with a
//! pointer to the native equivalents.

use anyhow::{bail, Context, Result};

use pamm::cli::{Args, USAGE};
use pamm::config::{preset, RunConfig};
use pamm::memory::{self, ModelGeometry};

#[cfg(feature = "pjrt")]
use pamm::config::Variant;
#[cfg(feature = "pjrt")]
use pamm::coordinator::train_run;
#[cfg(feature = "pjrt")]
use pamm::data::glue;
#[cfg(feature = "pjrt")]
use pamm::runtime::{Engine, HostTensor};

/// The uniform "this build has no PJRT" error for artifact commands.
#[cfg(not(feature = "pjrt"))]
fn engine_unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "`{what}` drives the PJRT artifact runtime, which this binary was built without \
         (rebuild with `--features pjrt` and an xla binding in the workspace). \
         The native path is self-contained: `pamm train --native`, `pamm finetune --native`, \
         `pamm ablate`, `pamm generate`, `pamm serve-sim`, `pamm ledger`, `pamm memory`, \
         `pamm reproduce table7|attention|ablation|finetune`, `pamm kernels --probe`, \
         `pamm bench-report`."
    )
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    // Reject unknown PAMM_SIMD values up front with the friendly
    // level list — the library fallback (used by tests/benches that
    // don't pass through here) only warns.
    if let Err(msg) = pamm::tensor::kernels::env_request() {
        bail!("{msg}");
    }
    // Install kernel tiles before any kernel runs: config `[kernels]`
    // section (the `--tune` persistence target; missing file = empty
    // overlay) layered under the PAMM_KC/MC/NC/BR/BC env overrides.
    let tiles_path = args.get_str("config").unwrap_or_else(|| "pamm.toml".into());
    pamm::config::KernelTiles::load_file(&tiles_path)?.env_overlay()?.apply()?;
    // Fix the native compute pool before any kernel runs; the CLI flag
    // wins over config-file `threads` (poolx is first-set-wins).
    if let Some(t) = args.get_usize("threads")? {
        pamm::poolx::set_global_threads(t);
    }
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "generate" => cmd_generate(&args),
        "serve-sim" => cmd_serve_sim(&args),
        "chaos" => cmd_chaos(&args),
        "finetune" => cmd_finetune(&args),
        "ablate" => cmd_ablate(&args),
        "reproduce" => cmd_reproduce(&args),
        "ledger" => cmd_ledger(&args),
        "memory" => cmd_memory(&args),
        "kernels" => cmd_kernels(&args),
        "list" => cmd_list(&args),
        "bench-report" => cmd_bench_report(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get_str("preset") {
        Some(p) => preset(&p)?,
        None => RunConfig::default(),
    };
    if let Some(path) = args.get_str("config") {
        cfg.load_file(&path)?;
    }
    if let Some(m) = args.get_str("model") {
        cfg.model = m;
    }
    if let Some(v) = args.get_str("variant") {
        cfg.variant.mode = v;
        if cfg.variant.mode != "baseline" && cfg.variant.r >= 1.0 {
            cfg.variant.r = 1.0 / 512.0;
        }
    }
    if let Some(ri) = args.get_usize("r-inv")? {
        cfg.variant.r = 1.0 / ri as f64;
        if cfg.variant.mode == "baseline" {
            cfg.variant.mode = "pamm".into();
        }
    }
    if let Some(e) = args.get_f64("eps")? {
        cfg.variant.eps = if e < 0.0 { None } else { Some(e) };
    }
    if args.get_bool("pallas") {
        cfg.variant.use_pallas = true;
    }
    if let Some(v) = args.get_usize("steps")? {
        cfg.steps = v;
    }
    if let Some(v) = args.get_usize("batch")? {
        cfg.batch = v;
    }
    if let Some(v) = args.get_usize("seq")? {
        cfg.seq = v;
    }
    if let Some(v) = args.get_usize("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = args.get_usize("grad-accum")? {
        cfg.grad_accum = v;
    }
    if let Some(v) = args.get_usize("eval-every")? {
        cfg.eval_every = v;
    }
    if let Some(s) = args.get_usize("seed")? {
        cfg.seed = s as u64;
    }
    if let Some(d) = args.get_str("artifacts") {
        cfg.artifacts_dir = d;
    }
    if let Some(d) = args.get_str("run-dir") {
        cfg.run_dir = d;
    }
    // Config-file `threads` reaches the pool only if --threads didn't
    // already fix it in real_main (set_global_threads is first-set-wins).
    if cfg.threads != 0 {
        pamm::poolx::set_global_threads(cfg.threads);
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    // `--native` (or the `--quick` smoke) runs REAL multi-layer
    // next-token pretraining on the native substrates — no artifacts,
    // no PJRT (coordinator::train_lm_native over model::TransformerLM).
    let quick = args.get_bool("quick");
    if quick || args.get_bool("native") {
        return cmd_train_native(args, &cfg, quick);
    }
    #[cfg(feature = "pjrt")]
    {
        let engine = Engine::load(&cfg.artifacts_dir)?;
        println!(
            "training {} [{}] for {} steps (batch {}×{}, workers {}, accum {})",
            cfg.model, cfg.variant.tag(), cfg.steps, cfg.batch, cfg.seq, cfg.workers, cfg.grad_accum
        );
        let out = train_run(&engine, &cfg, args.get_bool("quiet"))?;
        println!(
            "done: final loss {:.4}{}{}",
            out.final_loss,
            out.final_ppl.map(|p| format!(", eval ppl {p:.2}")).unwrap_or_default(),
            out.tokens_per_sec.map(|t| format!(", {t:.0} tok/s")).unwrap_or_default()
        );
        println!("run log: {}/{}.jsonl", cfg.run_dir, out.run_name);
        Ok(())
    }
    #[cfg(not(feature = "pjrt"))]
    Err(engine_unavailable("pamm train (artifact mode)"))
}

/// `pamm train --native` / `--quick`: native LM pretraining end to end
/// — model geometry from the `memory::ModelGeometry` zoo (`--model`,
/// default `nano`: 2 layers), packed next-token batches from the
/// `data` pipeline, fwd/bwd through the multi-op graph tape, Adam,
/// periodic checkpoints (`--ckpt-every`, `--resume`). `--quick`
/// shrinks the run to a CI smoke AND asserts the loss decreased.
fn cmd_train_native(args: &Args, cfg: &RunConfig, quick: bool) -> Result<()> {
    use pamm::coordinator::{train_lm_native, LmRunConfig, NativeOpt};
    use pamm::model::LmConfig;

    let g = ModelGeometry::by_name(&cfg.model)
        .with_context(|| format!("unknown model `{}` (zoo: nano/tiny/small/…)", cfg.model))?;
    let mcfg = LmConfig::from_geometry(&g)?;
    let (batch, seq, steps) = if quick {
        (
            args.get_usize("batch")?.unwrap_or(2),
            args.get_usize("seq")?.unwrap_or(32),
            args.get_usize("steps")?.unwrap_or(40),
        )
    } else {
        (cfg.batch, cfg.seq, cfg.steps)
    };
    let tokens = batch * seq;
    let r_inv = args.get_usize("r-inv")?.unwrap_or(16).max(1);
    let k = match args.get_usize("k")? {
        Some(k) => k.clamp(1, tokens),
        None => tokens.div_ceil(r_inv).max(1),
    };
    let lr = args.get_f64("lr")?.unwrap_or(3e-3) as f32;
    let mut rc = LmRunConfig {
        cfg: mcfg.clone(),
        batch,
        seq,
        steps,
        k,
        opt: NativeOpt::adam(lr),
        seed: cfg.seed,
        ckpt_every: args.get_usize("ckpt-every")?.unwrap_or(if quick { 0 } else { 50 }),
        keep_last: args.get_usize("keep-last")?.unwrap_or(3),
        run_dir: cfg.run_dir.clone(),
        run_name: format!("{}_native_k{}_s{}", cfg.model, k, cfg.seed),
        resume: args.get_bool("resume"),
    };

    // `--workers R` / `--grad-accum A` / `--elastic` route to the
    // data-parallel fleet (coordinator::dp): R logical workers on
    // deterministic interleaved shards, fixed rank-order all-reduce,
    // sharded crash-safe checkpoints. R = 1, A = 1 is bit-identical to
    // the single-process path below.
    let workers = cfg.workers.max(1);
    let accum = cfg.grad_accum.max(1);
    let elastic = args.get_bool("elastic");
    if workers > 1 || accum > 1 || elastic {
        use pamm::coordinator::{train_lm_dp_native, DpRunConfig};
        rc.run_name = format!("{}_native_k{}_s{}_w{}", cfg.model, k, cfg.seed, workers);
        let drc = DpRunConfig {
            base: rc,
            workers,
            accum,
            elastic,
            stall_budget: args.get_usize("stall-budget")?.unwrap_or(3).max(1),
        };
        println!(
            "native DP LM pretraining: {} ({} layers, d_model {}, d_ff {}, vocab {}) — {workers} worker(s) × {accum} microbatch(es), effective batch {} ({batch}x{seq} per microbatch), k={k}, {steps} steps, Adam lr {lr}, elastic {}, threads {}",
            cfg.model,
            mcfg.n_layers,
            mcfg.d_model(),
            mcfg.d_ff,
            mcfg.vocab,
            drc.effective_batch(),
            if elastic { "on" } else { "off" },
            pamm::poolx::global().threads()
        );
        let out = train_lm_dp_native(&drc, pamm::poolx::global(), args.get_bool("quiet"))?;
        return report_native_train(cfg, &mcfg, &out, quick, steps);
    }
    println!(
        "native LM pretraining: {} ({} layers, d_model {}, d_ff {}, vocab {}) — batch {batch}x{seq}, k={k}, {steps} steps, Adam lr {lr}, threads {}",
        cfg.model,
        mcfg.n_layers,
        mcfg.d_model(),
        mcfg.d_ff,
        mcfg.vocab,
        pamm::poolx::global().threads()
    );
    let out = train_lm_native(&rc, pamm::poolx::global(), args.get_bool("quiet"))?;
    report_native_train(cfg, &mcfg, &out, quick, steps)
}

/// Shared post-run reporting for the single-process and DP native
/// paths: already-complete handling, the done/run-log lines, and the
/// `--quick` loss-decreased acceptance smoke.
fn report_native_train(
    cfg: &RunConfig,
    mcfg: &pamm::model::LmConfig,
    out: &pamm::coordinator::TrainOutcome,
    quick: bool,
    steps: usize,
) -> Result<()> {
    if out.curve.is_empty() {
        // A --resume of an already-finished run trains nothing; the
        // checkpoint is the result. (The quick smoke needs fresh steps.)
        anyhow::ensure!(
            !quick,
            "quick smoke: checkpoint `{}` is already at the final step — \
             remove {}/ckpt or raise --steps",
            out.run_name,
            cfg.run_dir
        );
        println!("checkpoint: {}/ckpt/{}.bin (already complete)", cfg.run_dir, out.run_name);
        return Ok(());
    }
    println!(
        "done: final loss {:.4}{}",
        out.final_loss,
        out.tokens_per_sec.map(|t| format!(", {t:.0} tok/s")).unwrap_or_default()
    );
    println!(
        "run log: {}/{}.jsonl  checkpoint: {}/ckpt/{}.bin",
        cfg.run_dir, out.run_name, cfg.run_dir, out.run_name
    );
    if quick {
        // Acceptance smoke: multi-layer (N ≥ 2) native pretraining must
        // make real progress.
        anyhow::ensure!(
            mcfg.n_layers >= 2,
            "--quick expects a multi-layer model (got {} layers)",
            mcfg.n_layers
        );
        let window = (out.curve.len() / 2).clamp(1, 5);
        let avg = |w: &[(usize, f32)]| {
            w.iter().map(|&(_, l)| l as f64).sum::<f64>() / w.len() as f64
        };
        let head = avg(&out.curve[..window]);
        let tail = avg(&out.curve[out.curve.len() - window..]);
        anyhow::ensure!(
            tail < head,
            "quick smoke: loss did not decrease (first {head:.4} vs last {tail:.4})"
        );
        println!(
            "quick smoke OK: loss {head:.4} -> {tail:.4} over {steps} steps ({} layers, every layer PAMM-compressed)",
            mcfg.n_layers
        );
    }
    Ok(())
}


/// `pamm generate` — native greedy decoding with the PAMM-compressed
/// KV cache (no artifacts, no PJRT): prefill the prompt, fold one row
/// per decoded token into each layer's `Compressed`, and assert —
/// in-command, every run — that a one-shot prefill of
/// `prompt ++ generated` reproduces the incremental final logits bit
/// for bit, and that the measured cache peak sits under the analytic
/// byte bound (DESIGN.md §8). Weights come from `--ckpt NAME`
/// (a `pamm train --native` checkpoint under `--ckpt-dir`) or a fresh
/// seeded init — parity and memory hold for any weights.
fn cmd_generate(args: &Args) -> Result<()> {
    use pamm::generate::{self, Decoder, GenConfig};
    use pamm::memory::fmt_bytes;
    use pamm::model::{LmConfig, TransformerLM};
    use pamm::pamm::Eps;
    use pamm::rngx::Xoshiro256;

    let quick = args.get_bool("quick");
    let model_name = args.get_str("model").unwrap_or_else(|| "nano".into());
    let g = ModelGeometry::by_name(&model_name)
        .with_context(|| format!("unknown model `{model_name}` (zoo: nano/tiny/small/…)"))?;
    let mcfg = LmConfig::from_geometry(&g)?;
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let prompt_len = args.get_usize("prompt-len")?.unwrap_or(16).max(1);
    let max_new = args.get_usize("max-new")?.unwrap_or(if quick { 16 } else { 32 }).max(1);
    let r_inv = args.get_usize("r-inv")?.unwrap_or(4).max(1);
    let k = match args.get_usize("k")? {
        Some(k) => k.clamp(1, prompt_len),
        None => prompt_len.div_ceil(r_inv).max(1),
    };
    let eps = match args.get_f64("eps")? {
        Some(e) if e >= 0.0 => Eps::Val(e as f32),
        _ => Eps::Inf,
    };

    let mut model = TransformerLM::new(mcfg.clone(), seed);
    let weights = match args.get_str("ckpt") {
        Some(name) => {
            let dir = args.get_str("ckpt-dir").unwrap_or_else(|| "runs/ckpt".into());
            generate::load_checkpoint_params(&mut model, &dir, &name)?;
            format!("checkpoint {dir}/{name}.bin")
        }
        None => format!("fresh init (seed {seed})"),
    };

    let pool = pamm::poolx::global();
    let mut rng = Xoshiro256::new(seed ^ 0xD0);
    let prompt: Vec<i32> =
        (0..prompt_len).map(|_| rng.next_below(mcfg.vocab as u64) as i32).collect();

    let gcfg = GenConfig::new(k, eps, seed, prompt_len + max_new);
    println!(
        "generate: {model_name} ({} layers, d_model {}, vocab {}), {weights} — prompt {prompt_len} tokens, {max_new} new, k={k}, threads {}",
        mcfg.n_layers,
        mcfg.d_model(),
        mcfg.vocab,
        pool.threads()
    );
    let t0 = std::time::Instant::now();
    let mut dec = Decoder::new(&model, gcfg);
    dec.prefill(&prompt, pool);
    let generated = dec.generate(max_new, pool);
    let wall = t0.elapsed();

    // The tentpole contract, asserted on every invocation: one-shot
    // prefill over prompt ++ generated == incremental decode, bitwise.
    generate::check_decode_parity(&model, &gcfg, &prompt, &generated, dec.last_logits(), pool)?;

    let peak = dec.cache_peak_bytes();
    let bound = dec.cache_bound_bytes();
    let dense = dec.dense_baseline_bytes();
    anyhow::ensure!(
        peak <= bound,
        "KV-cache peak {peak} B exceeds the analytic bound {bound} B"
    );
    println!("tokens: {generated:?}");
    println!(
        "decode parity OK (one-shot prefill == incremental decode, bitwise) — {:.1} tok/s",
        max_new as f64 / wall.as_secs_f64().max(1e-12)
    );
    println!(
        "KV cache, {} layers × {} tokens (k={} generators/layer):",
        mcfg.n_layers,
        dec.len(),
        dec.effective_k()
    );
    println!("  measured peak   {:>12}", fmt_bytes(peak));
    println!("  analytic bound  {:>12}", fmt_bytes(bound));
    println!("  dense K/V       {:>12}", fmt_bytes(dense));
    println!(
        "  saved           {:>12} ({:.1}% of dense)",
        fmt_bytes(dense.saturating_sub(bound)),
        100.0 * dense.saturating_sub(bound) as f64 / dense.max(1) as f64
    );
    Ok(())
}

/// `pamm serve-sim` — play a deterministic scripted request load
/// through the continuous-batching serve loop
/// (`coordinator::serve`, DESIGN.md §8) and render the latency
/// percentiles, throughput, and compressed-vs-dense KV-cache savings.
/// The degradation knobs (`--max-queue`, `--token-budget`,
/// `--deadline-steps`, `--deadline-ms`) exercise the bounded-queue /
/// budget / deadline paths (DESIGN.md §9) and surface per-status
/// counters in the summary.
fn cmd_serve_sim(args: &Args) -> Result<()> {
    use pamm::coordinator::{scripted_load, serve, ServeConfig, SessionStatus};
    use pamm::memory::fmt_bytes;
    use pamm::model::{LmConfig, TransformerLM};
    use pamm::pamm::Eps;

    let quick = args.get_bool("quick");
    let model_name = args.get_str("model").unwrap_or_else(|| "nano".into());
    let g = ModelGeometry::by_name(&model_name)
        .with_context(|| format!("unknown model `{model_name}` (zoo: nano/tiny/small/…)"))?;
    let mcfg = LmConfig::from_geometry(&g)?;
    let n = args.get_usize("requests")?.unwrap_or(if quick { 6 } else { 12 }).max(1);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let max_concurrent = args.get_usize("max-concurrent")?.unwrap_or(4).max(1);
    let k = args.get_usize("k")?.unwrap_or(4).max(1);
    let eps = match args.get_f64("eps")? {
        Some(e) if e >= 0.0 => Eps::Val(e as f32),
        _ => Eps::Inf,
    };

    let model = TransformerLM::new(mcfg.clone(), seed);
    let reqs = scripted_load(n, mcfg.vocab, seed ^ 0x5EED);
    let mut scfg = ServeConfig::new(max_concurrent, k, eps, seed);
    scfg.max_queue = args.get_usize("max-queue")?.unwrap_or(0);
    scfg.token_budget = args.get_usize("token-budget")?.unwrap_or(0);
    scfg.deadline_steps = args.get_usize("deadline-steps")?.unwrap_or(0);
    if let Some(ms) = args.get_usize("deadline-ms")? {
        scfg.deadline = Some(std::time::Duration::from_millis(ms as u64));
    }
    let pool = pamm::poolx::global();
    println!(
        "serve-sim: {model_name} ({} layers, d_model {}, vocab {}) — {n} scripted requests, ≤{max_concurrent} concurrent, k={k}, threads {}",
        mcfg.n_layers,
        mcfg.d_model(),
        mcfg.vocab,
        pool.threads()
    );
    let out = serve(&model, &scfg, &reqs, pool)?;

    let ms = |d: std::time::Duration| format!("{:.3}ms", d.as_secs_f64() * 1e3);
    println!(
        "{:>4} {:>7} {:>6} {:>6} {:>5} {:>11} {:>12}  {:<11}",
        "id", "arrive", "admit", "done", "toks", "latency", "cache saved", "status"
    );
    for c in &out.completions {
        println!(
            "{:>4} {:>7} {:>6} {:>6} {:>5} {:>11} {:>12}  {:<11}{}",
            c.id,
            c.arrival,
            c.admitted_step,
            c.finished_step,
            c.tokens.len(),
            ms(c.latency),
            fmt_bytes(c.cache_saved_bytes),
            c.status.name(),
            c.diag.as_deref().map(|d| format!("  ({d})")).unwrap_or_default()
        );
    }
    for s in &out.shed {
        println!("{:>4} {:>7}   shed at step {} (queue full)", s.id, s.arrival, s.shed_step);
    }
    println!(
        "{} requests over {} serve steps in {} — {:.1} tok/s ({} tokens)",
        out.completions.len() + out.shed.len(),
        out.steps,
        ms(out.wall),
        out.tokens_per_sec(),
        out.total_tokens()
    );
    println!(
        "status: {} ok, {} truncated, {} timed-out, {} quarantined, {} rejected, {} shed",
        out.count(SessionStatus::Ok),
        out.count(SessionStatus::Truncated),
        out.count(SessionStatus::TimedOut),
        out.count(SessionStatus::Quarantined),
        out.count(SessionStatus::Rejected),
        out.shed.len()
    );
    println!(
        "latency p50 {}  p95 {}  p99 {}",
        ms(out.latency_percentile(0.50)),
        ms(out.latency_percentile(0.95)),
        ms(out.latency_percentile(0.99))
    );
    println!(
        "compressed KV caches saved {} vs dense K/V across the run",
        fmt_bytes(out.total_cache_saved_bytes())
    );
    Ok(())
}

/// `pamm chaos` — the deterministic fault-injection campaign
/// (`faultx::chaos`, DESIGN.md §9, EXPERIMENTS.md P15): scripted
/// kills at checkpoint boundaries, checkpoint bitrot, poisoned serve
/// sessions and burst overload, each verified against the fault-free
/// baseline (bitwise recovery / survivor identity). Exits non-zero if
/// any scenario fails. `--quick` is the CI smoke.
fn cmd_chaos(args: &Args) -> Result<()> {
    use pamm::faultx::chaos::{run_campaign, ChaosOpts};

    let opts = ChaosOpts {
        quick: args.get_bool("quick"),
        dp: args.get_bool("dp"),
        seed: args.get_usize("seed")?.unwrap_or(0xC4A05) as u64,
        dir: args.get_str("dir").unwrap_or_else(|| "target/chaos".into()),
    };
    println!(
        "chaos campaign: seed {}, {} mode{}, scratch dir {}",
        opts.seed,
        if opts.quick { "quick" } else { "full" },
        if opts.dp { " (data-parallel fleet)" } else { "" },
        opts.dir
    );
    let report = run_campaign(&opts, pamm::poolx::global())?;
    report.print_table();
    anyhow::ensure!(report.passed(), "chaos campaign failed");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_finetune(args: &Args) -> Result<()> {
    // No engine in this build — the native path is the only (and
    // default) fine-tuning engine; `--native` is accepted as a no-op.
    cmd_finetune_native(args)
}

/// `pamm finetune --native` — GLUE-style fine-tuning end to end on the
/// native stack (DESIGN.md §11): deterministic task corpus (synthetic
/// stand-in, or `--task-file` with pre-tokenized GLUE rows), stride
/// train/dev split with no leakage, classification head over the LM
/// trunk, dev-accuracy early stopping, bit-exact checkpoint/resume —
/// and an in-command loss-decrease assertion on every fresh run.
fn cmd_finetune_native(args: &Args) -> Result<()> {
    use pamm::coordinator::{finetune_native, find_task, FtRunConfig, NativeOpt};
    use pamm::model::LmConfig;

    let quick = args.get_bool("quick");
    let task_name =
        args.get_str("task").context("--task required (e.g. SST2, RTE, MNLI, AID)")?;
    let task = find_task(&task_name)?;
    let model_name = args.get_str("model").unwrap_or_else(|| "nano".into());
    let g = ModelGeometry::by_name(&model_name)
        .with_context(|| format!("unknown model `{model_name}` (zoo: nano/tiny/small/…)"))?;
    let mcfg = LmConfig::from_geometry(&g)?;
    anyhow::ensure!(
        mcfg.vocab > task.n_classes * 8 + 16,
        "model `{model_name}` (vocab {}) is too small for task {} ({} classes) — \
         pick a larger --model",
        mcfg.vocab,
        task.name,
        task.n_classes
    );
    let batch = args.get_usize("batch")?.unwrap_or(4).max(1);
    let seq = args.get_usize("seq")?.unwrap_or(if quick { 16 } else { 64 }).max(2);
    let steps = args.get_usize("steps")?.unwrap_or(if quick { 30 } else { 300 }).max(1);
    let tokens = batch * seq;
    let r_inv = args.get_usize("r-inv")?.unwrap_or(8).max(1);
    let k = match args.get_usize("k")? {
        Some(k) => k.clamp(1, tokens),
        None => tokens.div_ceil(r_inv).max(1),
    };
    let lr = args.get_f64("lr")?.unwrap_or(2e-3) as f32;
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let rc = FtRunConfig {
        cfg: mcfg.clone(),
        task: task.clone(),
        batch,
        seq,
        steps,
        k,
        opt: NativeOpt::adam(lr),
        seed,
        corpus_examples: args.get_usize("examples")?.unwrap_or(if quick { 64 } else { 512 }),
        dev_every: args.get_usize("dev-every")?.unwrap_or(5).max(2),
        eval_every: args.get_usize("eval-every")?.unwrap_or(if quick { 0 } else { 50 }),
        patience: args.get_usize("patience")?.unwrap_or(0),
        task_file: args.get_str("task-file"),
        ckpt_every: args.get_usize("ckpt-every")?.unwrap_or(0),
        keep_last: args.get_usize("keep-last")?.unwrap_or(3),
        run_dir: args.get_str("dir").unwrap_or_else(|| "runs".into()),
        run_name: format!(
            "ft_{model_name}_{}_k{k}_s{seed}",
            task.name.to_lowercase().replace('-', "_")
        ),
        resume: args.get_bool("resume"),
    };
    println!(
        "native fine-tuning: {} on {} ({} classes, {} metric) — batch {batch}x{seq}, k={k}, \
         {steps} steps, Adam lr {lr}, threads {}",
        model_name,
        task.name,
        task.n_classes,
        pamm::coordinator::finetune::metric_name(&task),
        pamm::poolx::global().threads()
    );
    let out = finetune_native(&rc, pamm::poolx::global(), args.get_bool("quiet"))?;
    println!(
        "dev: {}/{} correct ({:.1}% accuracy, {} {:.2})",
        out.dev.hits,
        out.dev.examples,
        100.0 * out.dev.accuracy,
        pamm::coordinator::finetune::metric_name(&task),
        out.dev.score
    );
    if out.curve.is_empty() {
        anyhow::ensure!(
            !quick,
            "quick smoke: checkpoint `{}` is already at the final step — \
             remove {}/ckpt or raise --steps",
            out.run_name,
            rc.run_dir
        );
        println!("checkpoint: {}/ckpt/{}.bin (already complete)", rc.run_dir, out.run_name);
        return Ok(());
    }
    if out.stopped_early {
        println!(
            "early stop at step {} (best dev {} hits at step {})",
            out.steps, out.best_hits, out.best_step
        );
    }
    println!(
        "done: final loss {:.4}  run log: {}/{}.jsonl  checkpoint: {}/ckpt/{}.bin",
        out.final_loss, rc.run_dir, out.run_name, rc.run_dir, out.run_name
    );
    if !rc.resume && out.curve.len() >= 2 {
        // Acceptance smoke, asserted in-command on every fresh run:
        // fine-tuning must make real progress on the task.
        let window = (out.curve.len() / 2).clamp(1, 5);
        let avg = |w: &[(usize, f32)]| {
            w.iter().map(|&(_, l)| l as f64).sum::<f64>() / w.len() as f64
        };
        let head = avg(&out.curve[..window]);
        let tail = avg(&out.curve[out.curve.len() - window..]);
        anyhow::ensure!(
            tail < head,
            "fine-tuning loss did not decrease (first {head:.4} vs last {tail:.4})"
        );
        println!("loss decreased: {head:.4} -> {tail:.4} over {} steps", out.steps);
    }
    Ok(())
}

/// `pamm ablate` — the native ε/k quality-vs-saved-bytes sweep (P17):
/// per-cell final loss against the exact tape saved bytes, the
/// all-generators cell asserted bit-equal to an independent dense
/// baseline, plus the analytic memory-zoo rows.
fn cmd_ablate(args: &Args) -> Result<()> {
    let out = args.get_str("out").unwrap_or_else(|| "results".into());
    let extra_eps = args.get_f64("epsilon")?.map(|e| e as f32);
    let extra_k = args.get_usize("k")?;
    pamm::experiments::ablation::ablation_table_with(
        args.get_bool("quick"),
        extra_eps,
        extra_k,
        &out,
    )
}

#[cfg(feature = "pjrt")]
fn cmd_finetune(args: &Args) -> Result<()> {
    if args.get_bool("native") {
        return cmd_finetune_native(args);
    }
    use pamm::coordinator::pipeline::LabeledPipeline;
    use pamm::coordinator::ClassifierSession;

    let task_name = args.get_str("task").context("--task required (e.g. SST2, AID)")?;
    let artifacts = args.get_str("artifacts").unwrap_or_else(|| "artifacts".into());
    let engine = Engine::load(&artifacts)?;

    let suite = glue::glue_suite();
    let spec = suite
        .iter()
        .find(|t| t.name.eq_ignore_ascii_case(&task_name))
        .cloned()
        .or_else(|| (task_name.eq_ignore_ascii_case("aid")).then(glue::aid_task))
        .with_context(|| format!("unknown task {task_name}"))?;

    let model = if spec.name == "AID" { "aid" } else { "glue" };
    let r_inv = args.get_usize("r-inv")?.unwrap_or(0);
    let variant = if r_inv == 0 { Variant::baseline() } else { Variant::pamm(r_inv as u32) };
    let meta = engine
        .find(|a| {
            a.kind == "cls_train_step"
                && a.config.as_deref() == Some(model)
                && a.variant_tag() == variant.tag()
        })
        .with_context(|| format!("no cls artifact for {model}/{}", variant.tag()))?
        .clone();
    let eval_name = meta
        .name
        .replace("clstrain", "clseval")
        .replace(&format!("_{}_", variant.tag()), "_");
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let steps = args
        .get_usize("steps")?
        .unwrap_or(meta.train.as_ref().map(|t| t.steps).unwrap_or(200));

    let mut session = ClassifierSession::new(&engine, &meta.name, &eval_name, seed)?;
    let vocab = engine.manifest.config(model).map(|c| c.vocab).unwrap_or(512);
    let gen = glue::TaskGenerator::new(spec.clone(), vocab, seed);
    let pipe = LabeledPipeline::spawn(gen, session.batch, session.seq, 2);

    for s in 0..steps {
        let b = pipe.next();
        let loss = session.step(
            &HostTensor::i32(vec![b.batch, b.seq], b.tokens.clone()),
            &HostTensor::i32(vec![b.batch], b.labels.clone()),
        )?;
        if s % (steps / 10).max(1) == 0 {
            println!("step {s:>4}  loss {loss:.4}");
        }
    }

    // Evaluate on a held-out stream.
    let mut gen = glue::TaskGenerator::new(spec.clone(), vocab, seed ^ 0xE);
    let (mut preds, mut golds) = (Vec::new(), Vec::new());
    for _ in 0..16 {
        let b = gen.batch(session.batch, session.seq);
        let p = session.predict(&HostTensor::i32(vec![b.batch, b.seq], b.tokens.clone()))?;
        preds.extend(p);
        golds.extend(b.labels);
    }
    println!(
        "{}: {} = {:.2}",
        spec.name,
        match spec.metric {
            glue::Metric::Accuracy => "accuracy",
            glue::Metric::F1 => "F1",
            glue::Metric::Matthews => "Matthews",
            glue::Metric::Pearson => "Pearson",
        },
        glue::score(&spec, &preds, &golds)
    );
    Ok(())
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    let name = args.pos(0, "experiment id")?;
    let artifacts = args.get_str("artifacts").unwrap_or_else(|| "artifacts".into());
    let out = args.get_str("out").unwrap_or_else(|| "results".into());
    // Native-only harnesses (table7, attention) run without artifacts —
    // don't demand an engine they never use. `table7 --native` swaps
    // the per-op breakdown for the real train-step optimization loop.
    if let Some(r) =
        pamm::experiments::run_native(name, args.get_bool("quick"), args.get_bool("native"), &out)
    {
        return r;
    }
    #[cfg(feature = "pjrt")]
    {
        let engine = Engine::load(&artifacts)?;
        pamm::experiments::run(&engine, name, args.get_bool("quick"), &out)
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = artifacts;
        Err(engine_unavailable(&format!("pamm reproduce {name}")))
    }
}

/// Parse a `BxHxLxD` shape flag.
fn parse_shape(shape_s: &str) -> Result<[usize; 4]> {
    let dims: Vec<usize> = shape_s
        .split('x')
        .map(|p| p.parse::<usize>().map_err(|_| anyhow::anyhow!("--shape expects BxHxLxD, got `{shape_s}`")))
        .collect::<Result<_>>()?;
    if dims.len() != 4 || dims.iter().any(|&v| v == 0) {
        bail!("--shape expects 4 nonzero dims BxHxLxD, got `{shape_s}`");
    }
    Ok([dims[0], dims[1], dims[2], dims[3]])
}

/// `pamm ledger` — one cold tracked fwd+bwd of the native train step at
/// a CLI-chosen shape, rendered as the per-phase memory ledger (the
/// README quickstart for the paper's training-memory claim; no
/// artifacts needed). `--layers N` switches to the whole-model
/// per-layer ledger (`cmd_ledger_model`).
fn cmd_ledger(args: &Args) -> Result<()> {
    use pamm::attention::AttnShape;
    use pamm::coordinator::{NativeOpt, NativeTrainer};
    use pamm::memory::{fmt_bytes, MemoryLedger};
    use pamm::rngx::Xoshiro256;
    use pamm::tensor::Mat;

    // `--workers R` switches to the data-parallel fleet ledger (one
    // tracked DP step: per-worker + aggregate saved-for-backward).
    if let Some(workers) = args.get_usize("workers")? {
        return cmd_ledger_dp(args, workers.max(1));
    }
    // `--layers N` switches to the whole-model per-layer ledger (one
    // tracked LM train step across N transformer blocks).
    if let Some(layers) = args.get_usize("layers")? {
        return cmd_ledger_model(args, layers.max(1));
    }

    let shape_s = args.get_str("shape").unwrap_or_else(|| "2x4x256x64".into());
    let dims = parse_shape(&shape_s)?;
    let shape = AttnShape::new(dims[0], dims[1], dims[2], dims[3], !args.get_bool("no-causal"));
    let tokens = shape.tokens();
    let k = match args.get_usize("k")? {
        Some(k) => k.clamp(1, tokens),
        None => {
            let r_inv = args.get_usize("r-inv")?.unwrap_or(16).max(1);
            (tokens.div_ceil(r_inv)).max(1)
        }
    };
    let dm = shape.d_model();
    let pool_threads = pamm::poolx::global().threads();
    println!(
        "memory ledger: one native train step, shape b={} h={} l={} d={} (tokens {tokens}, d_model {dm}), k={k}, threads={pool_threads}",
        dims[0], dims[1], dims[2], dims[3]
    );

    let mut rng = Xoshiro256::new(0x1ED6E8);
    let x = Mat::random_normal(tokens, dm, 1.0, &mut rng);
    let mut target = vec![0f32; shape.qkv_len()];
    rng.fill_normal_f32(&mut target, 1.0);

    // Cold protocol (EXPERIMENTS.md P12): fresh pool + fresh caller
    // thread so per-worker TLS scratch growth is measured.
    let ledger = MemoryLedger::new();
    std::thread::scope(|sc| {
        sc.spawn(|| {
            let cold = pamm::poolx::Pool::new(pool_threads);
            let mut t = NativeTrainer::new(shape, k, NativeOpt::adam(1e-3), 7);
            let _ = t.step_report(
                pamm::tensor::kernels::active(),
                &x,
                &target,
                &cold,
                Some(&ledger),
            );
        });
    });
    // The bound depends only on the compression geometry (k, n_in).
    let bwd_bound = pamm::autograd::backward_peak_bound(k, dm, &shape, pool_threads, false);
    let dense = pamm::autograd::dense_saved_bytes(dm, &shape);
    print!("{}", ledger.render(dense));
    println!(
        "backward peak ≤ analytic bound: {} ≤ {}",
        fmt_bytes(ledger.backward.peak()),
        fmt_bytes(bwd_bound)
    );
    println!(
        "saved-for-backward = Compressed (C {k}×{dm} + α/f {tokens} rows + β) + log-sum-exp ({} rows)",
        shape.batch * shape.heads * shape.seq
    );
    Ok(())
}

/// `pamm ledger --layers N`: per-layer memory ledger of one cold
/// tracked **whole-model** train step — per-block saved bytes vs the
/// dense-autodiff baseline, whole-model totals, and the measured
/// backward peak asserted under the model-level analytic bound
/// (`model::backward_peak_bound` = layers × per-block bound +
/// block-stack residual slack).
fn cmd_ledger_model(args: &Args, layers: usize) -> Result<()> {
    use pamm::attention::AttnShape;
    use pamm::coordinator::{LmTrainer, NativeOpt};
    use pamm::memory::{fmt_bytes, MemoryLedger};
    use pamm::model::{self, LmConfig};
    use pamm::rngx::Xoshiro256;

    let shape_s = args.get_str("shape").unwrap_or_else(|| "1x2x128x32".into());
    let [b, h, l, d] = parse_shape(&shape_s)?;
    let dm = h * d;
    let tokens = b * l;
    let vocab = args.get_usize("vocab")?.unwrap_or(256).max(4);
    let d_ff = args.get_usize("d-ff")?.unwrap_or(4 * dm);
    let k = match args.get_usize("k")? {
        Some(k) => k.clamp(1, tokens),
        None => {
            let r_inv = args.get_usize("r-inv")?.unwrap_or(16).max(1);
            tokens.div_ceil(r_inv).max(1)
        }
    };
    let cfg = LmConfig { vocab, n_layers: layers, heads: h, head_dim: d, d_ff };
    let threads = pamm::poolx::global().threads();
    println!(
        "memory ledger: one native LM train step, {layers} layers, shape b={b} h={h} l={l} d={d} (tokens {tokens}, d_model {dm}, d_ff {d_ff}, vocab {vocab}), k={k}, threads={threads}"
    );

    // Random token block — the ledger measures memory, not language.
    let mut rng = Xoshiro256::new(0x1ED6E8);
    let toks: Vec<i32> =
        (0..b * (l + 1)).map(|_| rng.next_below(vocab as u64) as i32).collect();

    // Cold protocol (EXPERIMENTS.md P12): fresh pool + fresh caller
    // thread so per-worker TLS scratch growth is measured.
    let ledger = MemoryLedger::new();
    let mut report = None;
    std::thread::scope(|sc| {
        sc.spawn(|| {
            let cold = pamm::poolx::Pool::new(threads);
            let mut t = LmTrainer::new(cfg.clone(), b, l, k, NativeOpt::adam(1e-3), 7);
            report =
                Some(t.step_report(pamm::tensor::kernels::active(), &toks, &cold, Some(&ledger)));
        });
    });
    let rep = report.expect("tracked step ran")?;
    let shape = AttnShape::new(b, h, l, d, true);
    let dense_block = model::dense_block_saved_bytes(&cfg, &shape);
    let tail = model::tail_saved_bytes(&cfg, &shape);
    let dense_total = model::dense_model_saved_bytes(&cfg, &shape);

    println!("\nper-layer saved-for-backward (step loss {:.4}):", rep.loss);
    println!("{:<14} {:>12} {:>12} {:>8}", "segment", "pamm saved", "dense saved", "factor");
    let shared = rep.inventory.embedding + rep.inventory.tail;
    println!(
        "{:<14} {:>12} {:>12} {:>7.1}x",
        "emb+head+loss",
        fmt_bytes(shared),
        fmt_bytes(tail),
        tail as f64 / shared.max(1) as f64
    );
    for (i, &bsaved) in rep.inventory.blocks.iter().enumerate() {
        println!(
            "{:<14} {:>12} {:>12} {:>7.1}x",
            format!("block {i}"),
            fmt_bytes(bsaved),
            fmt_bytes(dense_block),
            dense_block as f64 / bsaved.max(1) as f64
        );
    }
    println!(
        "{:<14} {:>12} {:>12} {:>7.1}x\n",
        "total",
        fmt_bytes(rep.inventory.total()),
        fmt_bytes(dense_total),
        dense_total as f64 / rep.inventory.total().max(1) as f64
    );
    print!("{}", ledger.render(dense_total));
    let bound = model::backward_peak_bound(&cfg, &shape, k, threads);
    println!(
        "backward peak ≤ model-level analytic bound: {} ≤ {}",
        fmt_bytes(ledger.backward.peak()),
        fmt_bytes(bound)
    );
    anyhow::ensure!(
        ledger.backward.peak() <= bound,
        "measured backward peak {} exceeds the model-level bound {bound}",
        ledger.backward.peak()
    );
    anyhow::ensure!(
        ledger.saved() == rep.saved_bytes,
        "ledger saved {} vs tape inventory {}",
        ledger.saved(),
        rep.saved_bytes
    );
    println!(
        "per-block saved = 2×LN(residual stream) + Compressed(QKV) + lse + O + Compressed(MLP); dense adds X_qkv + Q/K/V + X_mlp + z instead of the two Compressed structs"
    );
    Ok(())
}

/// `pamm ledger --workers R`: memory ledger of one cold tracked
/// **data-parallel fleet** step — per-worker and aggregate
/// saved-for-backward bytes across R × accum microbatches, against the
/// dense-autodiff baseline. The ranks execute in fixed order on the
/// one pool, so the transient peaks are per-microbatch, not R×.
fn cmd_ledger_dp(args: &Args, workers: usize) -> Result<()> {
    use pamm::attention::AttnShape;
    use pamm::coordinator::{DpTrainer, NativeOpt};
    use pamm::memory::{fmt_bytes, MemoryLedger};
    use pamm::model::{self, LmConfig};

    let shape_s = args.get_str("shape").unwrap_or_else(|| "1x2x128x32".into());
    let [b, h, l, d] = parse_shape(&shape_s)?;
    let dm = h * d;
    let tokens = b * l;
    let vocab = args.get_usize("vocab")?.unwrap_or(256).max(4);
    let d_ff = args.get_usize("d-ff")?.unwrap_or(4 * dm);
    let layers = args.get_usize("layers")?.unwrap_or(2).max(1);
    let accum = args.get_usize("grad-accum")?.unwrap_or(1).max(1);
    let k = match args.get_usize("k")? {
        Some(k) => k.clamp(1, tokens),
        None => {
            let r_inv = args.get_usize("r-inv")?.unwrap_or(16).max(1);
            tokens.div_ceil(r_inv).max(1)
        }
    };
    let cfg = LmConfig { vocab, n_layers: layers, heads: h, head_dim: d, d_ff };
    let threads = pamm::poolx::global().threads();
    println!(
        "memory ledger: one native DP fleet step, {workers} worker(s) × {accum} microbatch(es), {layers} layers, shape b={b} h={h} l={l} d={d} (tokens {tokens}, d_model {dm}, d_ff {d_ff}, vocab {vocab}), k={k}, threads={threads}"
    );

    // Cold protocol (EXPERIMENTS.md P12): fresh pool + fresh caller
    // thread so per-worker TLS scratch growth is measured.
    let ledger = MemoryLedger::new();
    let mut report = None;
    std::thread::scope(|sc| {
        sc.spawn(|| {
            let cold = pamm::poolx::Pool::new(threads);
            let mut t =
                DpTrainer::new(cfg.clone(), b, l, k, NativeOpt::adam(1e-3), 7, workers, accum);
            report = Some(t.train_step(&cold, Some(&ledger)));
        });
    });
    let rep = report.expect("tracked fleet step ran")?;

    let shape = AttnShape::new(b, h, l, d, true);
    let dense_one = model::dense_model_saved_bytes(&cfg, &shape);
    println!(
        "\nper-worker saved-for-backward (fleet step loss {:.4}, E = {} microbatches):",
        rep.loss, rep.e_active
    );
    println!("{:<10} {:>12} {:>12} {:>8}", "worker", "pamm saved", "dense saved", "factor");
    let dense_worker = dense_one * accum;
    for &(rank, saved) in &rep.per_worker_saved {
        println!(
            "{:<10} {:>12} {:>12} {:>7.1}x",
            format!("rank {rank}"),
            fmt_bytes(saved),
            fmt_bytes(dense_worker),
            dense_worker as f64 / saved.max(1) as f64
        );
    }
    let dense_total = dense_one * rep.e_active;
    println!(
        "{:<10} {:>12} {:>12} {:>7.1}x\n",
        "aggregate",
        fmt_bytes(rep.saved_bytes),
        fmt_bytes(dense_total),
        dense_total as f64 / rep.saved_bytes.max(1) as f64
    );
    print!("{}", ledger.render(dense_total));
    anyhow::ensure!(
        ledger.saved() == rep.saved_bytes,
        "ledger saved {} vs fleet per-worker total {}",
        ledger.saved(),
        rep.saved_bytes
    );
    println!(
        "ranks reduce in fixed order on one pool — transient peaks are per-microbatch, the saved rows scale with E = workers × accum"
    );
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let model = args.get_str("model").unwrap_or_else(|| "llama60m".into());
    let batch = args.get_usize("batch")?.unwrap_or(64);
    let seq = args.get_usize("seq")?.unwrap_or(256);
    let r_inv = args.get_usize("r-inv")?.unwrap_or(512);
    let g =
        ModelGeometry::by_name(&model).with_context(|| format!("unknown model `{model}`"))?;
    let rep = memory::report(&g, batch, seq, Some(1.0 / r_inv as f64));
    println!("model {model}: {} params", g.param_count());
    println!(
        "QKV activations @ batch {batch} × seq {seq}: baseline {}, PAMM(r=1/{r_inv}) {} ({:.2}% saved)",
        memory::fmt_bytes(rep.baseline_bytes),
        memory::fmt_bytes(rep.pamm_bytes.unwrap()),
        rep.savings_pct().unwrap()
    );
    Ok(())
}

/// Validate the native PAMM twin against the AOT kernel artifacts —
/// or, with `--probe`, report the SIMD dispatch level / tile parameters
/// / spot GFLOP/s of the native `tensor::kernels` GEMM (no artifacts
/// needed).
fn cmd_kernels(args: &Args) -> Result<()> {
    if args.get_bool("tune") {
        if args.get_bool("probe") {
            print!("{}", pamm::experiments::kernels::probe());
        }
        let cfg_path = args.get_str("config").unwrap_or_else(|| "pamm.toml".into());
        let quick = args.get_bool("quick");
        print!("{}", pamm::experiments::kernels::tune(&cfg_path, quick)?);
        return Ok(());
    }
    if args.get_bool("probe") {
        print!("{}", pamm::experiments::kernels::probe());
        return Ok(());
    }
    #[cfg(feature = "pjrt")]
    {
        let artifacts = args.get_str("artifacts").unwrap_or_else(|| "artifacts".into());
        let engine = Engine::load(&artifacts)?;
        let n = pamm::experiments::validate_kernels(&engine)?;
        println!("kernel validation OK ({n} artifacts checked)");
        Ok(())
    }
    #[cfg(not(feature = "pjrt"))]
    Err(engine_unavailable("pamm kernels (artifact validation; try --probe)"))
}

/// Render the persisted `BENCH_*.json` perf trail into markdown, keep
/// the commit-keyed history current, diff two history entries
/// (`--compare <a> <b>` — commit prefixes or `latest`/`prev`), or gate
/// a fresh run against the committed baseline (`--gate <pct>`).
fn cmd_bench_report(args: &Args) -> Result<()> {
    let dir = args.get_str("dir").unwrap_or_else(|| "benchmarks".into());
    let history = args.get_str("history").unwrap_or_else(|| "benchmarks/history.json".into());
    if let Some(a) = args.get_str("compare") {
        let b = args.pos(0, "second history entry (commit prefix | latest | prev)")?;
        print!("{}", pamm::benchx::history::compare_report(&history, &a, b)?);
        return Ok(());
    }
    if let Some(pct) = args.get_f64("gate")? {
        let verdict = pamm::benchx::history::gate(&dir, &history, pct)?;
        print!("{}", verdict.report);
        if verdict.failed {
            bail!("benchmark regression gate failed (>{pct}% vs baseline)");
        }
        return Ok(());
    }
    let out = args.get_str("out").unwrap_or_else(|| "BENCHMARKS.md".into());
    let report = pamm::benchx::report::render(&dir)?;
    if out == "-" {
        print!("{report}");
    } else {
        std::fs::write(&out, &report)?;
        println!("wrote {out} from {dir}/BENCH_*.json");
    }
    // Keep the append-only trail in step with the snapshot dir (same
    // commit ⇒ the entry is replaced, so re-renders don't duplicate).
    match pamm::benchx::history::append_from_dir(&dir, &history) {
        Ok(n) => println!("history: {history} now tracks {n} suite entr(y/ies) for this commit"),
        Err(e) => eprintln!("history: skipped ({e})"),
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_list(_args: &Args) -> Result<()> {
    Err(engine_unavailable("pamm list"))
}

#[cfg(feature = "pjrt")]
fn cmd_list(args: &Args) -> Result<()> {
    let artifacts = args.get_str("artifacts").unwrap_or_else(|| "artifacts".into());
    let engine = Engine::load(&artifacts)?;
    println!("{:<44} {:<14} {:>8} {:>8}", "name", "kind", "inputs", "outputs");
    for a in &engine.manifest.artifacts {
        println!(
            "{:<44} {:<14} {:>8} {:>8}",
            a.name,
            a.kind,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}
