//! Deterministic fault injection (DESIGN.md §9).
//!
//! A [`FaultPlan`] is a *replayable* chaos script: sampled once from a
//! seed via [`rngx::Xoshiro256`], it names the exact sites at which
//! faults fire — training crashes at checkpoint boundaries
//! ([`TrainFault`], three [`CrashPhase`]s), data-parallel worker kills
//! and stragglers ([`WorkerKill`], [`WorkerStall`], DESIGN.md §10),
//! checkpoint-file corruption (a seeded bit flip in the newest ring
//! entry), and poisoned serve sessions ([`PoisonSite`], non-finite
//! logits injected after a fixed token count). The same seed yields
//! the same plan on every machine,
//! thread count and SIMD level — chaos runs are as reproducible as the
//! training runs they attack, matching the repo's determinism
//! discipline.
//!
//! Injected crashes travel as [`InjectedCrash`] errors through the
//! ordinary `anyhow` error channel; the supervisor
//! (`coordinator::lm::train_lm_supervised`) recognizes them by
//! downcast ([`injected_crash`]) and recovers, while any *real* error
//! still propagates. The `chaos` submodule drives scripted campaigns
//! (`pamm chaos`).

pub mod chaos;

use std::fmt;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::rngx::Xoshiro256;

/// Where, relative to a checkpoint boundary, an injected kill lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashPhase {
    /// The process dies after the optimizer step but before the
    /// checkpoint write starts — the boundary's checkpoint is lost.
    BeforeCheckpoint,
    /// The process dies halfway through the blob write: a partial
    /// `.bin.tmp` is left behind, nothing was renamed into place.
    MidCheckpointWrite,
    /// The checkpoint (and the synced run log) landed, then the
    /// process dies — recovery resumes exactly at this boundary.
    AfterCheckpoint,
}

impl CrashPhase {
    pub const ALL: [CrashPhase; 3] =
        [CrashPhase::BeforeCheckpoint, CrashPhase::MidCheckpointWrite, CrashPhase::AfterCheckpoint];

    pub fn name(self) -> &'static str {
        match self {
            CrashPhase::BeforeCheckpoint => "before-ckpt",
            CrashPhase::MidCheckpointWrite => "mid-write",
            CrashPhase::AfterCheckpoint => "after-ckpt",
        }
    }
}

/// One scripted training kill: the run dies at checkpoint boundary
/// `step` (a completed-optimizer-step count), in the given phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrainFault {
    pub step: usize,
    pub phase: CrashPhase,
}

/// The error an injected kill raises. Carried inside `anyhow::Error`
/// so it flows through the normal error channel; the supervisor picks
/// it out by downcast ([`injected_crash`]) — anything else is a real
/// failure and still propagates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedCrash {
    pub step: usize,
    pub phase: CrashPhase,
}

impl fmt::Display for InjectedCrash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected crash at checkpoint boundary {} ({})", self.step, self.phase.name())
    }
}

impl std::error::Error for InjectedCrash {}

/// Downcast an error chain to the injected kill it carries, if any.
pub fn injected_crash(e: &anyhow::Error) -> Option<InjectedCrash> {
    e.downcast_ref::<InjectedCrash>().copied()
}

/// One scripted data-parallel worker kill: logical worker `rank` dies
/// at checkpoint boundary `step` (a completed-optimizer-step count) in
/// the given phase. For sharded checkpoints the phases map onto the
/// per-shard write sequence: `BeforeCheckpoint` kills before rank's
/// shard is written (earlier ranks' shards already landed but no
/// manifest committed), `MidCheckpointWrite` tears rank's shard blob
/// mid-write, `AfterCheckpoint` kills after the whole entry (manifest
/// included) committed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerKill {
    pub rank: usize,
    pub step: usize,
    pub phase: CrashPhase,
}

/// One scripted straggler: logical worker `rank` stalls at
/// 0-based execution step `step` for `polls` deadline polls before its
/// step report arrives. The supervisor retries with backoff up to its
/// stall budget; past the budget the rank is declared dead (elastic
/// runs re-shard, non-elastic runs fail with a diagnostic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerStall {
    pub rank: usize,
    pub step: usize,
    pub polls: usize,
}

/// One poisoned serve session: request `id`'s logits turn non-finite
/// once it has emitted `after_tokens` tokens (so every prior token is
/// clean, and the session is quarantined before emitting another).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoisonSite {
    pub id: usize,
    pub after_tokens: usize,
}

/// A complete scripted fault campaign. [`PartialEq`] so the replay
/// contract — same seed ⇒ the identical plan — is directly testable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Training kills, ascending by step; the supervisor arms
    /// `crashes[attempt]` on its `attempt`-th run.
    pub crashes: Vec<TrainFault>,
    /// After this many crashes have fired, flip one seeded bit in the
    /// newest ring entry before recovery — forcing the checksum +
    /// ring-fallback path.
    pub corrupt_after_attempt: Option<usize>,
    /// Poisoned serve sessions.
    pub poison: Vec<PoisonSite>,
    /// Data-parallel worker kills, ascending by step; the DP
    /// supervisor arms `worker_kills[attempt]` on its `attempt`-th run.
    pub worker_kills: Vec<WorkerKill>,
    /// Scripted stragglers, applied on every attempt (stalls are
    /// survivable, so replaying them keeps attempts trajectory-equal).
    pub stalls: Vec<WorkerStall>,
}

impl FaultPlan {
    /// An empty plan (no faults) under `seed`.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            crashes: Vec::new(),
            corrupt_after_attempt: None,
            poison: Vec::new(),
            worker_kills: Vec::new(),
            stalls: Vec::new(),
        }
    }

    /// Sample `n_crashes` distinct checkpoint boundaries (each with a
    /// seeded phase) from `boundaries`. Crashes are sorted ascending
    /// so every one fires: the supervisor's attempt `i` replays past
    /// all earlier kill points before `crashes[i]` triggers.
    pub fn sample_train(seed: u64, boundaries: &[usize], n_crashes: usize) -> FaultPlan {
        let mut plan = FaultPlan::new(seed);
        if boundaries.is_empty() || n_crashes == 0 {
            return plan;
        }
        let mut rng = Xoshiro256::fold_in(seed, 0xFA17, 0);
        let picks =
            rng.sample_without_replacement(boundaries.len(), n_crashes.min(boundaries.len()));
        let mut steps: Vec<usize> = picks.into_iter().map(|i| boundaries[i]).collect();
        steps.sort_unstable();
        plan.crashes = steps
            .into_iter()
            .map(|step| {
                let phase = CrashPhase::ALL[rng.next_below(3) as usize];
                TrainFault { step, phase }
            })
            .collect();
        plan
    }

    /// Every boundary × a cycling phase — the exhaustive kill sweep
    /// `prop_faults.rs` and the full chaos campaign iterate (one
    /// supervised run per entry, not one run with all of them).
    pub fn every_boundary(seed: u64, boundaries: &[usize]) -> Vec<FaultPlan> {
        let mut out = Vec::with_capacity(boundaries.len() * CrashPhase::ALL.len());
        for &step in boundaries {
            for phase in CrashPhase::ALL {
                let mut plan = FaultPlan::new(seed);
                plan.crashes.push(TrainFault { step, phase });
                out.push(plan);
            }
        }
        out
    }

    /// Poison `n` of the given `(id, max_new)` sessions at seeded
    /// token offsets in `[1, max_new - 2]` — strictly after the first
    /// clean token and strictly before the stream would complete, so a
    /// quarantine always fires and always leaves clean tokens behind.
    /// Sessions with `max_new < 3` are not eligible.
    pub fn sample_poison(mut self, sessions: &[(usize, usize)], n: usize) -> FaultPlan {
        let eligible: Vec<(usize, usize)> =
            sessions.iter().copied().filter(|&(_, max_new)| max_new >= 3).collect();
        if eligible.is_empty() || n == 0 {
            return self;
        }
        let mut rng = Xoshiro256::fold_in(self.seed, 0xFA17, 1);
        let picks = rng.sample_without_replacement(eligible.len(), n.min(eligible.len()));
        let mut sites: Vec<PoisonSite> = picks
            .into_iter()
            .map(|i| {
                let (id, max_new) = eligible[i];
                PoisonSite { id, after_tokens: 1 + rng.next_below((max_new - 2) as u64) as usize }
            })
            .collect();
        sites.sort_by_key(|s| s.id);
        self.poison = sites;
        self
    }

    /// Arm the checkpoint-corruption fault after crash `attempt`.
    pub fn with_corruption(mut self, after_attempt: usize) -> FaultPlan {
        self.corrupt_after_attempt = Some(after_attempt);
        self
    }

    /// Arm one data-parallel worker kill.
    pub fn with_worker_kill(mut self, rank: usize, step: usize, phase: CrashPhase) -> FaultPlan {
        self.worker_kills.push(WorkerKill { rank, step, phase });
        self.worker_kills.sort_by_key(|k| k.step);
        self
    }

    /// Arm one scripted straggler.
    pub fn with_stall(mut self, rank: usize, step: usize, polls: usize) -> FaultPlan {
        self.stalls.push(WorkerStall { rank, step, polls });
        self
    }

    /// Every (rank × boundary × phase) worker kill — the exhaustive DP
    /// recovery sweep `prop_dp.rs` and the full `pamm chaos --dp`
    /// campaign iterate (one supervised run per entry).
    pub fn every_worker_boundary(seed: u64, ranks: usize, boundaries: &[usize]) -> Vec<FaultPlan> {
        let mut out = Vec::with_capacity(ranks * boundaries.len() * CrashPhase::ALL.len());
        for rank in 0..ranks {
            for &step in boundaries {
                for phase in CrashPhase::ALL {
                    let mut plan = FaultPlan::new(seed);
                    plan.worker_kills.push(WorkerKill { rank, step, phase });
                    out.push(plan);
                }
            }
        }
        out
    }

    /// Sample one worker kill at a seeded (rank, boundary, phase) —
    /// the quick-mode stand-in for the exhaustive sweep.
    pub fn sample_worker_kill(seed: u64, ranks: usize, boundaries: &[usize]) -> FaultPlan {
        let mut plan = FaultPlan::new(seed);
        if ranks == 0 || boundaries.is_empty() {
            return plan;
        }
        let mut rng = Xoshiro256::fold_in(seed, 0xFA17, 2);
        let rank = rng.next_below(ranks as u64) as usize;
        let step = boundaries[rng.next_below(boundaries.len() as u64) as usize];
        let phase = CrashPhase::ALL[rng.next_below(3) as usize];
        plan.worker_kills.push(WorkerKill { rank, step, phase });
        plan
    }

    /// The poison site for request `id`, if this plan has one.
    pub fn poison_for(&self, id: usize) -> Option<PoisonSite> {
        self.poison.iter().copied().find(|s| s.id == id)
    }
}

/// Flip one seeded bit of the file at `path` (bitrot injection for the
/// checksum/fallback tests). Returns `(byte_offset, bit)` for the
/// diagnostic trail.
pub fn flip_bit_in_file(path: impl AsRef<Path>, rng: &mut Xoshiro256) -> Result<(usize, u8)> {
    let path = path.as_ref();
    let mut data = std::fs::read(path)
        .with_context(|| format!("fault injection: reading {}", path.display()))?;
    ensure!(!data.is_empty(), "fault injection: {} is empty", path.display());
    let byte = rng.next_below(data.len() as u64) as usize;
    let bit = (rng.next_below(8)) as u8;
    data[byte] ^= 1 << bit;
    std::fs::write(path, &data)
        .with_context(|| format!("fault injection: rewriting {}", path.display()))?;
    Ok((byte, bit))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_replay_identically_from_the_same_seed() {
        let boundaries = [2usize, 4, 6, 8];
        let sessions = [(0usize, 5usize), (1, 8), (2, 4), (3, 3)];
        let a = FaultPlan::sample_train(41, &boundaries, 2).sample_poison(&sessions, 2);
        let b = FaultPlan::sample_train(41, &boundaries, 2).sample_poison(&sessions, 2);
        assert_eq!(a, b, "same seed must yield the identical plan");
        let c = FaultPlan::sample_train(42, &boundaries, 2).sample_poison(&sessions, 2);
        assert!(!a.crashes.is_empty() && !a.poison.is_empty());
        // (different seeds *may* collide on tiny spaces; these don't)
        assert_ne!(a, c, "a different seed must be able to move the fault sites");
    }

    #[test]
    fn sampled_crashes_are_sorted_distinct_boundaries() {
        let boundaries = [10usize, 2, 6, 4, 8];
        let plan = FaultPlan::sample_train(7, &boundaries, 4);
        let steps: Vec<usize> = plan.crashes.iter().map(|c| c.step).collect();
        let mut sorted = steps.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(steps, sorted, "crashes must be ascending and distinct: {steps:?}");
        assert!(steps.iter().all(|s| boundaries.contains(s)));
    }

    #[test]
    fn poison_sites_leave_room_on_both_sides() {
        let sessions: Vec<(usize, usize)> = (0..8).map(|i| (i, 3 + i % 5)).collect();
        let plan = FaultPlan::new(3).sample_poison(&sessions, 8);
        assert!(!plan.poison.is_empty());
        for site in &plan.poison {
            let (_, max_new) = sessions.iter().find(|(id, _)| *id == site.id).unwrap();
            assert!(
                site.after_tokens >= 1 && site.after_tokens <= max_new - 2,
                "site {site:?} out of [1, {}]",
                max_new - 2
            );
        }
    }

    #[test]
    fn every_boundary_covers_the_full_grid() {
        let plans = FaultPlan::every_boundary(1, &[2, 4]);
        assert_eq!(plans.len(), 6);
        for phase in CrashPhase::ALL {
            for step in [2usize, 4] {
                assert!(plans
                    .iter()
                    .any(|p| p.crashes == vec![TrainFault { step, phase }]));
            }
        }
    }

    #[test]
    fn every_worker_boundary_covers_the_full_grid() {
        let plans = FaultPlan::every_worker_boundary(1, 2, &[2, 4]);
        assert_eq!(plans.len(), 12);
        for rank in 0..2 {
            for step in [2usize, 4] {
                for phase in CrashPhase::ALL {
                    assert!(plans
                        .iter()
                        .any(|p| p.worker_kills == vec![WorkerKill { rank, step, phase }]));
                }
            }
        }
    }

    #[test]
    fn sampled_worker_kills_replay_and_stay_in_range() {
        let boundaries = [2usize, 4, 6];
        let a = FaultPlan::sample_worker_kill(9, 4, &boundaries);
        let b = FaultPlan::sample_worker_kill(9, 4, &boundaries);
        assert_eq!(a, b, "same seed must yield the identical kill");
        assert_eq!(a.worker_kills.len(), 1);
        let k = a.worker_kills[0];
        assert!(k.rank < 4 && boundaries.contains(&k.step));
        assert!(FaultPlan::sample_worker_kill(9, 0, &boundaries).worker_kills.is_empty());
    }

    #[test]
    fn worker_kill_and_stall_builders_compose() {
        let plan = FaultPlan::new(5)
            .with_worker_kill(1, 6, CrashPhase::MidCheckpointWrite)
            .with_worker_kill(0, 2, CrashPhase::AfterCheckpoint)
            .with_stall(2, 3, 2);
        let steps: Vec<usize> = plan.worker_kills.iter().map(|k| k.step).collect();
        assert_eq!(steps, vec![2, 6], "kills must sort ascending by step");
        assert_eq!(plan.stalls, vec![WorkerStall { rank: 2, step: 3, polls: 2 }]);
    }

    #[test]
    fn injected_crash_downcasts_through_anyhow() {
        let crash = InjectedCrash { step: 4, phase: CrashPhase::MidCheckpointWrite };
        let err = anyhow::Error::new(crash).context("checkpoint boundary 4");
        assert_eq!(injected_crash(&err), Some(crash));
        let real = anyhow::anyhow!("disk on fire");
        assert_eq!(injected_crash(&real), None);
    }

    #[test]
    fn bit_flip_changes_exactly_one_bit() {
        let dir = std::env::temp_dir().join(format!("pamm_faultx_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("blob");
        let original = vec![0xA5u8; 64];
        std::fs::write(&p, &original).unwrap();
        let mut rng = Xoshiro256::new(11);
        let (byte, bit) = flip_bit_in_file(&p, &mut rng).unwrap();
        let flipped = std::fs::read(&p).unwrap();
        assert_eq!(flipped.len(), original.len());
        let diff: Vec<usize> =
            (0..64).filter(|&i| flipped[i] != original[i]).collect();
        assert_eq!(diff, vec![byte]);
        assert_eq!(flipped[byte] ^ original[byte], 1 << bit);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
