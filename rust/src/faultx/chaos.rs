//! The `pamm chaos` campaign: scripted fault injection with
//! pass/fail verdicts (DESIGN.md §9, EXPERIMENTS.md P15).
//!
//! Each row of the campaign runs one deterministic fault scenario
//! end-to-end and checks the recovery *property*, not just survival:
//!
//! * **Kill sweep** — one supervised training run per scripted kill
//!   (`--quick`: one seeded kill; full: every checkpoint boundary ×
//!   every [`CrashPhase`]). Pass iff the recovered run's final
//!   checkpoint is **bitwise identical** to the uninterrupted
//!   baseline's and the fsync'd run log replays to the identical loss
//!   curve ([`metrics::replay_run_log`]).
//! * **Corruption fallback** — a kill right after a mid-run
//!   checkpoint, then a seeded bit flip in the newest ring entry.
//!   Pass iff recovery *detects* the corruption (diagnostic present),
//!   falls back to the previous ring entry, and still converges to
//!   the bitwise-identical final state.
//! * **Serve quarantine** — a poisoned session under the
//!   continuous-batching loop at 1 and 2 workers. Pass iff exactly
//!   the scripted sessions are quarantined with clean token prefixes
//!   and every *surviving* stream is bitwise identical to the
//!   fault-free baseline at every worker count.
//! * **Overload shedding** — a burst load against a bounded queue
//!   with a token budget. Pass iff every request is accounted for
//!   (completions + shed == requests) and the shed/truncation
//!   decisions are identical at 1 and 2 workers.
//!
//! With `--dp` the campaign targets the data-parallel fleet instead
//! (DESIGN.md §10, EXPERIMENTS.md P16): a 2-worker baseline checked
//! for physical-thread invariance, a worker-kill sweep against
//! *sharded* checkpoints (quick: one seeded (rank, boundary, phase);
//! full: every rank × boundary × phase), a shard-corruption fallback
//! row, a within-budget straggler row (the stall must not change the
//! trajectory), a straggler-timeout row (the non-elastic run must fail
//! with the actionable diagnostic), and an elastic degradation row
//! (the fleet reshards onto the survivor, logs
//! `{"event":"reshard"}`, and a rerun reproduces the degraded run
//! bit for bit).
//!
//! The campaign is a pure function of `(seed, quick)` — rerunning it
//! reproduces every fault and every verdict bit-for-bit, which is
//! what makes a failing row debuggable.

use anyhow::{Context, Result};

use crate::checkpoint;
use crate::coordinator::dp::{
    train_lm_dp_native_run, train_lm_dp_supervised, DpRunConfig,
};
use crate::coordinator::lm::{
    checkpoint_boundaries, train_lm_native_run, train_lm_supervised, LmRunConfig,
};
use crate::coordinator::serve::{serve, serve_faulted, ServeConfig, ServeRequest, SessionStatus};
use crate::coordinator::NativeOpt;
use crate::faultx::{CrashPhase, FaultPlan, TrainFault};
use crate::metrics;
use crate::model::LmConfig;
use crate::pamm::Eps;
use crate::poolx::Pool;
use crate::runtime::HostTensor;

/// Campaign knobs (the `pamm chaos` flags).
#[derive(Debug, Clone)]
pub struct ChaosOpts {
    /// CI smoke mode: one seeded kill + one poisoned session instead
    /// of the exhaustive boundary × phase sweep.
    pub quick: bool,
    /// Target the data-parallel fleet (worker kills, shard corruption,
    /// stragglers, elastic degradation) instead of the single-process
    /// scenarios.
    pub dp: bool,
    pub seed: u64,
    /// Scratch directory for the campaign's run dirs (wiped first).
    pub dir: String,
}

impl Default for ChaosOpts {
    fn default() -> Self {
        ChaosOpts { quick: false, dp: false, seed: 0xC4A0_5, dir: "target/chaos".into() }
    }
}

/// One scenario's verdict.
#[derive(Debug)]
pub struct ChaosRow {
    pub name: String,
    pub pass: bool,
    /// What was checked (pass) or what diverged (fail).
    pub detail: String,
}

/// The full campaign result; `pamm chaos` renders it as a table and
/// exits non-zero unless [`ChaosReport::passed`].
#[derive(Debug)]
pub struct ChaosReport {
    pub rows: Vec<ChaosRow>,
}

impl ChaosReport {
    pub fn passed(&self) -> bool {
        self.rows.iter().all(|r| r.pass)
    }

    /// Render the pass/fail table to stdout.
    pub fn print_table(&self) {
        let w = self.rows.iter().map(|r| r.name.len()).max().unwrap_or(8).max(8);
        println!("{:<w$}  {:<6}  detail", "scenario", "verdict");
        println!("{}  {}  {}", "-".repeat(w), "-".repeat(6), "-".repeat(32));
        for r in &self.rows {
            println!("{:<w$}  {:<6}  {}", r.name, if r.pass { "PASS" } else { "FAIL" }, r.detail);
        }
        let (p, n) = (self.rows.iter().filter(|r| r.pass).count(), self.rows.len());
        println!("{}", "-".repeat(w + 10 + 32));
        println!("{p}/{n} scenarios passed");
    }
}

/// The tiny-but-real model every training scenario uses: 2 layers so
/// cross-layer state is exercised, small enough that the full sweep
/// (a dozen supervised runs) stays in CI-smoke territory.
fn train_rc(opts: &ChaosOpts, run_name: &str) -> LmRunConfig {
    LmRunConfig {
        cfg: LmConfig { vocab: 120, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 },
        batch: 2,
        seq: 12,
        steps: if opts.quick { 4 } else { 8 },
        k: 4,
        opt: NativeOpt::adam(3e-3),
        seed: opts.seed,
        ckpt_every: 2,
        keep_last: 3,
        run_dir: format!("{}/{run_name}", opts.dir),
        run_name: run_name.to_string(),
        resume: false,
    }
}

/// Final plain checkpoint of a finished run, for bitwise comparison.
fn final_tensors(rc: &LmRunConfig) -> Result<Vec<(String, HostTensor)>> {
    checkpoint::load(format!("{}/ckpt", rc.run_dir), &rc.run_name)
        .with_context(|| format!("final checkpoint of `{}`", rc.run_name))
}

/// Replayed (step, loss-bits) curve of a run's fsync'd log.
fn replayed_bits(rc: &LmRunConfig) -> Result<Vec<(usize, u64)>> {
    let curve = metrics::replay_run_log(&rc.run_dir, &rc.run_name)?;
    Ok(curve.into_iter().map(|(s, l)| (s, l.to_bits())).collect())
}

/// Run the whole campaign. Wipes `opts.dir` first; every scenario gets
/// its own run dir underneath it. `--dp` switches to the data-parallel
/// fleet campaign.
pub fn run_campaign(opts: &ChaosOpts, pool: &Pool) -> Result<ChaosReport> {
    let _ = std::fs::remove_dir_all(&opts.dir);
    std::fs::create_dir_all(&opts.dir)
        .with_context(|| format!("creating chaos dir {}", opts.dir))?;
    if opts.dp {
        return run_dp_campaign(opts, pool);
    }
    let mut rows = Vec::new();

    // -- training baseline: the uninterrupted run every recovery must
    //    reproduce bit-for-bit.
    let base_rc = train_rc(opts, "base");
    train_lm_native_run(&base_rc, None, pool, true)?;
    let base_final = final_tensors(&base_rc)?;
    let base_log = replayed_bits(&base_rc)?;

    // -- kill sweep.
    let boundaries = checkpoint_boundaries(&base_rc);
    let plans: Vec<FaultPlan> = if opts.quick {
        vec![FaultPlan::sample_train(opts.seed, &boundaries, 1)]
    } else {
        FaultPlan::every_boundary(opts.seed, &boundaries)
    };
    for plan in &plans {
        let f = plan.crashes[0];
        let name = format!("kill s{}/{}", f.step, f.phase.name());
        let rc = train_rc(opts, &format!("kill_s{}_{}", f.step, f.phase.name()));
        rows.push(match kill_row(&rc, plan, pool, &base_final, &base_log) {
            Ok(detail) => ChaosRow { name, pass: true, detail },
            Err(e) => ChaosRow { name, pass: false, detail: format!("{e:#}") },
        });
    }

    // -- corruption fallback: kill right after the second boundary's
    //    checkpoint landed, then bit-flip it — recovery must detect,
    //    fall back to the first boundary, and still converge bitwise.
    {
        let rc = train_rc(opts, "corrupt");
        let plan = {
            let mut p = FaultPlan::new(opts.seed);
            p.crashes.push(TrainFault { step: boundaries[1], phase: CrashPhase::AfterCheckpoint });
            p.with_corruption(0)
        };
        rows.push(match corruption_row(&rc, &plan, boundaries[0], pool, &base_final) {
            Ok(detail) => ChaosRow { name: "corrupt newest ckpt".into(), pass: true, detail },
            Err(e) => ChaosRow { name: "corrupt newest ckpt".into(), pass: false, detail: format!("{e:#}") },
        });
    }

    // -- serve scenarios (no run dirs; pure in-memory).
    let model = crate::model::TransformerLM::new(
        LmConfig { vocab: 64, n_layers: 2, heads: 2, head_dim: 4, d_ff: 16 },
        opts.seed,
    );
    let load = crate::coordinator::scripted_load(if opts.quick { 6 } else { 8 }, 64, opts.seed);
    let scfg = ServeConfig::new(2, 4, Eps::Inf, opts.seed);
    rows.push(
        match quarantine_row(&model, &scfg, &load, opts, if opts.quick { 1 } else { 2 }) {
            Ok(detail) => ChaosRow { name: "serve quarantine".into(), pass: true, detail },
            Err(e) => ChaosRow { name: "serve quarantine".into(), pass: false, detail: format!("{e:#}") },
        },
    );
    rows.push(match shed_row(&model, &scfg, &load) {
        Ok(detail) => ChaosRow { name: "overload shed".into(), pass: true, detail },
        Err(e) => ChaosRow { name: "overload shed".into(), pass: false, detail: format!("{e:#}") },
    });

    Ok(ChaosReport { rows })
}

/// One supervised run under `plan`; pass iff bitwise-identical final
/// checkpoint and replayed log vs the baseline.
fn kill_row(
    rc: &LmRunConfig,
    plan: &FaultPlan,
    pool: &Pool,
    base_final: &[(String, HostTensor)],
    base_log: &[(usize, u64)],
) -> Result<String> {
    let out = train_lm_supervised(rc, plan, pool, true)?;
    anyhow::ensure!(
        out.crashes.len() == plan.crashes.len(),
        "armed {} crash(es) but {} fired",
        plan.crashes.len(),
        out.crashes.len()
    );
    let fin = final_tensors(rc)?;
    anyhow::ensure!(fin == base_final, "recovered final checkpoint differs from baseline");
    let log = replayed_bits(rc)?;
    anyhow::ensure!(log == base_log, "replayed run log differs from baseline");
    Ok(format!(
        "recovered in {} attempt(s), resume at {:?}; final ckpt + replayed log bitwise equal",
        out.attempts, out.resume_steps
    ))
}

/// Corruption scenario; pass iff the flip was detected, the ring fell
/// back to `expect_resume`, and the final state still matches.
fn corruption_row(
    rc: &LmRunConfig,
    plan: &FaultPlan,
    expect_resume: usize,
    pool: &Pool,
    base_final: &[(String, HostTensor)],
) -> Result<String> {
    let out = train_lm_supervised(rc, plan, pool, true)?;
    anyhow::ensure!(
        out.recovery_diags.iter().any(|d| d.contains("injected corruption")),
        "corruption was never injected"
    );
    anyhow::ensure!(
        out.recovery_diags.iter().any(|d| d.contains("failed verification")),
        "corrupted entry was not detected: {:?}",
        out.recovery_diags
    );
    anyhow::ensure!(
        out.resume_steps == vec![expect_resume],
        "expected fallback resume at step {expect_resume}, got {:?}",
        out.resume_steps
    );
    let fin = final_tensors(rc)?;
    anyhow::ensure!(fin == base_final, "post-fallback final checkpoint differs from baseline");
    Ok(format!(
        "flip detected, fell back to s{expect_resume}, final ckpt bitwise equal ({} diag(s))",
        out.recovery_diags.len()
    ))
}

/// Poisoned-session scenario at 1 and 2 workers.
fn quarantine_row(
    model: &crate::model::TransformerLM,
    scfg: &ServeConfig,
    load: &[ServeRequest],
    opts: &ChaosOpts,
    n_poison: usize,
) -> Result<String> {
    let clean = serve(model, scfg, load, &Pool::serial())?;
    let sessions: Vec<(usize, usize)> = load.iter().map(|r| (r.id, r.max_new)).collect();
    let plan = FaultPlan::new(opts.seed).sample_poison(&sessions, n_poison);
    anyhow::ensure!(plan.poison.len() == n_poison, "poison sampling came up short");
    let mut detail = String::new();
    for workers in [1usize, 2] {
        let pool = if workers == 1 { Pool::serial() } else { Pool::new(2).with_min_chunk(1) };
        let out = serve_faulted(model, scfg, load, Some(&plan), &pool)?;
        anyhow::ensure!(
            out.count(SessionStatus::Quarantined) == n_poison,
            "expected {n_poison} quarantined at {workers} worker(s), got {}",
            out.count(SessionStatus::Quarantined)
        );
        for c in &out.completions {
            let base = clean
                .completions
                .iter()
                .find(|k| k.id == c.id)
                .context("completion for unknown id")?;
            if let Some(site) = plan.poison_for(c.id) {
                anyhow::ensure!(
                    c.status == SessionStatus::Quarantined
                        && c.tokens[..] == base.tokens[..site.after_tokens],
                    "poisoned session {} kept a dirty stream at {workers} worker(s)",
                    c.id
                );
            } else {
                anyhow::ensure!(
                    c.status == SessionStatus::Ok && c.tokens == base.tokens,
                    "survivor {} drifted at {workers} worker(s)",
                    c.id
                );
            }
        }
        detail = format!(
            "{n_poison} quarantined with clean prefixes, {} survivor(s) bitwise equal @ 1+2 workers",
            out.completions.len() - n_poison
        );
    }
    Ok(detail)
}

/// Burst load against a bounded queue + token budget.
fn shed_row(model: &crate::model::TransformerLM, scfg: &ServeConfig, load: &[ServeRequest]) -> Result<String> {
    // Everyone arrives at once; one slot and a 2-deep queue force shed.
    let burst: Vec<ServeRequest> =
        load.iter().map(|r| ServeRequest { arrival: 0, ..r.clone() }).collect();
    let hard = ServeConfig { max_concurrent: 1, max_queue: 2, token_budget: 3, ..*scfg };
    let serial = serve(model, &hard, &burst, &Pool::serial())?;
    anyhow::ensure!(!serial.shed.is_empty(), "bounded queue never shed under burst load");
    anyhow::ensure!(
        serial.completions.len() + serial.shed.len() == burst.len(),
        "requests unaccounted for: {} completed + {} shed of {}",
        serial.completions.len(),
        serial.shed.len(),
        burst.len()
    );
    let par = serve(model, &hard, &burst, &Pool::new(2).with_min_chunk(1))?;
    let ids = |o: &crate::coordinator::ServeOutcome| {
        (
            o.shed.iter().map(|s| s.id).collect::<Vec<_>>(),
            o.completions.iter().map(|c| (c.id, c.status, c.tokens.clone())).collect::<Vec<_>>(),
        )
    };
    anyhow::ensure!(ids(&serial) == ids(&par), "shed/truncation decisions drifted with workers");
    let truncated = serial.count(SessionStatus::Truncated);
    Ok(format!(
        "{} shed, {truncated} truncated by budget, all {} accounted for, deterministic @ 1+2 workers",
        serial.shed.len(),
        burst.len()
    ))
}

// ---------------------------------------------------------------------------
// The data-parallel campaign (`pamm chaos --dp`)
// ---------------------------------------------------------------------------

/// The 2-worker fleet every DP scenario uses (`batch` drops to 1 so a
/// fleet step costs what a single-process step does).
fn dp_rc(opts: &ChaosOpts, run_name: &str, elastic: bool) -> DpRunConfig {
    let mut base = train_rc(opts, run_name);
    base.batch = 1;
    base.steps = if opts.quick { 4 } else { 6 };
    DpRunConfig { base, workers: 2, accum: 1, elastic, stall_budget: 3 }
}

fn row(name: &str, res: Result<String>) -> ChaosRow {
    match res {
        Ok(detail) => ChaosRow { name: name.to_string(), pass: true, detail },
        Err(e) => ChaosRow { name: name.to_string(), pass: false, detail: format!("{e:#}") },
    }
}

/// The `--dp` campaign: fleet determinism, worker-kill recovery from
/// sharded checkpoints, shard-corruption fallback, stragglers within
/// and past the stall budget, and elastic degradation.
fn run_dp_campaign(opts: &ChaosOpts, pool: &Pool) -> Result<ChaosReport> {
    let mut rows = Vec::new();

    // -- baseline: the uninterrupted 2-worker run every recovery must
    //    reproduce bit-for-bit — itself checked for physical-thread
    //    invariance first.
    let base_rc = dp_rc(opts, "dp_base", false);
    train_lm_dp_native_run(&base_rc, None, &[], pool, true)?;
    let base_final = final_tensors(&base_rc.base)?;
    let base_log = replayed_bits(&base_rc.base)?;
    {
        let rc2 = dp_rc(opts, "dp_base_t2", false);
        train_lm_dp_native_run(&rc2, None, &[], &Pool::new(2).with_min_chunk(1), true)?;
        let fin = final_tensors(&rc2.base)?;
        rows.push(row(
            "dp determinism",
            if fin == base_final {
                Ok("2-worker fleet bitwise equal at 1 and 2 physical threads".into())
            } else {
                Err(anyhow::anyhow!("final checkpoint drifted with physical threads"))
            },
        ));
    }

    // -- worker-kill sweep against sharded checkpoints.
    let boundaries = checkpoint_boundaries(&base_rc.base);
    let plans: Vec<FaultPlan> = if opts.quick {
        vec![FaultPlan::sample_worker_kill(opts.seed, base_rc.workers, &boundaries)]
    } else {
        FaultPlan::every_worker_boundary(opts.seed, base_rc.workers, &boundaries)
    };
    for plan in &plans {
        let k = plan.worker_kills[0];
        let name = format!("dp kill r{} s{}/{}", k.rank, k.step, k.phase.name());
        let rc = dp_rc(opts, &format!("dp_kill_r{}_s{}_{}", k.rank, k.step, k.phase.name()), false);
        rows.push(row(&name, dp_kill_row(&rc, plan, pool, &base_final, &base_log)));
    }

    // -- shard-corruption fallback: kill right after a mid-run sharded
    //    entry committed, flip one seeded bit in one of its shards —
    //    recovery must detect the bad shard, fall back a whole entry,
    //    and still converge bitwise.
    {
        let rc = dp_rc(opts, "dp_corrupt", false);
        let plan = FaultPlan::new(opts.seed)
            .with_worker_kill(1, boundaries[1], CrashPhase::AfterCheckpoint)
            .with_corruption(0);
        rows.push(row(
            "dp corrupt shard",
            dp_corruption_row(&rc, &plan, boundaries[0], pool, &base_final),
        ));
    }

    // -- straggler within the stall budget: retry/backoff absorbs it
    //    and the trajectory must not change.
    {
        let rc = dp_rc(opts, "dp_stall", false);
        let plan = FaultPlan::new(opts.seed).with_stall(1, 1, 2);
        rows.push(row("dp straggler ok", dp_stall_row(&rc, &plan, pool, &base_final)));
    }

    // -- straggler past the budget, non-elastic: the run must fail
    //    with the actionable diagnostic, not hang or corrupt.
    {
        let rc = dp_rc(opts, "dp_timeout", false);
        let plan = FaultPlan::new(opts.seed).with_stall(1, 1, 5);
        rows.push(row("dp straggler timeout", dp_timeout_row(&rc, &plan, pool)));
    }

    // -- elastic degradation: same overload under --elastic — the
    //    fleet reshards onto the survivor and a rerun reproduces the
    //    degraded trajectory bit for bit.
    {
        let rc_a = dp_rc(opts, "dp_elastic_a", true);
        let rc_b = dp_rc(opts, "dp_elastic_b", true);
        let plan = FaultPlan::new(opts.seed).with_stall(1, 1, 5);
        rows.push(row("dp elastic reshard", dp_elastic_row(&rc_a, &rc_b, &plan, pool)));
    }

    Ok(ChaosReport { rows })
}

/// One supervised fleet run under `plan`; pass iff bitwise-identical
/// final checkpoint and replayed log vs the kill-free baseline.
fn dp_kill_row(
    rc: &DpRunConfig,
    plan: &FaultPlan,
    pool: &Pool,
    base_final: &[(String, HostTensor)],
    base_log: &[(usize, u64)],
) -> Result<String> {
    let out = train_lm_dp_supervised(rc, plan, pool, true)?;
    anyhow::ensure!(
        out.kills.len() == plan.worker_kills.len(),
        "armed {} kill(s) but {} fired",
        plan.worker_kills.len(),
        out.kills.len()
    );
    let fin = final_tensors(&rc.base)?;
    anyhow::ensure!(fin == base_final, "recovered final checkpoint differs from baseline");
    let log = replayed_bits(&rc.base)?;
    anyhow::ensure!(log == base_log, "replayed run log differs from baseline");
    Ok(format!(
        "fleet recovered in {} attempt(s), resume at {:?}; final ckpt + replayed log bitwise equal",
        out.attempts, out.resume_steps
    ))
}

/// Shard-corruption scenario; pass iff the bad shard was detected, the
/// ring fell back to `expect_resume`, and the final state matches.
fn dp_corruption_row(
    rc: &DpRunConfig,
    plan: &FaultPlan,
    expect_resume: usize,
    pool: &Pool,
    base_final: &[(String, HostTensor)],
) -> Result<String> {
    let out = train_lm_dp_supervised(rc, plan, pool, true)?;
    anyhow::ensure!(
        out.recovery_diags.iter().any(|d| d.contains("injected corruption")),
        "corruption was never injected"
    );
    anyhow::ensure!(
        out.recovery_diags.iter().any(|d| d.contains("shard") && d.contains("failed verification")),
        "corrupted shard was not detected: {:?}",
        out.recovery_diags
    );
    anyhow::ensure!(
        out.resume_steps == vec![expect_resume],
        "expected fallback resume at step {expect_resume}, got {:?}",
        out.resume_steps
    );
    let fin = final_tensors(&rc.base)?;
    anyhow::ensure!(fin == base_final, "post-fallback final checkpoint differs from baseline");
    Ok(format!(
        "bad shard detected, fell back to s{expect_resume}, final ckpt bitwise equal ({} diag(s))",
        out.recovery_diags.len()
    ))
}

/// Within-budget straggler; pass iff the stall was absorbed and the
/// trajectory is unchanged.
fn dp_stall_row(
    rc: &DpRunConfig,
    plan: &FaultPlan,
    pool: &Pool,
    base_final: &[(String, HostTensor)],
) -> Result<String> {
    let out = train_lm_dp_supervised(rc, plan, pool, true)?;
    anyhow::ensure!(out.stalls_recovered == plan.stalls.len(), "stall was never absorbed");
    anyhow::ensure!(out.reshards.is_empty(), "within-budget stall must not reshard");
    let fin = final_tensors(&rc.base)?;
    anyhow::ensure!(fin == base_final, "an absorbed stall changed the trajectory");
    Ok(format!(
        "{} stall(s) absorbed by the retry budget, trajectory bitwise unchanged",
        out.stalls_recovered
    ))
}

/// Past-budget straggler, non-elastic; pass iff the run fails with the
/// actionable diagnostic.
fn dp_timeout_row(rc: &DpRunConfig, plan: &FaultPlan, pool: &Pool) -> Result<String> {
    let err = match train_lm_dp_native_run(rc, None, &plan.stalls, pool, true) {
        Ok(_) => anyhow::bail!("over-budget straggler did not fail the non-elastic run"),
        Err(e) => format!("{e:#}"),
    };
    anyhow::ensure!(
        err.contains("--elastic") && err.contains("deadline poll"),
        "timeout diagnostic is not actionable: {err}"
    );
    Ok("over-budget straggler failed fast with the --elastic hint".into())
}

/// Elastic degradation; pass iff the fleet resharded onto the
/// survivor, logged the reshard event, and a rerun reproduces the
/// degraded trajectory bit for bit.
fn dp_elastic_row(
    rc_a: &DpRunConfig,
    rc_b: &DpRunConfig,
    plan: &FaultPlan,
    pool: &Pool,
) -> Result<String> {
    let out = train_lm_dp_supervised(rc_a, plan, pool, true)?;
    anyhow::ensure!(out.reshards.len() == 1, "expected 1 reshard, got {:?}", out.reshards);
    anyhow::ensure!(out.workers_final == 1, "fleet should have degraded to 1 worker");
    let jsonl = std::fs::read_to_string(format!(
        "{}/{}.jsonl",
        rc_a.base.run_dir, rc_a.base.run_name
    ))?;
    anyhow::ensure!(jsonl.contains("\"reshard\""), "reshard event missing from the run log");
    let a = final_tensors(&rc_a.base)?;
    train_lm_dp_supervised(rc_b, plan, pool, true)?;
    let b = final_tensors(&rc_b.base)?;
    anyhow::ensure!(a == b, "degraded trajectory is not reproducible");
    let r = out.reshards[0];
    Ok(format!(
        "rank {} dropped at boundary {}, resharded onto {} survivor(s), rerun bitwise equal",
        r.dead_rank, r.step, r.workers
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_campaign_passes_end_to_end() {
        let dir = std::env::temp_dir().join("pamm_chaos_quick");
        let _ = std::fs::remove_dir_all(&dir);
        let opts = ChaosOpts {
            quick: true,
            dp: false,
            seed: 11,
            dir: dir.to_string_lossy().into_owned(),
        };
        let report = run_campaign(&opts, &Pool::serial()).unwrap();
        assert!(!report.rows.is_empty());
        for r in &report.rows {
            assert!(r.pass, "chaos scenario `{}` failed: {}", r.name, r.detail);
        }
        assert!(report.passed());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quick_dp_campaign_passes_end_to_end() {
        let dir = std::env::temp_dir().join("pamm_chaos_dp_quick");
        let _ = std::fs::remove_dir_all(&dir);
        let opts = ChaosOpts {
            quick: true,
            dp: true,
            seed: 11,
            dir: dir.to_string_lossy().into_owned(),
        };
        let report = run_campaign(&opts, &Pool::serial()).unwrap();
        assert!(!report.rows.is_empty());
        for r in &report.rows {
            assert!(r.pass, "dp chaos scenario `{}` failed: {}", r.name, r.detail);
        }
        assert!(report.passed());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn report_table_counts_failures() {
        let rep = ChaosReport {
            rows: vec![
                ChaosRow { name: "a".into(), pass: true, detail: "ok".into() },
                ChaosRow { name: "b".into(), pass: false, detail: "boom".into() },
            ],
        };
        assert!(!rep.passed());
    }
}
