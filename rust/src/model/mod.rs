//! GPT-style transformer LM over the multi-op autograd tape — the
//! whole-model realization of the paper's per-layer memory claim
//! (DESIGN.md §7).
//!
//! Architecture (pre-norm decoder, weight-tied head, no biases on the
//! projections — LLaMA-flavored, matching `python/compile/model.py`'s
//! shape conventions at native-runnable scales):
//!
//! ```text
//! tokens → embedding
//!   × n_layers: [ LN → fused PAMM-QKV causal attention → +residual
//!                 → LN → PAMM MLP (W₁ → GELU → W₂) → +residual ]
//! → LN → tied LM head (x·Embᵀ) → softmax cross-entropy
//! ```
//!
//! Every block's two projection-layer activations — the QKV input and
//! the MLP input — persist between forward and backward **only** as
//! `pamm::Compressed` structs; what stays dense (layernorm inputs =
//! the residual stream, the attention output O, the head input) is
//! exactly what dense autodiff keeps too, so
//! [`dense_block_saved_bytes`] compares like against like. The forward
//! runs *off* the compressed representation (`Ã·W`, the convention of
//! `attention::pamm_qkv_attention`), so at ε = ∞ with all generators
//! the analytic gradients are exact for the function actually computed
//! — which is what `rust/tests/prop_model.rs`'s finite-difference
//! oracle checks through two stacked blocks.
//!
//! Parameters live in one flat `Vec<Mat>` with a fixed layout
//! ([`param_names`]) so the optimizer, checkpointing
//! (`coordinator::LmTrainer`) and the tape's [`ParamId`]s all agree on
//! indices. Determinism: parameter init, generator sampling, batching
//! and every kernel below are seed-deterministic and bit-identical at
//! any thread count / SIMD dispatch level, so whole multi-layer
//! training runs are too (`rust/tests/prop_model.rs`).

use anyhow::{ensure, Result};

use crate::attention::AttnShape;
use crate::autograd::{self, ParamId, Tape};
use crate::memory::{MemoryLedger, ModelGeometry};
use crate::pamm::{self, Eps};
use crate::poolx::Pool;
use crate::rngx::Xoshiro256;
use crate::tensor::kernels::Dispatch;
use crate::tensor::Mat;

/// Parameters per transformer block in the flat layout:
/// `ln1.g, ln1.b, wq, wk, wv, ln2.g, ln2.b, mlp.w1, mlp.w2`.
pub const PARAMS_PER_BLOCK: usize = 9;

/// Tape nodes one block contributes:
/// `LN, qkv_attn, residual, LN, mlp, residual`.
pub const NODES_PER_BLOCK: usize = 6;

/// Tape nodes past the block stack in a classification forward
/// ([`TransformerLM::forward_classify`]):
/// `LN, mean_pool, linear_head, softmax_xent`.
pub const CLS_TAIL_NODES: usize = 4;

/// Checkpoint key of the classification head weight — the one extra
/// `d_model×n_classes` parameter `forward_classify` expects appended
/// past the fixed LM layout (`ParamId == LmConfig::n_params()`).
pub const CLS_HEAD_NAME: &str = "cls.head";

/// Model geometry of the native transformer LM.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LmConfig {
    pub vocab: usize,
    pub n_layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
}

impl LmConfig {
    pub fn d_model(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Derive from a `memory::ModelGeometry` zoo entry (the `pamm
    /// train` presets: nano/tiny/small/…).
    pub fn from_geometry(g: &ModelGeometry) -> Result<LmConfig> {
        ensure!(g.n_heads > 0 && g.d_model % g.n_heads == 0,
            "model `{}`: d_model {} not divisible by heads {}", g.name, g.d_model, g.n_heads);
        Ok(LmConfig {
            vocab: g.vocab,
            n_layers: g.n_layers.max(1),
            heads: g.n_heads,
            head_dim: g.d_model / g.n_heads,
            d_ff: g.d_ff,
        })
    }

    /// Number of parameter matrices in the flat layout.
    pub fn n_params(&self) -> usize {
        1 + self.n_layers * PARAMS_PER_BLOCK + 2
    }

    /// Trainable scalar count (tied head counted once).
    pub fn param_count(&self) -> usize {
        let dm = self.d_model();
        let per_block = 3 * dm * dm + 2 * dm * self.d_ff + 4 * dm;
        self.vocab * dm + self.n_layers * per_block + 2 * dm
    }
}

/// Fixed parameter naming (checkpoint keys; index == [`ParamId`]).
pub fn param_names(cfg: &LmConfig) -> Vec<String> {
    let mut names = vec!["emb".to_string()];
    for b in 0..cfg.n_layers {
        for n in ["ln1.g", "ln1.b", "wq", "wk", "wv", "ln2.g", "ln2.b", "mlp.w1", "mlp.w2"] {
            names.push(format!("blk{b}.{n}"));
        }
    }
    names.push("lnf.g".into());
    names.push("lnf.b".into());
    names
}

/// The native GPT-style LM: config + the flat parameter vector.
#[derive(Debug, Clone)]
pub struct TransformerLM {
    pub cfg: LmConfig,
    pub params: Vec<Mat>,
}

impl TransformerLM {
    /// Deterministic init from `seed`: embeddings and projections
    /// ~ N(0, 0.02), layernorm gains 1 / biases 0. Same seed ⇒ the
    /// same model at any thread count or dispatch level.
    pub fn new(cfg: LmConfig, seed: u64) -> Self {
        let dm = cfg.d_model();
        let mut rng = Xoshiro256::new(seed);
        let ones = |n: usize| Mat::from_vec(1, n, vec![1.0; n]);
        let mut params = Vec::with_capacity(cfg.n_params());
        params.push(Mat::random_normal(cfg.vocab, dm, 0.02, &mut rng)); // emb (tied)
        for _ in 0..cfg.n_layers {
            params.push(ones(dm)); // ln1.g
            params.push(Mat::zeros(1, dm)); // ln1.b
            params.push(Mat::random_normal(dm, dm, 0.02, &mut rng)); // wq
            params.push(Mat::random_normal(dm, dm, 0.02, &mut rng)); // wk
            params.push(Mat::random_normal(dm, dm, 0.02, &mut rng)); // wv
            params.push(ones(dm)); // ln2.g
            params.push(Mat::zeros(1, dm)); // ln2.b
            params.push(Mat::random_normal(dm, cfg.d_ff, 0.02, &mut rng)); // mlp.w1
            params.push(Mat::random_normal(cfg.d_ff, dm, 0.02, &mut rng)); // mlp.w2
        }
        params.push(ones(dm)); // lnf.g
        params.push(Mat::zeros(1, dm)); // lnf.b
        debug_assert_eq!(params.len(), cfg.n_params());
        Self { cfg, params }
    }

    /// Attention geometry of one forward at `(batch, seq)` — always
    /// causal (next-token pretraining).
    pub fn shape_for(&self, batch: usize, seq: usize) -> AttnShape {
        AttnShape::new(batch, self.cfg.heads, seq, self.cfg.head_dim, true)
    }

    #[inline]
    fn pid(&self, block: usize, off: usize) -> ParamId {
        1 + block * PARAMS_PER_BLOCK + off
    }

    /// Shared encoder trunk: embedding → N blocks → final LN. Returns
    /// the final-LN output and its tape id; both heads (the tied LM
    /// head of [`Self::forward`] and the classification head of
    /// [`Self::forward_classify`]) sit on top of this. Generator
    /// indices for the 2·n_layers compressions are drawn from `rng` in
    /// a fixed order (two per block, attention first), so the sampling
    /// stream is independent of threads and dispatch.
    #[allow(clippy::too_many_arguments)]
    fn encode(
        &self,
        d: Dispatch,
        ids: &[i32],
        batch: usize,
        seq: usize,
        k: usize,
        eps: Eps,
        rng: &mut Xoshiro256,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
        tape: &mut Tape,
    ) -> (Mat, usize) {
        let tokens = batch * seq;
        assert_eq!(ids.len(), tokens, "model: ids vs batch·seq");
        let shape = self.shape_for(batch, seq);
        let k = k.clamp(1, tokens);
        let (mut x, mut xid) = tape.embedding(&self.params[0], 0, ids, ledger);
        for b in 0..self.cfg.n_layers {
            let p = |o: usize| self.pid(b, o);
            let (h1, h1id) = tape.layer_norm(
                &x, xid, &self.params[p(0)], p(0), &self.params[p(1)], p(1), ledger,
            );
            let gen_attn = pamm::sample_generators(rng, tokens, k);
            let (attn, attnid) = tape.qkv_attn(
                d,
                &h1,
                h1id,
                &self.params[p(2)],
                p(2),
                &self.params[p(3)],
                p(3),
                &self.params[p(4)],
                p(4),
                &gen_attn,
                eps,
                &shape,
                pool,
                ledger,
            );
            let (x1, x1id) = tape.residual(&x, xid, &attn, attnid, ledger);
            let (h2, h2id) = tape.layer_norm(
                &x1, x1id, &self.params[p(5)], p(5), &self.params[p(6)], p(6), ledger,
            );
            let gen_mlp = pamm::sample_generators(rng, tokens, k);
            let (mlp, mlpid) = tape.mlp_pamm(
                &h2,
                h2id,
                &self.params[p(7)],
                p(7),
                &self.params[p(8)],
                p(8),
                &gen_mlp,
                eps,
                pool,
                ledger,
            );
            let (x2, x2id) = tape.residual(&x1, x1id, &mlp, mlpid, ledger);
            x = x2;
            xid = x2id;
        }
        let lnf = 1 + self.cfg.n_layers * PARAMS_PER_BLOCK;
        let (hf, hfid) =
            tape.layer_norm(&x, xid, &self.params[lnf], lnf, &self.params[lnf + 1], lnf + 1, ledger);
        (hf, hfid)
    }

    /// Full training forward: embedding → N blocks → final LN → tied
    /// head → mean next-token cross-entropy. Returns the loss and the
    /// tape holding every node's minimal saved state.
    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &self,
        d: Dispatch,
        ids: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
        k: usize,
        eps: Eps,
        rng: &mut Xoshiro256,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> (f32, Tape) {
        assert_eq!(targets.len(), batch * seq, "model: targets vs batch·seq");
        let mut tape = Tape::new();
        let (hf, hfid) = self.encode(d, ids, batch, seq, k, eps, rng, pool, ledger, &mut tape);
        let (logits, lid) = tape.tied_head(&hf, hfid, &self.params[0], 0, pool, ledger);
        let loss = tape.softmax_xent(&logits, lid, targets, ledger);
        (loss, tape)
    }

    /// Classification forward: the same encoder trunk, then
    /// mean-pool over each sequence → dense linear head → softmax
    /// cross-entropy over `labels` (one per sequence). The head weight
    /// is `self.params[cfg.n_params()]` — an extra `d_model×n_classes`
    /// parameter appended past the fixed LM layout
    /// ([`CLS_HEAD_NAME`], owned by `coordinator::finetune`), so LM
    /// checkpoints and the pretraining layout are untouched. The tape
    /// has `1 + n_layers·NODES_PER_BLOCK + CLS_TAIL_NODES` nodes.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_classify(
        &self,
        d: Dispatch,
        ids: &[i32],
        labels: &[i32],
        batch: usize,
        seq: usize,
        k: usize,
        eps: Eps,
        rng: &mut Xoshiro256,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> (f32, Tape) {
        let head_id = self.cfg.n_params();
        assert_eq!(
            self.params.len(),
            head_id + 1,
            "forward_classify: params must be the LM layout + one classification head"
        );
        assert_eq!(labels.len(), batch, "model: one label per sequence");
        let mut tape = Tape::new();
        let (hf, hfid) = self.encode(d, ids, batch, seq, k, eps, rng, pool, ledger, &mut tape);
        let (pooled, pid) = tape.mean_pool(&hf, hfid, batch, seq, ledger);
        let (logits, lid) =
            tape.linear_head(&pooled, pid, &self.params[head_id], head_id, pool, ledger);
        let loss = tape.softmax_xent(&logits, lid, labels, ledger);
        (loss, tape)
    }

    /// Prediction-only classification pass: the per-sequence class
    /// logits (`batch×n_classes`), no loss, tape discarded. Same
    /// forward function as [`Self::forward_classify`] — `rng` must be
    /// positioned identically for the generator draws to match.
    #[allow(clippy::too_many_arguments)]
    pub fn classify_logits(
        &self,
        d: Dispatch,
        ids: &[i32],
        batch: usize,
        seq: usize,
        k: usize,
        eps: Eps,
        rng: &mut Xoshiro256,
        pool: &Pool,
    ) -> Mat {
        let head_id = self.cfg.n_params();
        assert_eq!(
            self.params.len(),
            head_id + 1,
            "classify_logits: params must be the LM layout + one classification head"
        );
        let mut tape = Tape::new();
        let (hf, hfid) = self.encode(d, ids, batch, seq, k, eps, rng, pool, None, &mut tape);
        let (pooled, pid) = tape.mean_pool(&hf, hfid, batch, seq, None);
        let (logits, _) =
            tape.linear_head(&pooled, pid, &self.params[head_id], head_id, pool, None);
        logits
    }

    /// Convenience: forward + backward in one call — returns the loss
    /// and one gradient per parameter (the tape is consumed).
    #[allow(clippy::too_many_arguments)]
    pub fn loss_and_grads(
        &self,
        d: Dispatch,
        ids: &[i32],
        targets: &[i32],
        batch: usize,
        seq: usize,
        k: usize,
        eps: Eps,
        rng: &mut Xoshiro256,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> (f32, Vec<Mat>) {
        let (loss, tape) =
            self.forward(d, ids, targets, batch, seq, k, eps, rng, pool, ledger);
        let res = tape.backward(d, &self.params, pool, ledger);
        (loss, res.params)
    }
}

// ---------------------------------------------------------------------------
// Per-layer saved-for-backward inventory + analytic baselines
// ---------------------------------------------------------------------------

/// Per-segment saved-for-backward bytes of one forward's tape: the
/// embedding node, each block's six nodes, and the shared tail (final
/// LN + tied head + cross-entropy seed).
#[derive(Debug, Clone)]
pub struct SavedInventory {
    pub embedding: usize,
    pub blocks: Vec<usize>,
    pub tail: usize,
}

impl SavedInventory {
    pub fn total(&self) -> usize {
        self.embedding + self.blocks.iter().sum::<usize>() + self.tail
    }
}

/// Aggregate a model forward's tape into the per-layer inventory. The
/// node layout is fixed by [`TransformerLM::forward`]:
/// `embedding, n_layers × [LN, qkv_attn, residual, LN, mlp, residual],
/// LN, tied_head, softmax_xent`.
pub fn saved_inventory(tape: &Tape, n_layers: usize) -> SavedInventory {
    let inv = tape.saved_inventory();
    assert_eq!(
        inv.len(),
        1 + n_layers * NODES_PER_BLOCK + 3,
        "saved_inventory: tape is not a {n_layers}-layer model forward"
    );
    let embedding = inv[0].1;
    let mut blocks = Vec::with_capacity(n_layers);
    for b in 0..n_layers {
        let base = 1 + b * NODES_PER_BLOCK;
        blocks.push(inv[base..base + NODES_PER_BLOCK].iter().map(|(_, s)| s).sum());
    }
    let tail = inv[1 + n_layers * NODES_PER_BLOCK..].iter().map(|(_, s)| s).sum();
    SavedInventory { embedding, blocks, tail }
}

/// Saved-for-backward bytes of one block under **dense** autodiff,
/// same conventions as the tape keeps for its own dense rows (LN
/// inputs + per-row stats, the attention output O, the lse): the
/// difference is that dense autodiff additionally keeps the QKV
/// projection input X, the Q/K/V tensors, the MLP input X and the
/// `b×d_ff` pre-activation — the rows PAMM replaces with two
/// `Compressed` structs. (Conservative in dense's favor: the GELU
/// output h is assumed recomputed, not saved.)
pub fn dense_block_saved_bytes(cfg: &LmConfig, shape: &AttnShape) -> usize {
    let tokens = shape.tokens();
    let dm = shape.d_model();
    let ln = tokens * dm * 4 + 2 * tokens * 4; // input + mean/rstd
    let lse = shape.batch * shape.heads * shape.seq * 4;
    2 * ln                              // two layernorms
        + tokens * dm * 4               // QKV projection input X
        + 3 * shape.tensor_bytes()      // Q, K, V
        + shape.tensor_bytes()          // attention output O
        + lse
        + tokens * dm * 4               // MLP input X
        + tokens * cfg.d_ff * 4         // MLP pre-activation z
}

/// Saved bytes of the model's shared (non-block) tape segment — token
/// ids, final LN, head input, cross-entropy seed. Identical under
/// dense and PAMM autodiff (nothing here is compressed), and equal by
/// construction to the measured `SavedInventory::embedding + tail`.
pub fn tail_saved_bytes(cfg: &LmConfig, shape: &AttnShape) -> usize {
    let tokens = shape.tokens();
    let dm = shape.d_model();
    tokens * 4                              // token ids
        + tokens * dm * 4 + 2 * tokens * 4  // final LN (input + stats)
        + tokens * dm * 4                   // head input
        + tokens * cfg.vocab * 4            // dlogits seed
}

/// Whole-model dense saved-for-backward baseline: shared tail +
/// `n_layers` dense blocks. The ledger's model-level factor row
/// divides this by the tape's measured total.
pub fn dense_model_saved_bytes(cfg: &LmConfig, shape: &AttnShape) -> usize {
    tail_saved_bytes(cfg, shape) + cfg.n_layers * dense_block_saved_bytes(cfg, shape)
}

/// Ceiling for the tracked backward-transient peak of one whole-model
/// [`Tape::backward`]: `n_layers ×` (the fused attention block's
/// [`autograd::backward_peak_bound`] with `need_dx` + the MLP op's
/// recomputed G₁/z/h/dz and transposed weights + residual-stream grad
/// slack) plus the head segment (the dlogits seed and the Embᵀ-sized
/// temporary). Generous by construction — each op frees its transients
/// before the next runs, so the measured peak is close to the *max*
/// per-op term, not the sum; soundness is what the property test
/// asserts (`measured ≤ bound`), per-op tightness is covered by
/// `prop_backward`.
pub fn backward_peak_bound(cfg: &LmConfig, shape: &AttnShape, k: usize, threads: usize) -> usize {
    let tokens = shape.tokens();
    let dm = shape.d_model();
    let dff = cfg.d_ff;
    let k = k.clamp(1, tokens);
    let attn = autograd::backward_peak_bound(k, dm, shape, threads, true);
    let mlp = 4 * (k * dff + 3 * tokens * dff + 2 * dm * dff)
        + threads * autograd::pack_bytes_bound(tokens, dff, dm);
    let residual_slack = 4 * 2 * tokens * dm;
    let head = 4 * (tokens * cfg.vocab + cfg.vocab * dm);
    cfg.n_layers * (attn + mlp + residual_slack) + head
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::kernels;

    fn tiny_cfg() -> LmConfig {
        LmConfig { vocab: 13, n_layers: 2, heads: 2, head_dim: 4, d_ff: 12 }
    }

    fn token_batch(cfg: &LmConfig, tokens: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Xoshiro256::new(seed);
        let ids: Vec<i32> =
            (0..tokens).map(|_| rng.next_below(cfg.vocab as u64) as i32).collect();
        let targets: Vec<i32> =
            (0..tokens).map(|_| rng.next_below(cfg.vocab as u64) as i32).collect();
        (ids, targets)
    }

    #[test]
    fn layout_and_names_agree() {
        let cfg = tiny_cfg();
        let m = TransformerLM::new(cfg.clone(), 7);
        let names = param_names(&cfg);
        assert_eq!(m.params.len(), cfg.n_params());
        assert_eq!(names.len(), cfg.n_params());
        assert_eq!(names[0], "emb");
        assert_eq!(names[1], "blk0.ln1.g");
        assert_eq!(names[1 + PARAMS_PER_BLOCK], "blk1.ln1.g");
        assert_eq!(names[names.len() - 2], "lnf.g");
        // Shapes: emb vocab×dm, wq dm×dm, w1 dm×dff, w2 dff×dm, LN 1×dm.
        let dm = cfg.d_model();
        assert_eq!((m.params[0].rows(), m.params[0].cols()), (cfg.vocab, dm));
        assert_eq!((m.params[3].rows(), m.params[3].cols()), (dm, dm));
        assert_eq!((m.params[8].rows(), m.params[8].cols()), (dm, cfg.d_ff));
        assert_eq!((m.params[9].rows(), m.params[9].cols()), (cfg.d_ff, dm));
        assert_eq!((m.params[1].rows(), m.params[1].cols()), (1, dm));
        // Scalar count matches the analytic formula.
        let scalars: usize = m.params.iter().map(|p| p.rows() * p.cols()).sum();
        // n_params counts the tied embedding once; param_count too.
        assert_eq!(scalars, cfg.param_count());
    }

    #[test]
    fn forward_builds_the_expected_tape_and_a_finite_loss() {
        let cfg = tiny_cfg();
        let m = TransformerLM::new(cfg.clone(), 11);
        let (batch, seq) = (2usize, 5usize);
        let (ids, targets) = token_batch(&cfg, batch * seq, 21);
        let mut rng = Xoshiro256::new(22);
        let pool = Pool::serial();
        let (loss, tape) = m.forward(
            kernels::active(),
            &ids,
            &targets,
            batch,
            seq,
            4,
            Eps::Inf,
            &mut rng,
            &pool,
            None,
        );
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // At 0.02-scale init the logits are near-uniform: loss ≈ ln(vocab).
        assert!((loss - (cfg.vocab as f32).ln()).abs() < 0.5, "loss {loss}");
        assert_eq!(tape.len(), 1 + cfg.n_layers * NODES_PER_BLOCK + 3);
        let inv = saved_inventory(&tape, cfg.n_layers);
        assert_eq!(inv.blocks.len(), cfg.n_layers);
        assert_eq!(inv.total(), tape.saved_bytes());
        // The shared tail matches its analytic inventory exactly.
        let shape = m.shape_for(batch, seq);
        assert_eq!(inv.embedding + inv.tail, tail_saved_bytes(&cfg, &shape));
        // Both blocks saved the same amount (same geometry, k).
        assert_eq!(inv.blocks[0], inv.blocks[1]);
        // And each block undercuts its dense baseline.
        assert!(inv.blocks[0] < dense_block_saved_bytes(&cfg, &shape));
    }

    #[test]
    fn grads_cover_every_parameter_and_training_reduces_loss() {
        // A few Adam-free SGD steps on a FIXED batch must reduce the
        // loss — the optimization sanity the acceptance criterion asks
        // `pamm train --quick` to assert at model scale.
        let cfg = tiny_cfg();
        let mut m = TransformerLM::new(cfg.clone(), 31);
        let (batch, seq) = (2usize, 6usize);
        let (ids, _) = token_batch(&cfg, batch * seq, 32);
        // Copy task (predict the current token): a target the tied
        // embedding/head pair learns fast and monotonically.
        let targets = ids.clone();
        let mut rng = Xoshiro256::new(33);
        let pool = Pool::serial();
        let d = kernels::active();
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for s in 0..40 {
            let (loss, grads) = m.loss_and_grads(
                d, &ids, &targets, batch, seq, 6, Eps::Inf, &mut rng, &pool, None,
            );
            if s == 0 {
                first = loss;
                // Every parameter must receive a nonzero gradient on
                // step 0 (weight tying included) except possibly exact
                // zeros in untouched LN biases — which DO get grads.
                for (g, name) in grads.iter().zip(param_names(&cfg)) {
                    assert!(
                        g.data().iter().any(|&v| v != 0.0),
                        "param {name} got an all-zero gradient"
                    );
                }
            }
            last = loss;
            for (p, g) in m.params.iter_mut().zip(&grads) {
                for (pv, &gv) in p.data_mut().iter_mut().zip(g.data()) {
                    *pv -= 0.3 * gv;
                }
            }
        }
        assert!(
            last < first * 0.95,
            "fixed-batch SGD must make progress: first {first}, last {last}"
        );
    }

    #[test]
    fn classify_forward_tape_shape_and_fixed_batch_learning() {
        // The classification head must (a) produce a near-uniform loss
        // at init, (b) lay down the documented tape layout, (c) route
        // gradients into every parameter including the appended head,
        // and (d) overfit a fixed labeled batch under plain SGD.
        let cfg = tiny_cfg();
        let mut m = TransformerLM::new(cfg.clone(), 51);
        let n_classes = 3usize;
        let mut init_rng = Xoshiro256::new(52);
        m.params.push(Mat::random_normal(cfg.d_model(), n_classes, 0.02, &mut init_rng));
        let (batch, seq) = (4usize, 6usize);
        let (ids, _) = token_batch(&cfg, batch * seq, 53);
        let labels: Vec<i32> = (0..batch).map(|b| (b % n_classes) as i32).collect();
        let pool = Pool::serial();
        let d = kernels::active();
        let mut rng = Xoshiro256::new(54);
        let (loss0, tape) = m.forward_classify(
            d, &ids, &labels, batch, seq, batch * seq, Eps::Inf, &mut rng, &pool, None,
        );
        assert!(loss0.is_finite() && loss0 > 0.0, "loss {loss0}");
        assert!((loss0 - (n_classes as f32).ln()).abs() < 0.5, "near-uniform init: {loss0}");
        assert_eq!(tape.len(), 1 + cfg.n_layers * NODES_PER_BLOCK + CLS_TAIL_NODES);
        let logits = m.classify_logits(
            d, &ids, batch, seq, batch * seq, Eps::Inf, &mut Xoshiro256::new(54), &pool,
        );
        assert_eq!((logits.rows(), logits.cols()), (batch, n_classes));
        assert!(logits.data().iter().all(|v| v.is_finite()));

        let mut first = f32::NAN;
        let mut last = f32::NAN;
        let mut rng = Xoshiro256::new(55);
        for s in 0..30 {
            let (loss, tape) = m.forward_classify(
                d, &ids, &labels, batch, seq, batch * seq, Eps::Inf, &mut rng, &pool, None,
            );
            let res = tape.backward(d, &m.params, &pool, None);
            if s == 0 {
                first = loss;
                assert!(
                    res.params[cfg.n_params()].data().iter().any(|&v| v != 0.0),
                    "classification head got an all-zero gradient"
                );
                assert!(
                    res.params[0].data().iter().any(|&v| v != 0.0),
                    "embedding got an all-zero gradient through the head"
                );
            }
            last = loss;
            for (p, g) in m.params.iter_mut().zip(&res.params) {
                for (pv, &gv) in p.data_mut().iter_mut().zip(g.data()) {
                    *pv -= 0.3 * gv;
                }
            }
        }
        assert!(
            last < first * 0.9,
            "fixed-batch classification SGD must make progress: first {first}, last {last}"
        );
    }

    #[test]
    fn bounds_are_monotone_in_layers_and_dominate_blocks() {
        let mut cfg = tiny_cfg();
        let m = TransformerLM::new(cfg.clone(), 41);
        let shape = m.shape_for(2, 8);
        let b2 = backward_peak_bound(&cfg, &shape, 4, 2);
        cfg.n_layers = 4;
        let b4 = backward_peak_bound(&cfg, &shape, 4, 2);
        assert!(b4 > b2);
        assert!(dense_model_saved_bytes(&cfg, &shape)
            > cfg.n_layers * dense_block_saved_bytes(&cfg, &shape));
    }

    #[test]
    fn from_geometry_maps_the_zoo() {
        let g = ModelGeometry::by_name("nano").unwrap();
        let cfg = LmConfig::from_geometry(&g).unwrap();
        assert_eq!(cfg.vocab, 256);
        assert_eq!(cfg.n_layers, 2);
        assert_eq!(cfg.d_model(), 64);
        assert_eq!(cfg.d_ff, 176);
    }
}
