//! Top-level run loop: config → engine → session → steps, with eval,
//! logging, throughput metering and checkpointing. Used by the CLI
//! (`pamm train`), the examples, and the experiment harness.
//!
//! Two trainers live here:
//!
//! * [`train_run`] — the PJRT path: artifacts → [`TrainSession`] steps
//!   (the model compute is an HLO executable; needs `make artifacts`).
//! * [`NativeTrainer`] — the **native** path (no artifacts, pure L3):
//!   one PAMM-compressed QKV + flash-attention block optimized with
//!   real fwd → loss → bwd → update steps through `crate::autograd`.
//!   Saved-for-backward state per step is the `Compressed` struct plus
//!   O(seq) softmax statistics — the paper's training-memory story,
//!   measured by the [`MemoryLedger`] when one is passed. Loss and the
//!   updated weights are bit-identical at any thread count and SIMD
//!   dispatch level (the optimizer arithmetic is fixed-order scalar
//!   f32 on top of bit-identical gradients).

use anyhow::Result;

use crate::attention::AttnShape;
use crate::autograd::{self, QkvGrads};
use crate::memory::MemoryLedger;
use crate::pamm::{self, Eps};
use crate::poolx::Pool;
use crate::rngx::Xoshiro256;
use crate::tensor::kernels::Dispatch;
use crate::tensor::Mat;

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use crate::checkpoint;
#[cfg(feature = "pjrt")]
use crate::config::RunConfig;
#[cfg(feature = "pjrt")]
use crate::coordinator::ddp::DdpTrainer;
#[cfg(feature = "pjrt")]
use crate::coordinator::pipeline::BatchPipeline;
#[cfg(feature = "pjrt")]
use crate::coordinator::session::TrainSession;
#[cfg(feature = "pjrt")]
use crate::data::batcher::BatchIterator;
#[cfg(feature = "pjrt")]
use crate::jsonx;
#[cfg(feature = "pjrt")]
use crate::metrics::{perplexity, Ema, RunLogger, ThroughputMeter};
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, HostTensor};

/// Result of a completed run (consumed by the experiment harness).
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub run_name: String,
    pub steps: usize,
    pub final_loss: f32,
    pub final_eval_loss: Option<f32>,
    pub final_ppl: Option<f64>,
    pub tokens_per_sec: Option<f64>,
    /// (step, train-loss) curve, subsampled.
    pub curve: Vec<(usize, f32)>,
}

/// Seed for the held-out eval stream (never used for training data).
#[cfg(feature = "pjrt")]
const EVAL_STREAM: u64 = 0xE7A1;

/// Fixed eval token set: held-out stream so eval is comparable across
/// steps and variants.
#[cfg(feature = "pjrt")]
fn eval_batches(vocab: usize, batch: usize, seq: usize, n: usize, seed: u64) -> Vec<HostTensor> {
    let mut it = BatchIterator::from_seed(vocab, batch, seq, seed);
    (0..n).map(|_| it.next_batch().to_tensor()).collect()
}

/// Run a full training session per `cfg`. `quiet` suppresses per-step
/// prints (harness mode).
#[cfg(feature = "pjrt")]
pub fn train_run(engine: &Engine, cfg: &RunConfig, quiet: bool) -> Result<TrainOutcome> {
    if cfg.workers > 1 || cfg.grad_accum > 1 {
        return train_run_ddp(engine, cfg, quiet);
    }
    let artifact = cfg.train_artifact();
    let eval_art = cfg.eval_artifact();
    let have_eval = engine.meta(&eval_art).is_ok();
    let mut session = TrainSession::new(
        engine,
        &artifact,
        if have_eval { Some(eval_art.as_str()) } else { None },
        cfg.seed,
    )?;

    let vocab = engine
        .manifest
        .config(&cfg.model)
        .with_context(|| format!("config `{}` not in manifest", cfg.model))?
        .vocab;

    let run_name = format!("{}_{}_s{}", cfg.model, cfg.variant.tag(), cfg.seed);
    let mut logger = RunLogger::create(&cfg.run_dir, &run_name)?;
    let pipeline = BatchPipeline::spawn(
        BatchIterator::from_seed(vocab, session.batch, session.seq, cfg.seed),
        2,
    );
    let evals = if have_eval {
        eval_batches(vocab, session.batch, session.seq, cfg.eval_batches, EVAL_STREAM)
    } else {
        Vec::new()
    };

    let mut ema = Ema::new(0.05);
    let mut meter = ThroughputMeter::new(3.min(cfg.steps / 4));
    let mut curve = Vec::new();
    let mut last_loss = f32::NAN;
    let mut last_eval = None;

    for s in 0..cfg.steps {
        let batch = pipeline.next();
        let loss = session.step(&batch.to_tensor())?;
        meter.step(batch.n_tokens());
        last_loss = loss;
        let sm = ema.update(loss as f64);
        if s % (cfg.steps / 50).max(1) == 0 || s + 1 == cfg.steps {
            curve.push((s, loss));
            logger.log_step(s, loss as f64, sm, meter.tokens_per_sec())?;
            if !quiet {
                println!(
                    "step {s:>5}  loss {loss:7.4}  ema {sm:7.4}  ppl {:8.2}  tok/s {}",
                    perplexity(sm),
                    meter
                        .tokens_per_sec()
                        .map(|t| format!("{t:.0}"))
                        .unwrap_or_else(|| "-".into())
                );
            }
        }
        if have_eval && cfg.eval_every > 0 && (s + 1) % cfg.eval_every == 0 {
            let el = session.eval(&evals)?;
            last_eval = Some(el);
            logger.log_eval(s, el as f64)?;
            if !quiet {
                println!("  eval @ {s}: loss {el:.4}  ppl {:.2}", perplexity(el as f64));
            }
        }
    }

    if have_eval && last_eval.is_none() && !evals.is_empty() {
        last_eval = Some(session.eval(&evals)?);
    }

    // Final checkpoint for resume/analysis.
    let params = session.params_host()?;
    checkpoint::save(format!("{}/ckpt", cfg.run_dir), &run_name, &params)?;

    let tok_s = meter.tokens_per_sec();
    logger.log_summary(vec![
        ("final_loss", jsonx::num(last_loss as f64)),
        (
            "final_eval_loss",
            last_eval.map(|l| jsonx::num(l as f64)).unwrap_or(jsonx::Value::Null),
        ),
        ("tok_s", tok_s.map(jsonx::num).unwrap_or(jsonx::Value::Null)),
        ("steps", jsonx::num(cfg.steps as f64)),
    ])?;

    Ok(TrainOutcome {
        run_name,
        steps: cfg.steps,
        final_loss: last_loss,
        final_eval_loss: last_eval,
        final_ppl: last_eval.map(|l| perplexity(l as f64)),
        tokens_per_sec: tok_s,
        curve,
    })
}

// ---------------------------------------------------------------------------
// Native compressed-activation trainer
// ---------------------------------------------------------------------------

/// Optimizer of the native train step. Both variants are fixed-order
/// scalar f32 element loops — given bit-identical gradients, the
/// updated weights are bit-identical too.
#[derive(Debug, Clone, Copy)]
pub enum NativeOpt {
    Sgd { lr: f32 },
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl NativeOpt {
    /// Paper-style Adam defaults.
    pub fn adam(lr: f32) -> Self {
        NativeOpt::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// First/second-moment state of one weight matrix (Adam only).
#[derive(Debug, Clone)]
struct Moments {
    m: Mat,
    v: Mat,
}

/// The native train step: one PAMM-compressed QKV projection layer
/// fused with the flash-attention block, optimized for real on the L3
/// substrates — no artifacts, no PJRT. See the module docs.
pub struct NativeTrainer {
    pub shape: AttnShape,
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    /// Generator budget per step (`k = ⌈r·b⌉` of the paper).
    pub k: usize,
    pub eps: Eps,
    opt: NativeOpt,
    moments: Option<[Moments; 3]>,
    step_no: usize,
    rng: Xoshiro256,
}

/// Everything one step produced (harness/ledger consumers).
#[derive(Debug)]
pub struct NativeStepReport {
    pub loss: f32,
    /// Exact saved-for-backward bytes of the step's tape node.
    pub saved_bytes: usize,
    pub grads: QkvGrads,
}

impl NativeTrainer {
    /// Deterministic init: weights ~ N(0, 0.05) from `seed`, generator
    /// sampling from an independent stream. Same seed ⇒ the same run
    /// at any thread count or dispatch level.
    pub fn new(shape: AttnShape, k: usize, opt: NativeOpt, seed: u64) -> Self {
        let dm = shape.d_model();
        let mut rng = Xoshiro256::new(seed);
        let wq = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let wk = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let wv = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let moments = match opt {
            NativeOpt::Sgd { .. } => None,
            NativeOpt::Adam { .. } => Some(std::array::from_fn(|_| Moments {
                m: Mat::zeros(dm, dm),
                v: Mat::zeros(dm, dm),
            })),
        };
        Self {
            shape,
            wq,
            wk,
            wv,
            k: k.max(1),
            eps: Eps::Inf,
            opt,
            moments,
            step_no: 0,
            rng: Xoshiro256::new(seed ^ 0x9E3779B97F4A7C15),
        }
    }

    /// One full training step: sample generators → compressed forward
    /// (tape node = `Compressed` + statistics) → MSE loss vs `target`
    /// → compressed backward → optimizer update. Returns the loss.
    pub fn train_step_native(
        &mut self,
        x: &Mat,
        target: &[f32],
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> f32 {
        self.step_report(crate::tensor::kernels::active(), x, target, pool, ledger).loss
    }

    /// [`NativeTrainer::train_step_native`] with an explicit dispatch
    /// level, returning the full report (tests and the ledger harness).
    pub fn step_report(
        &mut self,
        d: Dispatch,
        x: &Mat,
        target: &[f32],
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> NativeStepReport {
        let gen_idx = pamm::sample_generators(&mut self.rng, self.shape.tokens(), self.k);
        let (out, saved) = autograd::qkv_attn_forward_on(
            d, x, &self.wq, &self.wk, &self.wv, &gen_idx, self.eps, &self.shape, pool, ledger,
        );
        let (loss, dout) = autograd::mse_loss(&out, target);
        let grads = autograd::qkv_attn_backward_on(
            d, &saved, &self.wq, &self.wk, &self.wv, &out, &dout, false, pool, ledger,
        );
        self.step_no += 1;
        self.apply_update(&grads);
        NativeStepReport { loss, saved_bytes: saved.saved_bytes(), grads }
    }

    fn apply_update(&mut self, grads: &QkvGrads) {
        let t = self.step_no;
        let opt = self.opt;
        let weights = [&mut self.wq, &mut self.wk, &mut self.wv];
        let gs = [&grads.dwq, &grads.dwk, &grads.dwv];
        match opt {
            NativeOpt::Sgd { lr } => {
                for (w, g) in weights.into_iter().zip(gs) {
                    for (wv, &gv) in w.data_mut().iter_mut().zip(g.data()) {
                        *wv -= lr * gv;
                    }
                }
            }
            NativeOpt::Adam { lr, beta1, beta2, eps } => {
                let moments = self.moments.as_mut().expect("adam state");
                let bc1 = 1.0 - beta1.powi(t as i32);
                let bc2 = 1.0 - beta2.powi(t as i32);
                for ((w, g), st) in weights.into_iter().zip(gs).zip(moments.iter_mut()) {
                    for (((wv, &gv), mv), vv) in w
                        .data_mut()
                        .iter_mut()
                        .zip(g.data())
                        .zip(st.m.data_mut().iter_mut())
                        .zip(st.v.data_mut().iter_mut())
                    {
                        *mv = beta1 * *mv + (1.0 - beta1) * gv;
                        *vv = beta2 * *vv + (1.0 - beta2) * gv * gv;
                        let mhat = *mv / bc1;
                        let vhat = *vv / bc2;
                        *wv -= lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
        }
    }
}

/// DDP / grad-accum path (grads + apply artifact pair).
#[cfg(feature = "pjrt")]
fn train_run_ddp(engine: &Engine, cfg: &RunConfig, quiet: bool) -> Result<TrainOutcome> {
    let grads = format!(
        "grads_{}_{}_{}x{}",
        cfg.model,
        cfg.variant.tag(),
        cfg.batch,
        cfg.seq
    );
    let apply = format!("apply_{}_{}_{}x{}", cfg.model, cfg.variant.tag(), cfg.batch, cfg.seq);
    let mut t = DdpTrainer::new(engine, &grads, &apply, cfg.workers, cfg.seed)?;

    let run_name = format!(
        "{}_{}_ddp{}x{}_s{}",
        cfg.model,
        cfg.variant.tag(),
        cfg.workers,
        cfg.grad_accum,
        cfg.seed
    );
    let mut logger = RunLogger::create(&cfg.run_dir, &run_name)?;
    let mut ema = Ema::new(0.05);
    let mut meter = ThroughputMeter::new(2);
    let mut curve = Vec::new();
    let mut last_loss = f32::NAN;

    for s in 0..cfg.steps {
        let loss = t.step(cfg.grad_accum)?;
        meter.step(t.tokens_per_step(cfg.grad_accum));
        last_loss = loss;
        let sm = ema.update(loss as f64);
        if s % (cfg.steps / 50).max(1) == 0 || s + 1 == cfg.steps {
            curve.push((s, loss));
            logger.log_step(s, loss as f64, sm, meter.tokens_per_sec())?;
            if !quiet {
                println!(
                    "ddp step {s:>5}  loss {loss:7.4}  ema {sm:7.4}  (workers={} accum={})",
                    cfg.workers, cfg.grad_accum
                );
            }
        }
    }

    let tok_s = meter.tokens_per_sec();
    logger.log_summary(vec![
        ("final_loss", jsonx::num(last_loss as f64)),
        ("workers", jsonx::num(cfg.workers as f64)),
        ("grad_accum", jsonx::num(cfg.grad_accum as f64)),
        ("tok_s", tok_s.map(jsonx::num).unwrap_or(jsonx::Value::Null)),
    ])?;

    Ok(TrainOutcome {
        run_name,
        steps: cfg.steps,
        final_loss: last_loss,
        final_eval_loss: None,
        final_ppl: None,
        tokens_per_sec: tok_s,
        curve,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention;

    /// Teacher-student fixture: the target is the DENSE attention
    /// output of a fixed teacher weight set, so the loss has a real
    /// minimum the student can move toward.
    fn fixture(shape: &AttnShape, seed: u64) -> (Mat, Vec<f32>) {
        let dm = shape.d_model();
        let mut rng = Xoshiro256::new(seed);
        let x = Mat::random_normal(shape.tokens(), dm, 1.0, &mut rng);
        let tq = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let tk = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let tv = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let q = attention::split_heads(&x.matmul(&tq), shape);
        let k = attention::split_heads(&x.matmul(&tk), shape);
        let v = attention::split_heads(&x.matmul(&tv), shape);
        let y = attention::flash_attention_with(&q, &k, &v, shape, &Pool::serial());
        (x, y)
    }

    #[test]
    fn native_training_reduces_the_loss() {
        let shape = AttnShape::new(1, 2, 24, 4, true);
        let (x, y) = fixture(&shape, 0xBEEF);
        let mut t = NativeTrainer::new(shape, 12, NativeOpt::adam(2e-3), 7);
        let pool = Pool::serial();
        let first = t.train_step_native(&x, &y, &pool, None);
        let mut last = first;
        for _ in 0..50 {
            last = t.train_step_native(&x, &y, &pool, None);
        }
        assert!(
            last < first * 0.9,
            "optimization must make real progress: first {first}, last {last}"
        );
    }

    #[test]
    fn native_training_is_bit_identical_across_thread_counts() {
        let shape = AttnShape::new(2, 2, 40, 4, true);
        let (x, y) = fixture(&shape, 0xF00D);
        let run = |pool: &Pool| {
            let mut t = NativeTrainer::new(shape, 10, NativeOpt::Sgd { lr: 0.1 }, 11);
            let losses: Vec<u32> =
                (0..4).map(|_| t.train_step_native(&x, &y, pool, None).to_bits()).collect();
            (losses, t.wq, t.wk, t.wv)
        };
        let base = run(&Pool::serial());
        for threads in [2usize, 4] {
            let got = run(&Pool::new(threads).with_min_chunk(1));
            assert_eq!(got.0, base.0, "loss trajectory t={threads}");
            assert_eq!(got.1, base.1, "wq t={threads}");
            assert_eq!(got.2, base.2, "wk t={threads}");
            assert_eq!(got.3, base.3, "wv t={threads}");
        }
    }

    #[test]
    fn ledger_records_saved_bytes_of_each_step() {
        let shape = AttnShape::new(1, 1, 32, 4, true);
        let (x, y) = fixture(&shape, 0xABBA);
        let mut t = NativeTrainer::new(shape, 4, NativeOpt::Sgd { lr: 0.05 }, 3);
        let ledger = MemoryLedger::new();
        let pool = Pool::serial();
        let rep = t.step_report(crate::tensor::kernels::active(), &x, &y, &pool, Some(&ledger));
        assert_eq!(ledger.saved(), rep.saved_bytes);
        assert!(ledger.backward.peak() > 0, "backward transients must be charged");
        let dense = autograd::dense_saved_bytes(shape.d_model(), &shape);
        assert!(rep.saved_bytes < dense, "compressed saved set must undercut dense");
    }
}
