//! Top-level run loop: config → engine → session → steps, with eval,
//! logging, throughput metering and checkpointing. Used by the CLI
//! (`pamm train`), the examples, and the experiment harness.

use anyhow::{Context, Result};

use crate::checkpoint;
use crate::config::RunConfig;
use crate::coordinator::ddp::DdpTrainer;
use crate::coordinator::pipeline::BatchPipeline;
use crate::coordinator::session::TrainSession;
use crate::data::batcher::BatchIterator;
use crate::jsonx;
use crate::metrics::{perplexity, Ema, RunLogger, ThroughputMeter};
use crate::runtime::{Engine, HostTensor};

/// Result of a completed run (consumed by the experiment harness).
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub run_name: String,
    pub steps: usize,
    pub final_loss: f32,
    pub final_eval_loss: Option<f32>,
    pub final_ppl: Option<f64>,
    pub tokens_per_sec: Option<f64>,
    /// (step, train-loss) curve, subsampled.
    pub curve: Vec<(usize, f32)>,
}

/// Seed for the held-out eval stream (never used for training data).
const EVAL_STREAM: u64 = 0xE7A1;

/// Fixed eval token set: held-out stream so eval is comparable across
/// steps and variants.
fn eval_batches(vocab: usize, batch: usize, seq: usize, n: usize, seed: u64) -> Vec<HostTensor> {
    let mut it = BatchIterator::from_seed(vocab, batch, seq, seed);
    (0..n).map(|_| it.next_batch().to_tensor()).collect()
}

/// Run a full training session per `cfg`. `quiet` suppresses per-step
/// prints (harness mode).
pub fn train_run(engine: &Engine, cfg: &RunConfig, quiet: bool) -> Result<TrainOutcome> {
    if cfg.workers > 1 || cfg.grad_accum > 1 {
        return train_run_ddp(engine, cfg, quiet);
    }
    let artifact = cfg.train_artifact();
    let eval_art = cfg.eval_artifact();
    let have_eval = engine.meta(&eval_art).is_ok();
    let mut session = TrainSession::new(
        engine,
        &artifact,
        if have_eval { Some(eval_art.as_str()) } else { None },
        cfg.seed,
    )?;

    let vocab = engine
        .manifest
        .config(&cfg.model)
        .with_context(|| format!("config `{}` not in manifest", cfg.model))?
        .vocab;

    let run_name = format!("{}_{}_s{}", cfg.model, cfg.variant.tag(), cfg.seed);
    let mut logger = RunLogger::create(&cfg.run_dir, &run_name)?;
    let pipeline = BatchPipeline::spawn(
        BatchIterator::from_seed(vocab, session.batch, session.seq, cfg.seed),
        2,
    );
    let evals = if have_eval {
        eval_batches(vocab, session.batch, session.seq, cfg.eval_batches, EVAL_STREAM)
    } else {
        Vec::new()
    };

    let mut ema = Ema::new(0.05);
    let mut meter = ThroughputMeter::new(3.min(cfg.steps / 4));
    let mut curve = Vec::new();
    let mut last_loss = f32::NAN;
    let mut last_eval = None;

    for s in 0..cfg.steps {
        let batch = pipeline.next();
        let loss = session.step(&batch.to_tensor())?;
        meter.step(batch.n_tokens());
        last_loss = loss;
        let sm = ema.update(loss as f64);
        if s % (cfg.steps / 50).max(1) == 0 || s + 1 == cfg.steps {
            curve.push((s, loss));
            logger.log_step(s, loss as f64, sm, meter.tokens_per_sec())?;
            if !quiet {
                println!(
                    "step {s:>5}  loss {loss:7.4}  ema {sm:7.4}  ppl {:8.2}  tok/s {}",
                    perplexity(sm),
                    meter
                        .tokens_per_sec()
                        .map(|t| format!("{t:.0}"))
                        .unwrap_or_else(|| "-".into())
                );
            }
        }
        if have_eval && cfg.eval_every > 0 && (s + 1) % cfg.eval_every == 0 {
            let el = session.eval(&evals)?;
            last_eval = Some(el);
            logger.log_eval(s, el as f64)?;
            if !quiet {
                println!("  eval @ {s}: loss {el:.4}  ppl {:.2}", perplexity(el as f64));
            }
        }
    }

    if have_eval && last_eval.is_none() && !evals.is_empty() {
        last_eval = Some(session.eval(&evals)?);
    }

    // Final checkpoint for resume/analysis.
    let params = session.params_host()?;
    checkpoint::save(format!("{}/ckpt", cfg.run_dir), &run_name, &params)?;

    let tok_s = meter.tokens_per_sec();
    logger.log_summary(vec![
        ("final_loss", jsonx::num(last_loss as f64)),
        (
            "final_eval_loss",
            last_eval.map(|l| jsonx::num(l as f64)).unwrap_or(jsonx::Value::Null),
        ),
        ("tok_s", tok_s.map(jsonx::num).unwrap_or(jsonx::Value::Null)),
        ("steps", jsonx::num(cfg.steps as f64)),
    ])?;

    Ok(TrainOutcome {
        run_name,
        steps: cfg.steps,
        final_loss: last_loss,
        final_eval_loss: last_eval,
        final_ppl: last_eval.map(|l| perplexity(l as f64)),
        tokens_per_sec: tok_s,
        curve,
    })
}

/// DDP / grad-accum path (grads + apply artifact pair).
fn train_run_ddp(engine: &Engine, cfg: &RunConfig, quiet: bool) -> Result<TrainOutcome> {
    let grads = format!(
        "grads_{}_{}_{}x{}",
        cfg.model,
        cfg.variant.tag(),
        cfg.batch,
        cfg.seq
    );
    let apply = format!("apply_{}_{}_{}x{}", cfg.model, cfg.variant.tag(), cfg.batch, cfg.seq);
    let mut t = DdpTrainer::new(engine, &grads, &apply, cfg.workers, cfg.seed)?;

    let run_name = format!(
        "{}_{}_ddp{}x{}_s{}",
        cfg.model,
        cfg.variant.tag(),
        cfg.workers,
        cfg.grad_accum,
        cfg.seed
    );
    let mut logger = RunLogger::create(&cfg.run_dir, &run_name)?;
    let mut ema = Ema::new(0.05);
    let mut meter = ThroughputMeter::new(2);
    let mut curve = Vec::new();
    let mut last_loss = f32::NAN;

    for s in 0..cfg.steps {
        let loss = t.step(cfg.grad_accum)?;
        meter.step(t.tokens_per_step(cfg.grad_accum));
        last_loss = loss;
        let sm = ema.update(loss as f64);
        if s % (cfg.steps / 50).max(1) == 0 || s + 1 == cfg.steps {
            curve.push((s, loss));
            logger.log_step(s, loss as f64, sm, meter.tokens_per_sec())?;
            if !quiet {
                println!(
                    "ddp step {s:>5}  loss {loss:7.4}  ema {sm:7.4}  (workers={} accum={})",
                    cfg.workers, cfg.grad_accum
                );
            }
        }
    }

    let tok_s = meter.tokens_per_sec();
    logger.log_summary(vec![
        ("final_loss", jsonx::num(last_loss as f64)),
        ("workers", jsonx::num(cfg.workers as f64)),
        ("grad_accum", jsonx::num(cfg.grad_accum as f64)),
        ("tok_s", tok_s.map(jsonx::num).unwrap_or(jsonx::Value::Null)),
    ])?;

    Ok(TrainOutcome {
        run_name,
        steps: cfg.steps,
        final_loss: last_loss,
        final_eval_loss: None,
        final_ppl: None,
        tokens_per_sec: tok_s,
        curve,
    })
}
