//! Legacy PJRT-era simulated data-parallel training (feature `pjrt`
//! only). The native path — deterministic rank-order reduce, sharded
//! crash-safe checkpoints, elastic recovery — lives in
//! [`crate::coordinator::dp`] (DESIGN.md §10); this module remains as
//! the thin artifact-based shim for the PJRT build and carries no
//! surface in the default build.
//!
//! The paper trains LLaMA-1B/7B with 8-GPU DDP (Table 2a). This host has
//! one PJRT CPU device, so we reproduce the *coordination logic* exactly
//! and the parallelism as a simulation: `workers` shards each run the
//! `grads_*` artifact on their own data shard, the coordinator all-reduces
//! (averages) the gradient sets, and a single `apply_*` execution performs
//! the AdamW update. Gradient *accumulation* (microbatching) composes the
//! same way with `accum` sequential shard batches.
//!
//! The all-reduce itself is a real reduction implemented host-side
//! (chunked accumulate — the degenerate single-host case of a ring
//! all-reduce where every rank is colocated); swapping in a network ring
//! is a transport change, not a logic change.
//!
//! Determinism: worker w at optimizer step s derives its PAMM seed from
//! (seed, w, s), so runs are reproducible at any worker count.

use anyhow::{bail, Result};

use crate::runtime::HostTensor;

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use crate::coordinator::pipeline::BatchPipeline;
#[cfg(feature = "pjrt")]
use crate::data::batcher::BatchIterator;
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, Exec};
#[cfg(feature = "pjrt")]
use crate::rngx::Xoshiro256;

/// Element-wise mean of `sets` gradient vectors (the all-reduce).
/// Each set must have identical structure.
pub fn all_reduce_mean(sets: Vec<Vec<HostTensor>>) -> Result<Vec<HostTensor>> {
    let g = sets.len();
    if g == 0 {
        bail!("all_reduce_mean: no gradient sets");
    }
    let mut iter = sets.into_iter();
    let first = iter.next().unwrap();
    let mut acc: Vec<Vec<f32>> = first
        .iter()
        .map(|t| t.as_f32().map(|s| s.to_vec()))
        .collect::<Result<_>>()?;
    let shapes: Vec<Vec<usize>> = first.iter().map(|t| t.shape().to_vec()).collect();
    for set in iter {
        if set.len() != acc.len() {
            bail!("gradient set arity mismatch");
        }
        for (a, t) in acc.iter_mut().zip(set.iter()) {
            let s = t.as_f32()?;
            if s.len() != a.len() {
                bail!("gradient tensor shape mismatch");
            }
            for (x, y) in a.iter_mut().zip(s) {
                *x += y;
            }
        }
    }
    let scale = 1.0 / g as f32;
    Ok(acc
        .into_iter()
        .zip(shapes)
        .map(|(mut data, shape)| {
            for x in data.iter_mut() {
                *x *= scale;
            }
            HostTensor::f32(shape, data)
        })
        .collect())
}

/// DDP/grad-accum trainer built on the (grads, apply) artifact pair.
#[cfg(feature = "pjrt")]
pub struct DdpTrainer {
    grads_exec: Exec,
    apply_exec: Exec,
    /// params ++ m ++ v literals.
    state: Vec<xla::Literal>,
    n_params: usize,
    step: i32,
    seed: u64,
    pub workers: usize,
    pub batch: usize,
    pub seq: usize,
    pipelines: Vec<BatchPipeline>,
}

#[cfg(feature = "pjrt")]
impl DdpTrainer {
    pub fn new(
        engine: &Engine,
        grads_artifact: &str,
        apply_artifact: &str,
        workers: usize,
        seed: u64,
    ) -> Result<DdpTrainer> {
        let grads_exec = engine.executable(grads_artifact)?;
        if grads_exec.meta.kind != "grad_step" {
            bail!("{grads_artifact} is `{}`, expected grad_step", grads_exec.meta.kind);
        }
        let apply_exec = engine.executable(apply_artifact)?;
        if apply_exec.meta.kind != "apply_step" {
            bail!("{apply_artifact} is `{}`, expected apply_step", apply_exec.meta.kind);
        }
        let meta = &grads_exec.meta;
        let n_params = meta.param_spec.len();
        let (batch, seq) =
            (meta.batch.context("missing batch")?, meta.seq.context("missing seq")?);

        // Initial state comes from the apply artifact's spec (same spec).
        let state = super::session::init_state_for(&apply_exec.meta, seed)?;

        // One independent data shard per worker (distinct stream seeds),
        // matching DDP's disjoint per-rank sharding.
        let vocab = engine
            .manifest
            .config(meta.config.as_deref().unwrap_or(""))
            .map(|c| c.vocab)
            .unwrap_or(512);
        let pipelines = (0..workers.max(1))
            .map(|w| {
                let it =
                    BatchIterator::from_seed(vocab, batch, seq, seed ^ (0xD0 + w as u64) << 8);
                BatchPipeline::spawn(it, 2)
            })
            .collect();

        Ok(DdpTrainer {
            grads_exec,
            apply_exec,
            state,
            n_params,
            step: 0,
            seed,
            workers: workers.max(1),
            batch,
            seq,
            pipelines,
        })
    }

    pub fn current_step(&self) -> usize {
        self.step as usize
    }

    /// One optimizer step = `workers × accum` gradient shards, all-reduced
    /// then applied once. Returns the mean shard loss.
    pub fn step(&mut self, accum: usize) -> Result<f32> {
        let accum = accum.max(1);
        let mut grad_sets = Vec::with_capacity(self.workers * accum);
        let mut losses = Vec::new();

        for w in 0..self.workers {
            for a in 0..accum {
                let batch = self.pipelines[w].next();
                // Fold (worker, microbatch) into the PAMM sampling seed so
                // shards draw independent generators (paper: fresh sample
                // per step).
                let shard_seed = Xoshiro256::fold_in(
                    self.seed,
                    0xDD,
                    (self.step as u64) << 16 | (w as u64) << 8 | a as u64,
                )
                .next_u64() as i32
                    & 0x7FFF_FFFF;

                let step_lit = xla::Literal::scalar(self.step);
                let tok_lit = batch.to_tensor().to_literal()?;
                let seed_lit = xla::Literal::scalar(shard_seed);

                let mut inputs: Vec<&xla::Literal> =
                    self.state[..self.n_params].iter().collect();
                inputs.push(&step_lit);
                inputs.push(&tok_lit);
                inputs.push(&seed_lit);

                let outs = self.grads_exec.run_literals(&inputs)?;
                losses.push(outs[0].to_vec::<f32>()?[0]);
                let grads: Vec<HostTensor> = outs[1..]
                    .iter()
                    .map(HostTensor::from_literal)
                    .collect::<Result<_>>()?;
                grad_sets.push(grads);
            }
        }

        let reduced = all_reduce_mean(grad_sets)?;
        let grad_lits: Vec<xla::Literal> =
            reduced.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;

        let step_lit = xla::Literal::scalar(self.step);
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.extend(grad_lits.iter());
        inputs.push(&step_lit);

        let outputs = self.apply_exec.run_literals(&inputs)?;
        if outputs.len() != 3 * self.n_params {
            bail!("apply_step returned {} outputs", outputs.len());
        }
        self.state = outputs;
        self.step += 1;
        Ok(losses.iter().sum::<f32>() / losses.len() as f32)
    }

    /// Tokens consumed per optimizer step.
    pub fn tokens_per_step(&self, accum: usize) -> usize {
        self.workers * accum.max(1) * self.batch * self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reduce_mean_averages() {
        let a = vec![HostTensor::f32(vec![2], vec![1.0, 2.0])];
        let b = vec![HostTensor::f32(vec![2], vec![3.0, 6.0])];
        let out = all_reduce_mean(vec![a, b]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn all_reduce_single_worker_identity() {
        let a = vec![HostTensor::f32(vec![3], vec![1.0, -1.0, 0.5])];
        let out = all_reduce_mean(vec![a.clone()]).unwrap();
        assert_eq!(out[0], a[0]);
    }

    #[test]
    fn all_reduce_rejects_mismatch() {
        let a = vec![HostTensor::f32(vec![2], vec![1.0, 2.0])];
        let b = vec![HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0])];
        assert!(all_reduce_mean(vec![a, b]).is_err());
        assert!(all_reduce_mean(vec![]).is_err());
    }
}
