//! One model replica bound to a train_step artifact.
//!
//! Owns the `params / m / v` literals, initializes them from the manifest
//! param spec (Gaussian by `init_std`, ones for norm gains), and threads
//! them through successive executions — the steady-state loop allocates
//! nothing but the token literal and the loss readback.

use anyhow::{bail, Context, Result};

use crate::runtime::{ArtifactMeta, Engine, Exec, HostTensor};
use crate::rngx::Xoshiro256;

/// Initialize one parameter tensor per its spec entry.
fn init_tensor(shape: &[usize], init_std: f64, rng: &mut Xoshiro256) -> HostTensor {
    let n: usize = shape.iter().product();
    let data = if init_std < 0.0 {
        vec![1.0f32; n] // norm gains
    } else {
        let mut v = vec![0.0f32; n];
        rng.fill_normal_f32(&mut v, init_std as f32);
        v
    };
    HostTensor::f32(shape.to_vec(), data)
}

/// Build the initial (params, m, v) literal vector for an artifact.
/// m and v start at zero (AdamW convention).
pub fn init_state_for(meta: &ArtifactMeta, seed: u64) -> Result<Vec<xla::Literal>> {
    if meta.param_spec.is_empty() {
        bail!("{}: artifact has no param_spec", meta.name);
    }
    let mut state = Vec::with_capacity(meta.param_spec.len() * 3);
    for (i, p) in meta.param_spec.iter().enumerate() {
        let mut rng = Xoshiro256::fold_in(seed, 0x1217, i as u64);
        state.push(init_tensor(&p.shape, p.init_std, &mut rng).to_literal()?);
    }
    for p in meta.param_spec.iter().chain(meta.param_spec.iter()) {
        let zeros = HostTensor::f32(p.shape.clone(), vec![0.0; p.elements()]);
        state.push(zeros.to_literal()?);
    }
    Ok(state)
}

/// Decoder-LM training session.
pub struct TrainSession {
    exec: Exec,
    eval_exec: Option<Exec>,
    /// params ++ m ++ v (3P literals, canonical order).
    state: Vec<xla::Literal>,
    n_params: usize,
    step: i32,
    seed: i32,
    pub batch: usize,
    pub seq: usize,
}

impl TrainSession {
    /// Bind to `train_artifact`; optionally attach an eval artifact.
    pub fn new(
        engine: &Engine,
        train_artifact: &str,
        eval_artifact: Option<&str>,
        seed: u64,
    ) -> Result<TrainSession> {
        let exec = engine
            .executable(train_artifact)
            .with_context(|| format!("loading {train_artifact}"))?;
        let meta = &exec.meta;
        if meta.kind != "train_step" {
            bail!("{train_artifact} is `{}`, expected train_step", meta.kind);
        }
        let n_params = meta.param_spec.len();
        let state = init_state_for(meta, seed)?;
        let (batch, seq) = (
            meta.batch.context("train_step missing batch")?,
            meta.seq.context("train_step missing seq")?,
        );
        let eval_exec = match eval_artifact {
            Some(name) => Some(engine.executable(name)?),
            None => None,
        };
        Ok(TrainSession {
            exec,
            eval_exec,
            state,
            n_params,
            step: 0,
            seed: (seed & 0x7FFF_FFFF) as i32,
            batch,
            seq,
        })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.exec.meta
    }

    pub fn current_step(&self) -> usize {
        self.step as usize
    }

    /// One fused fwd+bwd+AdamW step; returns the loss.
    pub fn step(&mut self, tokens: &HostTensor) -> Result<f32> {
        let expect = [self.batch, self.seq + 1];
        if tokens.shape() != expect {
            bail!("token batch {:?}, artifact expects {:?}", tokens.shape(), expect);
        }
        let step_lit = xla::Literal::scalar(self.step);
        let tok_lit = tokens.to_literal()?;
        let seed_lit = xla::Literal::scalar(self.seed);

        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.push(&step_lit);
        inputs.push(&tok_lit);
        inputs.push(&seed_lit);

        let mut outputs = self.exec.run_literals(&inputs)?;
        if outputs.len() != 1 + 3 * self.n_params {
            bail!(
                "train_step returned {} outputs, expected {}",
                outputs.len(),
                1 + 3 * self.n_params
            );
        }
        let loss = outputs[0].to_vec::<f32>()?[0];
        self.state = outputs.split_off(1);
        self.step += 1;
        Ok(loss)
    }

    /// Evaluate mean loss over an iterator of batches (baseline forward).
    pub fn eval(&self, batches: &[HostTensor]) -> Result<f32> {
        let exec = self.eval_exec.as_ref().context("no eval artifact attached")?;
        let mut total = 0.0f64;
        for t in batches {
            let tok_lit = t.to_literal()?;
            let mut inputs: Vec<&xla::Literal> =
                self.state[..self.n_params].iter().collect();
            inputs.push(&tok_lit);
            let out = exec.run_literals(&inputs)?;
            total += out[0].to_vec::<f32>()?[0] as f64;
        }
        Ok((total / batches.len().max(1) as f64) as f32)
    }

    /// Copy current parameters to host (checkpointing / analysis capture).
    pub fn params_host(&self) -> Result<Vec<(String, HostTensor)>> {
        let mut out = Vec::with_capacity(self.n_params);
        for (i, p) in self.exec.meta.param_spec.iter().enumerate() {
            out.push((p.name.clone(), HostTensor::from_literal(&self.state[i])?));
        }
        Ok(out)
    }

    /// Restore parameters (m/v reset to zero, step preserved by caller).
    pub fn load_params(&mut self, params: &[(String, HostTensor)]) -> Result<()> {
        if params.len() != self.n_params {
            bail!("checkpoint has {} params, artifact {}", params.len(), self.n_params);
        }
        for (i, (name, t)) in params.iter().enumerate() {
            let spec = &self.exec.meta.param_spec[i];
            if *name != spec.name || t.shape() != spec.shape.as_slice() {
                bail!("checkpoint entry {i} `{name}` mismatches spec `{}`", spec.name);
            }
            self.state[i] = t.to_literal()?;
        }
        Ok(())
    }
}

/// Classifier (GLUE/AID) training session — adds labels to each step and
/// an argmax-prediction eval path.
pub struct ClassifierSession {
    exec: Exec,
    eval_exec: Exec,
    state: Vec<xla::Literal>,
    n_params: usize,
    step: i32,
    seed: i32,
    pub batch: usize,
    pub seq: usize,
}

impl ClassifierSession {
    pub fn new(
        engine: &Engine,
        train_artifact: &str,
        eval_artifact: &str,
        seed: u64,
    ) -> Result<ClassifierSession> {
        let exec = engine.executable(train_artifact)?;
        if exec.meta.kind != "cls_train_step" {
            bail!("{train_artifact} is `{}`, expected cls_train_step", exec.meta.kind);
        }
        let eval_exec = engine.executable(eval_artifact)?;
        let n_params = exec.meta.param_spec.len();
        let state = init_state_for(&exec.meta, seed)?;
        let (batch, seq) = (exec.meta.batch.unwrap(), exec.meta.seq.unwrap());
        Ok(ClassifierSession {
            exec,
            eval_exec,
            state,
            n_params,
            step: 0,
            seed: (seed & 0x7FFF_FFFF) as i32,
            batch,
            seq,
        })
    }

    pub fn step(&mut self, tokens: &HostTensor, labels: &HostTensor) -> Result<f32> {
        let step_lit = xla::Literal::scalar(self.step);
        let tok_lit = tokens.to_literal()?;
        let lab_lit = labels.to_literal()?;
        let seed_lit = xla::Literal::scalar(self.seed);
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.push(&step_lit);
        inputs.push(&tok_lit);
        inputs.push(&lab_lit);
        inputs.push(&seed_lit);
        let mut outputs = self.exec.run_literals(&inputs)?;
        let loss = outputs[0].to_vec::<f32>()?[0];
        self.state = outputs.split_off(1);
        self.step += 1;
        Ok(loss)
    }

    /// Predicted class ids for a token batch.
    pub fn predict(&self, tokens: &HostTensor) -> Result<Vec<i32>> {
        let tok_lit = tokens.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = self.state[..self.n_params].iter().collect();
        inputs.push(&tok_lit);
        let out = self.eval_exec.run_literals(&inputs)?;
        Ok(out[0].to_vec::<i32>()?)
    }
}
