//! Model-replica sessions.
//!
//! Two kinds live here:
//!
//! * [`GenSession`] — the **native generation session**: one request's
//!   decode state over a shared [`TransformerLM`], wrapping a
//!   [`generate::Decoder`] with its PAMM-compressed KV cache. This is
//!   the unit `coordinator::serve`'s continuous-batching loop
//!   schedules — each session advances one token per serve step, and
//!   because a session's compute is a pure serial function of its own
//!   state (inner pool = serial, partition-only-task rule), a fixed
//!   arrival script yields bit-identical token streams at any worker
//!   count.
//! * [`TrainSession`] / [`ClassifierSession`] (feature `pjrt`) — one
//!   replica bound to a train_step artifact: owns the `params / m / v`
//!   literals, initializes them from the manifest param spec and
//!   threads them through successive executions. Artifact-bound and
//!   PJRT-only, so they compile only with `--features pjrt`.

use crate::generate::{self, Decoder, GenConfig};
use crate::model::TransformerLM;
use crate::pamm::Eps;
use crate::poolx::Pool;

#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use crate::runtime::{ArtifactMeta, Engine, Exec, HostTensor};
#[cfg(feature = "pjrt")]
use crate::rngx::Xoshiro256;

/// One generation request's session state: prompt in, greedy tokens
/// out, one token per [`GenSession::advance`] call. The decoder (and
/// its compressed KV cache) is created at admission time, so queued
/// sessions hold no cache memory.
pub struct GenSession<'m> {
    pub id: usize,
    /// Serve-step index at which the request becomes visible.
    pub arrival: usize,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    cfg: GenConfig,
    dec: Option<Decoder<'m>>,
    emitted: Vec<i32>,
}

impl<'m> GenSession<'m> {
    /// `seed` feeds the per-layer generator draw; sessions with the
    /// same (seed, prompt) build bit-identical caches regardless of
    /// scheduling. The cache is sized to `prompt + max_new` tokens.
    pub fn new(
        id: usize,
        arrival: usize,
        prompt: Vec<i32>,
        max_new: usize,
        k: usize,
        eps: Eps,
        seed: u64,
    ) -> Self {
        assert!(!prompt.is_empty(), "serve: empty prompt in request {id}");
        assert!(max_new > 0, "serve: request {id} asks for zero tokens");
        let cfg = GenConfig::new(k, eps, seed, prompt.len() + max_new);
        GenSession { id, arrival, prompt, max_new, cfg, dec: None, emitted: Vec::new() }
    }

    /// Prefill the prompt and emit the first token. Called once, by
    /// the serve loop, at the step the session is admitted.
    ///
    /// If the prefill produces non-finite logits the first token is
    /// **not** emitted — the session reports unhealthy
    /// ([`GenSession::logits_finite`]) and the serve loop quarantines
    /// it instead of streaming a token derived from NaN (greedy over
    /// all-NaN logits would silently return token 0).
    pub fn admit(&mut self, model: &'m TransformerLM, pool: &Pool) {
        assert!(self.dec.is_none(), "serve: request {} admitted twice", self.id);
        let mut dec = Decoder::new(model, self.cfg);
        dec.prefill(&self.prompt, pool);
        if dec.logits_finite() {
            self.emitted.push(generate::greedy(dec.last_logits()));
        }
        self.dec = Some(dec);
    }

    /// One decode step: fold the previously emitted token into the
    /// cache, emit the next. The final emitted token is never folded
    /// (nothing attends past it), which is why `advance` emits the
    /// same stream as [`Decoder::generate`] one step earlier.
    ///
    /// Like [`GenSession::admit`], never emits from non-finite logits:
    /// the poisoned step leaves the emitted stream as its clean prefix
    /// and the serve loop's health check takes over.
    pub fn advance(&mut self, pool: &Pool) {
        assert!(!self.is_done(), "serve: request {} advanced past completion", self.id);
        let dec = self.dec.as_mut().expect("serve: advance before admit");
        let last = *self.emitted.last().expect("admit emits the first token");
        dec.decode_step(last, pool);
        if dec.logits_finite() {
            self.emitted.push(generate::greedy(dec.last_logits()));
        }
    }

    pub fn is_admitted(&self) -> bool {
        self.dec.is_some()
    }

    /// Health check: false iff the decoder's current logits contain a
    /// NaN/Inf (true before admission — nothing has run yet). The
    /// serve loop quarantines unhealthy sessions.
    pub fn logits_finite(&self) -> bool {
        self.dec.as_ref().map_or(true, |d| d.logits_finite())
    }

    /// Fault-injection hook (`faultx` / `pamm chaos`): poison the
    /// decoder's current logits with NaN. No-op before admission.
    pub fn inject_poison(&mut self) {
        if let Some(dec) = self.dec.as_mut() {
            dec.poison_last_logits();
        }
    }

    pub fn is_done(&self) -> bool {
        self.emitted.len() >= self.max_new
    }

    /// Greedy tokens emitted so far.
    pub fn tokens(&self) -> &[i32] {
        &self.emitted
    }

    /// Measured cache peak of this session (0 before admission).
    pub fn cache_peak_bytes(&self) -> usize {
        self.dec.as_ref().map_or(0, |d| d.cache_peak_bytes())
    }

    /// Analytic cache bound for this session.
    pub fn cache_bound_bytes(&self) -> usize {
        self.dec.as_ref().map_or(0, |d| d.cache_bound_bytes())
    }

    /// Bytes a dense KV cache would hold for this session.
    pub fn dense_baseline_bytes(&self) -> usize {
        self.dec.as_ref().map_or(0, |d| d.dense_baseline_bytes())
    }
}

/// Initialize one parameter tensor per its spec entry.
#[cfg(feature = "pjrt")]
fn init_tensor(shape: &[usize], init_std: f64, rng: &mut Xoshiro256) -> HostTensor {
    let n: usize = shape.iter().product();
    let data = if init_std < 0.0 {
        vec![1.0f32; n] // norm gains
    } else {
        let mut v = vec![0.0f32; n];
        rng.fill_normal_f32(&mut v, init_std as f32);
        v
    };
    HostTensor::f32(shape.to_vec(), data)
}

/// Build the initial (params, m, v) literal vector for an artifact.
/// m and v start at zero (AdamW convention).
#[cfg(feature = "pjrt")]
pub fn init_state_for(meta: &ArtifactMeta, seed: u64) -> Result<Vec<xla::Literal>> {
    if meta.param_spec.is_empty() {
        bail!("{}: artifact has no param_spec", meta.name);
    }
    let mut state = Vec::with_capacity(meta.param_spec.len() * 3);
    for (i, p) in meta.param_spec.iter().enumerate() {
        let mut rng = Xoshiro256::fold_in(seed, 0x1217, i as u64);
        state.push(init_tensor(&p.shape, p.init_std, &mut rng).to_literal()?);
    }
    for p in meta.param_spec.iter().chain(meta.param_spec.iter()) {
        let zeros = HostTensor::f32(p.shape.clone(), vec![0.0; p.elements()]);
        state.push(zeros.to_literal()?);
    }
    Ok(state)
}

/// Decoder-LM training session.
#[cfg(feature = "pjrt")]
pub struct TrainSession {
    exec: Exec,
    eval_exec: Option<Exec>,
    /// params ++ m ++ v (3P literals, canonical order).
    state: Vec<xla::Literal>,
    n_params: usize,
    step: i32,
    seed: i32,
    pub batch: usize,
    pub seq: usize,
}

#[cfg(feature = "pjrt")]
impl TrainSession {
    /// Bind to `train_artifact`; optionally attach an eval artifact.
    pub fn new(
        engine: &Engine,
        train_artifact: &str,
        eval_artifact: Option<&str>,
        seed: u64,
    ) -> Result<TrainSession> {
        let exec = engine
            .executable(train_artifact)
            .with_context(|| format!("loading {train_artifact}"))?;
        let meta = &exec.meta;
        if meta.kind != "train_step" {
            bail!("{train_artifact} is `{}`, expected train_step", meta.kind);
        }
        let n_params = meta.param_spec.len();
        let state = init_state_for(meta, seed)?;
        let (batch, seq) = (
            meta.batch.context("train_step missing batch")?,
            meta.seq.context("train_step missing seq")?,
        );
        let eval_exec = match eval_artifact {
            Some(name) => Some(engine.executable(name)?),
            None => None,
        };
        Ok(TrainSession {
            exec,
            eval_exec,
            state,
            n_params,
            step: 0,
            seed: (seed & 0x7FFF_FFFF) as i32,
            batch,
            seq,
        })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.exec.meta
    }

    pub fn current_step(&self) -> usize {
        self.step as usize
    }

    /// One fused fwd+bwd+AdamW step; returns the loss.
    pub fn step(&mut self, tokens: &HostTensor) -> Result<f32> {
        let expect = [self.batch, self.seq + 1];
        if tokens.shape() != expect {
            bail!("token batch {:?}, artifact expects {:?}", tokens.shape(), expect);
        }
        let step_lit = xla::Literal::scalar(self.step);
        let tok_lit = tokens.to_literal()?;
        let seed_lit = xla::Literal::scalar(self.seed);

        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.push(&step_lit);
        inputs.push(&tok_lit);
        inputs.push(&seed_lit);

        let mut outputs = self.exec.run_literals(&inputs)?;
        if outputs.len() != 1 + 3 * self.n_params {
            bail!(
                "train_step returned {} outputs, expected {}",
                outputs.len(),
                1 + 3 * self.n_params
            );
        }
        let loss = outputs[0].to_vec::<f32>()?[0];
        self.state = outputs.split_off(1);
        self.step += 1;
        Ok(loss)
    }

    /// Evaluate mean loss over an iterator of batches (baseline forward).
    pub fn eval(&self, batches: &[HostTensor]) -> Result<f32> {
        let exec = self.eval_exec.as_ref().context("no eval artifact attached")?;
        let mut total = 0.0f64;
        for t in batches {
            let tok_lit = t.to_literal()?;
            let mut inputs: Vec<&xla::Literal> =
                self.state[..self.n_params].iter().collect();
            inputs.push(&tok_lit);
            let out = exec.run_literals(&inputs)?;
            total += out[0].to_vec::<f32>()?[0] as f64;
        }
        Ok((total / batches.len().max(1) as f64) as f32)
    }

    /// Copy current parameters to host (checkpointing / analysis capture).
    pub fn params_host(&self) -> Result<Vec<(String, HostTensor)>> {
        let mut out = Vec::with_capacity(self.n_params);
        for (i, p) in self.exec.meta.param_spec.iter().enumerate() {
            out.push((p.name.clone(), HostTensor::from_literal(&self.state[i])?));
        }
        Ok(out)
    }

    /// Restore parameters (m/v reset to zero, step preserved by caller).
    pub fn load_params(&mut self, params: &[(String, HostTensor)]) -> Result<()> {
        if params.len() != self.n_params {
            bail!("checkpoint has {} params, artifact {}", params.len(), self.n_params);
        }
        for (i, (name, t)) in params.iter().enumerate() {
            let spec = &self.exec.meta.param_spec[i];
            if *name != spec.name || t.shape() != spec.shape.as_slice() {
                bail!("checkpoint entry {i} `{name}` mismatches spec `{}`", spec.name);
            }
            self.state[i] = t.to_literal()?;
        }
        Ok(())
    }
}

/// Classifier (GLUE/AID) training session — adds labels to each step and
/// an argmax-prediction eval path.
#[cfg(feature = "pjrt")]
pub struct ClassifierSession {
    exec: Exec,
    eval_exec: Exec,
    state: Vec<xla::Literal>,
    n_params: usize,
    step: i32,
    seed: i32,
    pub batch: usize,
    pub seq: usize,
}

#[cfg(feature = "pjrt")]
impl ClassifierSession {
    pub fn new(
        engine: &Engine,
        train_artifact: &str,
        eval_artifact: &str,
        seed: u64,
    ) -> Result<ClassifierSession> {
        let exec = engine.executable(train_artifact)?;
        if exec.meta.kind != "cls_train_step" {
            bail!("{train_artifact} is `{}`, expected cls_train_step", exec.meta.kind);
        }
        let eval_exec = engine.executable(eval_artifact)?;
        let n_params = exec.meta.param_spec.len();
        let state = init_state_for(&exec.meta, seed)?;
        let (batch, seq) = (exec.meta.batch.unwrap(), exec.meta.seq.unwrap());
        Ok(ClassifierSession {
            exec,
            eval_exec,
            state,
            n_params,
            step: 0,
            seed: (seed & 0x7FFF_FFFF) as i32,
            batch,
            seq,
        })
    }

    pub fn step(&mut self, tokens: &HostTensor, labels: &HostTensor) -> Result<f32> {
        let step_lit = xla::Literal::scalar(self.step);
        let tok_lit = tokens.to_literal()?;
        let lab_lit = labels.to_literal()?;
        let seed_lit = xla::Literal::scalar(self.seed);
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.push(&step_lit);
        inputs.push(&tok_lit);
        inputs.push(&lab_lit);
        inputs.push(&seed_lit);
        let mut outputs = self.exec.run_literals(&inputs)?;
        let loss = outputs[0].to_vec::<f32>()?[0];
        self.state = outputs.split_off(1);
        self.step += 1;
        Ok(loss)
    }

    /// Predicted class ids for a token batch.
    pub fn predict(&self, tokens: &HostTensor) -> Result<Vec<i32>> {
        let tok_lit = tokens.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = self.state[..self.n_params].iter().collect();
        inputs.push(&tok_lit);
        let out = self.eval_exec.run_literals(&inputs)?;
        Ok(out[0].to_vec::<i32>()?)
    }
}
