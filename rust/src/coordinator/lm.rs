//! Native LM pretraining driver: real next-token training of the
//! multi-layer `model::TransformerLM` on the `data` pipeline — no
//! artifacts, no PJRT (DESIGN.md §7).
//!
//! [`LmTrainer`] owns the model, the optimizer state (SGD or Adam over
//! the flat parameter vector, fixed-order scalar f32 updates), the
//! step counter and the generator-sampling RNG stream;
//! [`train_lm_native`] is the run loop `pamm train --native` /
//! `--quick` drives: `data::BatchIterator` batches → fwd → softmax
//! cross-entropy → tape backward → update, with run logging, periodic
//! [`checkpoint::save`] and exact resume.
//!
//! # Exact resume
//!
//! A checkpoint stores parameters, Adam moments, the step counter,
//! the generator-RNG state (`rngx::Xoshiro256::state`, eight i32
//! words) and the run hyperparameters (batch/seq/k + optimizer
//! constants). On resume the trainer restores the first four,
//! **refuses** a hyperparameter mismatch (continuing under different
//! geometry or optimizer constants would silently diverge from the
//! original run), and the run loop appends to the existing run log
//! and fast-forwards the deterministic batch stream by
//! [`BatchIterator::skip_batches`] — so an interrupted-and-resumed run
//! is **bit-identical, step for step**, to an uninterrupted one
//! (property-tested in `rust/tests/prop_model.rs`). Combined with the
//! kernel contracts below, the whole training run is reproducible from
//! `(seed, steps)` at any thread count and SIMD dispatch level.
//!
//! # Crash recovery (DESIGN.md §9)
//!
//! PR 7 extends the resume contract from "user restarted cleanly" to
//! "process died at an arbitrary step": checkpoints go through a
//! [`checkpoint::CheckpointRing`] (atomic writes, CRC32-verified,
//! last-N retained), the run log is fsynced at every checkpoint
//! boundary, and [`train_lm_supervised`] wraps the run loop — catching
//! [`faultx::InjectedCrash`] kills, re-opening the ring, resuming from
//! the newest checkpoint that *verifies* (corrupted entries are
//! skipped with a diagnostic) and replaying to completion. The
//! recovered trajectory is bitwise identical to the uninterrupted
//! run's at every kill point (`rust/tests/prop_faults.rs`,
//! `pamm chaos`).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::{self, CheckpointRing};
use crate::faultx::{self, CrashPhase, InjectedCrash};
use crate::coordinator::trainer::{NativeOpt, TrainOutcome};
use crate::data::batcher::BatchIterator;
use crate::jsonx;
use crate::memory::MemoryLedger;
use crate::metrics::{perplexity, Ema, RunLogger, ThroughputMeter};
use crate::model::{self, LmConfig, SavedInventory, TransformerLM};
use crate::pamm::Eps;
use crate::poolx::Pool;
use crate::rngx::Xoshiro256;
use crate::runtime::HostTensor;
use crate::tensor::kernels::{self, Dispatch};
use crate::tensor::Mat;

/// First/second-moment state of one parameter matrix (Adam only).
/// `pub(crate)` so the data-parallel trainer (`coordinator::dp`) can
/// reuse the exact same optimizer state representation.
#[derive(Debug, Clone)]
pub(crate) struct Moments {
    pub(crate) m: Mat,
    pub(crate) v: Mat,
}

impl Moments {
    pub(crate) fn zeros_like(p: &Mat) -> Moments {
        Moments { m: Mat::zeros(p.rows(), p.cols()), v: Mat::zeros(p.rows(), p.cols()) }
    }
}

/// Everything one LM step produced (ledger/harness consumers).
#[derive(Debug)]
pub struct LmStepReport {
    pub loss: f32,
    /// Exact saved-for-backward bytes of the step's whole tape.
    pub saved_bytes: usize,
    /// The same bytes split per layer (embedding / blocks / tail).
    pub inventory: SavedInventory,
}

/// The native multi-layer trainer: model + optimizer + RNG stream.
pub struct LmTrainer {
    pub model: TransformerLM,
    pub batch: usize,
    pub seq: usize,
    /// Generator budget per compression (`k = ⌈r·b⌉` of the paper).
    pub k: usize,
    pub eps: Eps,
    opt: NativeOpt,
    moments: Option<Vec<Moments>>,
    step_no: usize,
    rng: Xoshiro256,
    /// The run seed (model init, generator stream AND the data stream
    /// the run loop derives from it) — checkpointed so resume can
    /// refuse a seed change, which would silently swap the batch
    /// stream under the restored weights.
    seed: u64,
}

impl LmTrainer {
    /// Deterministic init: model weights from `seed`, generator
    /// sampling from an independent stream. Same seed ⇒ the same run
    /// at any thread count or dispatch level.
    pub fn new(
        cfg: LmConfig,
        batch: usize,
        seq: usize,
        k: usize,
        opt: NativeOpt,
        seed: u64,
    ) -> Self {
        let model = TransformerLM::new(cfg, seed);
        let moments = match opt {
            NativeOpt::Sgd { .. } => None,
            NativeOpt::Adam { .. } => {
                Some(model.params.iter().map(Moments::zeros_like).collect())
            }
        };
        Self {
            model,
            batch,
            seq,
            k: k.max(1),
            eps: Eps::Inf,
            opt,
            moments,
            step_no: 0,
            rng: Xoshiro256::new(seed ^ 0x9E3779B97F4A7C15),
            seed,
        }
    }

    pub fn step_no(&self) -> usize {
        self.step_no
    }

    /// One full training step on a packed `(batch, seq+1)` token row
    /// block (the [`crate::data::batcher::TokenBatch`] layout):
    /// `tokens[:, :-1]` are the inputs, `tokens[:, 1:]` the targets.
    ///
    /// Fails — with the parameters, Adam moments and step counter
    /// untouched — if the loss or any gradient is non-finite (the
    /// divergence guard: a NaN that reaches the optimizer would
    /// silently corrupt the moments and every subsequent step).
    pub fn train_step(
        &mut self,
        tokens: &[i32],
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> Result<f32> {
        Ok(self.step_report(kernels::active(), tokens, pool, ledger)?.loss)
    }

    /// [`LmTrainer::train_step`] with an explicit dispatch level,
    /// returning the full report (tests, benches, `pamm ledger`).
    pub fn step_report(
        &mut self,
        d: Dispatch,
        tokens: &[i32],
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> Result<LmStepReport> {
        let (batch, seq) = (self.batch, self.seq);
        ensure!(
            tokens.len() == batch * (seq + 1),
            "lm step: expected a packed (batch, seq+1) = {}x{} token block, got {} tokens",
            batch,
            seq + 1,
            tokens.len()
        );
        let mut inputs = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for r in 0..batch {
            let row = &tokens[r * (seq + 1)..(r + 1) * (seq + 1)];
            inputs.extend_from_slice(&row[..seq]);
            targets.extend_from_slice(&row[1..]);
        }
        let (loss, tape) = self.model.forward(
            d,
            &inputs,
            &targets,
            batch,
            seq,
            self.k,
            self.eps,
            &mut self.rng,
            pool,
            ledger,
        );
        // Divergence guard, stage 1: a non-finite loss means the
        // forward already blew up — refuse before touching any state.
        ensure!(
            loss.is_finite(),
            "non-finite loss ({loss}) at step {}: training diverged; \
             parameters and optimizer moments left untouched",
            self.step_no + 1
        );
        let saved_bytes = tape.saved_bytes();
        let inventory = model::saved_inventory(&tape, self.model.cfg.n_layers);
        let res = tape.backward(d, &self.model.params, pool, ledger);
        // Stage 2: a finite loss can still backprop into Inf/NaN
        // gradients (overflow in the chain products). Scan before the
        // update and name the offending parameter.
        check_finite_grads(&model::param_names(&self.model.cfg), &res.params, self.step_no + 1)?;
        self.step_no += 1;
        self.apply_update(&res.params)?;
        Ok(LmStepReport { loss, saved_bytes, inventory })
    }

    /// Fixed-order scalar f32 optimizer update over the flat parameter
    /// vector — bit-identical given bit-identical gradients.
    fn apply_update(&mut self, grads: &[Mat]) -> Result<()> {
        apply_opt_update(self.opt, &mut self.model.params, self.moments.as_mut(), grads, self.step_no)
    }

    // -- checkpointing ------------------------------------------------------

    /// The full trainer state as named tensors — everything a
    /// checkpoint must carry for bit-exact resume: parameters, Adam
    /// moments, step counter, generator RNG state and the run
    /// hyperparameters ([`LmTrainer::restore_from`] refuses a
    /// mismatch).
    pub fn checkpoint_tensors(&self) -> Vec<(String, HostTensor)> {
        let names = model::param_names(&self.model.cfg);
        let mut tensors: Vec<(String, HostTensor)> = Vec::with_capacity(
            self.model.params.len() * if self.moments.is_some() { 3 } else { 1 } + 2,
        );
        let as_tensor =
            |m: &Mat| HostTensor::f32(vec![m.rows(), m.cols()], m.data().to_vec());
        for (n, p) in names.iter().zip(&self.model.params) {
            tensors.push((n.clone(), as_tensor(p)));
        }
        if let Some(ms) = &self.moments {
            for (n, st) in names.iter().zip(ms) {
                tensors.push((format!("opt_m.{n}"), as_tensor(&st.m)));
                tensors.push((format!("opt_v.{n}"), as_tensor(&st.v)));
            }
        }
        tensors.push(("meta.step".into(), HostTensor::i32(vec![1], vec![self.step_no as i32])));
        tensors.push(("meta.rng".into(), HostTensor::i32(vec![8], rng_words(self.rng.state()))));
        // Run hyperparameters that the bit-exact-resume contract depends
        // on: geometry + seed (batch/seq/k/seed drive the data stream
        // and generator sampling) and the optimizer constants.
        tensors.push(("meta.geom".into(), HostTensor::i32(vec![5], self.geom_words())));
        tensors.push(("meta.opt".into(), HostTensor::f32(vec![5], opt_words(self.opt))));
        tensors
    }

    /// Save parameters + optimizer moments + step counter + generator
    /// RNG state under `dir/name.{bin,json}` (crash-safe:
    /// [`checkpoint::save`] writes atomically with checksums).
    pub fn save_checkpoint(&self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        checkpoint::save(dir, name, &self.checkpoint_tensors())
    }

    /// Restore a checkpoint written by [`LmTrainer::save_checkpoint`]
    /// into this trainer (which must have the same config/optimizer).
    /// After this, continuing the run reproduces the uninterrupted one
    /// bit for bit (the caller fast-forwards the batch stream by
    /// [`LmTrainer::step_no`] batches).
    pub fn resume(&mut self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        let loaded = checkpoint::load(dir, name)?;
        self.restore_from(loaded)
    }

    /// Restore from already-loaded checkpoint tensors (the ring
    /// recovery path, where [`CheckpointRing::load_latest_good`]
    /// verified and loaded the newest good entry).
    pub fn restore_from(&mut self, loaded: Vec<(String, HostTensor)>) -> Result<()> {
        let map: std::collections::BTreeMap<String, HostTensor> = loaded.into_iter().collect();
        let names = model::param_names(&self.model.cfg);
        let restore = |dst: &mut Mat, key: &str| -> Result<()> {
            let t = map.get(key).with_context(|| format!("checkpoint missing `{key}`"))?;
            ensure!(
                t.shape() == [dst.rows(), dst.cols()],
                "checkpoint `{key}`: shape {:?} vs model {}x{}",
                t.shape(),
                dst.rows(),
                dst.cols()
            );
            dst.data_mut().copy_from_slice(t.as_f32()?);
            Ok(())
        };
        for (n, p) in names.iter().zip(self.model.params.iter_mut()) {
            restore(p, n)?;
        }
        match &mut self.moments {
            Some(ms) => {
                ensure!(
                    map.contains_key(&format!("opt_m.{}", names[0])),
                    "checkpoint has no Adam moments but the trainer uses Adam"
                );
                for (n, st) in names.iter().zip(ms.iter_mut()) {
                    restore(&mut st.m, &format!("opt_m.{n}"))?;
                    restore(&mut st.v, &format!("opt_v.{n}"))?;
                }
            }
            None => {
                if map.contains_key(&format!("opt_m.{}", names[0])) {
                    bail!("checkpoint carries Adam moments but the trainer uses SGD");
                }
            }
        }
        // The resume contract is "bit-identical to the uninterrupted
        // run" — that only holds if the data-stream geometry, the run
        // seed, the generator budget and the optimizer constants are
        // all unchanged.
        let geom = map.get("meta.geom").context("checkpoint missing `meta.geom`")?;
        let g = geom.as_i32()?;
        let want_geom = self.geom_words();
        ensure!(
            g == &want_geom[..],
            "checkpoint was trained with batch/seq/k/seed = {g:?}, trainer uses {want_geom:?} — \
             resuming would silently diverge from the original run"
        );
        let opt = map.get("meta.opt").context("checkpoint missing `meta.opt`")?;
        let want = opt_words(self.opt);
        let got = opt.as_f32()?;
        ensure!(
            got.iter().map(|v| v.to_bits()).eq(want.iter().map(|v| v.to_bits())),
            "checkpoint optimizer {got:?} differs from the trainer's {want:?}"
        );
        let step = map.get("meta.step").context("checkpoint missing `meta.step`")?;
        self.step_no = step.as_i32()?[0].max(0) as usize;
        let words = map.get("meta.rng").context("checkpoint missing `meta.rng`")?;
        self.rng = Xoshiro256::from_state(words_to_state(words.as_i32()?)?);
        Ok(())
    }

    /// `[batch, seq, k, seed_lo, seed_hi]` as i32 words — the geometry
    /// fingerprint a checkpoint must match to be resumable.
    fn geom_words(&self) -> Vec<i32> {
        vec![
            self.batch as i32,
            self.seq as i32,
            self.k as i32,
            (self.seed & 0xFFFF_FFFF) as u32 as i32,
            (self.seed >> 32) as u32 as i32,
        ]
    }
}

/// The fixed-order scalar f32 optimizer update, shared verbatim by the
/// single-process trainer and the data-parallel one
/// (`coordinator::dp`): same loop nesting, same operation order, so
/// bit-identical gradients produce bit-identical parameters wherever
/// the update runs. `t` is the step count *after* the step was counted
/// (Adam bias correction uses `1 - βᵗ`).
pub(crate) fn apply_opt_update(
    opt: NativeOpt,
    params: &mut [Mat],
    moments: Option<&mut Vec<Moments>>,
    grads: &[Mat],
    t: usize,
) -> Result<()> {
    match opt {
        NativeOpt::Sgd { lr } => {
            for (p, g) in params.iter_mut().zip(grads) {
                for (pv, &gv) in p.data_mut().iter_mut().zip(g.data()) {
                    *pv -= lr * gv;
                }
            }
        }
        NativeOpt::Adam { lr, beta1, beta2, eps } => {
            let moments =
                moments.context("adam update without moment state (trainer invariant broken)")?;
            let bc1 = 1.0 - beta1.powi(t as i32);
            let bc2 = 1.0 - beta2.powi(t as i32);
            for ((p, g), st) in params.iter_mut().zip(grads).zip(moments) {
                for (((pv, &gv), mv), vv) in p
                    .data_mut()
                    .iter_mut()
                    .zip(g.data())
                    .zip(st.m.data_mut().iter_mut())
                    .zip(st.v.data_mut().iter_mut())
                {
                    *mv = beta1 * *mv + (1.0 - beta1) * gv;
                    *vv = beta2 * *vv + (1.0 - beta2) * gv * gv;
                    let mhat = *mv / bc1;
                    let vhat = *vv / bc2;
                    *pv -= lr * mhat / (vhat.sqrt() + eps);
                }
            }
        }
    }
    Ok(())
}

/// Divergence guard, stage 2: refuse a gradient vector containing a
/// NaN/Inf, naming the first offending parameter (`names` follows
/// [`model::param_names`] order). Runs *before* `step_no` and the
/// optimizer update mutate, so a failed step leaves the trainer
/// exactly as it was.
pub(crate) fn check_finite_grads(names: &[String], grads: &[Mat], step: usize) -> Result<()> {
    for (name, g) in names.iter().zip(grads) {
        if let Some((i, bad)) = g.data().iter().enumerate().find(|(_, v)| !v.is_finite()) {
            bail!(
                "non-finite gradient ({bad}) in `{name}`[{i}] at step {step}: training \
                 diverged; parameters and optimizer moments left untouched"
            );
        }
    }
    Ok(())
}

/// Optimizer constants as a flat f32 tensor (`[kind, lr, β1, β2, ε]`;
/// kind 0 = SGD, 1 = Adam) — checkpointed so resume can refuse a
/// hyperparameter mismatch that would break bit-exactness.
pub(crate) fn opt_words(opt: NativeOpt) -> Vec<f32> {
    match opt {
        NativeOpt::Sgd { lr } => vec![0.0, lr, 0.0, 0.0, 0.0],
        NativeOpt::Adam { lr, beta1, beta2, eps } => vec![1.0, lr, beta1, beta2, eps],
    }
}

/// `[u64; 4]` RNG state ⇄ eight little-endian i32 words (checkpoints
/// only carry f32/i32 tensors).
pub(crate) fn rng_words(s: [u64; 4]) -> Vec<i32> {
    s.iter()
        .flat_map(|&x| [(x & 0xFFFF_FFFF) as u32 as i32, (x >> 32) as u32 as i32])
        .collect()
}

pub(crate) fn words_to_state(w: &[i32]) -> Result<[u64; 4]> {
    ensure!(w.len() == 8, "meta.rng: expected 8 words, got {}", w.len());
    let mut s = [0u64; 4];
    for (i, st) in s.iter_mut().enumerate() {
        let lo = w[2 * i] as u32 as u64;
        let hi = w[2 * i + 1] as u32 as u64;
        *st = (hi << 32) | lo;
    }
    Ok(s)
}

// ---------------------------------------------------------------------------
// The run loop (`pamm train --native` / `--quick`)
// ---------------------------------------------------------------------------

/// Run configuration for one native LM pretraining run.
#[derive(Debug, Clone)]
pub struct LmRunConfig {
    pub cfg: LmConfig,
    pub batch: usize,
    pub seq: usize,
    pub steps: usize,
    pub k: usize,
    pub opt: NativeOpt,
    pub seed: u64,
    /// Checkpoint every N optimizer steps (0 = only the final one).
    pub ckpt_every: usize,
    /// Ring retention: keep the last N boundary checkpoints (clamped
    /// to ≥ 1) so recovery can fall back past a corrupted newest.
    pub keep_last: usize,
    pub run_dir: String,
    pub run_name: String,
    /// Resume from the newest verifying ring entry under
    /// `run_dir/ckpt` (falling back to the plain `run_name`
    /// checkpoint from pre-ring runs) if one exists.
    pub resume: bool,
}

/// The checkpoint-boundary steps of a run — every
/// `ckpt_every`-divisible completed-step count plus the final step.
/// This is the site list fault plans are sampled from
/// ([`faultx::FaultPlan::sample_train`]).
pub fn checkpoint_boundaries(rc: &LmRunConfig) -> Vec<usize> {
    let mut out = Vec::new();
    if rc.ckpt_every > 0 {
        let mut s = rc.ckpt_every;
        while s < rc.steps {
            out.push(s);
            s += rc.ckpt_every;
        }
    }
    out.push(rc.steps);
    out
}

/// What [`train_lm_native_run`] produced beyond the outcome: where it
/// resumed from (if it did) and the ring-recovery diagnostics (every
/// corrupted/truncated entry that had to be skipped).
#[derive(Debug)]
pub struct LmRunReport {
    pub outcome: TrainOutcome,
    pub resumed_from: Option<usize>,
    pub recovery_diags: Vec<String>,
}

/// Write the boundary checkpoint for `step` — ring entry (+ the plain
/// `run_name` checkpoint at the final boundary) — then fsync the run
/// log (the `RunLogger` durability contract: every row up to a
/// checkpoint is on disk before the checkpoint is trusted). An armed
/// [`faultx::TrainFault`] for this boundary turns the call into the
/// scripted kill instead: before / halfway through / right after the
/// write, surfacing as an [`InjectedCrash`] error.
fn write_boundary_checkpoint(
    t: &LmTrainer,
    rc: &LmRunConfig,
    ring: &CheckpointRing,
    logger: &mut RunLogger,
    step: usize,
    fault: Option<&faultx::TrainFault>,
) -> Result<()> {
    let armed = fault.filter(|f| f.step == step);
    if let Some(f) = armed {
        match f.phase {
            CrashPhase::BeforeCheckpoint => {
                logger.sync()?;
                return Err(InjectedCrash { step, phase: f.phase }.into());
            }
            CrashPhase::MidCheckpointWrite => {
                checkpoint::save_interrupted(
                    ring.dir(),
                    &ring.entry_name(step),
                    &t.checkpoint_tensors(),
                    50,
                )?;
                logger.sync()?;
                return Err(InjectedCrash { step, phase: f.phase }.into());
            }
            CrashPhase::AfterCheckpoint => {}
        }
    }
    let tensors = t.checkpoint_tensors();
    ring.save(step, &tensors).with_context(|| format!("checkpoint boundary {step}"))?;
    if step == rc.steps {
        checkpoint::save(ring.dir(), &rc.run_name, &tensors)
            .with_context(|| format!("final checkpoint `{}`", rc.run_name))?;
    }
    logger.sync()?;
    if let Some(f) = armed {
        return Err(InjectedCrash { step, phase: f.phase }.into());
    }
    Ok(())
}

/// Native next-token pretraining end to end: tokenizer + packed
/// batches from `data`, fwd/bwd through the graph tape, SGD/Adam
/// updates, run logging, periodic checkpoints, exact resume. Returns
/// the standard [`TrainOutcome`] (curve subsampled like the PJRT
/// trainer; with ≤ 50 steps every step is on the curve).
pub fn train_lm_native(rc: &LmRunConfig, pool: &Pool, quiet: bool) -> Result<TrainOutcome> {
    Ok(train_lm_native_run(rc, None, pool, quiet)?.outcome)
}

/// [`train_lm_native`] with an optional armed training fault — the
/// fault-injection entry point the supervisor and `pamm chaos` drive.
/// With `fault: None` this *is* the production run loop; the injection
/// sites cost one comparison per checkpoint boundary.
pub fn train_lm_native_run(
    rc: &LmRunConfig,
    fault: Option<&faultx::TrainFault>,
    pool: &Pool,
    quiet: bool,
) -> Result<LmRunReport> {
    ensure!(rc.steps > 0, "lm train: steps must be > 0");
    let mut t = LmTrainer::new(rc.cfg.clone(), rc.batch, rc.seq, rc.k, rc.opt, rc.seed);
    let ckpt_dir = format!("{}/ckpt", rc.run_dir);
    let ring = CheckpointRing::new(&ckpt_dir, &rc.run_name, rc.keep_last);
    let mut resumed_from = None;
    let mut recovery_diags = Vec::new();
    if rc.resume {
        let (found, diags) = ring.load_latest_good();
        for d in &diags {
            if !quiet {
                println!("recovery: {d}");
            }
        }
        recovery_diags = diags;
        match found {
            Some((_, tensors)) => {
                t.restore_from(tensors)?;
                resumed_from = Some(t.step_no());
            }
            None => {
                // Pre-ring runs left only the plain `run_name`
                // checkpoint; honor it so old run dirs stay resumable.
                if Path::new(&ckpt_dir).join(format!("{}.json", rc.run_name)).exists() {
                    t.resume(&ckpt_dir, &rc.run_name)?;
                    resumed_from = Some(t.step_no());
                }
            }
        }
        if let (Some(s), false) = (resumed_from, quiet) {
            println!("resumed `{}` at step {s}", rc.run_name);
        }
    }
    ensure!(
        t.step_no() <= rc.steps,
        "checkpoint is at step {} but the run asks for {} steps",
        t.step_no(),
        rc.steps
    );
    if t.step_no() == rc.steps {
        // Already complete: nothing to train, nothing to (re)log — and
        // the caller gets an empty curve it must not index blindly.
        // (A kill right after the final ring entry landed can still
        // have lost the plain checkpoint — rewrite it; the state is
        // bit-identical so the overwrite is idempotent.)
        checkpoint::save(&ckpt_dir, &rc.run_name, &t.checkpoint_tensors())?;
        if !quiet {
            println!("run `{}` is already at its final step {} — nothing to do", rc.run_name, rc.steps);
        }
        return Ok(LmRunReport {
            outcome: TrainOutcome {
                run_name: rc.run_name.clone(),
                steps: rc.steps,
                final_loss: f32::NAN,
                final_eval_loss: None,
                final_ppl: None,
                tokens_per_sec: None,
                curve: Vec::new(),
            },
            resumed_from,
            recovery_diags,
        });
    }

    let mut it = BatchIterator::from_seed(rc.cfg.vocab, rc.batch, rc.seq, rc.seed);
    it.skip_batches(t.step_no()); // deterministic stream fast-forward
    // A resumed run appends to the existing log instead of truncating
    // the pre-interruption step history, and drops a resume marker:
    // steps between the last checkpoint and a crash are re-logged after
    // it (training replays them bit-identically; the EMA column
    // restarts from the first replayed loss — it is presentation-only
    // smoothing, not training state).
    let mut logger = if resumed_from.is_some() {
        let mut l = RunLogger::append(&rc.run_dir, &rc.run_name)?;
        l.log_resume(t.step_no())?;
        l
    } else {
        RunLogger::create(&rc.run_dir, &rc.run_name)?
    };
    let mut ema = Ema::new(0.05);
    let mut meter = ThroughputMeter::new(2.min(rc.steps / 4));
    let mut curve = Vec::new();
    let mut last_loss = f32::NAN;

    for s in t.step_no()..rc.steps {
        let b = it.next_batch();
        let loss = t
            .train_step(&b.tokens, pool, None)
            .with_context(|| format!("run `{}` step {s}", rc.run_name))?;
        meter.step(b.n_tokens());
        last_loss = loss;
        let sm = ema.update(loss as f64);
        if s % (rc.steps / 50).max(1) == 0 || s + 1 == rc.steps {
            curve.push((s, loss));
            logger.log_step(s, loss as f64, sm, meter.tokens_per_sec())?;
            if !quiet {
                println!(
                    "step {s:>5}  loss {loss:7.4}  ema {sm:7.4}  ppl {:8.2}  tok/s {}",
                    perplexity(sm),
                    meter
                        .tokens_per_sec()
                        .map(|t| format!("{t:.0}"))
                        .unwrap_or_else(|| "-".into())
                );
            }
        }
        if rc.ckpt_every > 0 && (s + 1) % rc.ckpt_every == 0 && s + 1 < rc.steps {
            write_boundary_checkpoint(&t, rc, &ring, &mut logger, s + 1, fault)?;
        }
    }
    write_boundary_checkpoint(&t, rc, &ring, &mut logger, rc.steps, fault)?;

    let tok_s = meter.tokens_per_sec();
    logger.log_summary(vec![
        ("final_loss", jsonx::num(last_loss as f64)),
        ("steps", jsonx::num(rc.steps as f64)),
        ("layers", jsonx::num(rc.cfg.n_layers as f64)),
        ("k", jsonx::num(rc.k as f64)),
        ("tok_s", tok_s.map(jsonx::num).unwrap_or(jsonx::Value::Null)),
    ])?;

    Ok(LmRunReport {
        outcome: TrainOutcome {
            run_name: rc.run_name.clone(),
            steps: rc.steps,
            final_loss: last_loss,
            final_eval_loss: None,
            final_ppl: None,
            tokens_per_sec: tok_s,
            curve,
        },
        resumed_from,
        recovery_diags,
    })
}

// ---------------------------------------------------------------------------
// The crash supervisor
// ---------------------------------------------------------------------------

/// What a supervised (crash-recovering) run went through on its way
/// to the final [`TrainOutcome`].
#[derive(Debug)]
pub struct SupervisedOutcome {
    pub outcome: TrainOutcome,
    /// Total run-loop launches (1 = no crash fired).
    pub attempts: usize,
    /// Every injected kill that was caught, in firing order.
    pub crashes: Vec<InjectedCrash>,
    /// Step each recovery resumed from (one per successful fallback).
    pub resume_steps: Vec<usize>,
    /// Ring diagnostics: every corrupted/truncated entry skipped, plus
    /// the injected-corruption notes.
    pub recovery_diags: Vec<String>,
}

/// Supervise [`train_lm_native_run`] under a [`faultx::FaultPlan`]:
/// run, catch the injected kill, re-open the ring, resume from the
/// newest checkpoint that verifies, repeat until the run completes.
/// Attempt `i` arms `plan.crashes[i]` (ascending steps, so each kill
/// fires after the previous recovery has replayed past it); if the
/// plan scripts checkpoint corruption, the newest ring entry gets a
/// seeded bit flip before the corresponding recovery — forcing the
/// checksum-detect + fall-back path. A *real* error (not an
/// [`InjectedCrash`]) propagates immediately.
///
/// Because resume is bit-exact and the batch/generator streams are
/// pure functions of `(seed, step)`, the returned outcome is bitwise
/// identical to the crash-free run's — the property `pamm chaos` and
/// `prop_faults.rs` assert at every kill point.
pub fn train_lm_supervised(
    rc: &LmRunConfig,
    plan: &faultx::FaultPlan,
    pool: &Pool,
    quiet: bool,
) -> Result<SupervisedOutcome> {
    let mut rc2 = rc.clone();
    let ckpt_dir = format!("{}/ckpt", rc.run_dir);
    let ring = CheckpointRing::new(&ckpt_dir, &rc.run_name, rc.keep_last);
    let mut crashes: Vec<InjectedCrash> = Vec::new();
    let mut resume_steps = Vec::new();
    let mut recovery_diags = Vec::new();
    // Every armed crash fires at most once, so crashes.len() + 1
    // launches always suffice; the bound exists so a supervisor bug
    // cannot loop forever.
    let max_attempts = plan.crashes.len() + 1;
    for attempt in 0..max_attempts {
        let fault = plan.crashes.get(crashes.len());
        match train_lm_native_run(&rc2, fault, pool, quiet) {
            Ok(rep) => {
                if let Some(s) = rep.resumed_from {
                    resume_steps.push(s);
                }
                recovery_diags.extend(rep.recovery_diags);
                return Ok(SupervisedOutcome {
                    outcome: rep.outcome,
                    attempts: attempt + 1,
                    crashes,
                    resume_steps,
                    recovery_diags,
                });
            }
            Err(e) => {
                let Some(crash) = faultx::injected_crash(&e) else {
                    return Err(e);
                };
                if !quiet {
                    println!("supervisor: caught {crash}; recovering from the ring");
                }
                if plan.corrupt_after_attempt == Some(crashes.len()) {
                    // Scripted bitrot: flip one seeded bit in the
                    // newest committed ring entry (if any) so the
                    // recovery must detect it and fall back.
                    if let Some(&(step, _)) = ring.entries().last() {
                        let mut rng =
                            crate::rngx::Xoshiro256::fold_in(plan.seed, 0xB17F, crashes.len() as u64);
                        let (byte, bit) = faultx::flip_bit_in_file(ring.blob_path(step), &mut rng)?;
                        recovery_diags.push(format!(
                            "injected corruption: flipped bit {bit} of byte {byte} in ring entry step {step}"
                        ));
                    }
                }
                crashes.push(crash);
                rc2.resume = true;
            }
        }
    }
    bail!(
        "supervisor: plan with {} crash(es) did not converge within {max_attempts} attempts",
        plan.crashes.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> LmConfig {
        LmConfig { vocab: 300, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 }
    }

    #[test]
    fn rng_state_words_roundtrip() {
        let s = [0x0123_4567_89AB_CDEFu64, u64::MAX, 0, 0x8000_0000_0000_0001];
        let w = rng_words(s);
        assert_eq!(w.len(), 8);
        assert_eq!(words_to_state(&w).unwrap(), s);
        assert!(words_to_state(&w[..7]).is_err());
    }

    #[test]
    fn lm_training_on_real_batches_reduces_the_loss() {
        let cfg = tiny_cfg();
        let (batch, seq) = (2usize, 24usize);
        let mut t = LmTrainer::new(cfg.clone(), batch, seq, 8, NativeOpt::adam(3e-3), 5);
        let mut it = BatchIterator::from_seed(cfg.vocab, batch, seq, 5);
        let pool = Pool::serial();
        let mut first = 0f32;
        let mut last = 0f32;
        let steps = 25;
        let mut head = Vec::new();
        let mut tail = Vec::new();
        for s in 0..steps {
            let b = it.next_batch();
            let loss = t.train_step(&b.tokens, &pool, None).unwrap();
            if s == 0 {
                first = loss;
            }
            if s < 5 {
                head.push(loss);
            }
            if s >= steps - 5 {
                tail.push(loss);
            }
            last = loss;
        }
        assert!(first.is_finite() && last.is_finite());
        let avg = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!(
            avg(&tail) < avg(&head),
            "LM pretraining must reduce the loss: head {:?} tail {:?}",
            head,
            tail
        );
        assert_eq!(t.step_no(), steps);
    }

    #[test]
    fn checkpoint_roundtrip_restores_exact_state() {
        let dir = std::env::temp_dir().join(format!("pamm_lm_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = tiny_cfg();
        let (batch, seq) = (1usize, 16usize);
        let mut a = LmTrainer::new(cfg.clone(), batch, seq, 4, NativeOpt::adam(1e-3), 9);
        let mut it = BatchIterator::from_seed(cfg.vocab, batch, seq, 9);
        let pool = Pool::serial();
        for _ in 0..3 {
            let b = it.next_batch();
            a.train_step(&b.tokens, &pool, None).unwrap();
        }
        a.save_checkpoint(&dir, "t").unwrap();

        let mut b = LmTrainer::new(cfg.clone(), batch, seq, 4, NativeOpt::adam(1e-3), 9);
        b.resume(&dir, "t").unwrap();
        assert_eq!(b.step_no(), 3);
        for (pa, pb) in a.model.params.iter().zip(&b.model.params) {
            assert_eq!(pa, pb, "params must restore bit-identically");
        }
        let (ma, mb) = (a.moments.as_ref().unwrap(), b.moments.as_ref().unwrap());
        for (sa, sb) in ma.iter().zip(mb) {
            assert_eq!(sa.m, sb.m);
            assert_eq!(sa.v, sb.v);
        }
        assert_eq!(a.rng.state(), b.rng.state(), "generator stream must resume in place");

        // An SGD trainer must refuse an Adam checkpoint…
        let mut c = LmTrainer::new(cfg.clone(), batch, seq, 4, NativeOpt::Sgd { lr: 0.1 }, 9);
        assert!(c.resume(&dir, "t").is_err());
        // …and so must a trainer whose geometry (here k) or optimizer
        // constants differ — either would silently break bit-exact
        // resume.
        let mut d = LmTrainer::new(cfg.clone(), batch, seq, 5, NativeOpt::adam(1e-3), 9);
        assert!(d.resume(&dir, "t").is_err(), "k mismatch must be refused");
        let mut e = LmTrainer::new(cfg.clone(), batch, seq, 4, NativeOpt::adam(2e-3), 9);
        assert!(e.resume(&dir, "t").is_err(), "lr mismatch must be refused");
        let mut f = LmTrainer::new(cfg, batch, seq, 4, NativeOpt::adam(1e-3), 10);
        assert!(f.resume(&dir, "t").is_err(), "seed mismatch must be refused");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn divergent_batch_fails_the_step_and_leaves_state_untouched() {
        let cfg = tiny_cfg();
        let (batch, seq) = (1usize, 12usize);
        let mut t = LmTrainer::new(cfg.clone(), batch, seq, 4, NativeOpt::adam(1e-3), 3);
        let mut it = BatchIterator::from_seed(cfg.vocab, batch, seq, 3);
        let pool = Pool::serial();
        // One healthy step so the moments are non-trivial.
        let b = it.next_batch();
        t.train_step(&b.tokens, &pool, None).unwrap();

        // Craft divergence: a NaN lands in a block weight (the state a
        // diverged update leaves behind); the very next forward must
        // produce a non-finite loss.
        t.model.params[3].data_mut()[0] = f32::NAN; // blk0.wq
        let params_before: Vec<Vec<u32>> = t
            .model
            .params
            .iter()
            .map(|p| p.data().iter().map(|v| v.to_bits()).collect())
            .collect();
        let moments_before: Vec<(Vec<u32>, Vec<u32>)> = t
            .moments
            .as_ref()
            .unwrap()
            .iter()
            .map(|st| {
                (
                    st.m.data().iter().map(|v| v.to_bits()).collect(),
                    st.v.data().iter().map(|v| v.to_bits()).collect(),
                )
            })
            .collect();
        let rng_before = t.rng.state();

        let b = it.next_batch();
        let err = t.train_step(&b.tokens, &pool, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("non-finite loss"), "{msg}");
        assert!(msg.contains("step 2"), "error must name the failing step: {msg}");

        // The guard's whole point: nothing the optimizer owns moved.
        assert_eq!(t.step_no(), 1, "a failed step must not count");
        for (p, before) in t.model.params.iter().zip(&params_before) {
            let now: Vec<u32> = p.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(&now, before, "params must be bitwise untouched");
        }
        for (st, (m, v)) in t.moments.as_ref().unwrap().iter().zip(&moments_before) {
            let mn: Vec<u32> = st.m.data().iter().map(|x| x.to_bits()).collect();
            let vn: Vec<u32> = st.v.data().iter().map(|x| x.to_bits()).collect();
            assert_eq!((&mn, &vn), (m, v), "moments must be bitwise untouched");
        }
        // (The generator stream advanced — sampling happened inside
        // the forward — which is fine: the run is dead either way.)
        assert_ne!(t.rng.state(), rng_before);
    }

    #[test]
    fn grad_guard_names_the_offending_parameter() {
        let names = vec!["emb".to_string(), "blk0.wq".to_string()];
        let good = Mat::zeros(2, 2);
        let mut bad = Mat::zeros(2, 2);
        bad.data_mut()[3] = f32::INFINITY;
        let err = check_finite_grads(&names, &[good.clone(), bad], 7).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("`blk0.wq`[3]"), "{msg}");
        assert!(msg.contains("step 7"), "{msg}");
        assert!(check_finite_grads(&names, &[good.clone(), good], 7).is_ok());
    }

    #[test]
    fn boundaries_cover_periodic_and_final_steps() {
        let rc = |steps: usize, every: usize| LmRunConfig {
            cfg: tiny_cfg(),
            batch: 1,
            seq: 8,
            steps,
            k: 4,
            opt: NativeOpt::adam(1e-3),
            seed: 1,
            ckpt_every: every,
            keep_last: 3,
            run_dir: "/tmp/unused".into(),
            run_name: "unused".into(),
            resume: false,
        };
        assert_eq!(checkpoint_boundaries(&rc(8, 2)), vec![2, 4, 6, 8]);
        assert_eq!(checkpoint_boundaries(&rc(8, 3)), vec![3, 6, 8]);
        assert_eq!(checkpoint_boundaries(&rc(8, 0)), vec![8], "ckpt_every=0 ⇒ final only");
        assert_eq!(checkpoint_boundaries(&rc(4, 4)), vec![4], "no duplicate final boundary");
    }
}
