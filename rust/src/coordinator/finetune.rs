//! Native GLUE-style fine-tuning driver: sequence classification over
//! the pretraining stack, end to end on the native substrates — no
//! artifacts, no PJRT (DESIGN.md §11).
//!
//! [`FtTrainer`] owns a `model::TransformerLM` whose flat parameter
//! vector carries one extra matrix past the LM layout — the
//! `d_model×n_classes` classification head
//! (`model::CLS_HEAD_NAME`, `ParamId == LmConfig::n_params()`) — plus
//! the optimizer state, step counter and generator-sampling RNG,
//! mirroring `coordinator::lm::LmTrainer` exactly so the two trainers
//! share the optimizer update, the divergence guards and the
//! checkpoint schema. [`finetune_native`] is the run loop
//! `pamm finetune --native` drives: a deterministic
//! [`TaskCorpus`] (synthetic by default, a GLUE-style task file when
//! given), a stride train/dev split with no leakage, epoch-shuffled
//! [`LabeledStream`] batches → `forward_classify` → tape backward →
//! update, periodic dev evaluation with integer-exact early stopping,
//! run logging, ring checkpoints and bit-exact resume.
//!
//! # Exact resume
//!
//! The checkpoint carries parameters (head included), Adam moments,
//! the step counter, the generator-RNG words, the geometry fingerprint
//! — extended with the task identity (`n_classes` + a task-name hash),
//! so resuming under a different task is refused like any other
//! geometry change — the optimizer constants, and the early-stopping
//! bookkeeping as **integers** (best dev *hit count*, not a rounded
//! accuracy, so resumed stop decisions compare exactly). The labeled
//! stream fast-forwards by [`LabeledStream::skip_batches`], dev
//! evaluation is a pure function of `(params, dev corpus, seed)`, and
//! every kernel below is bit-identical at any thread count and
//! dispatch level — so an interrupted-and-resumed fine-tuning run is
//! bit-identical, step for step, to an uninterrupted one
//! (`rust/tests/prop_finetune.rs`).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::{self, CheckpointRing};
use crate::coordinator::lm::{
    apply_opt_update, check_finite_grads, opt_words, rng_words, words_to_state, Moments,
};
use crate::coordinator::trainer::NativeOpt;
use crate::data::glue::{self, LabeledBatch, LabeledStream, TaskCorpus, TaskSpec};
use crate::jsonx;
use crate::memory::MemoryLedger;
use crate::metrics::{Ema, RunLogger};
use crate::model::{self, LmConfig, TransformerLM};
use crate::pamm::Eps;
use crate::poolx::Pool;
use crate::rngx::Xoshiro256;
use crate::runtime::HostTensor;
use crate::tensor::kernels::{self, Dispatch};
use crate::tensor::Mat;

/// Checkpoint-key order for a fine-tuning trainer: the LM layout plus
/// the appended classification head.
pub fn ft_param_names(cfg: &LmConfig) -> Vec<String> {
    let mut names = model::param_names(cfg);
    names.push(model::CLS_HEAD_NAME.to_string());
    names
}

/// Stable i32 fingerprint of a task name (part of the checkpoint
/// geometry so resume refuses a task swap).
pub fn task_fingerprint(name: &str) -> i32 {
    name.bytes().fold(0u32, |a, b| a.wrapping_mul(31).wrapping_add(b as u32)) as i32
}

/// Everything one fine-tuning step produced.
#[derive(Debug)]
pub struct FtStepReport {
    pub loss: f32,
    /// Exact saved-for-backward bytes of the step's whole tape.
    pub saved_bytes: usize,
}

/// One dev-set evaluation: integer hits (the early-stopping currency —
/// exact under resume), the task metric on the percent scale, and the
/// raw accuracy.
#[derive(Debug, Clone, Copy)]
pub struct DevEval {
    pub hits: usize,
    pub examples: usize,
    pub score: f64,
    pub accuracy: f64,
}

/// The native fine-tuning trainer: LM + classification head +
/// optimizer + RNG stream. The structural twin of
/// `coordinator::lm::LmTrainer` — same optimizer update, same guards,
/// same checkpoint schema (plus the head tensor and the task-aware
/// geometry fingerprint).
pub struct FtTrainer {
    pub model: TransformerLM,
    pub task: TaskSpec,
    pub batch: usize,
    pub seq: usize,
    /// Generator budget per compression (`k = ⌈r·b⌉` of the paper).
    pub k: usize,
    pub eps: Eps,
    opt: NativeOpt,
    moments: Option<Vec<Moments>>,
    step_no: usize,
    rng: Xoshiro256,
    seed: u64,
    /// Early-stopping bookkeeping, checkpointed as integers:
    /// best dev hit count, the step it was reached, and the number of
    /// evaluations since without improvement.
    best_hits: usize,
    best_step: usize,
    stale_evals: usize,
}

impl FtTrainer {
    /// Deterministic init: LM weights from `seed` (the same init
    /// `LmTrainer::new` produces — a pretrained checkpoint can be
    /// loaded over them via [`FtTrainer::load_lm_params`]), the head
    /// from an independent stream folded with the class count.
    pub fn new(
        cfg: LmConfig,
        task: TaskSpec,
        batch: usize,
        seq: usize,
        k: usize,
        opt: NativeOpt,
        seed: u64,
    ) -> Self {
        let mut model = TransformerLM::new(cfg, seed);
        let dm = model.cfg.d_model();
        let mut head_rng = Xoshiro256::fold_in(seed, 0xC125, task.n_classes as u64);
        model.params.push(Mat::random_normal(dm, task.n_classes, 0.02, &mut head_rng));
        let moments = match opt {
            NativeOpt::Sgd { .. } => None,
            NativeOpt::Adam { .. } => {
                Some(model.params.iter().map(Moments::zeros_like).collect())
            }
        };
        Self {
            model,
            task,
            batch,
            seq,
            k: k.max(1),
            eps: Eps::Inf,
            opt,
            moments,
            step_no: 0,
            rng: Xoshiro256::new(seed ^ 0x9E3779B97F4A7C15),
            seed,
            best_hits: 0,
            best_step: 0,
            stale_evals: 0,
        }
    }

    pub fn step_no(&self) -> usize {
        self.step_no
    }

    pub fn best_dev(&self) -> (usize, usize, usize) {
        (self.best_hits, self.best_step, self.stale_evals)
    }

    /// Overwrite the LM trunk (everything but the head) from a `pamm
    /// train --native` checkpoint's parameter tensors — fine-tuning
    /// from pretrained weights instead of a fresh init.
    pub fn load_lm_params(&mut self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        let map: std::collections::BTreeMap<String, HostTensor> =
            checkpoint::load(dir, name)?.into_iter().collect();
        for (n, p) in model::param_names(&self.model.cfg)
            .iter()
            .zip(self.model.params.iter_mut())
        {
            let t = map.get(n).with_context(|| format!("LM checkpoint missing `{n}`"))?;
            ensure!(
                t.shape() == [p.rows(), p.cols()],
                "LM checkpoint `{n}`: shape {:?} vs model {}x{}",
                t.shape(),
                p.rows(),
                p.cols()
            );
            p.data_mut().copy_from_slice(t.as_f32()?);
        }
        Ok(())
    }

    /// One fine-tuning step on a labeled batch. Fails — with the
    /// parameters, moments and counters untouched — on a non-finite
    /// loss or gradient (the same divergence guards as `LmTrainer`).
    pub fn train_step(
        &mut self,
        lb: &LabeledBatch,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> Result<f32> {
        Ok(self.step_report(kernels::active(), lb, pool, ledger)?.loss)
    }

    /// [`FtTrainer::train_step`] with an explicit dispatch level,
    /// returning the full report (tests, benches).
    pub fn step_report(
        &mut self,
        d: Dispatch,
        lb: &LabeledBatch,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> Result<FtStepReport> {
        ensure!(
            lb.batch == self.batch && lb.seq == self.seq,
            "ft step: batch geometry {}x{} vs trainer {}x{}",
            lb.batch,
            lb.seq,
            self.batch,
            self.seq
        );
        let (loss, tape) = self.model.forward_classify(
            d,
            &lb.tokens,
            &lb.labels,
            lb.batch,
            lb.seq,
            self.k,
            self.eps,
            &mut self.rng,
            pool,
            ledger,
        );
        ensure!(
            loss.is_finite(),
            "non-finite loss ({loss}) at step {}: fine-tuning diverged; \
             parameters and optimizer moments left untouched",
            self.step_no + 1
        );
        let saved_bytes = tape.saved_bytes();
        let res = tape.backward(d, &self.model.params, pool, ledger);
        check_finite_grads(&ft_param_names(&self.model.cfg), &res.params, self.step_no + 1)?;
        self.step_no += 1;
        apply_opt_update(
            self.opt,
            &mut self.model.params,
            self.moments.as_mut(),
            &res.params,
            self.step_no,
        )?;
        Ok(FtStepReport { loss, saved_bytes })
    }

    /// Evaluate on a held-out corpus: fixed-order batches, argmax
    /// predictions (first index wins ties), the task's own metric. A
    /// pure function of `(params, corpus, seed)` — the generator draws
    /// come from a fresh stream folded from the run seed, never from
    /// the training RNG, so evaluation neither perturbs the training
    /// trajectory nor depends on when it runs.
    pub fn evaluate(&self, corpus: &TaskCorpus, pool: &Pool) -> DevEval {
        let d = kernels::active();
        let mut rng = Xoshiro256::fold_in(self.seed, 0xE7A1, self.task.n_classes as u64);
        let (mut preds, mut golds) = (Vec::new(), Vec::new());
        for lb in corpus.eval_batches(self.batch) {
            let logits = self.model.classify_logits(
                d, &lb.tokens, lb.batch, lb.seq, self.k, self.eps, &mut rng, pool,
            );
            for r in 0..lb.batch {
                let row = logits.row(r);
                let mut arg = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[arg] {
                        arg = j;
                    }
                }
                preds.push(arg as i32);
            }
            golds.extend_from_slice(&lb.labels);
        }
        let hits = preds.iter().zip(&golds).filter(|(p, g)| p == g).count();
        DevEval {
            hits,
            examples: golds.len(),
            score: glue::score(&self.task, &preds, &golds),
            accuracy: hits as f64 / golds.len().max(1) as f64,
        }
    }

    /// Record one dev evaluation into the early-stopping state;
    /// returns true when `patience` consecutive evaluations failed to
    /// improve the best hit count (0 disables stopping). Integer
    /// comparisons only — exact under checkpoint/resume.
    pub fn note_eval(&mut self, dev: &DevEval, patience: usize) -> bool {
        if dev.hits > self.best_hits {
            self.best_hits = dev.hits;
            self.best_step = self.step_no;
            self.stale_evals = 0;
        } else {
            self.stale_evals += 1;
        }
        patience > 0 && self.stale_evals >= patience
    }

    // -- checkpointing ------------------------------------------------------

    /// The full trainer state as named tensors — the `LmTrainer`
    /// schema plus the head tensor, the task-aware geometry and the
    /// integer early-stopping words.
    pub fn checkpoint_tensors(&self) -> Vec<(String, HostTensor)> {
        let names = ft_param_names(&self.model.cfg);
        let mut tensors: Vec<(String, HostTensor)> = Vec::with_capacity(
            self.model.params.len() * if self.moments.is_some() { 3 } else { 1 } + 5,
        );
        let as_tensor =
            |m: &Mat| HostTensor::f32(vec![m.rows(), m.cols()], m.data().to_vec());
        for (n, p) in names.iter().zip(&self.model.params) {
            tensors.push((n.clone(), as_tensor(p)));
        }
        if let Some(ms) = &self.moments {
            for (n, st) in names.iter().zip(ms) {
                tensors.push((format!("opt_m.{n}"), as_tensor(&st.m)));
                tensors.push((format!("opt_v.{n}"), as_tensor(&st.v)));
            }
        }
        tensors.push(("meta.step".into(), HostTensor::i32(vec![1], vec![self.step_no as i32])));
        tensors.push(("meta.rng".into(), HostTensor::i32(vec![8], rng_words(self.rng.state()))));
        tensors.push(("meta.geom".into(), HostTensor::i32(vec![7], self.geom_words())));
        tensors.push(("meta.opt".into(), HostTensor::f32(vec![5], opt_words(self.opt))));
        tensors.push((
            "meta.dev".into(),
            HostTensor::i32(
                vec![3],
                vec![self.best_hits as i32, self.best_step as i32, self.stale_evals as i32],
            ),
        ));
        tensors
    }

    /// Crash-safe save under `dir/name.{bin,json}`.
    pub fn save_checkpoint(&self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        checkpoint::save(dir, name, &self.checkpoint_tensors())
    }

    pub fn resume(&mut self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        let loaded = checkpoint::load(dir, name)?;
        self.restore_from(loaded)
    }

    /// Restore from already-loaded checkpoint tensors, refusing any
    /// geometry / task / optimizer mismatch (the bit-exact-resume
    /// contract of `LmTrainer::restore_from`, task-extended).
    pub fn restore_from(&mut self, loaded: Vec<(String, HostTensor)>) -> Result<()> {
        let map: std::collections::BTreeMap<String, HostTensor> = loaded.into_iter().collect();
        let names = ft_param_names(&self.model.cfg);
        let restore = |dst: &mut Mat, key: &str| -> Result<()> {
            let t = map.get(key).with_context(|| format!("checkpoint missing `{key}`"))?;
            ensure!(
                t.shape() == [dst.rows(), dst.cols()],
                "checkpoint `{key}`: shape {:?} vs model {}x{}",
                t.shape(),
                dst.rows(),
                dst.cols()
            );
            dst.data_mut().copy_from_slice(t.as_f32()?);
            Ok(())
        };
        for (n, p) in names.iter().zip(self.model.params.iter_mut()) {
            restore(p, n)?;
        }
        match &mut self.moments {
            Some(ms) => {
                ensure!(
                    map.contains_key(&format!("opt_m.{}", names[0])),
                    "checkpoint has no Adam moments but the trainer uses Adam"
                );
                for (n, st) in names.iter().zip(ms.iter_mut()) {
                    restore(&mut st.m, &format!("opt_m.{n}"))?;
                    restore(&mut st.v, &format!("opt_v.{n}"))?;
                }
            }
            None => {
                if map.contains_key(&format!("opt_m.{}", names[0])) {
                    bail!("checkpoint carries Adam moments but the trainer uses SGD");
                }
            }
        }
        let geom = map.get("meta.geom").context("checkpoint missing `meta.geom`")?;
        let g = geom.as_i32()?;
        let want_geom = self.geom_words();
        ensure!(
            g == &want_geom[..],
            "checkpoint was fine-tuned with batch/seq/k/seed/task = {g:?}, trainer uses \
             {want_geom:?} — resuming would silently diverge from the original run"
        );
        let opt = map.get("meta.opt").context("checkpoint missing `meta.opt`")?;
        let want = opt_words(self.opt);
        let got = opt.as_f32()?;
        ensure!(
            got.iter().map(|v| v.to_bits()).eq(want.iter().map(|v| v.to_bits())),
            "checkpoint optimizer {got:?} differs from the trainer's {want:?}"
        );
        let step = map.get("meta.step").context("checkpoint missing `meta.step`")?;
        self.step_no = step.as_i32()?[0].max(0) as usize;
        let words = map.get("meta.rng").context("checkpoint missing `meta.rng`")?;
        self.rng = Xoshiro256::from_state(words_to_state(words.as_i32()?)?);
        let dev = map.get("meta.dev").context("checkpoint missing `meta.dev`")?;
        let dw = dev.as_i32()?;
        ensure!(dw.len() == 3, "meta.dev: expected 3 words, got {}", dw.len());
        self.best_hits = dw[0].max(0) as usize;
        self.best_step = dw[1].max(0) as usize;
        self.stale_evals = dw[2].max(0) as usize;
        Ok(())
    }

    /// `[batch, seq, k, seed_lo, seed_hi, n_classes, task_hash]` — the
    /// geometry fingerprint a checkpoint must match to be resumable.
    fn geom_words(&self) -> Vec<i32> {
        vec![
            self.batch as i32,
            self.seq as i32,
            self.k as i32,
            (self.seed & 0xFFFF_FFFF) as u32 as i32,
            (self.seed >> 32) as u32 as i32,
            self.task.n_classes as i32,
            task_fingerprint(self.task.name),
        ]
    }
}

// ---------------------------------------------------------------------------
// The run loop (`pamm finetune --native`)
// ---------------------------------------------------------------------------

/// Run configuration for one native fine-tuning run.
#[derive(Debug, Clone)]
pub struct FtRunConfig {
    pub cfg: LmConfig,
    pub task: TaskSpec,
    pub batch: usize,
    pub seq: usize,
    /// Optimizer-step budget (early stopping may finish sooner).
    pub steps: usize,
    pub k: usize,
    pub opt: NativeOpt,
    pub seed: u64,
    /// Synthetic corpus size (ignored when `task_file` is given).
    pub corpus_examples: usize,
    /// Train/dev stride: every `dev_every`-th example is dev (≥ 2).
    pub dev_every: usize,
    /// Dev evaluation every N steps (0 = final only).
    pub eval_every: usize,
    /// Early stop after N consecutive non-improving evals (0 = off).
    pub patience: usize,
    /// GLUE-style pre-tokenized task file; None ⇒ synthetic corpus.
    pub task_file: Option<String>,
    /// Checkpoint every N optimizer steps (0 = only the final one).
    pub ckpt_every: usize,
    pub keep_last: usize,
    pub run_dir: String,
    pub run_name: String,
    pub resume: bool,
}

/// What a fine-tuning run produced.
#[derive(Debug)]
pub struct FtOutcome {
    pub run_name: String,
    /// Steps actually trained to (< the budget if stopped early).
    pub steps: usize,
    pub final_loss: f32,
    /// Final dev evaluation (always present — the dev pass is pure).
    pub dev: DevEval,
    /// Best dev hit count seen and the step it was reached at.
    pub best_hits: usize,
    pub best_step: usize,
    pub stopped_early: bool,
    pub curve: Vec<(usize, f32)>,
}

/// Build the run's corpora: the full universe (synthetic fallback or
/// task file) and its deterministic train/dev split.
pub fn build_corpora(rc: &FtRunConfig) -> Result<(TaskCorpus, TaskCorpus)> {
    let corpus = TaskCorpus::load_or_synthetic(
        rc.task.clone(),
        rc.cfg.vocab,
        rc.seq,
        rc.corpus_examples,
        rc.seed,
        rc.task_file.as_deref(),
    )?;
    ensure!(
        corpus.examples.len() / rc.dev_every.max(2) >= 1,
        "corpus of {} examples leaves no dev split at stride {}",
        corpus.examples.len(),
        rc.dev_every
    );
    Ok(corpus.split(rc.dev_every.max(2)))
}

/// Native fine-tuning end to end: deterministic labeled corpus →
/// train/dev split → epoch-shuffled stream → classification fwd/bwd →
/// SGD/Adam, with periodic dev evaluation, integer-exact early
/// stopping, run logging, ring checkpoints and bit-exact resume.
pub fn finetune_native(rc: &FtRunConfig, pool: &Pool, quiet: bool) -> Result<FtOutcome> {
    ensure!(rc.steps > 0, "finetune: steps must be > 0");
    let (train_c, dev_c) = build_corpora(rc)?;
    ensure!(
        train_c.examples.len() >= rc.batch,
        "train split of {} examples cannot fill a batch of {}",
        train_c.examples.len(),
        rc.batch
    );
    let mut t =
        FtTrainer::new(rc.cfg.clone(), rc.task.clone(), rc.batch, rc.seq, rc.k, rc.opt, rc.seed);
    let ckpt_dir = format!("{}/ckpt", rc.run_dir);
    let ring = CheckpointRing::new(&ckpt_dir, &rc.run_name, rc.keep_last);
    let mut resumed_from = None;
    if rc.resume {
        let (found, diags) = ring.load_latest_good();
        for d in &diags {
            if !quiet {
                println!("recovery: {d}");
            }
        }
        match found {
            Some((_, tensors)) => {
                t.restore_from(tensors)?;
                resumed_from = Some(t.step_no());
            }
            None => {
                if Path::new(&ckpt_dir).join(format!("{}.json", rc.run_name)).exists() {
                    t.resume(&ckpt_dir, &rc.run_name)?;
                    resumed_from = Some(t.step_no());
                }
            }
        }
        if let (Some(s), false) = (resumed_from, quiet) {
            println!("resumed `{}` at step {s}", rc.run_name);
        }
    }
    ensure!(
        t.step_no() <= rc.steps,
        "checkpoint is at step {} but the run asks for {} steps",
        t.step_no(),
        rc.steps
    );
    if t.step_no() == rc.steps {
        let dev = t.evaluate(&dev_c, pool);
        if !quiet {
            println!(
                "run `{}` is already at its final step {} — nothing to do",
                rc.run_name, rc.steps
            );
        }
        let (best_hits, best_step, _) = t.best_dev();
        return Ok(FtOutcome {
            run_name: rc.run_name.clone(),
            steps: rc.steps,
            final_loss: f32::NAN,
            dev,
            best_hits,
            best_step,
            stopped_early: false,
            curve: Vec::new(),
        });
    }

    let mut stream = LabeledStream::new(train_c, rc.batch, rc.seed);
    stream.skip_batches(t.step_no());
    let mut logger = if resumed_from.is_some() {
        let mut l = RunLogger::append(&rc.run_dir, &rc.run_name)?;
        l.log_resume(t.step_no())?;
        l
    } else {
        RunLogger::create(&rc.run_dir, &rc.run_name)?
    };
    let mut ema = Ema::new(0.05);
    let mut curve = Vec::new();
    let mut last_loss = f32::NAN;
    let mut stopped_early = false;

    for s in t.step_no()..rc.steps {
        let lb = stream.next_batch();
        let loss = t
            .train_step(&lb, pool, None)
            .with_context(|| format!("run `{}` step {s}", rc.run_name))?;
        last_loss = loss;
        let sm = ema.update(loss as f64);
        if s % (rc.steps / 50).max(1) == 0 || s + 1 == rc.steps {
            curve.push((s, loss));
            logger.log_step(s, loss as f64, sm, None)?;
            if !quiet {
                println!("step {s:>5}  loss {loss:7.4}  ema {sm:7.4}");
            }
        }
        let at_eval = rc.eval_every > 0 && (s + 1) % rc.eval_every == 0 && s + 1 < rc.steps;
        if at_eval {
            let dev = t.evaluate(&dev_c, pool);
            let stop = t.note_eval(&dev, rc.patience);
            if !quiet {
                println!(
                    "  dev @ step {}: {}/{} ({:.1}% acc, {} {:.2})",
                    s + 1,
                    dev.hits,
                    dev.examples,
                    100.0 * dev.accuracy,
                    metric_name(&rc.task),
                    dev.score
                );
            }
            if stop {
                stopped_early = true;
            }
        }
        if rc.ckpt_every > 0 && (s + 1) % rc.ckpt_every == 0 && s + 1 < rc.steps {
            let tensors = t.checkpoint_tensors();
            ring.save(s + 1, &tensors)
                .with_context(|| format!("checkpoint boundary {}", s + 1))?;
            logger.sync()?;
        }
        if stopped_early {
            break;
        }
    }
    // Final checkpoint at wherever the loop stopped (budget or early
    // stop) — ring entry + the plain `run_name` checkpoint.
    let tensors = t.checkpoint_tensors();
    ring.save(t.step_no(), &tensors).context("final ring checkpoint")?;
    checkpoint::save(&ckpt_dir, &rc.run_name, &tensors)
        .with_context(|| format!("final checkpoint `{}`", rc.run_name))?;
    logger.sync()?;

    let dev = t.evaluate(&dev_c, pool);
    t.note_eval(&dev, 0);
    let (best_hits, best_step, _) = t.best_dev();
    logger.log_summary(vec![
        ("final_loss", jsonx::num(last_loss as f64)),
        ("steps", jsonx::num(t.step_no() as f64)),
        ("k", jsonx::num(rc.k as f64)),
        ("dev_hits", jsonx::num(dev.hits as f64)),
        ("dev_examples", jsonx::num(dev.examples as f64)),
        ("dev_score", jsonx::num(dev.score)),
        ("stopped_early", jsonx::num(if stopped_early { 1.0 } else { 0.0 })),
    ])?;

    Ok(FtOutcome {
        run_name: rc.run_name.clone(),
        steps: t.step_no(),
        final_loss: last_loss,
        dev,
        best_hits,
        best_step,
        stopped_early,
        curve,
    })
}

/// Human name of a task's metric (report lines).
pub fn metric_name(task: &TaskSpec) -> &'static str {
    match task.metric {
        glue::Metric::Accuracy => "accuracy",
        glue::Metric::F1 => "F1",
        glue::Metric::Matthews => "Matthews",
        glue::Metric::Pearson => "Pearson",
    }
}

/// Look a task up by (case-insensitive) name across the GLUE stand-in
/// suite and the AID task.
pub fn find_task(name: &str) -> Result<TaskSpec> {
    glue::glue_suite()
        .into_iter()
        .find(|t| t.name.eq_ignore_ascii_case(name))
        .or_else(|| name.eq_ignore_ascii_case("aid").then(glue::aid_task))
        .with_context(|| {
            format!(
                "unknown task `{name}` (tasks: {}, AID)",
                glue::glue_suite()
                    .iter()
                    .map(|t| t.name)
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> LmConfig {
        LmConfig { vocab: 300, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 }
    }

    fn tiny_rc(dir: &str, steps: usize) -> FtRunConfig {
        FtRunConfig {
            cfg: tiny_cfg(),
            task: find_task("SST2").unwrap(),
            batch: 4,
            seq: 16,
            steps,
            k: 8,
            opt: NativeOpt::adam(2e-3),
            seed: 11,
            corpus_examples: 64,
            dev_every: 4,
            eval_every: 0,
            patience: 0,
            task_file: None,
            ckpt_every: 0,
            keep_last: 2,
            run_dir: dir.to_string(),
            run_name: "ft_test".into(),
            resume: false,
        }
    }

    #[test]
    fn finetuning_reduces_the_loss_and_reports_dev() {
        let dir = std::env::temp_dir().join(format!("pamm_ft_run_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let rc = tiny_rc(dir.to_str().unwrap(), 30);
        let pool = Pool::serial();
        let out = finetune_native(&rc, &pool, true).unwrap();
        assert_eq!(out.steps, 30);
        assert!(out.final_loss.is_finite());
        let head: f32 = out.curve.iter().take(5).map(|&(_, l)| l).sum::<f32>() / 5.0;
        let tail: f32 =
            out.curve.iter().rev().take(5).map(|&(_, l)| l).sum::<f32>() / 5.0;
        assert!(tail < head, "fine-tuning must reduce the loss: {head} -> {tail}");
        assert!(out.dev.examples > 0 && out.dev.hits <= out.dev.examples);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_roundtrip_refuses_mismatches() {
        let dir = std::env::temp_dir().join(format!("pamm_ft_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let task = find_task("SST2").unwrap();
        let corpus = TaskCorpus::synthetic(task.clone(), 300, 12, 16, 7);
        let mut stream = LabeledStream::new(corpus, 2, 7);
        let mut a = FtTrainer::new(tiny_cfg(), task.clone(), 2, 12, 4, NativeOpt::adam(1e-3), 7);
        let pool = Pool::serial();
        for _ in 0..3 {
            let lb = stream.next_batch();
            a.train_step(&lb, &pool, None).unwrap();
        }
        a.save_checkpoint(&dir, "t").unwrap();

        let mut b = FtTrainer::new(tiny_cfg(), task.clone(), 2, 12, 4, NativeOpt::adam(1e-3), 7);
        b.resume(&dir, "t").unwrap();
        assert_eq!(b.step_no(), 3);
        for (pa, pb) in a.model.params.iter().zip(&b.model.params) {
            assert_eq!(pa, pb, "params (head included) must restore bit-identically");
        }
        assert_eq!(a.rng.state(), b.rng.state());

        // A different task (even with the same class count) must be
        // refused — the corpus behind the stream would silently swap.
        let rte = find_task("RTE").unwrap();
        let mut c = FtTrainer::new(tiny_cfg(), rte, 2, 12, 4, NativeOpt::adam(1e-3), 7);
        assert!(c.resume(&dir, "t").is_err(), "task swap must be refused");
        let mut d = FtTrainer::new(tiny_cfg(), task.clone(), 2, 12, 5, NativeOpt::adam(1e-3), 7);
        assert!(d.resume(&dir, "t").is_err(), "k mismatch must be refused");
        let mut e = FtTrainer::new(tiny_cfg(), task, 2, 12, 4, NativeOpt::Sgd { lr: 0.1 }, 7);
        assert!(e.resume(&dir, "t").is_err(), "optimizer mismatch must be refused");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn early_stopping_is_integer_exact() {
        let task = find_task("SST2").unwrap();
        let mut t = FtTrainer::new(tiny_cfg(), task, 2, 12, 4, NativeOpt::adam(1e-3), 7);
        let mk = |hits| DevEval { hits, examples: 10, score: 0.0, accuracy: 0.0 };
        assert!(!t.note_eval(&mk(5), 2)); // first eval sets the best
        t.step_no = 1;
        assert!(!t.note_eval(&mk(5), 2)); // stale 1
        assert!(t.note_eval(&mk(4), 2), "two stale evals at patience 2 must stop");
        assert!(!t.note_eval(&mk(6), 2), "an improvement resets staleness");
        assert_eq!(t.best_dev().0, 6);
    }

    #[test]
    fn task_lookup_and_fingerprint() {
        assert_eq!(find_task("sst2").unwrap().name, "SST2");
        assert_eq!(find_task("AID").unwrap().n_classes, 30);
        assert!(find_task("nope").is_err());
        assert_ne!(task_fingerprint("SST2"), task_fingerprint("RTE"));
    }
}
