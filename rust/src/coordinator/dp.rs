//! Elastic fault-tolerant data-parallel LM training (DESIGN.md §10).
//!
//! [`DpTrainer`] runs `R` *logical* workers in fixed rank order over
//! one shared model replica: each rank owns a deterministic
//! interleaved shard of the global batch stream
//! ([`data::BatchShard`](crate::data::BatchShard)) and its own slice
//! of the generator-RNG stream, gradients accumulate microbatch by
//! microbatch in **global stream order** (rank-order all-reduce = the
//! partition-only rule the kernels already obey), and one optimizer
//! update fires per step. Because the reduce order is the global
//! microbatch order regardless of how microbatches are assigned to
//! ranks, the loss trajectory is a function of the *effective batch*
//! `E = R·A` alone: bit-identical at any physical thread count and
//! SIMD level, identical across `R × A` factorizations of the same
//! `E`, and `R = 1, A = 1` bit-matches the single-process
//! [`LmTrainer`](crate::coordinator::lm::LmTrainer).
//!
//! # RNG partitioning
//!
//! There is exactly one logical generator stream — the same
//! `seed ^ golden-ratio` stream the single-process trainer owns —
//! advanced in global microbatch order. The model forward draws
//! exactly two `sample_generators` calls per block per microbatch, so
//! a rank fast-forwards past other ranks' slices by *replaying* those
//! draws and discarding them ([`skip_microbatch_draws`]); replay (not
//! arithmetic jump-ahead) stays exact even though rejection sampling
//! consumes a variable number of raw RNG words.
//!
//! # Sharded checkpoints
//!
//! A boundary checkpoint is one ring entry of `R` shard blobs — shard
//! `r` carries every parameter (and Adam moment) with index
//! `i mod R == r`, plus that rank's RNG state and shard cursor — and a
//! tiny manifest whose atomic rename commits the entry only after all
//! shards fsync ([`CheckpointRing::save_sharded`]). Recovery falls
//! back past any entry with a missing or corrupt shard
//! ([`CheckpointRing::load_latest_good_sharded`]), and
//! [`train_lm_dp_supervised`] proves the recovered trajectory bitwise
//! identical to the uninterrupted run at every (rank × boundary ×
//! phase) kill point (`rust/tests/prop_dp.rs`, `pamm chaos --dp`).
//!
//! # Elastic degradation
//!
//! A straggler that misses more deadline polls than the stall budget
//! is declared dead. Non-elastic runs fail with a diagnostic; under
//! `--elastic` the fleet drops the rank immediately (interim steps
//! average over the survivors) and at the next checkpoint boundary
//! **re-shards**: the global stream is re-interleaved across the
//! survivors from the boundary's cursor — the dead rank's *future*
//! data is redistributed, not lost — and the event is logged as
//! `{"event":"reshard"}`. From that row on the determinism contract is
//! restated as a function of the surviving worker set: same survivors,
//! same boundary ⇒ the same bit-exact continuation.

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::{self, CheckpointRing};
use crate::coordinator::lm::{
    apply_opt_update, check_finite_grads, opt_words, rng_words, words_to_state, LmRunConfig,
    Moments,
};
use crate::coordinator::trainer::{NativeOpt, TrainOutcome};
use crate::data::BatchShard;
use crate::faultx::{self, CrashPhase, InjectedCrash, WorkerKill, WorkerStall};
use crate::jsonx;
use crate::memory::MemoryLedger;
use crate::metrics::{perplexity, Ema, RunLogger, ThroughputMeter};
use crate::model::{self, LmConfig, TransformerLM};
use crate::pamm::{self, Eps};
use crate::poolx::Pool;
use crate::rngx::Xoshiro256;
use crate::runtime::HostTensor;
use crate::tensor::kernels::{self, Dispatch};
use crate::tensor::Mat;

/// The shared generator stream the whole fleet partitions — identical
/// to the single-process trainer's stream, which is what makes
/// `R = 1` a bit-match.
fn base_stream(seed: u64) -> Xoshiro256 {
    Xoshiro256::new(seed ^ 0x9E3779B97F4A7C15)
}

/// Fast-forward `rng` past `micro` microbatches' worth of generator
/// draws by replaying them: the model forward draws exactly two
/// `sample_generators(rng, tokens, k)` per block (attention, then
/// MLP), so replay-and-discard advances the stream to exactly where a
/// real forward would leave it — robust to the variable raw-word
/// consumption of rejection sampling inside the RNG.
pub(crate) fn skip_microbatch_draws(
    rng: &mut Xoshiro256,
    micro: usize,
    n_layers: usize,
    tokens: usize,
    k: usize,
) {
    let k = k.clamp(1, tokens);
    for _ in 0..micro {
        for _ in 0..n_layers {
            let _ = pamm::sample_generators(rng, tokens, k);
            let _ = pamm::sample_generators(rng, tokens, k);
        }
    }
}

fn split_words(n: usize) -> Vec<i32> {
    vec![(n as u64 & 0xFFFF_FFFF) as u32 as i32, ((n as u64) >> 32) as u32 as i32]
}

fn join_words(w: &[i32]) -> Result<usize> {
    ensure!(w.len() == 2, "expected 2 cursor words, got {}", w.len());
    let lo = w[0] as u32 as u64;
    let hi = w[1] as u32 as u64;
    Ok(((hi << 32) | lo) as usize)
}

/// One logical worker: its slice of the generator stream, its batch
/// shard, and whether it is still part of the fleet. Dead workers keep
/// their slot (the interleave pattern stays static) until the next
/// checkpoint boundary reshards them away.
struct DpWorker {
    rank: usize,
    rng: Xoshiro256,
    shard: BatchShard,
    alive: bool,
}

/// Everything one data-parallel step produced.
#[derive(Debug)]
pub struct DpStepReport {
    /// Mean microbatch loss over the live fleet.
    pub loss: f32,
    /// Aggregate saved-for-backward bytes across all microbatch tapes.
    pub saved_bytes: usize,
    /// The same bytes per worker: `(rank, bytes over its A
    /// microbatches)` — the `pamm ledger --workers` table rows.
    pub per_worker_saved: Vec<(usize, usize)>,
    /// Microbatches that actually contributed (`live workers × accum`;
    /// shrinks between a death and the reshard boundary).
    pub e_active: usize,
}

/// One elastic degradation event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DpReshard {
    /// Checkpoint boundary (completed-step count) the reshard ran at.
    pub step: usize,
    pub dead_rank: usize,
    /// Surviving worker count after the re-interleave.
    pub workers: usize,
}

/// The data-parallel trainer: one model replica, `R` logical workers.
pub struct DpTrainer {
    pub model: TransformerLM,
    pub batch: usize,
    pub seq: usize,
    pub k: usize,
    pub eps: Eps,
    opt: NativeOpt,
    moments: Option<Vec<Moments>>,
    step_no: usize,
    seed: u64,
    accum: usize,
    workers: Vec<DpWorker>,
    /// Global-stream batches consumed *or dropped* before the current
    /// step — advances by `slots × accum` per optimizer step and
    /// anchors the elastic re-interleave.
    origin: usize,
}

impl DpTrainer {
    /// Deterministic init: same model weights as
    /// [`LmTrainer::new`](crate::coordinator::lm::LmTrainer::new)
    /// under the same seed; worker `r`'s generator stream is the
    /// shared stream fast-forwarded past ranks `0..r`'s first-step
    /// microbatch draws.
    pub fn new(
        cfg: LmConfig,
        batch: usize,
        seq: usize,
        k: usize,
        opt: NativeOpt,
        seed: u64,
        workers: usize,
        accum: usize,
    ) -> Self {
        assert!(workers >= 1 && accum >= 1, "dp trainer: workers/accum must be >= 1");
        let model = TransformerLM::new(cfg, seed);
        let moments = match opt {
            NativeOpt::Sgd { .. } => None,
            NativeOpt::Adam { .. } => {
                Some(model.params.iter().map(Moments::zeros_like).collect())
            }
        };
        let k = k.max(1);
        let tokens = batch * seq;
        let (n_layers, vocab) = (model.cfg.n_layers, model.cfg.vocab);
        let mut stream = base_stream(seed);
        let mut ws = Vec::with_capacity(workers);
        for r in 0..workers {
            ws.push(DpWorker {
                rank: r,
                rng: Xoshiro256::from_state(stream.state()),
                shard: BatchShard::new(vocab, batch, seq, seed, r, workers, accum),
                alive: true,
            });
            skip_microbatch_draws(&mut stream, accum, n_layers, tokens, k);
        }
        Self {
            model,
            batch,
            seq,
            k,
            eps: Eps::Inf,
            opt,
            moments,
            step_no: 0,
            seed,
            accum,
            workers: ws,
            origin: 0,
        }
    }

    pub fn step_no(&self) -> usize {
        self.step_no
    }

    /// Worker slots (live + dead-awaiting-reshard).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    pub fn live_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    pub fn is_live(&self, rank: usize) -> bool {
        self.workers.iter().any(|w| w.rank == rank && w.alive)
    }

    pub fn accum(&self) -> usize {
        self.accum
    }

    /// One data-parallel optimizer step with the active dispatch.
    pub fn train_step(&mut self, pool: &Pool, ledger: Option<&MemoryLedger>) -> Result<DpStepReport> {
        self.step_report(kernels::active(), pool, ledger)
    }

    /// [`DpTrainer::train_step`] with an explicit dispatch level.
    ///
    /// Ranks run in fixed rank order (the repo's `poolx` forbids
    /// nested parallelism, so each microbatch's kernels parallelize
    /// internally); gradients accumulate in global microbatch order
    /// and are scaled by `1/E` only when `E > 1`, so the `E = 1` path
    /// is bit-for-bit the single-process step. Fails — with
    /// parameters, moments and step counter untouched — on a
    /// non-finite loss or gradient, naming the offending worker.
    pub fn step_report(
        &mut self,
        d: Dispatch,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> Result<DpStepReport> {
        let (batch, seq) = (self.batch, self.seq);
        let live = self.live_workers();
        ensure!(live >= 1, "dp step: no live workers");
        let e_active = live * self.accum;
        let names = model::param_names(&self.model.cfg);
        let step = self.step_no + 1;
        let accum = self.accum;
        let mut acc: Option<Vec<Mat>> = None;
        let mut loss_sum: Option<f32> = None;
        let mut per_worker_saved = Vec::with_capacity(live);
        let mut saved_total = 0usize;
        for w in self.workers.iter_mut().filter(|w| w.alive) {
            let mut w_saved = 0usize;
            for _ in 0..accum {
                let b = w.shard.next_batch();
                let mut inputs = Vec::with_capacity(batch * seq);
                let mut targets = Vec::with_capacity(batch * seq);
                for r in 0..batch {
                    let row = &b.tokens[r * (seq + 1)..(r + 1) * (seq + 1)];
                    inputs.extend_from_slice(&row[..seq]);
                    targets.extend_from_slice(&row[1..]);
                }
                let (loss, tape) = self.model.forward(
                    d,
                    &inputs,
                    &targets,
                    batch,
                    seq,
                    self.k,
                    self.eps,
                    &mut w.rng,
                    pool,
                    ledger,
                );
                ensure!(
                    loss.is_finite(),
                    "non-finite loss ({loss}) on worker {} at step {step}: training diverged; \
                     parameters and optimizer moments left untouched",
                    w.rank
                );
                w_saved += tape.saved_bytes();
                let res = tape.backward(d, &self.model.params, pool, ledger);
                check_finite_grads(&names, &res.params, step)
                    .with_context(|| format!("worker {}", w.rank))?;
                // Global-order accumulation: the first microbatch's
                // gradients are *moved in*, not added to zeros — a
                // `0.0 + g` pass could flip -0.0 signs and break the
                // E = 1 bit-match with the single-process trainer.
                match &mut acc {
                    None => acc = Some(res.params),
                    Some(a) => {
                        for (av, g) in a.iter_mut().zip(&res.params) {
                            for (x, &y) in av.data_mut().iter_mut().zip(g.data()) {
                                *x += y;
                            }
                        }
                    }
                }
                loss_sum = Some(match loss_sum {
                    None => loss,
                    Some(l) => l + loss,
                });
            }
            per_worker_saved.push((w.rank, w_saved));
            saved_total += w_saved;
        }
        let mut grads = acc.context("dp step produced no microbatches (invariant broken)")?;
        let mut loss = loss_sum.context("dp step produced no loss (invariant broken)")?;
        if e_active > 1 {
            let scale = 1.0 / e_active as f32;
            for g in &mut grads {
                for v in g.data_mut() {
                    *v *= scale;
                }
            }
            loss *= scale;
        }
        self.step_no += 1;
        apply_opt_update(self.opt, &mut self.model.params, self.moments.as_mut(), &grads, self.step_no)?;
        // Fast-forward every live worker's generator stream past the
        // other slots' draws (dead slots included — the interleave
        // pattern stays static until the reshard boundary), landing
        // each rank on its slice of the next step.
        let width = self.workers.len() * accum;
        let (tokens, k, n_layers) = (batch * seq, self.k, self.model.cfg.n_layers);
        for w in self.workers.iter_mut().filter(|w| w.alive) {
            skip_microbatch_draws(&mut w.rng, width - accum, n_layers, tokens, k);
        }
        self.origin += width;
        Ok(DpStepReport { loss, saved_bytes: saved_total, per_worker_saved, e_active })
    }

    /// Declare `rank` dead (straggler past the stall budget). Its slot
    /// keeps occupying the interleave — its microbatches are dropped,
    /// interim steps average over the survivors — until
    /// [`DpTrainer::reshard`] at the next checkpoint boundary.
    pub fn mark_dead(&mut self, rank: usize) -> Result<()> {
        let live = self.live_workers();
        let w = self
            .workers
            .iter_mut()
            .find(|w| w.rank == rank)
            .with_context(|| format!("mark_dead: no worker with rank {rank}"))?;
        ensure!(w.alive, "mark_dead: worker {rank} is already dead");
        ensure!(
            live >= 2,
            "mark_dead: worker {rank} is the last live worker — nothing to degrade onto"
        );
        w.alive = false;
        Ok(())
    }

    /// Re-interleave the global stream across the survivors from the
    /// current cursor (`origin`): survivors become ranks `0..R′`, each
    /// with a fresh shard and a generator stream reconstructed from
    /// the shared base stream — the dead rank's future data is
    /// redistributed, not lost. Returns the new worker count.
    /// Checkpoints are only ever written *after* a pending reshard, so
    /// a sharded entry never contains a dead worker.
    pub fn reshard(&mut self) -> Result<usize> {
        let live = self.live_workers();
        ensure!(live >= 1, "reshard: no survivors");
        ensure!(live < self.workers.len(), "reshard: no dead workers to drop");
        let (batch, seq, k, accum, seed) = (self.batch, self.seq, self.k, self.accum, self.seed);
        let (n_layers, vocab) = (self.model.cfg.n_layers, self.model.cfg.vocab);
        let tokens = batch * seq;
        // Rewind-by-replay: xoshiro cannot step backwards and the
        // survivors' streams are already ahead, so rebuild the shared
        // stream from scratch and fast-forward it to `origin`. O(origin)
        // replayed draws — trivial next to one training step.
        let mut stream = base_stream(seed);
        skip_microbatch_draws(&mut stream, self.origin, n_layers, tokens, k);
        let mut ws = Vec::with_capacity(live);
        for slot in 0..live {
            ws.push(DpWorker {
                rank: slot,
                rng: Xoshiro256::from_state(stream.state()),
                shard: BatchShard::at_origin(vocab, batch, seq, seed, slot, live, accum, self.origin),
                alive: true,
            });
            skip_microbatch_draws(&mut stream, accum, n_layers, tokens, k);
        }
        self.workers = ws;
        Ok(live)
    }

    /// `[batch, seq, k, seed_lo, seed_hi, accum]` — the geometry
    /// fingerprint every shard must match to be resumable (worker
    /// count is *not* geometry: it lives in the ring manifest, and an
    /// elastic run legitimately changes it).
    fn geom_words(&self) -> Vec<i32> {
        vec![
            self.batch as i32,
            self.seq as i32,
            self.k as i32,
            (self.seed & 0xFFFF_FFFF) as u32 as i32,
            (self.seed >> 32) as u32 as i32,
            self.accum as i32,
        ]
    }

    /// The fleet state as one shard of named tensors per worker: shard
    /// `r` carries every parameter (and Adam moment) with index
    /// `i mod R == r`, plus the shared metadata and that rank's RNG
    /// state and shard cursor. Refuses to snapshot a fleet with dead
    /// workers — the run loop reshards first, so checkpoints are
    /// always a clean R′-worker state.
    pub fn shard_tensors(&self) -> Result<Vec<Vec<(String, HostTensor)>>> {
        ensure!(
            self.workers.iter().all(|w| w.alive),
            "sharded checkpoint with dead workers (reshard must run first)"
        );
        let names = model::param_names(&self.model.cfg);
        let r = self.workers.len();
        let as_tensor = |m: &Mat| HostTensor::f32(vec![m.rows(), m.cols()], m.data().to_vec());
        let mut shards = Vec::with_capacity(r);
        for (slot, w) in self.workers.iter().enumerate() {
            let mut t: Vec<(String, HostTensor)> = Vec::new();
            for (i, (n, p)) in names.iter().zip(&self.model.params).enumerate() {
                if i % r == slot {
                    t.push((n.clone(), as_tensor(p)));
                }
            }
            if let Some(ms) = &self.moments {
                for (i, (n, st)) in names.iter().zip(ms).enumerate() {
                    if i % r == slot {
                        t.push((format!("opt_m.{n}"), as_tensor(&st.m)));
                        t.push((format!("opt_v.{n}"), as_tensor(&st.v)));
                    }
                }
            }
            t.push(("meta.step".into(), HostTensor::i32(vec![1], vec![self.step_no as i32])));
            t.push(("meta.geom".into(), HostTensor::i32(vec![6], self.geom_words())));
            t.push(("meta.opt".into(), HostTensor::f32(vec![5], opt_words(self.opt))));
            t.push(("meta.rank".into(), HostTensor::i32(vec![2], vec![slot as i32, r as i32])));
            t.push(("meta.rng".into(), HostTensor::i32(vec![8], rng_words(w.rng.state()))));
            t.push(("meta.cursor".into(), HostTensor::i32(vec![2], split_words(w.shard.cursor()))));
            t.push(("meta.origin".into(), HostTensor::i32(vec![2], split_words(self.origin))));
            shards.push(t);
        }
        Ok(shards)
    }

    /// Restore the fleet from a verified sharded ring entry. The shard
    /// count is authoritative (an elastic run may have degraded since
    /// this trainer was configured): the fleet is rebuilt at
    /// `shards.len()` workers. Refuses geometry/optimizer/step
    /// mismatches shard by shard, exactly like the single-process
    /// resume contract.
    pub fn restore_from_shards(&mut self, shards: Vec<Vec<(String, HostTensor)>>) -> Result<()> {
        let r = shards.len();
        ensure!(r >= 1, "restore: empty shard set");
        let maps: Vec<std::collections::BTreeMap<String, HostTensor>> =
            shards.into_iter().map(|s| s.into_iter().collect()).collect();
        let want_geom = self.geom_words();
        let want_opt = opt_words(self.opt);
        let mut step = None;
        for (slot, m) in maps.iter().enumerate() {
            let geom =
                m.get("meta.geom").with_context(|| format!("shard {slot}: missing `meta.geom`"))?;
            let g = geom.as_i32()?;
            ensure!(
                g == &want_geom[..],
                "shard {slot} was trained with batch/seq/k/seed/accum = {g:?}, trainer uses \
                 {want_geom:?} — resuming would silently diverge from the original run"
            );
            let opt =
                m.get("meta.opt").with_context(|| format!("shard {slot}: missing `meta.opt`"))?;
            let got = opt.as_f32()?;
            ensure!(
                got.iter().map(|v| v.to_bits()).eq(want_opt.iter().map(|v| v.to_bits())),
                "shard {slot} optimizer {got:?} differs from the trainer's {want_opt:?}"
            );
            let rank =
                m.get("meta.rank").with_context(|| format!("shard {slot}: missing `meta.rank`"))?;
            let rk = rank.as_i32()?;
            ensure!(
                rk == [slot as i32, r as i32],
                "shard {slot}: rank stamp {rk:?} does not match its position in the {r}-shard set"
            );
            let s = m
                .get("meta.step")
                .with_context(|| format!("shard {slot}: missing `meta.step`"))?
                .as_i32()?[0]
                .max(0) as usize;
            match step {
                None => step = Some(s),
                Some(prev) => ensure!(prev == s, "shards disagree on the step: {prev} vs {s}"),
            }
        }
        let names = model::param_names(&self.model.cfg);
        let restore = |dst: &mut Mat,
                       key: &str,
                       map: &std::collections::BTreeMap<String, HostTensor>|
         -> Result<()> {
            let t = map.get(key).with_context(|| format!("shard set missing `{key}`"))?;
            ensure!(
                t.shape() == [dst.rows(), dst.cols()],
                "checkpoint `{key}`: shape {:?} vs model {}x{}",
                t.shape(),
                dst.rows(),
                dst.cols()
            );
            dst.data_mut().copy_from_slice(t.as_f32()?);
            Ok(())
        };
        for (i, (n, p)) in names.iter().zip(self.model.params.iter_mut()).enumerate() {
            restore(p, n, &maps[i % r])?;
        }
        match &mut self.moments {
            Some(ms) => {
                ensure!(
                    maps[0].contains_key(&format!("opt_m.{}", names[0])),
                    "checkpoint has no Adam moments but the trainer uses Adam"
                );
                for (i, (n, st)) in names.iter().zip(ms.iter_mut()).enumerate() {
                    restore(&mut st.m, &format!("opt_m.{n}"), &maps[i % r])?;
                    restore(&mut st.v, &format!("opt_v.{n}"), &maps[i % r])?;
                }
            }
            None => {
                if maps[0].contains_key(&format!("opt_m.{}", names[0])) {
                    bail!("checkpoint carries Adam moments but the trainer uses SGD");
                }
            }
        }
        self.step_no = step.unwrap_or(0);
        self.origin = join_words(
            maps[0].get("meta.origin").context("shard 0: missing `meta.origin`")?.as_i32()?,
        )?;
        let (vocab, batch, seq, seed, accum) =
            (self.model.cfg.vocab, self.batch, self.seq, self.seed, self.accum);
        let mut ws = Vec::with_capacity(r);
        for (slot, m) in maps.iter().enumerate() {
            let rng = Xoshiro256::from_state(words_to_state(
                m.get("meta.rng")
                    .with_context(|| format!("shard {slot}: missing `meta.rng`"))?
                    .as_i32()?,
            )?);
            let cursor = join_words(
                m.get("meta.cursor")
                    .with_context(|| format!("shard {slot}: missing `meta.cursor`"))?
                    .as_i32()?,
            )?;
            ws.push(DpWorker {
                rank: slot,
                rng,
                shard: BatchShard::from_cursor(vocab, batch, seq, seed, slot, r, accum, cursor),
                alive: true,
            });
        }
        self.workers = ws;
        Ok(())
    }

    /// The merged full-model view — what the final plain
    /// `{run_name}.bin/json` checkpoint carries for downstream
    /// consumers (`pamm generate --ckpt` reads parameters by name).
    /// Sharded ring entries, not this file, are the resume format.
    pub fn merged_tensors(&self) -> Vec<(String, HostTensor)> {
        let names = model::param_names(&self.model.cfg);
        let mut tensors = Vec::with_capacity(self.model.params.len() + 1);
        for (n, p) in names.iter().zip(&self.model.params) {
            tensors.push((n.clone(), HostTensor::f32(vec![p.rows(), p.cols()], p.data().to_vec())));
        }
        tensors.push(("meta.step".into(), HostTensor::i32(vec![1], vec![self.step_no as i32])));
        tensors
    }
}

// ---------------------------------------------------------------------------
// The run loop (`pamm train --native --workers R`)
// ---------------------------------------------------------------------------

/// Run configuration for one data-parallel run: the single-process
/// config plus the fleet shape. `base.batch` is the *per-microbatch*
/// batch size; the effective batch is `workers × accum × base.batch`
/// rows per optimizer step.
#[derive(Debug, Clone)]
pub struct DpRunConfig {
    pub base: LmRunConfig,
    pub workers: usize,
    /// Gradient-accumulation microbatches per worker per step.
    pub accum: usize,
    /// Degrade onto the survivors when a worker dies (vs failing).
    pub elastic: bool,
    /// Deadline polls a stalled worker may miss before it is declared
    /// dead.
    pub stall_budget: usize,
}

impl DpRunConfig {
    pub fn effective_batch(&self) -> usize {
        self.workers * self.accum * self.base.batch
    }
}

/// What [`train_lm_dp_native_run`] produced beyond the outcome.
#[derive(Debug)]
pub struct DpRunReport {
    pub outcome: TrainOutcome,
    pub resumed_from: Option<usize>,
    /// Ring diagnostics: every manifest/shard that failed verification
    /// on the way to the newest good entry.
    pub recovery_diags: Vec<String>,
    /// Elastic degradation events, in firing order.
    pub reshards: Vec<DpReshard>,
    /// Stalls absorbed by the retry/backoff budget.
    pub stalls_recovered: usize,
    /// Fleet size at the end of the run (< configured `workers` after
    /// an elastic death).
    pub workers_final: usize,
}

/// Write the sharded boundary checkpoint for `step` (+ the merged
/// plain checkpoint at the final boundary), then fsync the run log.
/// An armed [`WorkerKill`] for this boundary turns the call into the
/// scripted kill instead: shards `0..rank` land, then the fleet dies
/// before / halfway through / right after rank's shard — for the two
/// early phases no manifest was committed, so the partial entry is
/// invisible to recovery.
fn write_dp_boundary_checkpoint(
    t: &DpTrainer,
    rc: &DpRunConfig,
    ring: &CheckpointRing,
    logger: &mut RunLogger,
    step: usize,
    kill: Option<&WorkerKill>,
) -> Result<()> {
    let armed = kill.filter(|k| k.step == step);
    let shards = t.shard_tensors()?;
    if let Some(k) = armed {
        // An elastic run may have shrunk below the scripted rank;
        // clamp so every scripted kill still fires.
        let rank = k.rank.min(shards.len() - 1);
        match k.phase {
            CrashPhase::BeforeCheckpoint | CrashPhase::MidCheckpointWrite => {
                for (r, shard) in shards.iter().take(rank).enumerate() {
                    checkpoint::save(ring.dir(), &ring.shard_name(step, r), shard)?;
                }
                if k.phase == CrashPhase::MidCheckpointWrite {
                    checkpoint::save_interrupted(
                        ring.dir(),
                        &ring.shard_name(step, rank),
                        &shards[rank],
                        50,
                    )?;
                }
                logger.sync()?;
                return Err(InjectedCrash { step, phase: k.phase }.into());
            }
            CrashPhase::AfterCheckpoint => {}
        }
    }
    ring.save_sharded(step, &shards).with_context(|| format!("sharded checkpoint boundary {step}"))?;
    if step == rc.base.steps {
        checkpoint::save(ring.dir(), &rc.base.run_name, &t.merged_tensors())
            .with_context(|| format!("final merged checkpoint `{}`", rc.base.run_name))?;
    }
    logger.sync()?;
    if let Some(k) = armed {
        return Err(InjectedCrash { step, phase: k.phase }.into());
    }
    Ok(())
}

/// Data-parallel native pretraining end to end — the production entry
/// point `pamm train --native --workers R` drives.
pub fn train_lm_dp_native(rc: &DpRunConfig, pool: &Pool, quiet: bool) -> Result<TrainOutcome> {
    Ok(train_lm_dp_native_run(rc, None, &[], pool, quiet)?.outcome)
}

/// [`train_lm_dp_native`] with an optional armed worker kill and
/// scripted stragglers — the fault-injection entry point the DP
/// supervisor and `pamm chaos --dp` drive. With no faults armed this
/// *is* the production run loop.
pub fn train_lm_dp_native_run(
    rc: &DpRunConfig,
    kill: Option<&WorkerKill>,
    stalls: &[WorkerStall],
    pool: &Pool,
    quiet: bool,
) -> Result<DpRunReport> {
    let b = &rc.base;
    ensure!(b.steps > 0, "dp train: steps must be > 0");
    ensure!(rc.workers >= 1 && rc.accum >= 1, "dp train: workers/accum must be >= 1");
    let mut t =
        DpTrainer::new(b.cfg.clone(), b.batch, b.seq, b.k, b.opt, b.seed, rc.workers, rc.accum);
    let ckpt_dir = format!("{}/ckpt", b.run_dir);
    let ring = CheckpointRing::new(&ckpt_dir, &b.run_name, b.keep_last);
    let mut resumed_from = None;
    let mut recovery_diags = Vec::new();
    if b.resume {
        let (found, diags) = ring.load_latest_good_sharded();
        for d in &diags {
            if !quiet {
                println!("recovery: {d}");
            }
        }
        recovery_diags = diags;
        if let Some((_, shards)) = found {
            t.restore_from_shards(shards)?;
            resumed_from = Some(t.step_no());
            if !quiet {
                println!(
                    "resumed `{}` at step {} with {} worker(s)",
                    b.run_name,
                    t.step_no(),
                    t.workers()
                );
            }
        }
    }
    ensure!(
        t.step_no() <= b.steps,
        "checkpoint is at step {} but the run asks for {} steps",
        t.step_no(),
        b.steps
    );
    if t.step_no() == b.steps {
        // Already complete (a kill right after the final entry landed
        // can still have lost the merged checkpoint — rewrite it; the
        // state is bit-identical so the overwrite is idempotent).
        checkpoint::save(&ckpt_dir, &b.run_name, &t.merged_tensors())?;
        if !quiet {
            println!("run `{}` is already at its final step {} — nothing to do", b.run_name, b.steps);
        }
        return Ok(DpRunReport {
            outcome: TrainOutcome {
                run_name: b.run_name.clone(),
                steps: b.steps,
                final_loss: f32::NAN,
                final_eval_loss: None,
                final_ppl: None,
                tokens_per_sec: None,
                curve: Vec::new(),
            },
            resumed_from,
            recovery_diags,
            reshards: Vec::new(),
            stalls_recovered: 0,
            workers_final: t.workers(),
        });
    }

    let mut logger = if resumed_from.is_some() {
        let mut l = RunLogger::append(&b.run_dir, &b.run_name)?;
        l.log_resume(t.step_no())?;
        l
    } else {
        RunLogger::create(&b.run_dir, &b.run_name)?
    };
    let mut ema = Ema::new(0.05);
    let mut meter = ThroughputMeter::new(2.min(b.steps / 4));
    let mut curve = Vec::new();
    let mut last_loss = f32::NAN;
    let mut reshards: Vec<DpReshard> = Vec::new();
    let mut stalls_recovered = 0usize;
    let mut pending_dead: Vec<usize> = Vec::new();

    for s in t.step_no()..b.steps {
        // Scripted stragglers: a virtual per-step deadline poll loop.
        // Within the budget the retry/backoff absorbs the stall (the
        // step result is unchanged — determinism holds); past it the
        // rank is declared dead.
        for st in stalls.iter().filter(|st| st.step == s) {
            if !t.is_live(st.rank) {
                continue;
            }
            if st.polls <= rc.stall_budget {
                logger.log_stall(s, st.rank, st.polls, true)?;
                stalls_recovered += 1;
                if !quiet {
                    println!(
                        "worker {} stalled for {} poll(s) at step {s}; recovered within budget {}",
                        st.rank, st.polls, rc.stall_budget
                    );
                }
            } else {
                logger.log_stall(s, st.rank, st.polls, false)?;
                if !rc.elastic {
                    bail!(
                        "worker {} missed {} deadline poll(s) at step {s} (stall budget {}); \
                         rerun with --elastic to degrade onto the survivors instead of failing",
                        st.rank,
                        st.polls,
                        rc.stall_budget
                    );
                }
                t.mark_dead(st.rank).with_context(|| format!("declaring worker {} dead", st.rank))?;
                pending_dead.push(st.rank);
                if !quiet {
                    println!(
                        "worker {} declared dead at step {s} ({} polls > budget {}); \
                         degrading elastically",
                        st.rank, st.polls, rc.stall_budget
                    );
                }
            }
        }
        let rep =
            t.train_step(pool, None).with_context(|| format!("run `{}` step {s}", b.run_name))?;
        meter.step(rep.e_active * b.batch * (b.seq + 1));
        last_loss = rep.loss;
        let sm = ema.update(rep.loss as f64);
        if s % (b.steps / 50).max(1) == 0 || s + 1 == b.steps {
            curve.push((s, rep.loss));
            logger.log_step(s, rep.loss as f64, sm, meter.tokens_per_sec())?;
            if !quiet {
                println!(
                    "step {s:>5}  loss {:7.4}  ema {sm:7.4}  ppl {:8.2}  workers {}  tok/s {}",
                    rep.loss,
                    perplexity(sm),
                    rep.e_active / rc.accum,
                    meter
                        .tokens_per_sec()
                        .map(|t| format!("{t:.0}"))
                        .unwrap_or_else(|| "-".into())
                );
            }
        }
        let boundary =
            (b.ckpt_every > 0 && (s + 1) % b.ckpt_every == 0 && s + 1 < b.steps) || s + 1 == b.steps;
        if boundary {
            if !pending_dead.is_empty() {
                let survivors = t.reshard()?;
                for dead in pending_dead.drain(..) {
                    logger.log_reshard(s + 1, dead, survivors)?;
                    reshards.push(DpReshard { step: s + 1, dead_rank: dead, workers: survivors });
                    if !quiet {
                        println!(
                            "resharded at boundary {}: rank {dead} dropped, {survivors} \
                             worker(s) re-interleaved",
                            s + 1
                        );
                    }
                }
            }
            write_dp_boundary_checkpoint(&t, rc, &ring, &mut logger, s + 1, kill)?;
        }
    }

    let tok_s = meter.tokens_per_sec();
    logger.log_summary(vec![
        ("final_loss", jsonx::num(last_loss as f64)),
        ("steps", jsonx::num(b.steps as f64)),
        ("layers", jsonx::num(b.cfg.n_layers as f64)),
        ("k", jsonx::num(b.k as f64)),
        ("workers", jsonx::num(t.workers() as f64)),
        ("grad_accum", jsonx::num(rc.accum as f64)),
        ("tok_s", tok_s.map(jsonx::num).unwrap_or(jsonx::Value::Null)),
    ])?;

    Ok(DpRunReport {
        outcome: TrainOutcome {
            run_name: b.run_name.clone(),
            steps: b.steps,
            final_loss: last_loss,
            final_eval_loss: None,
            final_ppl: None,
            tokens_per_sec: tok_s,
            curve,
        },
        resumed_from,
        recovery_diags,
        reshards,
        stalls_recovered,
        workers_final: t.workers(),
    })
}

// ---------------------------------------------------------------------------
// The fleet crash supervisor
// ---------------------------------------------------------------------------

/// What a supervised data-parallel run went through on its way to the
/// final [`TrainOutcome`].
#[derive(Debug)]
pub struct DpSupervisedOutcome {
    pub outcome: TrainOutcome,
    /// Total run-loop launches (1 = no kill fired).
    pub attempts: usize,
    /// Every scripted worker kill that fired, in order.
    pub kills: Vec<WorkerKill>,
    /// Step each recovery resumed from.
    pub resume_steps: Vec<usize>,
    /// Ring diagnostics plus injected-corruption notes.
    pub recovery_diags: Vec<String>,
    /// Elastic degradation events of the completing attempt.
    pub reshards: Vec<DpReshard>,
    pub stalls_recovered: usize,
    pub workers_final: usize,
}

/// Supervise [`train_lm_dp_native_run`] under a [`faultx::FaultPlan`]:
/// run, catch the injected worker kill, re-open the sharded ring,
/// resume the whole fleet from the newest entry whose manifest *and
/// every shard* verify, repeat until the run completes. Attempt `i`
/// arms `plan.worker_kills[i]`; scripted stalls replay on every
/// attempt (they are survivable and deterministic, so replaying keeps
/// attempts trajectory-equal). If the plan scripts corruption, one
/// seeded bit flips in a seeded shard of the newest entry before the
/// corresponding recovery — forcing the per-shard checksum-detect +
/// whole-entry fallback path. A real error propagates immediately.
///
/// Because sharded resume is bit-exact and both the batch and
/// generator streams are pure functions of `(seed, position)`, the
/// returned outcome is bitwise identical to the kill-free run's at
/// every (rank × boundary × phase) kill point — the property
/// `prop_dp.rs` and `pamm chaos --dp` assert.
pub fn train_lm_dp_supervised(
    rc: &DpRunConfig,
    plan: &faultx::FaultPlan,
    pool: &Pool,
    quiet: bool,
) -> Result<DpSupervisedOutcome> {
    let mut rc2 = rc.clone();
    let ckpt_dir = format!("{}/ckpt", rc.base.run_dir);
    let ring = CheckpointRing::new(&ckpt_dir, &rc.base.run_name, rc.base.keep_last);
    let mut kills: Vec<WorkerKill> = Vec::new();
    let mut resume_steps = Vec::new();
    let mut recovery_diags = Vec::new();
    // Every armed kill fires at most once, so kills.len() + 1 launches
    // always suffice; the bound exists so a supervisor bug cannot loop
    // forever.
    let max_attempts = plan.worker_kills.len() + 1;
    for attempt in 0..max_attempts {
        let kill = plan.worker_kills.get(kills.len());
        match train_lm_dp_native_run(&rc2, kill, &plan.stalls, pool, quiet) {
            Ok(rep) => {
                if let Some(s) = rep.resumed_from {
                    resume_steps.push(s);
                }
                recovery_diags.extend(rep.recovery_diags);
                return Ok(DpSupervisedOutcome {
                    outcome: rep.outcome,
                    attempts: attempt + 1,
                    kills,
                    resume_steps,
                    recovery_diags,
                    reshards: rep.reshards,
                    stalls_recovered: rep.stalls_recovered,
                    workers_final: rep.workers_final,
                });
            }
            Err(e) => {
                let Some(crash) = faultx::injected_crash(&e) else {
                    return Err(e);
                };
                let Some(&armed) = kill else {
                    return Err(e);
                };
                if !quiet {
                    println!(
                        "supervisor: caught {crash} (worker {}); recovering the fleet from the \
                         sharded ring",
                        armed.rank
                    );
                }
                if plan.corrupt_after_attempt == Some(kills.len()) {
                    // Scripted bitrot in one seeded shard of the
                    // newest committed entry (if any): recovery must
                    // detect it and fall back a whole entry.
                    if let Some(&(step, _)) = ring.entries().last() {
                        if let Some(n) = ring.manifest_shards(step).filter(|&n| n > 0) {
                            let mut rng =
                                Xoshiro256::fold_in(plan.seed, 0xB17F, kills.len() as u64);
                            let shard = rng.next_below(n as u64) as usize;
                            let (byte, bit) = faultx::flip_bit_in_file(
                                ring.shard_blob_path(step, shard),
                                &mut rng,
                            )?;
                            recovery_diags.push(format!(
                                "injected corruption: flipped bit {bit} of byte {byte} in shard \
                                 {shard} of ring entry step {step}"
                            ));
                        }
                    }
                }
                kills.push(armed);
                rc2.base.resume = true;
            }
        }
    }
    bail!(
        "dp supervisor: plan with {} worker kill(s) did not converge within {max_attempts} attempts",
        plan.worker_kills.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lm::LmTrainer;
    use crate::data::BatchIterator;

    fn tiny_cfg() -> LmConfig {
        LmConfig { vocab: 120, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 }
    }

    fn param_bits(params: &[Mat]) -> Vec<Vec<u32>> {
        params.iter().map(|p| p.data().iter().map(|v| v.to_bits()).collect()).collect()
    }

    #[test]
    fn microbatch_rng_skip_matches_a_real_forward() {
        let cfg = tiny_cfg();
        let model = TransformerLM::new(cfg.clone(), 3);
        let (batch, seq, k) = (1usize, 8usize, 3usize);
        let mut a = Xoshiro256::new(99);
        let mut b = Xoshiro256::from_state(a.state());
        let ids: Vec<i32> = (0..batch * seq).map(|i| (i % cfg.vocab) as i32).collect();
        let pool = Pool::serial();
        let _ = model.forward(
            kernels::active(),
            &ids,
            &ids,
            batch,
            seq,
            k,
            Eps::Inf,
            &mut a,
            &pool,
            None,
        );
        skip_microbatch_draws(&mut b, 1, cfg.n_layers, batch * seq, k);
        assert_eq!(a.state(), b.state(), "replay-skip must land exactly where a forward does");
    }

    #[test]
    fn single_worker_dp_bit_matches_the_lm_trainer() {
        let cfg = tiny_cfg();
        let (batch, seq, k, seed) = (1usize, 12usize, 4usize, 9u64);
        let pool = Pool::serial();
        let mut lm = LmTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(3e-3), seed);
        let mut it = BatchIterator::from_seed(cfg.vocab, batch, seq, seed);
        let mut dp = DpTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(3e-3), seed, 1, 1);
        for _ in 0..4 {
            let b = it.next_batch();
            let lm_loss = lm.train_step(&b.tokens, &pool, None).unwrap();
            let dp_loss = dp.train_step(&pool, None).unwrap().loss;
            assert_eq!(
                lm_loss.to_bits(),
                dp_loss.to_bits(),
                "R=1 A=1 loss must bit-match the single-process trainer"
            );
        }
        assert_eq!(
            param_bits(&lm.model.params),
            param_bits(&dp.model.params),
            "R=1 A=1 params must bit-match the single-process trainer"
        );
    }

    #[test]
    fn worker_and_accum_factorizations_of_e_commute() {
        let cfg = tiny_cfg();
        let (batch, seq, k, seed) = (1usize, 10usize, 3usize, 7u64);
        let pool = Pool::serial();
        let mut runs: Vec<(Vec<u32>, Vec<Vec<u32>>)> = Vec::new();
        for (r, a) in [(4usize, 1usize), (2, 2), (1, 4)] {
            let mut t =
                DpTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(3e-3), seed, r, a);
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(t.train_step(&pool, None).unwrap().loss.to_bits());
            }
            runs.push((losses, param_bits(&t.model.params)));
        }
        assert_eq!(runs[0], runs[1], "4x1 and 2x2 must produce the identical trajectory");
        assert_eq!(runs[0], runs[2], "4x1 and 1x4 must produce the identical trajectory");
    }

    #[test]
    fn sharded_roundtrip_restores_exact_state() {
        let cfg = tiny_cfg();
        let (batch, seq, k, seed) = (1usize, 10usize, 3usize, 11u64);
        let pool = Pool::serial();
        let mut a = DpTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(1e-3), seed, 2, 1);
        for _ in 0..3 {
            a.train_step(&pool, None).unwrap();
        }
        let shards = a.shard_tensors().unwrap();
        assert_eq!(shards.len(), 2);
        let mut b = DpTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(1e-3), seed, 2, 1);
        b.restore_from_shards(shards).unwrap();
        assert_eq!(b.step_no(), 3);
        assert_eq!(param_bits(&a.model.params), param_bits(&b.model.params));
        // Continuing must stay bit-identical.
        let la = a.train_step(&pool, None).unwrap().loss;
        let lb = b.train_step(&pool, None).unwrap().loss;
        assert_eq!(la.to_bits(), lb.to_bits(), "post-restore step must bit-match");
        assert_eq!(param_bits(&a.model.params), param_bits(&b.model.params));
    }

    #[test]
    fn restore_refuses_mismatched_shards() {
        let cfg = tiny_cfg();
        let pool = Pool::serial();
        let mut a = DpTrainer::new(cfg.clone(), 1, 10, 3, NativeOpt::adam(1e-3), 5, 2, 1);
        a.train_step(&pool, None).unwrap();
        let shards = a.shard_tensors().unwrap();

        // accum is geometry: a different accumulation schedule resumes
        // a *different* global stream partition.
        let mut b = DpTrainer::new(cfg.clone(), 1, 10, 3, NativeOpt::adam(1e-3), 5, 2, 2);
        let err = b.restore_from_shards(shards.clone()).unwrap_err();
        assert!(format!("{err:#}").contains("silently diverge"), "{err:#}");

        // Optimizer constants are bit-compared.
        let mut c = DpTrainer::new(cfg.clone(), 1, 10, 3, NativeOpt::adam(2e-3), 5, 2, 1);
        assert!(c.restore_from_shards(shards.clone()).is_err());

        // Shards out of order: the rank stamp catches the swap.
        let mut d = DpTrainer::new(cfg.clone(), 1, 10, 3, NativeOpt::adam(1e-3), 5, 2, 1);
        let swapped: Vec<_> = shards.into_iter().rev().collect();
        let err = d.restore_from_shards(swapped).unwrap_err();
        assert!(format!("{err:#}").contains("rank stamp"), "{err:#}");
    }

    #[test]
    fn reshard_drops_the_dead_rank_and_reinterleaves_from_the_cursor() {
        let cfg = tiny_cfg();
        let (batch, seq, k, seed) = (1usize, 10usize, 3usize, 13u64);
        let pool = Pool::serial();
        let mut t = DpTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(1e-3), seed, 2, 1);
        for _ in 0..2 {
            t.train_step(&pool, None).unwrap();
        }
        assert!(t.mark_dead(1).is_ok());
        assert_eq!(t.live_workers(), 1);
        // The interim step averages over the survivor only.
        let rep = t.train_step(&pool, None).unwrap();
        assert_eq!(rep.e_active, 1);
        let origin_before = t.origin;
        assert_eq!(t.reshard().unwrap(), 1);
        assert_eq!(t.workers(), 1);
        // The survivor's new shard re-interleaves from the boundary
        // cursor: rank 0 of 1 starts exactly at `origin`.
        assert_eq!(t.workers[0].shard.cursor(), origin_before);
        assert_eq!(t.workers[0].shard.ranks(), 1);
        // And the fleet keeps training.
        assert!(t.train_step(&pool, None).unwrap().loss.is_finite());
        // A second reshard with nothing dead is an error, as is
        // killing the last survivor.
        assert!(t.reshard().is_err());
        assert!(t.mark_dead(0).is_err());
    }
}
