//! Continuous-batching serve loop over [`GenSession`]s (DESIGN.md §8;
//! graceful degradation §9).
//!
//! The simulator plays a scripted request load against one shared
//! [`TransformerLM`]: requests become visible at their `arrival` step,
//! are admitted FIFO by `(arrival, id)` while a concurrency slot is
//! free, and every active session emits exactly one token per step —
//! prefill + first token at the admission step, one decode afterwards
//! (the "continuous" in continuous batching: completions free their
//! slot for the next queued request at the very next step, no batch
//! barrier).
//!
//! **Determinism.** Sessions are partitioned over the serve pool's
//! workers by the partition-only-task rule ([`Pool::for_tasks`], one
//! lock per session per step, inner compute on [`Pool::serial`]), and
//! a session's token stream is a pure function of its own `(seed,
//! prompt)` — never of which worker ran it or what else was active.
//! Admission is decided before any session advances, from the script
//! alone. A fixed arrival script therefore yields **bit-identical
//! per-request token streams at any worker count**
//! (`rust/tests/prop_serve.rs` asserts 1 == 2 == 4 workers, and that
//! each stream equals a standalone [`generate::Decoder`] run).
//!
//! **Graceful degradation.** Instead of panicking or stalling, the
//! loop accounts for every request with a [`SessionStatus`]: malformed
//! requests are `Rejected` up front (empty prompt, zero tokens,
//! out-of-vocab ids), arrivals past a bounded queue are shed
//! ([`ServeOutcome::shed`]), sessions past their per-session token
//! budget complete `Truncated`, sessions past a step/wall deadline
//! complete `TimedOut` with their partial stream, and a session whose
//! decode produces non-finite logits is `Quarantined` with a
//! diagnostic — its clean token prefix retained, its NaN never
//! emitted ([`GenSession::advance`] refuses to emit from non-finite
//! logits). Because streams are pure per-session functions, every
//! *surviving* stream stays bit-identical to its fault-free run — the
//! isolation property `prop_faults.rs` checks at 1/2/4 workers.
//!
//! Wall-clock per-request latency (arrival-visible → final token,
//! queueing included) feeds the nearest-rank percentile summary
//! ([`benchx::percentile`]) the `pamm serve-sim` table renders next to
//! tokens/s and the compressed-vs-dense cache savings.
//!
//! [`generate::Decoder`]: crate::generate::Decoder

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::benchx;
use crate::coordinator::session::GenSession;
use crate::faultx::FaultPlan;
use crate::model::TransformerLM;
use crate::pamm::Eps;
use crate::poolx::Pool;

/// One scripted request: `arrival` is the serve step at which it
/// becomes visible to the admission policy.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: usize,
    pub arrival: usize,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// Serve-loop knobs. `seed` is folded with each request id so every
/// session draws its own generator stream deterministically. The
/// hardening knobs ([`ServeConfig::new`] defaults them off) bound the
/// queue, the per-session token count and the per-session lifetime.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Admission cap: at most this many sessions decode concurrently.
    pub max_concurrent: usize,
    /// Generator count per layer for every session's KV cache.
    pub k: usize,
    /// Neighborhood condition for the caches.
    pub eps: Eps,
    pub seed: u64,
    /// Bounded admission queue: at most this many visible-but-waiting
    /// requests; arrivals beyond it are shed (0 = unbounded).
    pub max_queue: usize,
    /// Per-session token budget: `max_new` is clamped to this and the
    /// completion marked [`SessionStatus::Truncated`] (0 = no cap).
    pub token_budget: usize,
    /// Deterministic deadline: a session still running after this many
    /// serve steps completes [`SessionStatus::TimedOut`] with its
    /// partial stream (0 = none).
    pub deadline_steps: usize,
    /// Wall-clock deadline per session (admission → now). Inherently
    /// non-deterministic — a CLI knob, not a test knob.
    pub deadline: Option<Duration>,
}

impl ServeConfig {
    /// The fault-free configuration used everywhere before PR 7:
    /// unbounded queue, no budget, no deadlines.
    pub fn new(max_concurrent: usize, k: usize, eps: Eps, seed: u64) -> ServeConfig {
        ServeConfig {
            max_concurrent,
            k,
            eps,
            seed,
            max_queue: 0,
            token_budget: 0,
            deadline_steps: 0,
            deadline: None,
        }
    }
}

/// How a request's service ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionStatus {
    /// Ran to its requested `max_new` tokens.
    Ok,
    /// Completed, but the token budget clamped it below `max_new`.
    Truncated,
    /// Deadline fired first; the stream is the partial prefix.
    TimedOut,
    /// Non-finite logits — isolated with its clean token prefix.
    Quarantined,
    /// Malformed request, never admitted (empty prompt, zero tokens,
    /// out-of-vocab ids).
    Rejected,
}

impl SessionStatus {
    pub fn name(self) -> &'static str {
        match self {
            SessionStatus::Ok => "ok",
            SessionStatus::Truncated => "truncated",
            SessionStatus::TimedOut => "timed-out",
            SessionStatus::Quarantined => "quarantined",
            SessionStatus::Rejected => "rejected",
        }
    }
}

/// One finished request with its schedule, status and cache accounting.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub arrival: usize,
    /// Step at which the session was admitted (== prefill step; the
    /// visibility step for `Rejected`).
    pub admitted_step: usize,
    /// Step at which the final token was emitted (or the session was
    /// retired).
    pub finished_step: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub status: SessionStatus,
    /// Human-readable reason for any non-`Ok` status.
    pub diag: Option<String>,
    /// Arrival-visible → final token, queueing included.
    pub latency: Duration,
    /// Measured compressed-cache peak (== the analytic bound).
    pub cache_peak_bytes: usize,
    /// Dense KV baseline minus the compressed bound.
    pub cache_saved_bytes: usize,
}

/// A request dropped by the bounded admission queue — it never ran.
#[derive(Debug, Clone, Copy)]
pub struct ShedRequest {
    pub id: usize,
    pub arrival: usize,
    /// Step at which the full queue turned it away.
    pub shed_step: usize,
}

/// Everything the simulation measured. `completions` is ordered by
/// `(finished_step, id)` — the completion order itself.
#[derive(Debug)]
pub struct ServeOutcome {
    pub completions: Vec<Completion>,
    /// Requests the bounded queue turned away (empty when unbounded).
    pub shed: Vec<ShedRequest>,
    /// Serve steps executed (idle gaps between arrivals are skipped).
    pub steps: usize,
    pub wall: Duration,
}

impl ServeOutcome {
    pub fn total_tokens(&self) -> usize {
        self.completions.iter().map(|c| c.tokens.len()).sum()
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.total_tokens() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    pub fn total_cache_saved_bytes(&self) -> usize {
        self.completions.iter().map(|c| c.cache_saved_bytes).sum()
    }

    /// Completions that ended with `status`.
    pub fn count(&self, status: SessionStatus) -> usize {
        self.completions.iter().filter(|c| c.status == status).count()
    }

    /// Nearest-rank latency percentile (`p` in `[0, 1]`) over the
    /// requests that actually ran (rejected/shed ones never queued).
    pub fn latency_percentile(&self, p: f64) -> Duration {
        let mut lats: Vec<Duration> = self
            .completions
            .iter()
            .filter(|c| c.status != SessionStatus::Rejected)
            .map(|c| c.latency)
            .collect();
        if lats.is_empty() {
            return Duration::ZERO;
        }
        lats.sort_unstable();
        benchx::percentile(&lats, p)
    }
}

/// Why a request cannot be admitted, if it cannot be.
fn validate_request(model: &TransformerLM, r: &ServeRequest) -> Option<String> {
    if r.prompt.is_empty() {
        return Some("empty prompt".into());
    }
    if r.max_new == 0 {
        return Some("zero tokens requested".into());
    }
    let vocab = model.cfg.vocab as i32;
    if let Some(&bad) = r.prompt.iter().find(|&&t| t < 0 || t >= vocab) {
        return Some(format!("prompt token {bad} outside vocab 0..{vocab}"));
    }
    None
}

/// Run the scripted load to completion. Requests must have unique ids;
/// the per-session compute runs serial (`Pool::serial`) while sessions
/// themselves are spread over `pool.for_tasks()`.
pub fn serve(
    model: &TransformerLM,
    cfg: &ServeConfig,
    requests: &[ServeRequest],
    pool: &Pool,
) -> Result<ServeOutcome> {
    serve_faulted(model, cfg, requests, None, pool)
}

/// [`serve`] with an optional [`FaultPlan`]: each scripted
/// [`crate::faultx::PoisonSite`] turns the matching session's logits
/// non-finite once it has emitted `after_tokens` tokens — the health
/// check must then quarantine it while every other stream is
/// untouched. With `plan: None` this *is* the production loop.
pub fn serve_faulted(
    model: &TransformerLM,
    cfg: &ServeConfig,
    requests: &[ServeRequest],
    plan: Option<&FaultPlan>,
    pool: &Pool,
) -> Result<ServeOutcome> {
    ensure!(cfg.max_concurrent > 0, "serve: max_concurrent must be ≥ 1");
    let mut ids: Vec<usize> = requests.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    ensure!(ids.len() == requests.len(), "serve: duplicate request ids");

    // FIFO admission order: (arrival, id). Pop from the back.
    let mut pending: Vec<&ServeRequest> = requests.iter().collect();
    pending.sort_by_key(|r| (r.arrival, r.id));
    pending.reverse();

    struct Active<'m> {
        sess: GenSession<'m>,
        admitted_step: usize,
        seen: Instant,
        /// `max_new` the request asked for (the session's own may be
        /// budget-clamped below it).
        requested: usize,
    }

    let t0 = Instant::now();
    let task_pool = pool.for_tasks();
    let inner = Pool::serial();
    let mut active: Vec<Active<'_>> = Vec::new();
    let mut waiting: VecDeque<(&ServeRequest, Instant)> = VecDeque::new();
    let mut completions: Vec<Completion> = Vec::new();
    let mut shed: Vec<ShedRequest> = Vec::new();
    let mut step = 0usize;
    let mut steps_run = 0usize;

    while !pending.is_empty() || !waiting.is_empty() || !active.is_empty() {
        // Nothing to run yet — jump to the next arrival instead of
        // spinning through empty steps.
        if active.is_empty() && waiting.is_empty() {
            if let Some(r) = pending.last() {
                if r.arrival > step {
                    step = r.arrival;
                }
            }
        }

        // Visibility: validate newly-arrived requests, then queue or
        // shed them. Rejection and shedding are decided from the
        // script alone, before anything advances — deterministic at
        // any worker count.
        while pending.last().is_some_and(|r| r.arrival <= step) {
            let Some(r) = pending.pop() else { break };
            if let Some(reason) = validate_request(model, r) {
                completions.push(Completion {
                    id: r.id,
                    arrival: r.arrival,
                    admitted_step: step,
                    finished_step: step,
                    prompt_len: r.prompt.len(),
                    tokens: Vec::new(),
                    status: SessionStatus::Rejected,
                    diag: Some(reason),
                    latency: Duration::ZERO,
                    cache_peak_bytes: 0,
                    cache_saved_bytes: 0,
                });
                continue;
            }
            if cfg.max_queue > 0 && waiting.len() >= cfg.max_queue {
                shed.push(ShedRequest { id: r.id, arrival: r.arrival, shed_step: step });
                continue;
            }
            waiting.push_back((r, Instant::now()));
        }

        // Admission: strict (arrival, id) FIFO while slots are free,
        // with the token budget clamped in at admission time.
        while active.len() < cfg.max_concurrent {
            let Some((r, seen)) = waiting.pop_front() else { break };
            let max_new =
                if cfg.token_budget > 0 { r.max_new.min(cfg.token_budget) } else { r.max_new };
            let sess = GenSession::new(
                r.id,
                r.arrival,
                r.prompt.clone(),
                max_new,
                cfg.k,
                cfg.eps,
                cfg.seed ^ (r.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            active.push(Active { sess, admitted_step: step, seen, requested: r.max_new });
        }

        // One token per active session, sessions spread over the task
        // pool. Each Mutex cell is locked by exactly one chunk, so
        // this is partition-only parallelism — results are those of
        // the serial loop at any worker count.
        {
            let cells: Vec<Mutex<&mut GenSession<'_>>> =
                active.iter_mut().map(|a| Mutex::new(&mut a.sess)).collect();
            task_pool.map_chunks(cells.len(), |lo, hi| {
                for cell in &cells[lo..hi] {
                    let mut s = cell.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                    if s.is_admitted() {
                        s.advance(&inner);
                    } else {
                        s.admit(model, &inner);
                    }
                }
            });
        }
        steps_run += 1;

        // Scripted poison injection — serial phase, after the parallel
        // advance, so it is deterministic and the health check below
        // catches it before another token is emitted.
        if let Some(plan) = plan {
            for a in active.iter_mut() {
                if let Some(site) = plan.poison_for(a.sess.id) {
                    if a.sess.tokens().len() == site.after_tokens {
                        a.sess.inject_poison();
                    }
                }
            }
        }

        // Retire sessions: health check (quarantine), deadlines, then
        // normal completion — ascending id within the step (stable,
        // since admission kept (arrival, id) order in `active`).
        let now = Instant::now();
        let mut i = 0;
        while i < active.len() {
            let a = &active[i];
            let emitted = a.sess.tokens().len();
            let steps_used = step + 1 - a.admitted_step;
            let verdict: Option<(SessionStatus, Option<String>)> = if !a.sess.logits_finite() {
                Some((
                    SessionStatus::Quarantined,
                    Some(format!(
                        "non-finite logits after {emitted} clean token(s) — session quarantined, \
                         stream truncated"
                    )),
                ))
            } else if a.sess.is_done() {
                if a.sess.max_new < a.requested {
                    Some((
                        SessionStatus::Truncated,
                        Some(format!(
                            "token budget {} < requested {}",
                            a.sess.max_new, a.requested
                        )),
                    ))
                } else {
                    Some((SessionStatus::Ok, None))
                }
            } else if cfg.deadline_steps > 0 && steps_used >= cfg.deadline_steps {
                Some((
                    SessionStatus::TimedOut,
                    Some(format!(
                        "deadline of {} serve step(s) exceeded after {emitted} token(s)",
                        cfg.deadline_steps
                    )),
                ))
            } else if cfg.deadline.is_some_and(|d| now.duration_since(a.seen) >= d) {
                Some((
                    SessionStatus::TimedOut,
                    Some(format!("wall-clock deadline exceeded after {emitted} token(s)")),
                ))
            } else {
                None
            };
            let Some((status, diag)) = verdict else {
                i += 1;
                continue;
            };
            let a = active.remove(i);
            let peak = a.sess.cache_peak_bytes();
            let saved = a.sess.dense_baseline_bytes().saturating_sub(a.sess.cache_bound_bytes());
            completions.push(Completion {
                id: a.sess.id,
                arrival: a.sess.arrival,
                admitted_step: a.admitted_step,
                finished_step: step,
                prompt_len: a.sess.prompt.len(),
                tokens: a.sess.tokens().to_vec(),
                status,
                diag,
                latency: now.duration_since(a.seen),
                cache_peak_bytes: peak,
                cache_saved_bytes: saved,
            });
        }
        step += 1;
    }

    Ok(ServeOutcome { completions, shed, steps: steps_run, wall: t0.elapsed() })
}

/// Deterministic synthetic load for `pamm serve-sim` and the benches:
/// `n` requests with staggered arrivals (every other step), prompt
/// lengths cycling 4/6/8 over a tiny vocab, `max_new` cycling 4..8.
pub fn scripted_load(n: usize, vocab: usize, seed: u64) -> Vec<ServeRequest> {
    let mut rng = crate::rngx::Xoshiro256::new(seed);
    (0..n)
        .map(|i| {
            let plen = 4 + 2 * (i % 3);
            let prompt: Vec<i32> =
                (0..plen).map(|_| (rng.next_below(vocab as u64) as i32)).collect();
            ServeRequest { id: i, arrival: i / 2, prompt, max_new: 4 + (i % 5) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::{Decoder, GenConfig};
    use crate::model::LmConfig;

    fn tiny_model() -> TransformerLM {
        TransformerLM::new(
            LmConfig { vocab: 29, n_layers: 2, heads: 2, head_dim: 4, d_ff: 16 },
            5,
        )
    }

    fn cfg() -> ServeConfig {
        ServeConfig::new(2, 4, Eps::Inf, 17)
    }

    #[test]
    fn streams_match_standalone_decoder_and_any_worker_count() {
        let model = tiny_model();
        let reqs = scripted_load(5, model.cfg.vocab, 3);
        let serial = serve(&model, &cfg(), &reqs, &Pool::serial()).unwrap();
        assert_eq!(serial.completions.len(), reqs.len());
        assert!(serial.completions.iter().all(|c| c.status == SessionStatus::Ok));
        assert!(serial.shed.is_empty());
        for workers in [2usize, 4] {
            let pool = Pool::new(workers).with_min_chunk(1);
            let out = serve(&model, &cfg(), &reqs, &pool).unwrap();
            for (a, b) in serial.completions.iter().zip(&out.completions) {
                assert_eq!((a.id, &a.tokens), (b.id, &b.tokens), "worker-count drift");
            }
        }
        // Each stream equals a standalone decoder over the same seed:
        // the session emits greedy(logits) one step before appending,
        // so its stream is exactly Decoder::generate's.
        for c in &serial.completions {
            let r = reqs.iter().find(|r| r.id == c.id).unwrap();
            let gc = GenConfig::new(
                cfg().k,
                cfg().eps,
                cfg().seed ^ (r.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                r.prompt.len() + r.max_new,
            );
            let mut dec = Decoder::new(&model, gc);
            dec.prefill(&r.prompt, &Pool::serial());
            assert_eq!(dec.generate(r.max_new, &Pool::serial()), c.tokens);
        }
    }

    #[test]
    fn admission_is_fifo_and_nothing_starves() {
        let model = tiny_model();
        // All arrive at step 0 with one slot: strict id order.
        let reqs: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest {
                id: 3 - i, // shuffled ids
                arrival: 0,
                prompt: vec![1, 2, 3],
                max_new: 3,
            })
            .collect();
        let one_slot = ServeConfig { max_concurrent: 1, ..cfg() };
        let out = serve(&model, &one_slot, &reqs, &Pool::serial()).unwrap();
        let admitted: Vec<usize> = out.completions.iter().map(|c| c.admitted_step).collect();
        let ids: Vec<usize> = out.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "admission must follow (arrival, id)");
        assert!(admitted.windows(2).all(|w| w[0] < w[1]), "one slot ⇒ serialized sessions");
        assert_eq!(out.total_tokens(), 12);
        assert!(out.total_cache_saved_bytes() > 0);
    }

    #[test]
    fn malformed_requests_are_rejected_not_panicked() {
        let model = tiny_model();
        let reqs = vec![
            ServeRequest { id: 0, arrival: 0, prompt: vec![1, 2], max_new: 3 },
            ServeRequest { id: 1, arrival: 0, prompt: vec![], max_new: 3 },
            ServeRequest { id: 2, arrival: 0, prompt: vec![1, 999], max_new: 3 },
            ServeRequest { id: 3, arrival: 0, prompt: vec![1], max_new: 0 },
        ];
        let out = serve(&model, &cfg(), &reqs, &Pool::serial()).unwrap();
        assert_eq!(out.completions.len(), 4);
        assert_eq!(out.count(SessionStatus::Rejected), 3);
        assert_eq!(out.count(SessionStatus::Ok), 1);
        for c in &out.completions {
            if c.status == SessionStatus::Rejected {
                assert!(c.tokens.is_empty());
                assert!(c.diag.is_some(), "rejections must say why");
            }
        }
    }

    #[test]
    fn bounded_queue_sheds_and_budget_truncates_deterministically() {
        let model = tiny_model();
        // 6 requests all at step 0, 1 slot, queue of 2: ids 0 admitted,
        // 1-2 queued, 3-5 shed.
        let reqs: Vec<ServeRequest> = (0..6)
            .map(|i| ServeRequest { id: i, arrival: 0, prompt: vec![1, 2, 3], max_new: 6 })
            .collect();
        let hard = ServeConfig {
            max_concurrent: 1,
            max_queue: 2,
            token_budget: 4,
            ..cfg()
        };
        let out = serve(&model, &hard, &reqs, &Pool::serial()).unwrap();
        let shed_ids: Vec<usize> = out.shed.iter().map(|s| s.id).collect();
        assert_eq!(shed_ids, vec![3, 4, 5], "overflow arrivals shed in script order");
        assert_eq!(out.completions.len(), 3);
        for c in &out.completions {
            assert_eq!(c.status, SessionStatus::Truncated, "budget 4 < requested 6");
            assert_eq!(c.tokens.len(), 4);
        }
        // Deterministic at any worker count (shedding is decided from
        // the script, before anything advances).
        let par = serve(&model, &hard, &reqs, &Pool::new(4).with_min_chunk(1)).unwrap();
        let par_shed: Vec<usize> = par.shed.iter().map(|s| s.id).collect();
        assert_eq!(par_shed, shed_ids);
    }

    #[test]
    fn step_deadline_times_out_with_partial_stream() {
        let model = tiny_model();
        let reqs = vec![ServeRequest { id: 0, arrival: 0, prompt: vec![1, 2], max_new: 8 }];
        let strict = ServeConfig { deadline_steps: 3, ..cfg() };
        let out = serve(&model, &strict, &reqs, &Pool::serial()).unwrap();
        let c = &out.completions[0];
        assert_eq!(c.status, SessionStatus::TimedOut);
        assert_eq!(c.tokens.len(), 3, "3 steps ⇒ 3 tokens, then the deadline fires");
        // The partial stream is the prefix of the unconstrained run.
        let free = serve(&model, &cfg(), &reqs, &Pool::serial()).unwrap();
        assert_eq!(free.completions[0].tokens[..3], c.tokens[..]);
    }

    #[test]
    fn poisoned_session_is_quarantined_with_its_clean_prefix() {
        let model = tiny_model();
        let reqs = scripted_load(4, model.cfg.vocab, 7);
        let clean = serve(&model, &cfg(), &reqs, &Pool::serial()).unwrap();
        let plan = FaultPlan::new(9)
            .sample_poison(&reqs.iter().map(|r| (r.id, r.max_new)).collect::<Vec<_>>(), 1);
        assert_eq!(plan.poison.len(), 1);
        let site = plan.poison[0];
        let out = serve_faulted(&model, &cfg(), &reqs, Some(&plan), &Pool::serial()).unwrap();
        assert_eq!(out.count(SessionStatus::Quarantined), 1);
        for c in &out.completions {
            let clean_c = clean.completions.iter().find(|k| k.id == c.id).unwrap();
            if c.id == site.id {
                assert_eq!(c.status, SessionStatus::Quarantined);
                assert_eq!(c.tokens.len(), site.after_tokens);
                assert_eq!(c.tokens[..], clean_c.tokens[..site.after_tokens], "prefix must be clean");
                assert!(c.diag.as_deref().unwrap_or("").contains("non-finite"), "{:?}", c.diag);
            } else {
                assert_eq!(c.status, SessionStatus::Ok);
                assert_eq!(c.tokens, clean_c.tokens, "survivors must be bit-identical");
            }
        }
    }
}
