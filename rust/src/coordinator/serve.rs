//! Continuous-batching serve loop over [`GenSession`]s (DESIGN.md §8).
//!
//! The simulator plays a scripted request load against one shared
//! [`TransformerLM`]: requests become visible at their `arrival` step,
//! are admitted FIFO by `(arrival, id)` while a concurrency slot is
//! free, and every active session emits exactly one token per step —
//! prefill + first token at the admission step, one decode afterwards
//! (the "continuous" in continuous batching: completions free their
//! slot for the next queued request at the very next step, no batch
//! barrier).
//!
//! **Determinism.** Sessions are partitioned over the serve pool's
//! workers by the partition-only-task rule ([`Pool::for_tasks`], one
//! lock per session per step, inner compute on [`Pool::serial`]), and
//! a session's token stream is a pure function of its own `(seed,
//! prompt)` — never of which worker ran it or what else was active.
//! Admission is decided before any session advances, from the script
//! alone. A fixed arrival script therefore yields **bit-identical
//! per-request token streams at any worker count**
//! (`rust/tests/prop_serve.rs` asserts 1 == 2 == 4 workers, and that
//! each stream equals a standalone [`generate::Decoder`] run).
//!
//! Wall-clock per-request latency (arrival-visible → final token,
//! queueing included) feeds the nearest-rank percentile summary
//! ([`benchx::percentile`]) the `pamm serve-sim` table renders next to
//! tokens/s and the compressed-vs-dense cache savings.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::benchx;
use crate::coordinator::session::GenSession;
use crate::model::TransformerLM;
use crate::pamm::Eps;
use crate::poolx::Pool;

/// One scripted request: `arrival` is the serve step at which it
/// becomes visible to the admission policy.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: usize,
    pub arrival: usize,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// Serve-loop knobs. `seed` is folded with each request id so every
/// session draws its own generator stream deterministically.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Admission cap: at most this many sessions decode concurrently.
    pub max_concurrent: usize,
    /// Generator count per layer for every session's KV cache.
    pub k: usize,
    /// Neighborhood condition for the caches.
    pub eps: Eps,
    pub seed: u64,
}

/// One finished request with its schedule and cache accounting.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub arrival: usize,
    /// Step at which the session was admitted (== prefill step).
    pub admitted_step: usize,
    /// Step at which the final token was emitted.
    pub finished_step: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Arrival-visible → final token, queueing included.
    pub latency: Duration,
    /// Measured compressed-cache peak (== the analytic bound).
    pub cache_peak_bytes: usize,
    /// Dense KV baseline minus the compressed bound.
    pub cache_saved_bytes: usize,
}

/// Everything the simulation measured. `completions` is ordered by
/// `(finished_step, id)` — the completion order itself.
#[derive(Debug)]
pub struct ServeOutcome {
    pub completions: Vec<Completion>,
    /// Serve steps executed (idle gaps between arrivals are skipped).
    pub steps: usize,
    pub wall: Duration,
}

impl ServeOutcome {
    pub fn total_tokens(&self) -> usize {
        self.completions.iter().map(|c| c.tokens.len()).sum()
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.total_tokens() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    pub fn total_cache_saved_bytes(&self) -> usize {
        self.completions.iter().map(|c| c.cache_saved_bytes).sum()
    }

    /// Nearest-rank latency percentile (`p` in `[0, 1]`).
    pub fn latency_percentile(&self, p: f64) -> Duration {
        let mut lats: Vec<Duration> = self.completions.iter().map(|c| c.latency).collect();
        if lats.is_empty() {
            return Duration::ZERO;
        }
        lats.sort_unstable();
        benchx::percentile(&lats, p)
    }
}

/// Run the scripted load to completion. Requests must have unique ids;
/// the per-session compute runs serial (`Pool::serial`) while sessions
/// themselves are spread over `pool.for_tasks()`.
pub fn serve(
    model: &TransformerLM,
    cfg: &ServeConfig,
    requests: &[ServeRequest],
    pool: &Pool,
) -> Result<ServeOutcome> {
    ensure!(cfg.max_concurrent > 0, "serve: max_concurrent must be ≥ 1");
    let mut ids: Vec<usize> = requests.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    ensure!(ids.len() == requests.len(), "serve: duplicate request ids");

    // FIFO admission order: (arrival, id). Pop from the back.
    let mut pending: Vec<&ServeRequest> = requests.iter().collect();
    pending.sort_by_key(|r| (r.arrival, r.id));
    pending.reverse();

    let t0 = Instant::now();
    let task_pool = pool.for_tasks();
    let inner = Pool::serial();
    let mut active: Vec<(GenSession<'_>, usize, Instant)> = Vec::new(); // (session, admitted_step, seen)
    let mut seen_at: Vec<(usize, Instant)> = Vec::new(); // requests visible but not yet admitted
    let mut completions: Vec<Completion> = Vec::new();
    let mut step = 0usize;
    let mut steps_run = 0usize;

    while !pending.is_empty() || !active.is_empty() {
        // Nothing to run yet — jump to the next arrival instead of
        // spinning through empty steps.
        if active.is_empty() && pending.last().is_some_and(|r| r.arrival > step) {
            step = pending.last().unwrap().arrival;
        }

        // Stamp the queue-entry instant of every request that just
        // became visible (latency includes its queueing time).
        for r in pending.iter().rev() {
            if r.arrival > step {
                break;
            }
            if !seen_at.iter().any(|(id, _)| *id == r.id) {
                seen_at.push((r.id, Instant::now()));
            }
        }

        // Admission: strict (arrival, id) FIFO while slots are free.
        while active.len() < cfg.max_concurrent
            && pending.last().is_some_and(|r| r.arrival <= step)
        {
            let r = pending.pop().unwrap();
            let seen = seen_at
                .iter()
                .find(|(id, _)| *id == r.id)
                .map(|(_, t)| *t)
                .unwrap_or_else(Instant::now);
            let sess = GenSession::new(
                r.id,
                r.arrival,
                r.prompt.clone(),
                r.max_new,
                cfg.k,
                cfg.eps,
                cfg.seed ^ (r.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            active.push((sess, step, seen));
        }

        // One token per active session, sessions spread over the task
        // pool. Each Mutex cell is locked by exactly one chunk, so
        // this is partition-only parallelism — results are those of
        // the serial loop at any worker count.
        {
            let cells: Vec<Mutex<&mut GenSession<'_>>> =
                active.iter_mut().map(|(s, _, _)| Mutex::new(s)).collect();
            task_pool.map_chunks(cells.len(), |lo, hi| {
                for cell in &cells[lo..hi] {
                    let mut s = cell.lock().unwrap();
                    if s.is_admitted() {
                        s.advance(&inner);
                    } else {
                        s.admit(model, &inner);
                    }
                }
            });
        }
        steps_run += 1;

        // Collect completions (ascending id within the step — stable
        // since admission kept (arrival, id) order in `active`).
        let now = Instant::now();
        let mut i = 0;
        while i < active.len() {
            if active[i].0.is_done() {
                let (sess, admitted_step, seen) = active.remove(i);
                seen_at.retain(|(id, _)| *id != sess.id);
                let peak = sess.cache_peak_bytes();
                let saved = sess.dense_baseline_bytes().saturating_sub(sess.cache_bound_bytes());
                completions.push(Completion {
                    id: sess.id,
                    arrival: sess.arrival,
                    admitted_step,
                    finished_step: step,
                    prompt_len: sess.prompt.len(),
                    tokens: sess.tokens().to_vec(),
                    latency: now.duration_since(seen),
                    cache_peak_bytes: peak,
                    cache_saved_bytes: saved,
                });
            } else {
                i += 1;
            }
        }
        step += 1;
    }

    Ok(ServeOutcome { completions, steps: steps_run, wall: t0.elapsed() })
}

/// Deterministic synthetic load for `pamm serve-sim` and the benches:
/// `n` requests with staggered arrivals (every other step), prompt
/// lengths cycling 4/6/8 over a tiny vocab, `max_new` cycling 4..8.
pub fn scripted_load(n: usize, vocab: usize, seed: u64) -> Vec<ServeRequest> {
    let mut rng = crate::rngx::Xoshiro256::new(seed);
    (0..n)
        .map(|i| {
            let plen = 4 + 2 * (i % 3);
            let prompt: Vec<i32> =
                (0..plen).map(|_| (rng.next_below(vocab as u64) as i32)).collect();
            ServeRequest { id: i, arrival: i / 2, prompt, max_new: 4 + (i % 5) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::{Decoder, GenConfig};
    use crate::model::LmConfig;

    fn tiny_model() -> TransformerLM {
        TransformerLM::new(
            LmConfig { vocab: 29, n_layers: 2, heads: 2, head_dim: 4, d_ff: 16 },
            5,
        )
    }

    fn cfg() -> ServeConfig {
        ServeConfig { max_concurrent: 2, k: 4, eps: Eps::Inf, seed: 17 }
    }

    #[test]
    fn streams_match_standalone_decoder_and_any_worker_count() {
        let model = tiny_model();
        let reqs = scripted_load(5, model.cfg.vocab, 3);
        let serial = serve(&model, &cfg(), &reqs, &Pool::serial()).unwrap();
        assert_eq!(serial.completions.len(), reqs.len());
        for workers in [2usize, 4] {
            let pool = Pool::new(workers).with_min_chunk(1);
            let out = serve(&model, &cfg(), &reqs, &pool).unwrap();
            for (a, b) in serial.completions.iter().zip(&out.completions) {
                assert_eq!((a.id, &a.tokens), (b.id, &b.tokens), "worker-count drift");
            }
        }
        // Each stream equals a standalone decoder over the same seed:
        // the session emits greedy(logits) one step before appending,
        // so its stream is exactly Decoder::generate's.
        for c in &serial.completions {
            let r = reqs.iter().find(|r| r.id == c.id).unwrap();
            let gc = GenConfig::new(
                cfg().k,
                cfg().eps,
                cfg().seed ^ (r.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                r.prompt.len() + r.max_new,
            );
            let mut dec = Decoder::new(&model, gc);
            dec.prefill(&r.prompt, &Pool::serial());
            assert_eq!(dec.generate(r.max_new, &Pool::serial()), c.tokens);
        }
    }

    #[test]
    fn admission_is_fifo_and_nothing_starves() {
        let model = tiny_model();
        // All arrive at step 0 with one slot: strict id order.
        let reqs: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest {
                id: 3 - i, // shuffled ids
                arrival: 0,
                prompt: vec![1, 2, 3],
                max_new: 3,
            })
            .collect();
        let one_slot = ServeConfig { max_concurrent: 1, ..cfg() };
        let out = serve(&model, &one_slot, &reqs, &Pool::serial()).unwrap();
        let admitted: Vec<usize> = out.completions.iter().map(|c| c.admitted_step).collect();
        let ids: Vec<usize> = out.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "admission must follow (arrival, id)");
        assert!(admitted.windows(2).all(|w| w[0] < w[1]), "one slot ⇒ serialized sessions");
        assert_eq!(out.total_tokens(), 12);
        assert!(out.total_cache_saved_bytes() > 0);
    }
}
