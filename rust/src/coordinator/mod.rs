//! L3 coordinator: the training orchestrator.
//!
//! The paper's contribution lives in the backward pass (L1/L2), so per the
//! architecture notes L3 is the *driver* — but a production one: process
//! lifecycle, deterministic parameter init, the step loop with state
//! threading, a background data pipeline, an eval scheduler, run logging,
//! checkpoints, and a simulated data-parallel mode with gradient
//! accumulation + all-reduce (the paper trains LLaMA-1B/7B with 8-GPU DDP;
//! we reproduce the *coordination logic* on the CPU device).
//!
//! Pieces:
//! * [`session::GenSession`] — one generation request's decode state
//!   over a shared `model::TransformerLM` (a `generate::Decoder` with
//!   its PAMM-compressed KV cache), the unit [`serve`] schedules.
//! * [`serve`] — the continuous-batching serve loop: FIFO admission by
//!   `(arrival, id)`, one token per active session per step over
//!   `poolx::Pool::for_tasks`, wall-clock latency percentiles — the
//!   `pamm serve-sim` engine (deterministic token streams at any
//!   worker count, `rust/tests/prop_serve.rs`).
//! * [`session::TrainSession`] (feature `pjrt`) — one model replica
//!   bound to a train_step artifact; owns the params/m/v literals and
//!   threads them step to step.
//! * [`pipeline::BatchPipeline`] — background-thread batch producer
//!   (bounded channel) so tokenization never stalls a step.
//! * [`ddp`] (feature `pjrt`) — the legacy artifact-era gradient
//!   accumulation + simulated all-reduce shim; the rank-order-reduce
//!   concept now lives in the native [`dp`] path, so the default build
//!   carries no dead DDP surface.
//! * [`dp`] — **native data-parallel training** (DESIGN.md §10):
//!   [`dp::DpTrainer`] runs R logical workers with deterministic
//!   interleaved batch/RNG sharding and a fixed rank-order gradient
//!   all-reduce (trajectories bit-identical for any `R × accum`
//!   factorization of the effective batch, `R = 1` bit-matches
//!   [`lm::train_lm_native`]), sharded crash-safe ring checkpoints,
//!   a fleet crash supervisor ([`dp::train_lm_dp_supervised`]) with
//!   bitwise worker-kill recovery, and elastic degradation
//!   (straggler death → re-shard onto the survivors) — the
//!   `pamm train --native --workers R` / `pamm chaos --dp` engine.
//! * [`trainer`] — the top-level run loop used by the CLI and examples,
//!   plus [`trainer::NativeTrainer`]: the artifact-free native train
//!   step (compressed-activation fwd+bwd+update through
//!   `crate::autograd`, the `pamm reproduce table7 --native` engine).
//! * [`finetune`] — native **GLUE-style fine-tuning** (DESIGN.md §11):
//!   [`finetune::FtTrainer`] trains `model::TransformerLM` plus a
//!   classification head (`model::forward_classify`) on labeled
//!   [`crate::data::glue::TaskCorpus`] batches — deterministic
//!   train/dev split, integer-exact dev-accuracy early stopping, and
//!   the same bit-exact crash-safe checkpoint/resume contract as LM
//!   pretraining, task-fingerprinted so resume refuses a task swap —
//!   the `pamm finetune --native` engine
//!   (`rust/tests/prop_finetune.rs`).
//! * [`lm`] — native **multi-layer LM pretraining**
//!   ([`lm::LmTrainer`] / [`lm::train_lm_native`]): real next-token
//!   training of `model::TransformerLM` on `data::BatchIterator`
//!   batches through the multi-op graph tape, with SGD/Adam, periodic
//!   checkpoints and bit-exact resume — the `pamm train --native` /
//!   `--quick` engine (no artifacts needed). PR 7 wraps the run loop
//!   in a crash supervisor ([`lm::train_lm_supervised`]): injected
//!   kills from a `faultx::FaultPlan` are caught, recovery falls back
//!   to the newest *verifying* ring checkpoint, and the recovered
//!   trajectory is bitwise identical to the uninterrupted one
//!   (DESIGN.md §9, `pamm chaos`).

#[cfg(feature = "pjrt")]
pub mod ddp;
pub mod dp;
pub mod finetune;
pub mod lm;
pub mod pipeline;
pub mod serve;
pub mod session;
pub mod trainer;

pub use dp::{
    train_lm_dp_native, train_lm_dp_native_run, train_lm_dp_supervised, DpRunConfig, DpRunReport,
    DpStepReport, DpSupervisedOutcome, DpTrainer,
};
pub use finetune::{
    build_corpora, finetune_native, find_task, ft_param_names, task_fingerprint, DevEval,
    FtOutcome, FtRunConfig, FtStepReport, FtTrainer,
};
pub use lm::{
    checkpoint_boundaries, train_lm_native, train_lm_native_run, train_lm_supervised, LmRunConfig,
    LmRunReport, LmStepReport, LmTrainer, SupervisedOutcome,
};
pub use serve::{
    serve, serve_faulted, scripted_load, Completion, ServeConfig, ServeOutcome, ServeRequest,
    SessionStatus, ShedRequest,
};
pub use session::GenSession;
#[cfg(feature = "pjrt")]
pub use session::{ClassifierSession, TrainSession};
#[cfg(feature = "pjrt")]
pub use trainer::train_run;
pub use trainer::{NativeOpt, NativeTrainer, TrainOutcome};
