//! Background data pipeline: batches are produced on a worker thread and
//! handed over a bounded channel, so tokenization/packing overlaps with
//! PJRT execution and the step loop never waits on data (§Perf target:
//! pipeline off the critical path).

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::data::batcher::{BatchIterator, TokenBatch};
use crate::data::glue::{LabeledBatch, TaskGenerator};

/// Prefetching LM-batch producer.
pub struct BatchPipeline {
    rx: Receiver<TokenBatch>,
    _producer: JoinHandle<()>,
}

impl BatchPipeline {
    /// `depth` = number of batches buffered ahead of the consumer.
    pub fn spawn(mut it: BatchIterator, depth: usize) -> BatchPipeline {
        let (tx, rx) = sync_channel(depth.max(1));
        let producer = std::thread::spawn(move || {
            loop {
                let b = it.next_batch();
                // Consumer dropped → stop quietly.
                if tx.send(b).is_err() {
                    return;
                }
            }
        });
        BatchPipeline { rx, _producer: producer }
    }

    /// Next batch (blocks only if the producer has fallen behind).
    pub fn next(&self) -> TokenBatch {
        self.rx.recv().expect("batch producer died")
    }
}

/// Prefetching labeled-batch producer (finetune path).
pub struct LabeledPipeline {
    rx: Receiver<LabeledBatch>,
    _producer: JoinHandle<()>,
}

impl LabeledPipeline {
    pub fn spawn(
        mut gen: TaskGenerator,
        batch: usize,
        seq: usize,
        depth: usize,
    ) -> LabeledPipeline {
        let (tx, rx) = sync_channel(depth.max(1));
        let producer = std::thread::spawn(move || loop {
            let b = gen.batch(batch, seq);
            if tx.send(b).is_err() {
                return;
            }
        });
        LabeledPipeline { rx, _producer: producer }
    }

    pub fn next(&self) -> LabeledBatch {
        self.rx.recv().expect("labeled producer died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::glue::{glue_suite, TaskGenerator};

    #[test]
    fn pipeline_streams_deterministically() {
        let mk = || BatchIterator::from_seed(300, 2, 16, 11);
        let p = BatchPipeline::spawn(mk(), 2);
        let mut direct = mk();
        for _ in 0..4 {
            assert_eq!(p.next().tokens, direct.next_batch().tokens);
        }
    }

    #[test]
    fn pipeline_prefetches_without_consumer() {
        // Producer should fill the channel and then park, not spin.
        let it = BatchIterator::from_seed(300, 2, 16, 12);
        let p = BatchPipeline::spawn(it, 3);
        std::thread::sleep(std::time::Duration::from_millis(50));
        // Drain more than the buffer to prove the producer resumed.
        for _ in 0..6 {
            let b = p.next();
            assert_eq!(b.tokens.len(), 2 * 17);
        }
    }

    #[test]
    fn labeled_pipeline_streams() {
        let gen = TaskGenerator::new(glue_suite()[0].clone(), 256, 5);
        let p = LabeledPipeline::spawn(gen, 4, 8, 2);
        for _ in 0..3 {
            let b = p.next();
            assert_eq!(b.labels.len(), 4);
            assert_eq!(b.tokens.len(), 32);
        }
    }

    #[test]
    fn dropping_pipeline_stops_producer() {
        let it = BatchIterator::from_seed(300, 2, 16, 13);
        let p = BatchPipeline::spawn(it, 1);
        let _ = p.next();
        drop(p); // must not hang or panic
    }
}
