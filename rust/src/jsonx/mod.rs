//! Minimal JSON parser/serializer.
//!
//! The offline image vendors no `serde`/`serde_json` (nor `thiserror` —
//! [`JsonError`] impls `Display`/`Error` by hand), so the runtime's
//! manifest loading, metrics logs, checkpoint indexes, and the persisted
//! `BENCH_*.json` perf entries use this ~300-line implementation instead
//! (DESIGN.md "substrates built from scratch").
//!
//! Scope: full JSON grammar (objects, arrays, strings with escapes incl.
//! `\uXXXX`, numbers, bools, null); numbers are held as `f64` which is exact
//! for every integer the manifest contains (shapes, counts < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse(usize, String),
    Type { expected: &'static str, path: String },
    Missing(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse(at, msg) => write!(f, "json parse error at byte {at}: {msg}"),
            JsonError::Type { expected, path } => {
                write!(f, "json type error: expected {expected} at {path}")
            }
            JsonError::Missing(key) => write!(f, "json missing key: {key}"),
        }
    }
}

impl std::error::Error for JsonError {}

pub type Result<T> = std::result::Result<T, JsonError>;

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object lookup; `Value::Null` for missing keys (chainable).
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn req(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(o) => o.get(key).ok_or_else(|| JsonError::Missing(key.into())),
            _ => Err(JsonError::Type { expected: "object", path: key.into() }),
        }
    }
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or(JsonError::Type { expected: "string", path: key.into() })
    }
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or(JsonError::Type { expected: "number", path: key.into() })
    }
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or(JsonError::Type { expected: "number", path: key.into() })
    }
    pub fn req_arr(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?.as_arr().ok_or(JsonError::Type { expected: "array", path: key.into() })
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

// --------------------------------------------------------------------------
// Parsing
// --------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Value> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(JsonError::Parse(p.i, "trailing garbage".into()));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T> {
        Err(JsonError::Parse(self.i, msg.into()))
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }
    fn expect_lit(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.expect_lit("true", Value::Bool(true)),
            Some(b'f') => self.expect_lit("false", Value::Bool(false)),
            Some(b'n') => self.expect_lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("lone high surrogate");
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        match ch {
                            Some(c) => out.push(c),
                            None => return self.err("invalid \\u escape"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return self.err("truncated utf-8");
                    }
                    match std::str::from_utf8(&self.b[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.i = end;
                        }
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or(JsonError::Parse(self.i, "eof in \\u".into()))?;
            let d = (c as char).to_digit(16).ok_or(JsonError::Parse(self.i, "bad hex".into()))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| JsonError::Parse(start, format!("bad number: {e}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// --------------------------------------------------------------------------
// Serialization
// --------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders used by metrics/checkpoint writers.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = parse(r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").as_str(), Some("hi\nthere"));
        assert!(v.get("c").is_null());
        assert_eq!(v.get("d").as_bool(), Some(true));
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn surrogate_pair_escape() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn nested_and_empty() {
        let v = parse(r#"{"x": {}, "y": [], "z": [[1], {"k": [2]}]}"#).unwrap();
        assert_eq!(v.get("z").as_arr().unwrap().len(), 2);
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("123 456").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\te\u{0001}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integer_precision() {
        let v = parse("[1024, 2048, 131072, 9007199254740991]").unwrap();
        assert_eq!(v.as_arr().unwrap()[3].as_i64(), Some(9007199254740991));
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }
}
