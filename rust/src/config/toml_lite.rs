//! TOML-subset parser for run configs (no `toml` crate offline).
//!
//! Supported grammar (all our configs need):
//!
//! ```toml
//! # comment
//! [section]
//! string_key = "value"
//! int_key    = 42
//! float_key  = -1.5e-3
//! bool_key   = true
//! array_key  = [1, 2, 3]
//! ```
//!
//! Unsupported TOML (nested tables, dates, multi-line strings) is rejected
//! with a line-numbered error rather than misparsed.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

#[derive(Debug, Default)]
pub struct TomlDoc {
    /// section → key → value ("" section for top-level keys).
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }
    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(TomlValue::Int(i)) => Some(*i),
            _ => None,
        }
    }
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(TomlValue::Float(f)) => Some(*f),
            Some(TomlValue::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }
    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }
}

pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
            if name.contains('[') || name.contains('.') {
                bail!("line {}: nested tables unsupported", lineno + 1);
            }
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected `key = value`", lineno + 1))?;
        let v = parse_value(value.trim())
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        doc.sections
            .entry(section.clone())
            .or_default()
            .insert(key.trim().to_string(), v);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        if inner.contains('"') {
            bail!("embedded quotes unsupported");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value: {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_kinds() {
        let doc = parse(
            r#"
            top = 1
            [a]
            s = "hello"   # trailing comment
            i = -42
            f = 2.5e-3
            b = false
            arr = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_int("", "top"), Some(1));
        assert_eq!(doc.get_str("a", "s"), Some("hello"));
        assert_eq!(doc.get_int("a", "i"), Some(-42));
        assert!((doc.get_float("a", "f").unwrap() - 0.0025).abs() < 1e-12);
        assert_eq!(doc.get_bool("a", "b"), Some(false));
        assert_eq!(
            doc.get("a", "arr"),
            Some(&TomlValue::Array(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ]))
        );
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse("[v]\nr = 1\n").unwrap();
        assert_eq!(doc.get_float("v", "r"), Some(1.0));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse("[a]\ns = \"x # y\"\n").unwrap();
        assert_eq!(doc.get_str("a", "s"), Some("x # y"));
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("[a]\nkey_no_value\n").is_err());
        assert!(parse("[a]\nk = \"oops\n").is_err());
        assert!(parse("[a.b]\nk = 1\n").is_err());
        assert!(parse("[a]\nk = what\n").is_err());
    }

    #[test]
    fn empty_array_and_empty_doc() {
        let doc = parse("[a]\narr = []\n").unwrap();
        assert_eq!(doc.get("a", "arr"), Some(&TomlValue::Array(vec![])));
        assert!(parse("").unwrap().sections().next().is_none());
    }
}
