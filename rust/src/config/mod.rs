//! Typed configuration system + TOML-subset parser.
//!
//! Runtime presets (model size, variant, batch geometry, seeds, run dirs)
//! can come from three layers, later layers overriding earlier ones:
//! built-in preset → config file (TOML subset) → CLI flags. The offline
//! image has no `toml`/`serde`, so [`toml_lite`] implements the subset we
//! need: `[section]` headers, `key = value` with strings, numbers, bools
//! and homogeneous arrays, `#` comments.

pub mod toml_lite;

use anyhow::{bail, Result};

use crate::attention::{self, AttnTiles};
use crate::tensor::kernels::{self, Tiles};
use toml_lite::TomlDoc;

/// Which compression runs in the QKV backward — mirrors the python
/// `VariantConfig` and the manifest `variant` block.
#[derive(Debug, Clone, PartialEq)]
pub struct Variant {
    pub mode: String, // baseline | pamm | crs | compact
    pub r: f64,
    pub eps: Option<f64>, // None = ∞
    pub use_pallas: bool,
}

impl Variant {
    pub fn baseline() -> Self {
        Self { mode: "baseline".into(), r: 1.0, eps: None, use_pallas: false }
    }
    pub fn pamm(r_inv: u32) -> Self {
        Self { mode: "pamm".into(), r: 1.0 / r_inv as f64, eps: None, use_pallas: false }
    }

    /// Tag matching aot.py's `variant_tag` (artifact-name component).
    pub fn tag(&self) -> String {
        if self.mode == "baseline" {
            return "baseline".into();
        }
        let inv = (1.0 / self.r).round() as i64;
        let mut t = format!("{}{}", self.mode, inv);
        if self.use_pallas {
            t.push_str("pl");
        }
        if let Some(e) = self.eps {
            t.push_str(&format!("_eps{}", format!("{e}").replace('.', "p")));
        }
        t
    }
}

/// A full run configuration for `pamm train` / the examples.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String, // config zoo name (nano/tiny/small/medium)
    pub variant: Variant,
    pub batch: usize,
    pub seq: usize,
    pub steps: usize,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub artifacts_dir: String,
    pub run_dir: String,
    /// Simulated data-parallel worker count (DDP stand-in; gradients from
    /// worker shards are averaged by the coordinator).
    pub workers: usize,
    /// Gradient-accumulation microbatches per optimizer step.
    pub grad_accum: usize,
    /// Worker threads for the native compute pool (poolx); 0 = auto
    /// (available parallelism). CLI `--threads` overrides this.
    pub threads: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: "nano".into(),
            variant: Variant::baseline(),
            batch: 4,
            seq: 64,
            steps: 100,
            seed: 42,
            eval_every: 50,
            eval_batches: 4,
            artifacts_dir: "artifacts".into(),
            run_dir: "runs".into(),
            workers: 1,
            grad_accum: 1,
            threads: 0,
        }
    }
}

/// Built-in presets (the zoo the examples and README reference).
pub fn preset(name: &str) -> Result<RunConfig> {
    let mut c = RunConfig::default();
    match name {
        "smoke" => {
            c.steps = 20;
            c.eval_every = 10;
        }
        "nano" => {
            c.steps = 200;
        }
        "tiny" | "tiny-baseline" => {
            c.model = "tiny".into();
            c.batch = 8;
            c.seq = 128;
            c.steps = 600;
        }
        "tiny-pamm" => {
            c.model = "tiny".into();
            c.batch = 8;
            c.seq = 128;
            c.steps = 600;
            c.variant = Variant::pamm(512);
        }
        "e2e" => {
            // The headline end-to-end run (DESIGN.md §12): largest
            // CPU-tractable model, few hundred steps, loss curve logged.
            c.model = "medium".into();
            c.batch = 4;
            c.seq = 256;
            c.steps = 300;
            c.eval_every = 50;
            c.variant = Variant::pamm(512);
        }
        other => bail!("unknown preset `{other}` (smoke|nano|tiny|tiny-pamm|e2e)"),
    }
    Ok(c)
}

impl RunConfig {
    /// Apply a parsed TOML document over this config.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        if let Some(v) = doc.get_str("run", "model") {
            self.model = v.to_string();
        }
        if let Some(v) = doc.get_int("run", "batch") {
            self.batch = v as usize;
        }
        if let Some(v) = doc.get_int("run", "seq") {
            self.seq = v as usize;
        }
        if let Some(v) = doc.get_int("run", "steps") {
            self.steps = v as usize;
        }
        if let Some(v) = doc.get_int("run", "seed") {
            self.seed = v as u64;
        }
        if let Some(v) = doc.get_int("run", "eval_every") {
            self.eval_every = v as usize;
        }
        if let Some(v) = doc.get_int("run", "workers") {
            self.workers = v as usize;
        }
        if let Some(v) = doc.get_int("run", "grad_accum") {
            self.grad_accum = v as usize;
        }
        if let Some(v) = doc.get_int("run", "threads") {
            // Negative values mean "auto" (0), not a wrapped huge usize.
            self.threads = v.max(0) as usize;
        }
        if let Some(v) = doc.get_str("run", "artifacts_dir") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get_str("run", "run_dir") {
            self.run_dir = v.to_string();
        }
        if let Some(v) = doc.get_str("variant", "mode") {
            self.variant.mode = v.to_string();
        }
        if let Some(v) = doc.get_float("variant", "r") {
            self.variant.r = v;
        }
        if let Some(v) = doc.get_float("variant", "eps") {
            self.variant.eps = if v < 0.0 { None } else { Some(v) };
        }
        if let Some(v) = doc.get_bool("variant", "use_pallas") {
            self.variant.use_pallas = v;
        }
        Ok(())
    }

    pub fn load_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        let doc = toml_lite::parse(&text)?;
        self.apply_toml(&doc)
    }

    /// Artifact name this config resolves to (must exist in the manifest).
    pub fn train_artifact(&self) -> String {
        format!("train_{}_{}_{}x{}", self.model, self.variant.tag(), self.batch, self.seq)
    }

    pub fn eval_artifact(&self) -> String {
        format!("eval_{}_{}x{}", self.model, self.batch, self.seq)
    }
}

// ---------------------------------------------------------------------------
// Kernel tile overlay ([kernels] section + PAMM_* env)
// ---------------------------------------------------------------------------

/// Tile overlay: the persistence half of `pamm kernels --tune`.
/// Precedence is compiled-in default < config file `[kernels]` section
/// < `PAMM_KC`/`PAMM_MC`/`PAMM_NC`/`PAMM_BR`/`PAMM_BC` env vars; fields
/// left `None` keep the lower layer's value. [`KernelTiles::apply`]
/// installs the result process-wide — called once at `pamm` startup
/// (before any pool spins up), which is what keeps the "tiles mutate
/// only at startup or `--tune`" determinism contract intact.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KernelTiles {
    pub kc: Option<usize>,
    pub mc: Option<usize>,
    pub nc: Option<usize>,
    pub br: Option<usize>,
    pub bc: Option<usize>,
}

impl KernelTiles {
    /// Read the `[kernels]` section of a parsed document (absent keys
    /// stay `None`).
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let g = |key: &str| doc.get_int("kernels", key).map(|v| v.max(0) as usize);
        Self { kc: g("kc"), mc: g("mc"), nc: g("nc"), br: g("br"), bc: g("bc") }
    }

    /// Parse a config file's `[kernels]` section; a missing file is an
    /// empty overlay (the CLI applies tiles even when no `--config` was
    /// given, so env-only overrides still work).
    pub fn load_file(path: &str) -> Result<Self> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(_) => return Ok(Self::default()),
        };
        Ok(Self::from_toml(&toml_lite::parse(&text)?))
    }

    /// Layer the `PAMM_KC`/`PAMM_MC`/`PAMM_NC`/`PAMM_BR`/`PAMM_BC` env
    /// vars over this overlay. Unparsable values are a friendly error,
    /// not a silent fallback — same contract as `PAMM_SIMD`.
    pub fn env_overlay(mut self) -> Result<Self> {
        for (var, slot) in [
            ("PAMM_KC", &mut self.kc),
            ("PAMM_MC", &mut self.mc),
            ("PAMM_NC", &mut self.nc),
            ("PAMM_BR", &mut self.br),
            ("PAMM_BC", &mut self.bc),
        ] {
            if let Ok(raw) = std::env::var(var) {
                match raw.trim().parse::<usize>() {
                    Ok(v) => *slot = Some(v),
                    Err(_) => bail!("{var}={raw}: expected a positive integer tile size"),
                }
            }
        }
        Ok(self)
    }

    /// True when every field is `None` — nothing to install.
    pub fn is_empty(&self) -> bool {
        *self == Self::default()
    }

    /// Install the overlay process-wide (defaults fill the `None`
    /// gaps). Validation errors from the kernel/attention setters are
    /// surfaced verbatim.
    pub fn apply(&self) -> Result<()> {
        if self.is_empty() {
            return Ok(());
        }
        let d = Tiles::defaults();
        let t = Tiles {
            kc: self.kc.unwrap_or(d.kc),
            mc: self.mc.unwrap_or(d.mc),
            nc: self.nc.unwrap_or(d.nc),
        };
        kernels::set_tiles(t).map_err(anyhow::Error::msg)?;
        let ad = AttnTiles::defaults();
        let at = AttnTiles { br: self.br.unwrap_or(ad.br), bc: self.bc.unwrap_or(ad.bc) };
        attention::set_attn_tiles(at).map_err(anyhow::Error::msg)?;
        Ok(())
    }

    /// Render as a `[kernels]` TOML section — what `--tune` persists
    /// (only the set fields are written).
    pub fn toml_section(&self) -> String {
        let mut s = String::from("[kernels]\n");
        for (key, v) in
            [("kc", self.kc), ("mc", self.mc), ("nc", self.nc), ("br", self.br), ("bc", self.bc)]
        {
            if let Some(v) = v {
                s.push_str(&format!("{key} = {v}\n"));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for p in ["smoke", "nano", "tiny", "tiny-pamm", "e2e"] {
            let c = preset(p).unwrap();
            assert!(!c.train_artifact().is_empty());
        }
        assert!(preset("bogus").is_err());
    }

    #[test]
    fn artifact_names_match_aot_convention() {
        let c = preset("tiny-pamm").unwrap();
        assert_eq!(c.train_artifact(), "train_tiny_pamm512_8x128");
        assert_eq!(c.eval_artifact(), "eval_tiny_8x128");
        let b = preset("tiny").unwrap();
        assert_eq!(b.train_artifact(), "train_tiny_baseline_8x128");
    }

    #[test]
    fn variant_tags() {
        assert_eq!(Variant::baseline().tag(), "baseline");
        assert_eq!(Variant::pamm(128).tag(), "pamm128");
        let mut v = Variant::pamm(512);
        v.eps = Some(0.5);
        assert_eq!(v.tag(), "pamm512_eps0p5");
        v.use_pallas = true;
        v.eps = None;
        assert_eq!(v.tag(), "pamm512pl");
    }

    #[test]
    fn toml_overlay() {
        let mut c = RunConfig::default();
        let doc = toml_lite::parse(
            r#"
            # overlay
            [run]
            model = "tiny"
            steps = 42
            workers = 4
            threads = 3
            [variant]
            mode = "pamm"
            r = 0.001953125
            eps = -1.0
            "#,
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.model, "tiny");
        assert_eq!(c.steps, 42);
        assert_eq!(c.workers, 4);
        assert_eq!(c.threads, 3);
        assert_eq!(c.variant.tag(), "pamm512");
        assert!(c.variant.eps.is_none());
    }

    #[test]
    fn kernel_tiles_overlay_roundtrip() {
        // Parse → render → parse is a fixed point, and absent keys stay
        // None. apply() with non-default values is deliberately NOT
        // exercised here: it mutates process-wide tile state and would
        // race with every other test (see `KernelTiles` docs).
        let doc = toml_lite::parse("[kernels]\nkc = 384\nbr = 32\n").unwrap();
        let t = KernelTiles::from_toml(&doc);
        assert_eq!(t.kc, Some(384));
        assert_eq!(t.br, Some(32));
        assert_eq!(t.mc, None);
        assert!(!t.is_empty());
        assert!(KernelTiles::default().is_empty());
        let rendered = t.toml_section();
        let t2 = KernelTiles::from_toml(&toml_lite::parse(&rendered).unwrap());
        assert_eq!(t, t2);
        // Empty overlay applies as a no-op (no global mutation).
        KernelTiles::default().apply().unwrap();
        // A file without a [kernels] section is the empty overlay.
        let none = KernelTiles::from_toml(&toml_lite::parse("[run]\nsteps = 1\n").unwrap());
        assert!(none.is_empty());
    }
}
