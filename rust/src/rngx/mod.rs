//! Deterministic PRNG + samplers (the offline image vendors no `rand`).
//!
//! * [`SplitMix64`] — seeding / stream splitting (Steele et al., 2014).
//! * [`Xoshiro256`] — xoshiro256** main generator (Blackman & Vigna).
//! * Samplers: uniform, normal (Box–Muller), Zipf (inverse-CDF),
//!   Fisher–Yates shuffle and partial-shuffle sampling without replacement.
//!
//! All experiment entropy flows through these types, so every run in
//! EXPERIMENTS.md is reproducible from its `(seed, step)` labels alone.

/// SplitMix64 — used to expand one u64 seed into independent streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream for `(purpose, index)` — the Rust
    /// analogue of `jax.random.fold_in`.
    pub fn fold_in(seed: u64, purpose: u64, index: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ purpose.rotate_left(17));
        let mixed = sm.next_u64() ^ index.wrapping_mul(0x9E3779B97F4A7C15);
        Self::new(mixed)
    }

    /// The raw 256-bit generator state — checkpointing: persisting and
    /// restoring it resumes the stream exactly where it left off.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Xoshiro256::state`] snapshot. Only
    /// feed states captured from a live generator (the all-zero state
    /// is a fixed point of xoshiro and must never occur).
    pub fn from_state(s: [u64; 4]) -> Self {
        assert!(s.iter().any(|&w| w != 0), "xoshiro256: all-zero state is invalid");
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, bound) via the widening-multiply trick.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    pub fn next_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.next_below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller (init-time only, clarity wins).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill with N(0, std²) f32 — parameter init path.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.next_normal() as f32 * std;
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) — partial Fisher–Yates; used for PAMM
    /// generator sampling (paper: uniform, without replacement).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Bounded Zipf(s) sampler over ranks [0, n) by inverse-CDF over the
/// precomputed harmonic table — exact, O(log n) per sample. Used by the
/// synthetic-corpus generator (token frequencies in natural text are
/// famously Zipfian, one source of the cross-token redundancy PAMM
/// exploits).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    /// Sample a rank in [0, n) (rank 0 is the most frequent).
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_snapshot_resumes_the_stream() {
        let mut a = Xoshiro256::new(7);
        for _ in 0..13 {
            a.next_u64();
        }
        let snap = a.state();
        let mut b = Xoshiro256::from_state(snap);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn deterministic_streams() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fold_in_independent() {
        let mut a = Xoshiro256::fold_in(1, 2, 3);
        let mut b = Xoshiro256::fold_in(1, 2, 4);
        let mut c = Xoshiro256::fold_in(1, 3, 3);
        let (x, y, z) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_ne!(x, y);
        assert_ne!(x, z);
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let v = rng.next_below(17);
            assert!(v < 17);
            let f = rng.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut rng = Xoshiro256::new(3);
        let s = rng.sample_without_replacement(100, 30);
        assert_eq!(s.len(), 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_full_is_permutation() {
        let mut rng = Xoshiro256::new(5);
        let mut s = rng.sample_without_replacement(50, 50);
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut rng = Xoshiro256::new(9);
        let z = Zipf::new(1000, 1.1);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[100] * 5, "{} vs {}", counts[0], counts[100]);
        assert_eq!(counts.iter().sum::<usize>(), 50_000);
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Xoshiro256::new(13);
        let mut v: Vec<u32> = (0..64).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64).collect::<Vec<_>>());
        assert_ne!(v, (0..64).collect::<Vec<_>>());
    }
}
