//! Training metrics: loss/perplexity tracking, throughput meters,
//! and structured run logs (JSONL + CSV — no external deps).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::jsonx::{self, Value};

/// Exponential moving average (loss smoothing for the printed curve).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Loss → perplexity (the paper reports ppl = exp(mean nats/token)).
pub fn perplexity(loss_nats: f64) -> f64 {
    loss_nats.exp()
}

/// Online mean/min/max/std accumulator.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }
    pub fn n(&self) -> usize {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Tokens/sec throughput meter with warmup skipping (paper Table 2
/// methodology: average over steady-state iterations).
#[derive(Debug)]
pub struct ThroughputMeter {
    warmup: usize,
    seen: usize,
    tokens: usize,
    start: Option<Instant>,
}

impl ThroughputMeter {
    pub fn new(warmup_steps: usize) -> Self {
        Self { warmup: warmup_steps, seen: 0, tokens: 0, start: None }
    }

    /// Record one completed step of `tokens` tokens.
    pub fn step(&mut self, tokens: usize) {
        self.seen += 1;
        if self.seen == self.warmup {
            self.start = Some(Instant::now());
        } else if self.seen > self.warmup {
            self.tokens += tokens;
        }
    }

    pub fn tokens_per_sec(&self) -> Option<f64> {
        let start = self.start?;
        let el = start.elapsed().as_secs_f64();
        if el <= 0.0 || self.tokens == 0 {
            None
        } else {
            Some(self.tokens as f64 / el)
        }
    }
}

/// Structured run log: JSONL events + a final summary JSON.
pub struct RunLogger {
    jsonl: BufWriter<File>,
    csv: BufWriter<File>,
    wrote_csv_header: bool,
}

impl RunLogger {
    pub fn create(dir: impl AsRef<Path>, run_name: &str) -> Result<RunLogger> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let jsonl = BufWriter::new(File::create(dir.join(format!("{run_name}.jsonl")))?);
        let csv = BufWriter::new(File::create(dir.join(format!("{run_name}.csv")))?);
        Ok(RunLogger { jsonl, csv, wrote_csv_header: false })
    }

    /// Open an existing run log for appending — a **resumed** run must
    /// not truncate the pre-interruption step history
    /// (`coordinator::lm::train_lm_native`). The CSV header is treated
    /// as already written when the file is non-empty.
    pub fn append(dir: impl AsRef<Path>, run_name: &str) -> Result<RunLogger> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let open = |path: std::path::PathBuf| {
            std::fs::OpenOptions::new().create(true).append(true).open(path)
        };
        let csv_path = dir.join(format!("{run_name}.csv"));
        let had_rows = std::fs::metadata(&csv_path).map(|m| m.len() > 0).unwrap_or(false);
        let jsonl = BufWriter::new(open(dir.join(format!("{run_name}.jsonl")))?);
        let csv = BufWriter::new(open(csv_path)?);
        Ok(RunLogger { jsonl, csv, wrote_csv_header: had_rows })
    }

    /// Log one training step (step, loss, lr-free — schedule is in HLO).
    pub fn log_step(&mut self, step: usize, loss: f64, ema: f64, tok_s: Option<f64>) -> Result<()> {
        let mut pairs = vec![
            ("event", jsonx::s("step")),
            ("step", jsonx::num(step as f64)),
            ("loss", jsonx::num(loss)),
            ("loss_ema", jsonx::num(ema)),
        ];
        if let Some(t) = tok_s {
            pairs.push(("tok_s", jsonx::num(t)));
        }
        writeln!(self.jsonl, "{}", jsonx::obj(pairs))?;
        if !self.wrote_csv_header {
            writeln!(self.csv, "step,loss,loss_ema,tok_s")?;
            self.wrote_csv_header = true;
        }
        writeln!(self.csv, "{step},{loss},{ema},{}", tok_s.unwrap_or(f64::NAN))?;
        Ok(())
    }

    /// Mark a resume point in the JSONL stream. Steps between the last
    /// checkpoint and a crash get re-logged after the marker (training
    /// replays them bit-identically); consumers that want a clean curve
    /// keep, for any step, the row after the LAST resume marker.
    pub fn log_resume(&mut self, step: usize) -> Result<()> {
        writeln!(
            self.jsonl,
            "{}",
            jsonx::obj(vec![
                ("event", jsonx::s("resume")),
                ("step", jsonx::num(step as f64)),
            ])
        )?;
        Ok(())
    }

    /// Elastic degradation marker (`coordinator::dp`, DESIGN.md §10):
    /// at checkpoint boundary `step` the dead `rank` was dropped and
    /// the stream re-interleaved across the `workers` survivors. The
    /// determinism contract from this row on is a function of the
    /// surviving rank set.
    pub fn log_reshard(&mut self, step: usize, dead_rank: usize, workers: usize) -> Result<()> {
        writeln!(
            self.jsonl,
            "{}",
            jsonx::obj(vec![
                ("event", jsonx::s("reshard")),
                ("step", jsonx::num(step as f64)),
                ("dead_rank", jsonx::num(dead_rank as f64)),
                ("workers", jsonx::num(workers as f64)),
            ])
        )?;
        self.flush()
    }

    /// Straggler marker: worker `rank` missed `polls` deadline polls at
    /// execution step `step`; `recovered` says whether it came back
    /// within the stall budget.
    pub fn log_stall(&mut self, step: usize, rank: usize, polls: usize, recovered: bool) -> Result<()> {
        writeln!(
            self.jsonl,
            "{}",
            jsonx::obj(vec![
                ("event", jsonx::s("stall")),
                ("step", jsonx::num(step as f64)),
                ("rank", jsonx::num(rank as f64)),
                ("polls", jsonx::num(polls as f64)),
                ("recovered", jsonx::Value::Bool(recovered)),
            ])
        )?;
        self.flush()
    }

    pub fn log_eval(&mut self, step: usize, loss: f64) -> Result<()> {
        writeln!(
            self.jsonl,
            "{}",
            jsonx::obj(vec![
                ("event", jsonx::s("eval")),
                ("step", jsonx::num(step as f64)),
                ("loss", jsonx::num(loss)),
                ("ppl", jsonx::num(perplexity(loss))),
            ])
        )?;
        Ok(())
    }

    pub fn log_summary(&mut self, fields: Vec<(&str, Value)>) -> Result<()> {
        let mut pairs = vec![("event", jsonx::s("summary"))];
        pairs.extend(fields);
        writeln!(self.jsonl, "{}", jsonx::obj(pairs))?;
        self.flush()
    }

    pub fn flush(&mut self) -> Result<()> {
        self.jsonl.flush()?;
        self.csv.flush()?;
        Ok(())
    }

    /// Flush + fsync both log files. The trainer calls this whenever a
    /// checkpoint is written, so the crash-window contract holds under
    /// real kills: every step row up to the last checkpoint is durable,
    /// and a resumed run's `{"event":"resume"}` marker lands after a
    /// prefix the disk actually has (`log_resume`'s replay rule).
    pub fn sync(&mut self) -> Result<()> {
        self.jsonl.flush()?;
        self.jsonl.get_ref().sync_all()?;
        self.csv.flush()?;
        self.csv.get_ref().sync_all()?;
        Ok(())
    }
}

/// Replay a JSONL run log into a clean `(step, loss)` curve, applying
/// the resume rule from [`RunLogger::log_resume`]: for any step, the
/// row written after the LAST resume marker wins (replayed steps are
/// bit-identical, so later rows simply overwrite earlier ones).
/// This is how the chaos harness proves a crashed-and-recovered run's
/// *logged* trajectory matches the uninterrupted one step for step.
pub fn replay_run_log(dir: impl AsRef<Path>, run_name: &str) -> Result<Vec<(usize, f64)>> {
    let path = dir.as_ref().join(format!("{run_name}.jsonl"));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("run log {}", path.display()))?;
    let mut by_step: std::collections::BTreeMap<usize, f64> = std::collections::BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let v = jsonx::parse(line)
            .with_context(|| format!("{}:{}: bad JSONL row", path.display(), lineno + 1))?;
        if v.get("event").as_str() == Some("step") {
            let step = v.req_usize("step")?;
            let loss = v.get("loss").as_f64().context("step row missing loss")?;
            by_step.insert(step, loss);
        }
    }
    Ok(by_step.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.1);
        for _ in 0..200 {
            e.update(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn ema_first_value_passthrough() {
        let mut e = Ema::new(0.01);
        assert_eq!(e.update(3.0), 3.0);
    }

    #[test]
    fn stats_moments() {
        let mut s = Stats::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn perplexity_of_uniform() {
        // Uniform over V classes → loss = ln V → ppl = V.
        let v = 512.0f64;
        assert!((perplexity(v.ln()) - v).abs() < 1e-6);
    }

    #[test]
    fn throughput_meter_skips_warmup() {
        let mut m = ThroughputMeter::new(2);
        m.step(100);
        assert!(m.tokens_per_sec().is_none());
        m.step(100); // warmup boundary: timer starts
        std::thread::sleep(std::time::Duration::from_millis(20));
        m.step(100);
        let t = m.tokens_per_sec().unwrap();
        assert!(t > 0.0 && t < 1e7, "tok/s = {t}");
    }

    #[test]
    fn run_logger_append_preserves_history() {
        let dir = std::env::temp_dir().join(format!("pamm_test_logs_app_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut lg = RunLogger::create(&dir, "resume").unwrap();
            lg.log_step(0, 5.0, 5.0, None).unwrap();
            lg.flush().unwrap();
        }
        {
            let mut lg = RunLogger::append(&dir, "resume").unwrap();
            lg.log_step(1, 4.0, 4.5, None).unwrap();
            lg.flush().unwrap();
        }
        let jsonl = std::fs::read_to_string(dir.join("resume.jsonl")).unwrap();
        assert_eq!(jsonl.lines().count(), 2, "append must keep the first run's rows");
        let csv = std::fs::read_to_string(dir.join("resume.csv")).unwrap();
        // One header + two data rows — no second header on append.
        assert_eq!(csv.lines().count(), 3, "{csv}");
        assert_eq!(csv.lines().filter(|l| l.starts_with("step,")).count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replay_applies_the_last_resume_wins_rule() {
        let dir = std::env::temp_dir().join(format!("pamm_test_logs_replay_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            // Crash window: steps 0..3 logged, checkpoint at 2, the
            // process dies; only rows the fsync landed survive.
            let mut lg = RunLogger::create(&dir, "r").unwrap();
            lg.log_step(0, 5.0, 5.0, None).unwrap();
            lg.log_step(1, 4.5, 4.7, None).unwrap();
            lg.sync().unwrap();
            lg.log_step(2, 4.25, 4.5, None).unwrap();
            lg.flush().unwrap(); // flushed but (conceptually) not durable
        }
        {
            // Resume from the step-2 checkpoint: marker, then steps
            // 2.. are re-logged bit-identically.
            let mut lg = RunLogger::append(&dir, "r").unwrap();
            lg.log_resume(2).unwrap();
            lg.log_step(2, 4.25, 4.25, None).unwrap();
            lg.log_step(3, 4.0, 4.2, None).unwrap();
            lg.sync().unwrap();
        }
        let curve = replay_run_log(&dir, "r").unwrap();
        assert_eq!(
            curve,
            vec![(0, 5.0), (1, 4.5), (2, 4.25), (3, 4.0)],
            "replay must keep exactly one row per step"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_logger_writes_parseable_jsonl() {
        let dir = std::env::temp_dir().join("pamm_test_logs");
        let mut lg = RunLogger::create(&dir, "unit").unwrap();
        lg.log_step(1, 3.5, 3.5, Some(1000.0)).unwrap();
        lg.log_eval(1, 3.2).unwrap();
        lg.log_summary(vec![("final_loss", jsonx::num(3.2))]).unwrap();
        let text = std::fs::read_to_string(dir.join("unit.jsonl")).unwrap();
        for line in text.lines() {
            let v = jsonx::parse(line).unwrap();
            assert!(!v.get("event").is_null());
        }
        assert_eq!(text.lines().count(), 3);
    }
}
