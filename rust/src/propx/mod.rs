//! Property-test mini-framework (`proptest` is not vendored offline).
//!
//! A property is a predicate over generated inputs; the runner draws
//! `cases` inputs from a deterministic RNG, and on failure performs a
//! simple halving shrink over the generator's *size parameter* to report
//! a small counterexample. Used for the PAMM invariants in
//! `rust/tests/prop_pamm.rs` (routing/assignment, β bookkeeping,
//! estimator identities across implementations).

use crate::rngx::Xoshiro256;

/// A value generator: draws from RNG at a given "size" (≥ 1).
pub trait Gen {
    type Item;
    fn generate(&self, rng: &mut Xoshiro256, size: usize) -> Self::Item;
}

/// Generator from a closure.
pub struct FnGen<T, F: Fn(&mut Xoshiro256, usize) -> T>(pub F);

impl<T, F: Fn(&mut Xoshiro256, usize) -> T> Gen for FnGen<T, F> {
    type Item = T;
    fn generate(&self, rng: &mut Xoshiro256, size: usize) -> T {
        (self.0)(rng, size)
    }
}

/// usize in [lo, min(hi, lo+size)] — scales with the shrink parameter.
pub fn usize_in(lo: usize, hi: usize) -> impl Gen<Item = usize> {
    FnGen(move |rng: &mut Xoshiro256, size: usize| {
        let cap = hi.min(lo + size);
        lo + rng.next_below((cap - lo + 1) as u64) as usize
    })
}

/// f32 in [-scale, scale] where scale grows with size (bounded by `max`).
pub fn f32_in(max: f32) -> impl Gen<Item = f32> {
    FnGen(move |rng: &mut Xoshiro256, size: usize| {
        let scale = max.min(size as f32);
        (rng.next_f32() * 2.0 - 1.0) * scale
    })
}

/// Vec of `inner` with length in [1, size].
pub fn vec_of<G: Gen>(inner: G) -> impl Gen<Item = Vec<G::Item>> {
    FnGen(move |rng: &mut Xoshiro256, size: usize| {
        let len = 1 + rng.next_below(size.max(1) as u64) as usize;
        (0..len).map(|_| inner.generate(rng, size)).collect()
    })
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult<T> {
    Ok { cases: usize },
    Failed { seed: u64, size: usize, input: T, message: String },
}

/// Configuration for the runner.
#[derive(Debug, Clone)]
pub struct PropOpts {
    pub cases: usize,
    pub seed: u64,
    pub max_size: usize,
}

impl Default for PropOpts {
    fn default() -> Self {
        Self { cases: 64, seed: 0xBEEF, max_size: 64 }
    }
}

/// Run `prop` over `opts.cases` generated inputs; shrink on failure by
/// halving the size parameter while the property still fails.
pub fn check<G, P>(opts: &PropOpts, gen: &G, prop: P) -> PropResult<G::Item>
where
    G: Gen,
    P: Fn(&G::Item) -> Result<(), String>,
{
    for case in 0..opts.cases {
        // size ramps up across cases (small inputs first — cheap shrinking).
        let size = 1 + (opts.max_size * (case + 1)) / opts.cases;
        let case_seed = opts.seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Xoshiro256::new(case_seed);
        let input = gen.generate(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // Shrink: regenerate at halved sizes from the same seed until
            // the property passes; report the smallest failing input.
            let mut best_size = size;
            let mut best_input = input;
            let mut best_msg = msg;
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Xoshiro256::new(case_seed);
                let candidate = gen.generate(&mut rng, s);
                match prop(&candidate) {
                    Err(m) => {
                        best_size = s;
                        best_input = candidate;
                        best_msg = m;
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            return PropResult::Failed {
                seed: case_seed,
                size: best_size,
                input: best_input,
                message: best_msg,
            };
        }
    }
    PropResult::Ok { cases: opts.cases }
}

/// Assert helper: panics with a readable report on failure.
pub fn assert_prop<G, P>(name: &str, opts: &PropOpts, gen: &G, prop: P)
where
    G: Gen,
    G::Item: std::fmt::Debug,
    P: Fn(&G::Item) -> Result<(), String>,
{
    match check(opts, gen, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { seed, size, input, message } => {
            panic!(
                "property `{name}` failed (seed={seed:#x}, size={size}):\n  {message}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let gen = usize_in(0, 100);
        match check(&PropOpts::default(), &gen, |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        }) {
            PropResult::Ok { cases } => assert_eq!(cases, 64),
            PropResult::Failed { .. } => panic!("should pass"),
        }
    }

    #[test]
    fn failing_property_shrinks() {
        // Fails whenever the vec is non-empty — shrinking should bring the
        // reported size down to 1.
        let gen = vec_of(usize_in(0, 10));
        match check(&PropOpts::default(), &gen, |v: &Vec<usize>| {
            if v.is_empty() {
                Ok(())
            } else {
                Err(format!("len={}", v.len()))
            }
        }) {
            PropResult::Failed { size, input, .. } => {
                assert_eq!(size, 1);
                assert!(input.len() <= 2, "shrunk input still large: {input:?}");
            }
            PropResult::Ok { .. } => panic!("should fail"),
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = usize_in(0, 1000);
        let opts = PropOpts { cases: 16, seed: 7, max_size: 1000 };
        let collect = |_: ()| {
            let vals = std::cell::RefCell::new(Vec::new());
            let _ = check(&opts, &gen, |&x| {
                vals.borrow_mut().push(x);
                Ok(())
            });
            vals.into_inner()
        };
        assert_eq!(collect(()), collect(()));
    }

    #[test]
    fn f32_gen_bounded() {
        let gen = f32_in(3.0);
        let mut rng = Xoshiro256::new(1);
        for size in 1..50 {
            let v = gen.generate(&mut rng, size);
            assert!(v.abs() <= 3.0);
        }
    }
}
