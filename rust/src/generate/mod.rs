//! Native autoregressive generation with a PAMM-compressed KV cache.
//!
//! The training stack (DESIGN.md §6–§7) erases the QKV projection
//! memory by saving `pamm::Compressed` instead of dense activations;
//! this module extends the same trick to the *inference* side, where
//! the KV cache is the dominant memory consumer. Per layer the cache
//! is one [`Compressed`] over the layer-normed hidden rows plus the
//! gather-ready projected generators `Gk = C·Wk`, `Gv = C·Wv`
//! ([`Compressed::project_generators`]) — dense K/V slabs never
//! materialize, at prefill or at decode:
//!
//! * **prefill** compresses the prompt's `h1` rows in one batch pass
//!   (generators drawn from the prompt positions), projects the k
//!   generator rows once, and attends through
//!   [`attention::attend_cached_on`] which gather-scales K/V strips
//!   tile by tile.
//! * **decode** folds each new token's `h1` row into the cache with
//!   [`pamm::IncrementalCompressor::fold_on`] — a 1×k Gram row +
//!   argmax, appending one `(α, f)` pair — then attends the single
//!   query row at its absolute position. No per-token dense K/V, no
//!   per-token cache reallocation (α/f are pre-sized to the session's
//!   `max_tokens`).
//!
//! **Bit-parity contract** (asserted by `rust/tests/prop_generate.rs`
//! and by `pamm generate --native` in-command): incremental decode is
//! bit-identical to a one-shot prefill over the full sequence whose
//! generator domain is the prompt length. The argument chains three
//! partition-invariance facts: the microkernel GEMM's per-element
//! accumulation order depends only on the depth blocking, never the
//! row count, so the 1-row fold/projection matches the same row of the
//! batch pass; the cached flash walk's masked lanes contribute exactly
//! `+0.0` after `exp(-inf)`, so a row's online-softmax state never
//! sees future positions; and every remaining op (embed, layernorm,
//! GELU, residual, the tied-head matvec) is row-local. Causality then
//! gives prefix invariance layer by layer, so the one-shot reference's
//! prompt rows — and its generator draw — match the incremental
//! session's exactly.
//!
//! Two deliberate deviations from the *training* forward (DESIGN.md
//! §7): queries stay dense (`Q = h1·Wq` — Q is never cached, so
//! compressing it saves nothing at decode and costs fidelity), and the
//! MLP runs dense (its activations die within the step; PAMM-MLP only
//! pays off when activations are *saved* for backward). The fidelity
//! oracle (Lemma 1 via the f64 reference in `prop_generate`) therefore
//! bounds exactly the error the cache introduces, nothing else.
//!
//! Memory accounting: the per-session cache inventory is charged to a
//! [`MemoryTracker`] at prefill (decode allocates nothing), and
//! [`kv_cache_bytes`] is the analytic bound the measured peak is
//! asserted against — see DESIGN.md §8 for the derivation and the
//! crossover vs the dense `2·T·d_model` baseline.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::attention;
use crate::autograd::{gelu, LN_EPS};
use crate::checkpoint;
use crate::memory::MemoryTracker;
use crate::model::{param_names, LmConfig, TransformerLM, PARAMS_PER_BLOCK};
use crate::pamm::{self, Compressed, Eps, IncrementalCompressor};
use crate::poolx::Pool;
use crate::rngx::Xoshiro256;
use crate::runtime::{ConfigMeta, HostTensor};
use crate::tensor::kernels;
use crate::tensor::{dot, Mat};

/// Generation-time knobs. `seed` feeds the per-layer generator draw at
/// prefill (one draw per layer, over prompt positions only), so two
/// decoders with the same seed and prompt build bit-identical caches.
#[derive(Debug, Clone, Copy)]
pub struct GenConfig {
    /// Generator count per layer (clamped to the generator domain).
    pub k: usize,
    /// Neighborhood condition for both the batch prefill compression
    /// and every incremental fold.
    pub eps: Eps,
    /// Generator-sampling seed.
    pub seed: u64,
    /// Session capacity: prompt + generated tokens. The α/f columns of
    /// every layer cache are pre-sized to this, so decode steps never
    /// reallocate and the analytic bound is exact.
    pub max_tokens: usize,
}

impl GenConfig {
    pub fn new(k: usize, eps: Eps, seed: u64, max_tokens: usize) -> Self {
        GenConfig { k, eps, seed, max_tokens }
    }
}

/// One layer's compressed KV cache: the shared compression state plus
/// the projected generator panels. `comp.alpha`/`comp.assign` grow by
/// one entry per decoded token; everything else is fixed at prefill.
struct LayerCache {
    comp: Compressed,
    inc: IncrementalCompressor,
    gk: Mat,
    gv: Mat,
}

/// Incremental greedy decoder over a [`TransformerLM`].
///
/// Lifecycle: [`Decoder::new`] → [`Decoder::prefill`] (once) →
/// [`Decoder::decode_step`] / [`Decoder::generate`]. The decoder holds
/// only borrowed parameters plus its per-layer [`LayerCache`]s — many
/// sessions can share one model (see `coordinator::serve`).
pub struct Decoder<'m> {
    model: &'m TransformerLM,
    cfg: GenConfig,
    rng: Xoshiro256,
    layers: Vec<LayerCache>,
    len: usize,
    tracker: MemoryTracker,
    last_logits: Vec<f32>,
}

impl<'m> Decoder<'m> {
    pub fn new(model: &'m TransformerLM, cfg: GenConfig) -> Self {
        assert!(cfg.max_tokens > 0, "generate: max_tokens must be ≥ 1");
        let seed = cfg.seed;
        Decoder {
            model,
            cfg,
            rng: Xoshiro256::new(seed),
            layers: Vec::new(),
            len: 0,
            tracker: MemoryTracker::new(),
            last_logits: Vec::new(),
        }
    }

    /// Tokens currently in the cache (prompt + decoded).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Logits of the most recent position (empty before prefill).
    pub fn last_logits(&self) -> &[f32] {
        &self.last_logits
    }

    /// True iff every current logit is finite. Empty (pre-prefill)
    /// counts as healthy — there is nothing to emit from yet.
    pub fn logits_finite(&self) -> bool {
        self.last_logits.iter().all(|v| v.is_finite())
    }

    /// Fault-injection hook (`faultx` / `pamm chaos`): overwrite the
    /// current logits with NaN, simulating a numerically poisoned
    /// decode. The serve loop's health check must quarantine this
    /// session before it emits another token.
    pub fn poison_last_logits(&mut self) {
        for v in &mut self.last_logits {
            *v = f32::NAN;
        }
    }

    /// Effective generator count after the prefill clamp.
    pub fn effective_k(&self) -> usize {
        self.layers.first().map_or(0, |l| l.comp.k())
    }

    /// High-water mark of the charged cache bytes.
    pub fn cache_peak_bytes(&self) -> usize {
        self.tracker.peak()
    }

    /// Analytic bound for this session's cache: [`kv_cache_bytes`] at
    /// the effective k (valid only after prefill).
    pub fn cache_bound_bytes(&self) -> usize {
        kv_cache_bytes(&self.model.cfg, self.effective_k(), self.cfg.max_tokens)
    }

    /// Dense-cache baseline for this session's capacity.
    pub fn dense_baseline_bytes(&self) -> usize {
        dense_kv_cache_bytes(&self.model.cfg, self.cfg.max_tokens)
    }

    /// Compress the prompt and emit its last position's logits.
    /// Generator indices are drawn from all prompt positions.
    pub fn prefill(&mut self, tokens: &[i32], pool: &Pool) -> &[f32] {
        self.prefill_with_domain(tokens, tokens.len(), pool)
    }

    /// Prefill with generator indices restricted to the first
    /// `gen_domain` positions. This is the one-shot *reference* entry:
    /// prefilling `prompt ++ generated` with `gen_domain = prompt.len()`
    /// reproduces an incremental session's cache bit for bit (causal
    /// prefix invariance keeps the prompt rows — and hence the
    /// generator draw — identical between the two).
    pub fn prefill_with_domain(&mut self, tokens: &[i32], gen_domain: usize, pool: &Pool) -> &[f32] {
        assert!(self.layers.is_empty(), "generate: prefill called twice");
        assert!(!tokens.is_empty(), "generate: empty prompt");
        assert!(
            tokens.len() <= self.cfg.max_tokens,
            "generate: prompt {} exceeds max_tokens {}",
            tokens.len(),
            self.cfg.max_tokens
        );
        assert!(
            gen_domain >= 1 && gen_domain <= tokens.len(),
            "generate: gen_domain {} outside 1..={}",
            gen_domain,
            tokens.len()
        );
        let logits = self.forward_rows(tokens, Some(gen_domain), pool);
        self.last_logits = logits;
        &self.last_logits
    }

    /// Fold one token into every layer cache and emit the next logits.
    pub fn decode_step(&mut self, token: i32, pool: &Pool) -> &[f32] {
        assert!(!self.layers.is_empty(), "generate: decode before prefill");
        assert!(
            self.len < self.cfg.max_tokens,
            "generate: session at max_tokens {}",
            self.cfg.max_tokens
        );
        let logits = self.forward_rows(&[token], None, pool);
        self.last_logits = logits;
        &self.last_logits
    }

    /// Greedy-decode `n_new` tokens (each emitted token is appended, so
    /// the cache afterwards holds prompt + all generated tokens and the
    /// final `last_logits` is the next-token distribution past them).
    pub fn generate(&mut self, n_new: usize, pool: &Pool) -> Vec<i32> {
        assert!(!self.layers.is_empty(), "generate: generate before prefill");
        assert!(
            self.len + n_new <= self.cfg.max_tokens,
            "generate: {} + {} new tokens exceeds max_tokens {}",
            self.len,
            n_new,
            self.cfg.max_tokens
        );
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            let tok = greedy(&self.last_logits);
            out.push(tok);
            self.decode_step(tok, pool);
        }
        out
    }

    /// Shared prefill/decode forward over `ids` at absolute positions
    /// `len..len+ids.len()`. `prefill_domain = Some(d)` builds the
    /// caches (batch compression, generators from the first `d` rows);
    /// `None` folds each row into the existing caches. Returns the
    /// last row's tied-head logits.
    fn forward_rows(&mut self, ids: &[i32], prefill_domain: Option<usize>, pool: &Pool) -> Vec<f32> {
        let d = kernels::active();
        let cfg = &self.model.cfg;
        let (dm, heads, head_dim) = (cfg.d_model(), cfg.heads, cfg.head_dim);
        let eps = self.cfg.eps;
        let pos0 = self.len;
        let rows = ids.len();

        // Embedding gather — row-local, same bits at any batch size.
        let emb = &self.model.params[0];
        let mut x = Mat::zeros(rows, dm);
        for (r, &id) in ids.iter().enumerate() {
            let id = id as usize;
            assert!(id < cfg.vocab, "generate: token {id} outside vocab {}", cfg.vocab);
            x.row_mut(r).copy_from_slice(emb.row(id));
        }

        for b in 0..cfg.n_layers {
            let p = |o: usize| 1 + b * PARAMS_PER_BLOCK + o;
            let h1 = ln_rows(&x, &self.model.params[p(0)], &self.model.params[p(1)]);

            if let Some(domain) = prefill_domain {
                // Build this layer's cache: batch-compress the prompt's
                // h1 rows, project the generators once, pre-size α/f to
                // the session capacity, and charge the whole inventory.
                let k_eff = self.cfg.k.clamp(1, domain);
                let gen_idx = pamm::sample_generators(&mut self.rng, domain, k_eff);
                let mut comp = pamm::compress_with(&h1, &gen_idx, eps, pool);
                let cap = self.cfg.max_tokens;
                let mut alpha = Vec::with_capacity(cap);
                alpha.extend_from_slice(&comp.alpha);
                comp.alpha = alpha;
                let mut assign = Vec::with_capacity(cap);
                assign.extend_from_slice(&comp.assign);
                comp.assign = assign;
                let inc = IncrementalCompressor::new(&comp);
                let gk = comp.project_generators(&self.model.params[p(3)]);
                let gv = comp.project_generators(&self.model.params[p(4)]);
                self.tracker.alloc(
                    comp.generators.rows() * comp.generators.cols() * 4 // C
                        + inc.stored_bytes()                            // Cᵀ + ‖c‖
                        + 2 * cap * 4 + 4                               // α, f, β
                        + gk.rows() * gk.cols() * 4                     // Gk
                        + gv.rows() * gv.cols() * 4,                    // Gv
                );
                self.layers.push(LayerCache { comp, inc, gk, gv });
            } else {
                let lc = &mut self.layers[b];
                for r in 0..rows {
                    lc.inc.fold_on(d, &mut lc.comp, h1.row(r), eps);
                }
                debug_assert!(
                    lc.comp.alpha.capacity() == self.cfg.max_tokens
                        && lc.comp.assign.capacity() == self.cfg.max_tokens,
                    "generate: decode fold reallocated the cache"
                );
            }

            // Dense queries; K/V stay compressed and are gather-scaled
            // strip by strip inside the cached flash walk.
            let lc = &self.layers[b];
            let q = h1.matmul_with(&self.model.params[p(2)], pool);
            let attn = attention::attend_cached_on(
                d,
                &q,
                pos0,
                &lc.gk,
                &lc.gv,
                &lc.comp.alpha,
                &lc.comp.assign,
                heads,
                head_dim,
                pool,
            );
            x.add_assign(&attn);

            // Dense MLP (activations die within the step — nothing to
            // compress at inference).
            let h2 = ln_rows(&x, &self.model.params[p(5)], &self.model.params[p(6)]);
            let mut z = h2.matmul_with(&self.model.params[p(7)], pool);
            for v in z.data_mut() {
                *v = gelu(*v);
            }
            let y = z.matmul_with(&self.model.params[p(8)], pool);
            x.add_assign(&y);
        }

        let lnf = 1 + cfg.n_layers * PARAMS_PER_BLOCK;
        let hf = ln_rows(&x, &self.model.params[lnf], &self.model.params[lnf + 1]);
        self.len += rows;
        tied_logits(hf.row(rows - 1), emb)
    }
}

/// Greedy argmax (strict `>`, lowest index on ties — deterministic).
pub fn greedy(logits: &[f32]) -> i32 {
    assert!(!logits.is_empty(), "generate: greedy over empty logits");
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as i32
}

/// Inference layernorm — the exact per-row arithmetic of the training
/// tape's `layer_norm` (same `inv_n` mean/variance loops, same
/// [`LN_EPS`]), minus the saved state. Row-local, so prefill and
/// decode see identical bits.
fn ln_rows(x: &Mat, gain: &Mat, bias: &Mat) -> Mat {
    let (rows, n) = (x.rows(), x.cols());
    let inv_n = 1.0 / n as f32;
    let (g, bvec) = (gain.data(), bias.data());
    let mut y = Mat::zeros(rows, n);
    for i in 0..rows {
        let xr = x.row(i);
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu *= inv_n;
        let mut var = 0.0f32;
        for &v in xr {
            let dv = v - mu;
            var += dv * dv;
        }
        var *= inv_n;
        let r = 1.0 / (var + LN_EPS).sqrt();
        let yr = y.row_mut(i);
        for j in 0..n {
            yr[j] = (xr[j] - mu) * r * g[j] + bvec[j];
        }
    }
    y
}

/// Tied-head logits of one hidden row: `logits[v] = ⟨hf, emb_v⟩` as a
/// serial matvec over the vocab — no `embᵀ` materialization, and
/// trivially the same bits for the same row at prefill and decode.
fn tied_logits(hf_row: &[f32], emb: &Mat) -> Vec<f32> {
    (0..emb.rows()).map(|v| dot(hf_row, emb.row(v))).collect()
}

/// Analytic per-session cache bytes at generator count `k` and session
/// capacity `max_tokens` (DESIGN.md §8): per layer the generator panel
/// `C` (k·dm), its transpose + norms held by the fold state (k·dm + k),
/// the projected `Gk`/`Gv` (2·k·dm), the pre-sized α/f columns
/// (2·max_tokens) and β — all f32/u32, 4 bytes each. The per-*token*
/// marginal is 8 bytes/layer vs the dense cache's `2·dm·4`.
pub fn kv_cache_bytes(cfg: &LmConfig, k: usize, max_tokens: usize) -> usize {
    let dm = cfg.d_model();
    cfg.n_layers * (4 * k * dm * 4 + k * 4 + 2 * max_tokens * 4 + 4)
}

/// Dense KV-cache baseline: per layer K and V slabs of
/// `max_tokens × d_model` f32 each.
pub fn dense_kv_cache_bytes(cfg: &LmConfig, max_tokens: usize) -> usize {
    cfg.n_layers * 2 * max_tokens * cfg.d_model() * 4
}

/// Assert bitwise prefill-vs-decode parity for a finished session: a
/// fresh one-shot prefill over `prompt ++ generated` (same `cfg`,
/// generator domain = prompt length) must reproduce `got_logits` — the
/// incremental session's final logits — bit for bit.
pub fn check_decode_parity(
    model: &TransformerLM,
    cfg: &GenConfig,
    prompt: &[i32],
    generated: &[i32],
    got_logits: &[f32],
    pool: &Pool,
) -> Result<()> {
    ensure!(!prompt.is_empty(), "decode parity: empty prompt");
    let mut full = prompt.to_vec();
    full.extend_from_slice(generated);
    let mut oneshot = Decoder::new(model, *cfg);
    oneshot.prefill_with_domain(&full, prompt.len(), pool);
    let want = oneshot.last_logits();
    ensure!(
        want.len() == got_logits.len(),
        "decode parity: logit width {} vs {}",
        want.len(),
        got_logits.len()
    );
    for (i, (w, g)) in want.iter().zip(got_logits.iter()).enumerate() {
        ensure!(
            w.to_bits() == g.to_bits(),
            "decode parity: logit {i} differs — one-shot {w:e} vs incremental {g:e}"
        );
    }
    Ok(())
}

/// Map a serving-manifest model card onto the native [`LmConfig`]
/// (activates the `runtime::manifest` scaffolding on the native path).
pub fn config_from_manifest(meta: &ConfigMeta) -> Result<LmConfig> {
    ensure!(meta.n_heads > 0, "manifest config {}: zero heads", meta.name);
    ensure!(
        meta.d_model % meta.n_heads == 0,
        "manifest config {}: d_model {} not divisible by {} heads",
        meta.name,
        meta.d_model,
        meta.n_heads
    );
    ensure!(meta.n_layers > 0, "manifest config {}: zero layers", meta.name);
    ensure!(meta.vocab > 0 && meta.d_ff > 0, "manifest config {}: empty dims", meta.name);
    let cfg = LmConfig {
        vocab: meta.vocab,
        n_layers: meta.n_layers,
        heads: meta.n_heads,
        head_dim: meta.d_model / meta.n_heads,
        d_ff: meta.d_ff,
    };
    ensure!(
        meta.param_count == 0 || meta.param_count == cfg.param_count(),
        "manifest config {}: param_count {} vs derived {}",
        meta.name,
        meta.param_count,
        cfg.param_count()
    );
    Ok(cfg)
}

/// Load trained weights from a `checkpoint::save`d file into `model`,
/// validating every parameter's name and shape against
/// [`param_names`]. (`LmTrainer` checkpoints carry no geometry, so the
/// caller picks the model config — mismatches fail loudly here.)
pub fn load_checkpoint_params(
    model: &mut TransformerLM,
    dir: impl AsRef<Path>,
    name: &str,
) -> Result<()> {
    let tensors = checkpoint::load(dir, name)?;
    let map: BTreeMap<String, HostTensor> = tensors.into_iter().collect();
    for (i, pname) in param_names(&model.cfg).iter().enumerate() {
        let t = map
            .get(pname.as_str())
            .with_context(|| format!("checkpoint missing parameter `{pname}`"))?;
        let (r, c) = (model.params[i].rows(), model.params[i].cols());
        ensure!(
            t.shape() == [r, c],
            "checkpoint `{pname}`: shape {:?} vs model [{r}, {c}]",
            t.shape()
        );
        let data = t.as_f32().with_context(|| format!("checkpoint `{pname}` dtype"))?;
        model.params[i] = Mat::from_vec(r, c, data.to_vec());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> LmConfig {
        LmConfig { vocab: 31, n_layers: 2, heads: 2, head_dim: 4, d_ff: 16 }
    }

    fn gc(max_tokens: usize) -> GenConfig {
        GenConfig::new(4, Eps::Inf, 9, max_tokens)
    }

    #[test]
    fn incremental_decode_matches_one_shot_bitwise() {
        let model = TransformerLM::new(tiny(), 7);
        let pool = Pool::new(2).with_min_chunk(1);
        let prompt: Vec<i32> = vec![3, 1, 4, 1, 5, 9];
        let mut dec = Decoder::new(&model, gc(16));
        dec.prefill(&prompt, &pool);
        let toks = dec.generate(5, &pool);
        assert_eq!(toks.len(), 5);
        assert_eq!(dec.len(), prompt.len() + 5);
        let got = dec.last_logits().to_vec();
        check_decode_parity(&model, &gc(16), &prompt, &toks, &got, &pool).unwrap();
        // Eps::Val exercises the drop path through the same parity.
        let cfg2 = GenConfig::new(3, Eps::Val(0.7), 9, 16);
        let mut dec2 = Decoder::new(&model, cfg2);
        dec2.prefill(&prompt, &pool);
        let toks2 = dec2.generate(4, &pool);
        let got2 = dec2.last_logits().to_vec();
        check_decode_parity(&model, &cfg2, &prompt, &toks2, &got2, &pool).unwrap();
    }

    #[test]
    fn cache_peak_matches_analytic_inventory() {
        let model = TransformerLM::new(tiny(), 11);
        let pool = Pool::serial();
        let cfg = gc(24);
        let mut dec = Decoder::new(&model, cfg);
        dec.prefill(&[2, 7, 1, 8, 2, 8], &pool);
        dec.generate(6, &pool);
        assert_eq!(dec.effective_k(), 4);
        let bound = kv_cache_bytes(&model.cfg, dec.effective_k(), cfg.max_tokens);
        // The charged inventory is exact, so peak == bound here.
        assert_eq!(dec.cache_peak_bytes(), bound);
        assert_eq!(dec.cache_bound_bytes(), bound);
        assert!(
            bound < dec.dense_baseline_bytes(),
            "compressed cache {} not below dense {} at this shape",
            bound,
            dec.dense_baseline_bytes()
        );
    }

    #[test]
    fn greedy_is_lowest_index_on_ties() {
        assert_eq!(greedy(&[0.0, 2.0, 2.0, -1.0]), 1);
        assert_eq!(greedy(&[-1.0]), 0);
    }

    #[test]
    fn manifest_config_maps_and_validates() {
        let meta = ConfigMeta {
            name: "nano".into(),
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            d_ff: 176,
            param_count: 0,
        };
        let cfg = config_from_manifest(&meta).unwrap();
        assert_eq!((cfg.vocab, cfg.n_layers, cfg.heads, cfg.head_dim, cfg.d_ff), (256, 2, 2, 32, 176));
        let mut counted = meta.clone();
        counted.param_count = cfg.param_count();
        assert!(config_from_manifest(&counted).is_ok());
        let mut bad = meta.clone();
        bad.d_model = 65;
        assert!(config_from_manifest(&bad).is_err());
        let mut wrong = meta;
        wrong.param_count = 1;
        assert!(config_from_manifest(&wrong).is_err());
    }

    #[test]
    fn checkpoint_roundtrip_drives_identical_logits() {
        let dir = std::env::temp_dir().join(format!("pamm-gen-ckpt-{}", std::process::id()));
        let model = TransformerLM::new(tiny(), 13);
        let names = param_names(&model.cfg);
        let tensors: Vec<(String, HostTensor)> = names
            .iter()
            .zip(&model.params)
            .map(|(n, m)| {
                (n.clone(), HostTensor::f32(vec![m.rows(), m.cols()], m.data().to_vec()))
            })
            .collect();
        checkpoint::save(&dir, "gen-test", &tensors).unwrap();
        let mut loaded = TransformerLM::new(tiny(), 999);
        load_checkpoint_params(&mut loaded, &dir, "gen-test").unwrap();
        let pool = Pool::serial();
        let mut a = Decoder::new(&model, gc(8));
        let mut b = Decoder::new(&loaded, gc(8));
        let la = a.prefill(&[1, 2, 3], &pool).to_vec();
        let lb = b.prefill(&[1, 2, 3], &pool).to_vec();
        assert_eq!(
            la.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            lb.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
