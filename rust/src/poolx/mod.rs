//! Scoped thread pool + parallel-for (no tokio/rayon offline).
//!
//! Two pieces:
//!
//! * [`ThreadPool`] — long-lived workers fed through an MPMC channel built
//!   on `Mutex<VecDeque>` + `Condvar`; used by the coordinator's simulated
//!   DDP workers and the background data pipeline.
//! * [`scoped_for`] — fork-join parallel iteration over index ranges via
//!   `std::thread::scope` (no pool needed; used by the native PAMM benches
//!   to exercise multi-core roofline).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    cond: Condvar,
    shutdown: AtomicBool,
}

/// Fixed-size worker pool. Jobs are FIFO; `join` blocks until all
/// submitted jobs have finished (tracked with a completion counter).
pub struct ThreadPool {
    queue: Arc<Queue>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..threads)
            .map(|_| {
                let q = queue.clone();
                let p = pending.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let mut jobs = q.jobs.lock().unwrap();
                        loop {
                            if let Some(job) = jobs.pop_front() {
                                break job;
                            }
                            if q.shutdown.load(Ordering::Acquire) {
                                return;
                            }
                            jobs = q.cond.wait(jobs).unwrap();
                        }
                    };
                    job();
                    let (lock, cv) = &*p;
                    let mut n = lock.lock().unwrap();
                    *n -= 1;
                    if *n == 0 {
                        cv.notify_all();
                    }
                })
            })
            .collect();
        Self { queue, pending, workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.queue.jobs.lock().unwrap().push_back(Box::new(job));
        self.queue.cond.notify_one();
    }

    /// Block until every submitted job has completed.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n != 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        self.queue.shutdown.store(true, Ordering::Release);
        self.queue.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fork-join parallel for over `0..n`: splits into ≤ `threads` contiguous
/// chunks, runs `f(start, end)` per chunk on scoped threads.
pub fn scoped_for(n: usize, threads: usize, f: impl Fn(usize, usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(start, end));
        }
    });
}

/// Map each element of `inputs` to an output in parallel, preserving order.
pub fn parallel_map<T: Sync, R: Send>(
    inputs: &[T],
    threads: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..inputs.len()).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    scoped_for(inputs.len(), threads, |start, end| {
        let mut local: Vec<(usize, R)> = Vec::with_capacity(end - start);
        for i in start..end {
            local.push((i, f(&inputs[i])));
        }
        let mut guard = slots.lock().unwrap();
        for (i, r) in local {
            guard[i] = Some(r);
        }
    });
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_join_then_reuse() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for round in 1..=3 {
            for _ in 0..10 {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::Relaxed), round * 10);
        }
    }

    #[test]
    fn scoped_for_covers_range_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        scoped_for(n, 8, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let inputs: Vec<usize> = (0..257).collect();
        let out = parallel_map(&inputs, 7, |&x| x * 2);
        assert_eq!(out, inputs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_for_degenerate_cases() {
        scoped_for(0, 4, |s, e| assert_eq!(s, e, "empty range only"));
        let ran = AtomicUsize::new(0);
        scoped_for(3, 16, |s, e| {
            ran.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 3);
    }
}
