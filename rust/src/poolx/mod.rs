//! Scoped thread pool + shared parallel-compute handle (no tokio/rayon
//! offline).
//!
//! Three pieces:
//!
//! * [`ThreadPool`] — long-lived workers fed through an MPMC channel built
//!   on `Mutex<VecDeque>` + `Condvar`; used by the coordinator's simulated
//!   DDP workers, the background data pipeline, and as the engine under
//!   [`Pool`]. Workers survive panicking jobs (the panic is re-raised on
//!   the submitting thread by [`Pool::map_chunks`]).
//! * [`Pool`] — the shared handle the native PAMM hot paths take
//!   (`tensor::Mat::*_with`, `pamm::compress_with`, the experiment
//!   harnesses and benches). It carries a thread count and a tunable
//!   serial-fallback threshold ([`Pool::with_min_chunk`]): inputs smaller
//!   than one chunk run inline on the caller's thread and never touch the
//!   workers, so tiny matrices pay zero synchronization cost. Workers are
//!   spawned lazily on first parallel use. [`global`] is the
//!   process-wide instance configured by `--threads` / `PAMM_THREADS`.
//! * [`scoped_for`] / [`parallel_map`] — fork-join helpers on plain
//!   `std::thread::scope` (no pool needed) for one-shot callers.
//!
//! Every decomposition [`Pool`] hands out is a contiguous partition of
//! `0..n` with deterministic bounds, and the kernels built on it are
//! written so each output element accumulates in the same order at any
//! thread count — results are **bit-identical** for 1, 2, 4, … threads
//! (asserted by `rust/tests/prop_pamm.rs`). Flat outputs are stitched
//! through [`Pool::map_chunks_flat`]: chunks land at their `(start,
//! end)` offsets — never in iteration order — with a debug assert that
//! every range was written exactly once. The GEMM row-block kernels and
//! the attention (batch·head) grid share that one path.
//!
//! Workers are **long-lived threads**, which is what makes the
//! `tensor::kernels` per-thread `Workspace` (packed GEMM panels, Gram /
//! B̃ scratch) effective: each worker's thread-local buffers warm up on
//! first use and are reused by every later `map_chunks` job, so
//! steady-state train-step iterations allocate no kernel scratch.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    cond: Condvar,
    shutdown: AtomicBool,
}

/// Fixed-size worker pool. Jobs are FIFO; `join` blocks until all
/// submitted jobs have finished (tracked with a completion counter).
pub struct ThreadPool {
    queue: Arc<Queue>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..threads)
            .map(|_| {
                let q = queue.clone();
                let p = pending.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let mut jobs = q.jobs.lock().unwrap();
                        loop {
                            if let Some(job) = jobs.pop_front() {
                                break job;
                            }
                            if q.shutdown.load(Ordering::Acquire) {
                                return;
                            }
                            jobs = q.cond.wait(jobs).unwrap();
                        }
                    };
                    // A panicking job must not kill the worker or wedge
                    // `join`: the pending count always decrements, and
                    // `Pool` users observe the panic through their
                    // completion latch and re-raise it at the call site.
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    let (lock, cv) = &*p;
                    let mut n = lock.lock().unwrap();
                    *n -= 1;
                    if *n == 0 {
                        cv.notify_all();
                    }
                })
            })
            .collect();
        Self { queue, pending, workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.queue.jobs.lock().unwrap().push_back(Box::new(job));
        self.queue.cond.notify_one();
    }

    /// Block until every submitted job has completed.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n != 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        self.queue.shutdown.store(true, Ordering::Release);
        self.queue.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Completion latch for one scoped batch of pool jobs: counts jobs down
/// and remembers whether any of them panicked.
struct Latch {
    state: Mutex<(usize, bool)>,
    cond: Condvar,
}

impl Latch {
    fn new(jobs: usize) -> Self {
        Self { state: Mutex::new((jobs, false)), cond: Condvar::new() }
    }

    fn complete(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        st.1 |= panicked;
        if st.0 == 0 {
            self.cond.notify_all();
        }
    }

    /// Wait for all jobs; returns true if any job panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 != 0 {
            st = self.cond.wait(st).unwrap();
        }
        st.1
    }
}

/// Completes its latch when dropped — unwind-safe job bookkeeping.
struct LatchGuard(Arc<Latch>);

impl Drop for LatchGuard {
    fn drop(&mut self) {
        self.0.complete(std::thread::panicking());
    }
}

/// Default serial-fallback threshold: below this many items per chunk,
/// threading overhead beats the win on every shape we measured
/// (EXPERIMENTS.md §Perf), so [`Pool::chunks_for`] degrades to 1 chunk.
pub const DEFAULT_MIN_CHUNK: usize = 256;

/// Cap for auto-detected parallelism (diminishing returns past this for
/// the memory-bound PAMM kernels).
pub const MAX_AUTO_THREADS: usize = 16;

/// Hard cap on explicit thread requests — a typo'd `--threads` or a
/// bad config value must not try to spawn an unbounded worker count.
pub const MAX_POOL_THREADS: usize = 256;

/// Fallback threshold for *column-strip* kernels (`matmul_tn`,
/// `apply`): a column's cost scales with the row count, so strips are
/// allowed to be much narrower than the row-oriented
/// [`DEFAULT_MIN_CHUNK`].
pub const COLUMN_MIN_CHUNK: usize = 32;

/// Fallback threshold for coarse-grained *task grids* (the attention
/// subsystem's (batch·head) slabs): one item is already a whole tile
/// walk over a sequence, so splitting pays from the second item onward.
pub const TASK_MIN_CHUNK: usize = 1;

/// Shared parallel-compute handle: a thread count, a serial-fallback
/// threshold, and a lazily-spawned [`ThreadPool`]. Cheap to clone (clones
/// share the workers). See the module docs for the determinism contract.
///
/// `map_chunks` must not be called from inside one of its own jobs
/// (no nested parallelism) — with every worker blocked on the inner
/// latch the pool would deadlock. The native kernels are all leaf
/// computations, so this never arises on the shipped paths.
#[derive(Clone)]
pub struct Pool {
    threads: usize,
    min_chunk: usize,
    /// True once `with_min_chunk` ran — lets [`Pool::for_columns`]
    /// distinguish "still the default" from an explicit request for the
    /// same value.
    min_chunk_custom: bool,
    workers: Arc<OnceLock<ThreadPool>>,
}

impl Pool {
    /// Pool that will use up to `threads` threads (clamped to
    /// 1..=[`MAX_POOL_THREADS`]).
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.clamp(1, MAX_POOL_THREADS),
            min_chunk: DEFAULT_MIN_CHUNK,
            min_chunk_custom: false,
            workers: Arc::new(OnceLock::new()),
        }
    }

    /// Single-threaded pool: every `map_chunks` call runs inline.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Pool sized to the host (`available_parallelism`, capped at
    /// [`MAX_AUTO_THREADS`]).
    pub fn auto() -> Self {
        let t = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1);
        Self::new(t.min(MAX_AUTO_THREADS))
    }

    /// Override the serial-fallback threshold (items per chunk). The
    /// returned handle shares this pool's workers. A custom value is
    /// honored by every kernel, including the column-strip ones (see
    /// [`Pool::for_columns`]) — set it huge to force inline execution.
    pub fn with_min_chunk(mut self, min_chunk: usize) -> Self {
        self.min_chunk = min_chunk.max(1);
        self.min_chunk_custom = true;
        self
    }

    /// Handle for column-strip kernels: if the threshold was never
    /// customized, tighten it from the row-oriented
    /// [`DEFAULT_MIN_CHUNK`] to [`COLUMN_MIN_CHUNK`] (a column's cost
    /// scales with rows, so much narrower chunks are worth splitting).
    /// Any `with_min_chunk` value — including one equal to the default
    /// — is kept as-is, so it remains an effective "never/always split"
    /// override for these kernels too.
    pub fn for_columns(&self) -> Pool {
        if self.min_chunk_custom {
            self.clone()
        } else {
            self.clone().with_min_chunk(COLUMN_MIN_CHUNK)
        }
    }

    /// Handle for coarse-grained task grids (one item = one attention
    /// (batch, head) tile walk): like [`Pool::for_columns`], an
    /// uncustomized threshold is tightened — here all the way to
    /// [`TASK_MIN_CHUNK`] — while an explicit `with_min_chunk` value is
    /// kept as-is, so "never split" overrides still work.
    pub fn for_tasks(&self) -> Pool {
        if self.min_chunk_custom {
            self.clone()
        } else {
            self.clone().with_min_chunk(TASK_MIN_CHUNK)
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn min_chunk(&self) -> usize {
        self.min_chunk
    }

    /// How many chunks `0..n` will be split into: 1 (serial) when `n` is
    /// below the fallback threshold, else at most `threads`.
    pub fn chunks_for(&self, n: usize) -> usize {
        if self.threads == 1 || n == 0 {
            return 1;
        }
        (n / self.min_chunk).clamp(1, self.threads)
    }

    fn workers(&self) -> &ThreadPool {
        self.workers.get_or_init(|| ThreadPool::new(self.threads))
    }

    /// Partition `0..n` into contiguous chunks, evaluate `f(start, end)`
    /// per chunk on the worker pool, and return `(start, end, result)`
    /// per chunk in range order. Runs inline when [`Pool::chunks_for`]
    /// says 1. A panic inside `f` is re-raised here after all chunks
    /// finish.
    pub fn map_chunks<R: Send>(
        &self,
        n: usize,
        f: impl Fn(usize, usize) -> R + Sync,
    ) -> Vec<(usize, usize, R)> {
        let chunks = self.chunks_for(n);
        if chunks <= 1 {
            return vec![(0, n, f(0, n))];
        }
        let chunk = n.div_ceil(chunks);
        let bounds: Vec<(usize, usize)> = (0..chunks)
            .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
            .filter(|(s, e)| s < e)
            .collect();
        let slots: Vec<Mutex<Option<R>>> = bounds.iter().map(|_| Mutex::new(None)).collect();
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = bounds
                .iter()
                .enumerate()
                .map(|(ix, &(s, e))| {
                    let f = &f;
                    let slots = &slots;
                    Box::new(move || {
                        *slots[ix].lock().unwrap() = Some(f(s, e));
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            self.execute_scoped(jobs);
        }
        bounds
            .into_iter()
            .zip(slots)
            .map(|((s, e), slot)| {
                (s, e, slot.into_inner().unwrap().expect("poolx: chunk result missing"))
            })
            .collect()
    }

    /// Partition `0..n`, run `f(start, end, chunk_out)` per chunk —
    /// `chunk_out` is the zeroed `(end-start)·width` window of one
    /// shared `n·width` output, carved out with `split_at_mut` at the
    /// chunk's `start·width` offset. This is the generalized `(start,
    /// end)` offset-write path shared by the GEMM row-block kernels
    /// (`Mat::matmul_with`, `Mat::row_norms_with`) and the attention
    /// (batch·head) task grid: results land by *range*, never by
    /// chunk-iteration or append order, there are **no per-chunk
    /// temporaries** (workers write the final buffer in place — no
    /// output-sized transient at stitch time), and a debug assert
    /// checks the chunk ranges tile `0..n` exactly once (no gap, no
    /// overlap, no double write).
    ///
    /// Runs inline (one allocation, no workers) when
    /// [`Pool::chunks_for`] says 1.
    pub fn map_chunks_flat<T: Send + Copy + Default>(
        &self,
        n: usize,
        width: usize,
        f: impl Fn(usize, usize, &mut [T]) + Sync,
    ) -> Vec<T> {
        let chunks = self.chunks_for(n);
        let mut out = vec![T::default(); n * width];
        if chunks <= 1 || width == 0 {
            // width 0 ⇒ empty output; one inline call keeps f's side
            // effects without a zero-sized chunks_mut panic.
            f(0, n, &mut out);
            return out;
        }
        let chunk = n.div_ceil(chunks);
        let bounds: Vec<(usize, usize)> = (0..chunks)
            .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
            .filter(|(s, e)| s < e)
            .collect();
        #[cfg(debug_assertions)]
        {
            let mut expect = 0usize;
            for &(s, e) in &bounds {
                assert_eq!(s, expect, "poolx: chunk ranges must tile 0..{n} exactly once");
                expect = e;
            }
            assert_eq!(expect, n, "poolx: stitch left a gap — some range never written");
        }
        {
            // chunks_mut(chunk·width) carves exactly the bounds
            // partition out of `out` — disjoint windows, written in
            // place by the workers.
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = bounds
                .iter()
                .zip(out.chunks_mut(chunk * width))
                .map(|(&(s, e), window)| {
                    debug_assert_eq!(window.len(), (e - s) * width);
                    let f = &f;
                    Box::new(move || f(s, e, window)) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            self.execute_scoped(jobs);
        }
        out
    }

    /// [`Pool::map_chunks_flat`] with TWO flat outputs of independent
    /// per-item widths, carved from the same chunk partition: each
    /// worker gets `f(start, end, w1_window, w2_window)` where the
    /// windows are the zeroed `(end-start)·width` slices of the two
    /// shared outputs at the chunk's offset. Same determinism story as
    /// the one-output form (results land by range, no per-chunk
    /// temporaries); the attention training forward uses it to write
    /// its output slab and its per-row softmax statistics in one pass
    /// without a packed intermediate.
    ///
    /// Runs inline when [`Pool::chunks_for`] says 1 (or either width is
    /// 0 — a zero-sized `chunks_mut` would panic; the inline call keeps
    /// `f`'s writes to the non-empty output).
    pub fn map_chunks_flat2<T: Send + Copy + Default>(
        &self,
        n: usize,
        w1: usize,
        w2: usize,
        f: impl Fn(usize, usize, &mut [T], &mut [T]) + Sync,
    ) -> (Vec<T>, Vec<T>) {
        let chunks = self.chunks_for(n);
        let mut out1 = vec![T::default(); n * w1];
        let mut out2 = vec![T::default(); n * w2];
        if chunks <= 1 || w1 == 0 || w2 == 0 {
            f(0, n, &mut out1, &mut out2);
            return (out1, out2);
        }
        let chunk = n.div_ceil(chunks);
        let bounds: Vec<(usize, usize)> = (0..chunks)
            .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
            .filter(|(s, e)| s < e)
            .collect();
        #[cfg(debug_assertions)]
        {
            let mut expect = 0usize;
            for &(s, e) in &bounds {
                assert_eq!(s, expect, "poolx: chunk ranges must tile 0..{n} exactly once");
                expect = e;
            }
            assert_eq!(expect, n, "poolx: stitch left a gap — some range never written");
        }
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = bounds
                .iter()
                .zip(out1.chunks_mut(chunk * w1).zip(out2.chunks_mut(chunk * w2)))
                .map(|(&(s, e), (win1, win2))| {
                    debug_assert_eq!(win1.len(), (e - s) * w1);
                    debug_assert_eq!(win2.len(), (e - s) * w2);
                    let f = &f;
                    Box::new(move || f(s, e, win1, win2)) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            self.execute_scoped(jobs);
        }
        (out1, out2)
    }

    /// Run a batch of borrowed jobs on the worker pool and wait for all
    /// of them. The latch wait is what makes the lifetime erasure sound:
    /// no job can outlive this call.
    fn execute_scoped<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        let pool = self.workers();
        let latch = Arc::new(Latch::new(jobs.len()));
        for job in jobs {
            // SAFETY: `latch.wait()` below blocks until every job's
            // LatchGuard has dropped, i.e. until every job has finished
            // running (or unwound), so the borrows inside `job` are live
            // for the whole time the workers can touch them.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job)
            };
            let latch = latch.clone();
            pool.submit(move || {
                let _done = LatchGuard(latch);
                job();
            });
        }
        if latch.wait() {
            panic!("poolx: worker job panicked");
        }
    }
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Pool(threads={}, min_chunk={})", self.threads, self.min_chunk)
    }
}

static GLOBAL_POOL: OnceLock<Pool> = OnceLock::new();

fn make_pool(threads: usize) -> Pool {
    if threads == 0 {
        Pool::auto()
    } else {
        Pool::new(threads)
    }
}

/// Configure the process-wide pool (0 = auto). First caller wins — the
/// CLI calls this with `--threads` before any compute runs; later calls
/// (e.g. a config-file value after the flag) are ignored and return
/// false.
pub fn set_global_threads(threads: usize) -> bool {
    GLOBAL_POOL.set(make_pool(threads)).is_ok()
}

/// The process-wide pool used by the default `pamm::compress` / `apply` /
/// matmul entry points. Initialized from `PAMM_THREADS` (or host
/// parallelism) on first use unless [`set_global_threads`] ran earlier.
pub fn global() -> &'static Pool {
    GLOBAL_POOL.get_or_init(|| {
        let env = std::env::var("PAMM_THREADS").ok().and_then(|v| v.parse::<usize>().ok());
        make_pool(env.unwrap_or(0))
    })
}

/// Fork-join parallel for over `0..n`: splits into ≤ `threads` contiguous
/// chunks, runs `f(start, end)` per chunk on scoped threads.
pub fn scoped_for(n: usize, threads: usize, f: impl Fn(usize, usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(start, end));
        }
    });
}

/// Map each element of `inputs` to an output in parallel, preserving order.
pub fn parallel_map<T: Sync, R: Send>(
    inputs: &[T],
    threads: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..inputs.len()).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    scoped_for(inputs.len(), threads, |start, end| {
        let mut local: Vec<(usize, R)> = Vec::with_capacity(end - start);
        for i in start..end {
            local.push((i, f(&inputs[i])));
        }
        let mut guard = slots.lock().unwrap();
        for (i, r) in local {
            guard[i] = Some(r);
        }
    });
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_join_then_reuse() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for round in 1..=3 {
            for _ in 0..10 {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::Relaxed), round * 10);
        }
    }

    #[test]
    fn scoped_for_covers_range_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        scoped_for(n, 8, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let inputs: Vec<usize> = (0..257).collect();
        let out = parallel_map(&inputs, 7, |&x| x * 2);
        assert_eq!(out, inputs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_for_degenerate_cases() {
        scoped_for(0, 4, |s, e| assert_eq!(s, e, "empty range only"));
        let ran = AtomicUsize::new(0);
        scoped_for(3, 16, |s, e| {
            ran.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn map_chunks_covers_range_in_order() {
        let pool = Pool::new(4).with_min_chunk(1);
        let res = pool.map_chunks(103, |s, e| (s..e).sum::<usize>());
        assert!(res.len() > 1, "expected a parallel split, got {}", res.len());
        let mut expect_start = 0;
        let mut total = 0;
        for &(s, e, sum) in &res {
            assert_eq!(s, expect_start, "chunks must be contiguous");
            expect_start = e;
            total += sum;
        }
        assert_eq!(expect_start, 103);
        assert_eq!(total, (0..103).sum::<usize>());
    }

    #[test]
    fn small_inputs_fall_back_to_serial() {
        let pool = Pool::new(8).with_min_chunk(512);
        assert_eq!(pool.chunks_for(511), 1);
        assert_eq!(pool.chunks_for(512), 1);
        assert_eq!(pool.chunks_for(1024), 2);
        assert_eq!(pool.chunks_for(1_000_000), 8);
        // Serial fallback runs inline on the calling thread.
        let main_id = std::thread::current().id();
        let res = pool.map_chunks(100, |s, e| (std::thread::current().id() == main_id, s, e));
        assert_eq!(res.len(), 1);
        let (inline, s, e) = res[0].2;
        assert!(inline, "below-threshold work must not hit the workers");
        assert_eq!((s, e), (0, 100));
    }

    #[test]
    fn map_chunks_reuses_workers_across_calls() {
        let pool = Pool::new(3).with_min_chunk(1);
        for round in 1..=4 {
            let res = pool.map_chunks(30, |s, e| e - s);
            let total: usize = res.iter().map(|&(_, _, r)| r).sum();
            assert_eq!(total, 30, "round {round}");
        }
    }

    #[test]
    fn map_chunks_propagates_panics() {
        let pool = Pool::new(2).with_min_chunk(1);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map_chunks(8, |s, _e| {
                if s == 0 {
                    panic!("boom");
                }
                0usize
            });
        }));
        assert!(caught.is_err(), "panic in a chunk must surface to the caller");
        // Pool must still be usable afterwards.
        let res = pool.map_chunks(8, |s, e| e - s);
        assert_eq!(res.iter().map(|&(_, _, r)| r).sum::<usize>(), 8);
    }

    #[test]
    fn map_chunks_flat_matches_serial_and_covers_every_range() {
        // Fill out[i·w..(i+1)·w] with i so any misplaced chunk shows.
        let fill = |s: usize, e: usize, buf: &mut [usize]| {
            for i in s..e {
                for v in &mut buf[(i - s) * 3..(i - s + 1) * 3] {
                    *v = i;
                }
            }
        };
        let serial = Pool::serial().map_chunks_flat(101, 3, fill);
        let parallel = Pool::new(4).with_min_chunk(1).map_chunks_flat(101, 3, fill);
        assert_eq!(serial, parallel);
        for i in 0..101 {
            assert_eq!(&serial[i * 3..(i + 1) * 3], &[i, i, i]);
        }
        // Width 0 degenerates to an empty output without panicking.
        assert!(Pool::new(2).with_min_chunk(1).map_chunks_flat(8, 0, |_, _, _| {}).is_empty());
    }

    #[test]
    fn map_chunks_flat2_matches_serial_on_both_outputs() {
        // out1[i·2..] = i doubled, out2[i] = i² — any misplaced chunk
        // or swapped window shows immediately.
        let fill = |s: usize, e: usize, a: &mut [usize], b: &mut [usize]| {
            for i in s..e {
                a[(i - s) * 2] = i;
                a[(i - s) * 2 + 1] = i;
                b[i - s] = i * i;
            }
        };
        let (sa, sb) = Pool::serial().map_chunks_flat2(97, 2, 1, fill);
        let (pa, pb) = Pool::new(4).with_min_chunk(1).map_chunks_flat2(97, 2, 1, fill);
        assert_eq!(sa, pa);
        assert_eq!(sb, pb);
        for i in 0..97 {
            assert_eq!(&sa[i * 2..i * 2 + 2], &[i, i]);
            assert_eq!(sb[i], i * i);
        }
        // A zero width degrades to the inline path without panicking.
        let (a, b) =
            Pool::new(2).with_min_chunk(1).map_chunks_flat2(8, 0, 1, |_, _, _, _| {});
        assert!(a.is_empty());
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn for_tasks_threshold() {
        // Uncustomized pools split task grids from the second item on…
        let pool = Pool::new(4);
        assert_eq!(pool.chunks_for(4), 1, "row-oriented default stays serial at 4 items");
        assert_eq!(pool.for_tasks().chunks_for(4), 4);
        assert_eq!(pool.for_tasks().chunks_for(1), 1);
        // …while an explicit min-chunk override is honored as-is.
        let forced = Pool::new(4).with_min_chunk(1_000_000);
        assert_eq!(forced.for_tasks().chunks_for(4), 1);
    }

    #[test]
    fn global_pool_is_configured_once() {
        // Whichever runs first (this test or a kernel using global())
        // fixes the pool; the second set call must report failure.
        let first = set_global_threads(2);
        let second = set_global_threads(4);
        assert!(!second || first, "second set cannot succeed after a first");
        assert!(global().threads() >= 1);
    }
}
