//! # pamm — "QKV Projections Require a Fraction of Their Memory"
//!
//! Production-grade reproduction of PAMM (Point-Approximate Matrix
//! Multiplication) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels`) — Pallas kernels: PAMM compress /
//!   one-hot-matmul apply, flash attention (build time only).
//! * **L2** (`python/compile`) — JAX LLaMA-family model with PAMM
//!   custom-vjp projections, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3** (this crate) — the runtime: PJRT engine, training
//!   coordinator, native PAMM twin (parallel on the shared `poolx`
//!   pool, `--threads`), the fused flash-attention subsystem
//!   (`attention`: tiled online softmax consuming PAMM-compressed
//!   Q/K/V), the compressed-activation autograd (`autograd`: a
//!   reverse-mode **multi-op graph tape** — embedding, layernorm,
//!   fused PAMM-QKV attention, residual, PAMM MLP, tied head, softmax
//!   cross-entropy — whose projection nodes save only the `Compressed`
//!   struct + O(seq) softmax statistics, with a measured per-phase
//!   memory ledger), the GPT-style native LM built on it (`model`:
//!   config-driven layer count, trained end to end by `pamm train
//!   --native` through `coordinator::LmTrainer` with checkpointed
//!   resume), the inference subsystem (`generate`: prefill +
//!   incremental greedy decode over a PAMM-compressed KV cache,
//!   `coordinator::serve`: deterministic continuous-batching loop,
//!   `pamm generate` / `pamm serve-sim`), data pipeline, memory
//!   accountant, the fault-injection & recovery subsystem (`faultx`:
//!   seeded crash/corruption/poison plans, crash-safe checkpoint ring,
//!   the `pamm chaos` campaign), experiment harness (one per paper
//!   table/figure — see DESIGN.md).
//!
//! Python never runs on the request path: `make artifacts` once, then the
//! Rust binary is self-contained.
//!
//! Documentation trail: README.md (overview + quickstart), DESIGN.md
//! (harness ↔ paper mapping), EXPERIMENTS.md (recorded runs, §Perf),
//! BENCHMARKS.md (rendered from the persisted `benchmarks/BENCH_*.json`
//! via `pamm bench-report`).

pub mod attention;
pub mod autograd;
pub mod benchx;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod faultx;
pub mod generate;
pub mod jsonx;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod pamm;
pub mod poolx;
pub mod propx;
pub mod rngx;
pub mod runtime;
pub mod tensor;
