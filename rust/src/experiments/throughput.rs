//! Throughput & runtime-breakdown experiments: Tables 2a, 2b, 7/8 —
//! plus the **native train-step harness** (`pamm reproduce table7
//! --native`, EXPERIMENTS.md P11): real fwd → loss → bwd → Adam
//! optimization of a PAMM-compressed QKV+attention block through
//! `crate::autograd`, with the measured per-phase memory ledger.
//!
//! The native per-op timers (table7) run on the process-wide poolx pool
//! (`--threads`; the breakdown header records the count), so the
//! breakdown reflects the same parallel kernels the benches measure.
//! Results are thread-count invariant; only the timings change.

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::attention::{self, AttnShape};
use crate::autograd;
use crate::benchx::{bench_fn, BenchOpts};
use crate::checkpoint::write_csv;
#[cfg(feature = "pjrt")]
use crate::config::Variant;
#[cfg(feature = "pjrt")]
use crate::coordinator::session::TrainSession;
use crate::coordinator::{NativeOpt, NativeTrainer};
#[cfg(feature = "pjrt")]
use crate::data::batcher::BatchIterator;
use crate::memory::{fmt_bytes, MemoryLedger};
use crate::pamm::{self, Eps};
use crate::poolx::{self, Pool};
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
use crate::rngx::Xoshiro256;
use crate::tensor::Mat;

fn opts(quick: bool) -> BenchOpts {
    if quick {
        BenchOpts { warmup_iters: 1, min_iters: 3, max_iters: 5, max_total: std::time::Duration::from_secs(20) }
    } else {
        BenchOpts { warmup_iters: 2, min_iters: 8, max_iters: 15, max_total: std::time::Duration::from_secs(90) }
    }
}

/// Median seconds per training step for (model, variant).
#[cfg(feature = "pjrt")]
fn step_time(engine: &Engine, model: &str, var: &Variant, b: usize, l: usize, quick: bool) -> Result<f64> {
    let train_name = format!("train_{model}_{}_{b}x{l}", var.tag());
    let mut session = TrainSession::new(engine, &train_name, None, 7)?;
    let vocab = engine.manifest.config(model).context("config")?.vocab;
    let mut it = BatchIterator::from_seed(vocab, b, l, 7);
    let batches: Vec<_> = (0..4).map(|_| it.next_batch().to_tensor()).collect();
    let mut i = 0;
    let r = bench_fn(&train_name, &opts(quick), || {
        session.step(&batches[i % batches.len()]).expect("step");
        i += 1;
    });
    Ok(r.median_secs())
}

/// Table 2a: tokens/sec across model sizes, PAMM vs baseline.
#[cfg(feature = "pjrt")]
pub fn table2a(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let sizes: &[(&str, usize, usize)] =
        if quick { &[("tiny", 8, 128)] } else { &[("tiny", 8, 128), ("small", 8, 128), ("medium", 4, 256)] };
    let mut rows = Vec::new();
    println!(
        "{:<8} {:>14} {:>14} {:>12}",
        "model", "pamm tok/s", "base tok/s", "degradation"
    );
    for &(model, b, l) in sizes {
        let toks = (b * l) as f64;
        let t_base = step_time(engine, model, &Variant::baseline(), b, l, quick)?;
        let t_pamm = step_time(engine, model, &Variant::pamm(512), b, l, quick)?;
        let (r_base, r_pamm) = (toks / t_base, toks / t_pamm);
        let deg = 100.0 * (1.0 - r_pamm / r_base);
        println!("{model:<8} {r_pamm:>14.0} {r_base:>14.0} {deg:>11.2}%");
        rows.push(format!("{model},{r_pamm},{r_base},{deg}"));
    }
    write_csv(format!("{out}/table2a.csv"), "model,pamm_tok_s,base_tok_s,degradation_pct", &rows)?;
    println!("\nshape check: degradation shrinks as model size grows (paper Table 2a).");
    Ok(())
}

/// Table 2b: forward-pass vs total-step throughput split.
#[cfg(feature = "pjrt")]
pub fn table2b(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let (model, b, l) = ("tiny", 8usize, 128usize);
    let toks = (b * l) as f64;
    let vocab = engine.manifest.config(model).context("config")?.vocab;
    let mut it = BatchIterator::from_seed(vocab, b, l, 9);
    let batches: Vec<_> = (0..4).map(|_| it.next_batch().to_tensor()).collect();

    let mut rows = Vec::new();
    println!("{:<10} {:>14} {:>14} {:>12}", "phase", "base tok/s", "pamm tok/s", "degradation");
    let mut results = Vec::new();
    for var in [Variant::baseline(), Variant::pamm(512)] {
        // Forward-only throughput via the eval artifact (loss fwd pass).
        let session = TrainSession::new(
            engine,
            &format!("train_{model}_{}_{b}x{l}", var.tag()),
            Some(&format!("eval_{model}_{b}x{l}")),
            9,
        )?;
        let mut i = 0;
        let fwd = bench_fn("fwd", &opts(quick), || {
            session.eval(std::slice::from_ref(&batches[i % batches.len()])).expect("eval");
            i += 1;
        })
        .median_secs();
        let total = step_time(engine, model, &var, b, l, quick)?;
        // Backward+update time = total − forward.
        let bwd = (total - fwd).max(1e-9);
        results.push((var.tag(), toks / fwd, toks / bwd, toks / total));
    }
    for phase in 0..3 {
        let name = ["forward", "backward", "total"][phase];
        let pick = |r: &(String, f64, f64, f64)| match phase {
            0 => r.1,
            1 => r.2,
            _ => r.3,
        };
        let base = pick(&results[0]);
        let pamm = pick(&results[1]);
        let deg = 100.0 * (1.0 - pamm / base);
        println!("{name:<10} {base:>14.0} {pamm:>14.0} {deg:>11.2}%");
        rows.push(format!("{name},{base},{pamm},{deg}"));
    }
    write_csv(format!("{out}/table2b.csv"), "phase,base_tok_s,pamm_tok_s,degradation_pct", &rows)?;
    println!("\nnote: eval fwd omits the compress step only in baseline; PAMM fwd includes compression (paper Table 2b shape: small fwd overhead, smaller bwd overhead).");
    Ok(())
}

/// Tables 7/8: per-op runtime breakdown of PAMM forward & backward, on the
/// native twin at a paper-like per-GPU shape (b=4096, n=m=512; the paper's
/// 16384 scaled /4 to keep the naive-matmul baseline in seconds).
pub fn table7(quick: bool, out: &str) -> Result<()> {
    let (b, n, m, k) = if quick { (1024, 256, 256, 8) } else { (4096, 512, 512, 16) };
    let mut rng = Xoshiro256::new(0x7AB7E);
    let a = Mat::random_normal(b, n, 1.0, &mut rng);
    let w = Mat::random_normal(n, m, 0.05, &mut rng);
    let dz = Mat::random_normal(b, m, 1.0, &mut rng);
    let o = opts(quick);
    let pool = poolx::global();

    // ---- forward ops ------------------------------------------------------
    let fwd_matmul = bench_fn("fwd matmul x@w", &o, || {
        std::hint::black_box(a.matmul_with(&w, pool));
    })
    .median_secs();
    let mut rng2 = Xoshiro256::new(1);
    let idx_sel = bench_fn("index selection", &o, || {
        std::hint::black_box(pamm::sample_generators(&mut rng2, b, k));
    })
    .median_secs();
    let idx = pamm::sample_generators(&mut rng, b, k);
    let c = a.gather_rows(&idx);
    let normalization = bench_fn("normalization", &o, || {
        std::hint::black_box(a.row_norms_with(pool));
        std::hint::black_box(c.row_norms());
    })
    .median_secs();
    let ct = c.transpose();
    let cosine = bench_fn("cosine matmul A·Cᵀ", &o, || {
        std::hint::black_box(a.matmul_with(&ct, pool));
    })
    .median_secs();
    let compress_total = bench_fn("compress total", &o, || {
        std::hint::black_box(pamm::compress_with(&a, &idx, Eps::Inf, pool));
    })
    .median_secs();
    let max_assign = (compress_total - cosine - normalization).max(0.0);

    // ---- backward ops -----------------------------------------------------
    let comp = pamm::compress_with(&a, &idx, Eps::Inf, pool);
    let wt = w.transpose();
    let input_grad = bench_fn("input grad dz@wᵀ", &o, || {
        std::hint::black_box(dz.matmul_with(&wt, pool));
    })
    .median_secs();
    let apply_total = bench_fn("apply total", &o, || {
        std::hint::black_box(pamm::apply_with(&comp, &dz, pool));
    })
    .median_secs();
    let exact_dw = bench_fn("exact dW = XᵀdZ", &o, || {
        std::hint::black_box(pamm::exact_matmul_with(&a, &dz, pool));
    })
    .median_secs();

    let fwd_total = fwd_matmul + idx_sel + compress_total;
    let bwd_total = input_grad + apply_total;
    println!(
        "PAMM forward breakdown (b={b}, n={n}, m={m}, k={k}, threads={}):",
        pool.threads()
    );
    let mut rows = Vec::new();
    for (name, t) in [
        ("forward matmul", fwd_matmul),
        ("index selection", idx_sel),
        ("normalization", normalization),
        ("cosine matmul", cosine),
        ("max/assign", max_assign),
        ("PAMM forward total", fwd_total),
    ] {
        println!("  {:<22} {:>9.3} ms  {:>6.1}% of fwd", name, t * 1e3, 100.0 * t / fwd_total);
        rows.push(format!("fwd,{name},{}", t * 1e3));
    }
    println!("PAMM backward breakdown:");
    for (name, t) in [
        ("input grad matmul", input_grad),
        ("approx dW (apply)", apply_total),
        ("PAMM backward total", bwd_total),
        ("exact dW baseline", exact_dw),
    ] {
        println!("  {:<22} {:>9.3} ms  {:>6.1}% of bwd", name, t * 1e3, 100.0 * t / bwd_total);
        rows.push(format!("bwd,{name},{}", t * 1e3));
    }
    println!(
        "\nspeedup of approx dW over exact dW: {:.1}× (paper App. J: γ = bm/(k(b+m)) = {:.1})",
        exact_dw / apply_total,
        (b * m) as f64 / (k * (b + m)) as f64
    );
    write_csv(format!("{out}/table7.csv"), "phase,op,ms", &rows)?;
    Ok(())
}

/// `pamm reproduce table7 --native` (P11): the per-op breakdown above
/// times ops in isolation — this harness runs REAL optimization
/// through the native autograd (fwd → MSE loss → compressed bwd → Adam
/// update), prints the loss trajectory, and renders the measured
/// per-phase memory ledger of one cold tracked step, asserting the
/// acceptance bounds in-harness:
///
/// * saved-for-backward bytes == `Compressed::stored_bytes()` + the
///   O(seq) softmax statistics, and at least 4× below the dense
///   baseline (X + Q/K/V + stats) at the harness shapes;
/// * measured backward-transient peak ≤ `autograd::backward_peak_bound`.
///
/// Cold-measurement protocol per P10/P12: the ledger step runs on a
/// fresh pool from a fresh thread so per-worker TLS growth is visible.
pub fn table7_native(quick: bool, out: &str) -> Result<()> {
    let (b, h, l, d, k, steps) =
        if quick { (1, 2, 128, 32, 16, 12) } else { (2, 4, 256, 64, 32, 40) };
    let shape = AttnShape::new(b, h, l, d, true);
    let dm = shape.d_model();
    let pool = poolx::global();
    println!(
        "native train step (b={b} h={h} l={l} d={d} k={k}, threads={}, {} steps, Adam):",
        pool.threads(),
        steps
    );

    // Teacher-student: the target is the dense attention output of a
    // fixed teacher, so the loss has a real minimum to move toward.
    let mut rng = Xoshiro256::new(0x7EAC);
    let x = Mat::random_normal(shape.tokens(), dm, 1.0, &mut rng);
    let tq = Mat::random_normal(dm, dm, 0.05, &mut rng);
    let tk = Mat::random_normal(dm, dm, 0.05, &mut rng);
    let tv = Mat::random_normal(dm, dm, 0.05, &mut rng);
    let project = |w: &Mat| attention::split_heads(&x.matmul_with(w, pool), &shape);
    let target = attention::flash_attention_with(&project(&tq), &project(&tk), &project(&tv), &shape, pool);

    let mut trainer = NativeTrainer::new(shape, k, NativeOpt::adam(2e-3), 42);
    let mut rows = Vec::new();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let loss = trainer.train_step_native(&x, &target, pool, None);
        if s == 0 {
            first = loss;
        }
        last = loss;
        if s % (steps / 8).max(1) == 0 || s + 1 == steps {
            println!("  step {s:>3}  loss {loss:.6}");
        }
        rows.push(format!("{s},{loss}"));
    }
    let per_step = t0.elapsed().as_secs_f64() / steps as f64;
    println!(
        "  loss {first:.6} -> {last:.6} over {steps} steps ({:.1} ms/step, {:.0} tok/s)",
        per_step * 1e3,
        shape.tokens() as f64 / per_step
    );
    assert!(
        last < first,
        "native optimization must reduce the loss: first {first}, last {last}"
    );

    // One tracked step under the cold protocol: fresh pool + fresh
    // caller thread, so worker-TLS scratch growth is measured, not
    // hidden by warm reuse.
    let ledger = MemoryLedger::new();
    let threads = pool.threads();
    let mut saved_bytes = 0usize;
    std::thread::scope(|sc| {
        sc.spawn(|| {
            let cold = Pool::new(threads);
            let mut t2 = NativeTrainer::new(shape, k, NativeOpt::adam(2e-3), 42);
            let rep = t2.step_report(
                crate::tensor::kernels::active(),
                &x,
                &target,
                &cold,
                Some(&ledger),
            );
            saved_bytes = rep.saved_bytes;
        });
    });
    // The bound depends only on the compression geometry (k, n_in).
    let bwd_bound = autograd::backward_peak_bound(k, dm, &shape, threads, false);
    let dense = autograd::dense_saved_bytes(dm, &shape);
    println!("\nmemory ledger (cold tracked step, {threads} thread(s)):");
    print!("{}", ledger.render(dense));
    println!(
        "  backward transient peak {} ≤ backward_peak_bound {}",
        fmt_bytes(ledger.backward.peak()),
        fmt_bytes(bwd_bound)
    );
    assert_eq!(ledger.saved(), saved_bytes, "ledger must record the tape node exactly");
    assert!(
        ledger.saved() * 4 <= dense,
        "saved-for-backward {} not ≥4× below the dense baseline {dense}",
        ledger.saved()
    );
    assert!(
        ledger.backward.peak() <= bwd_bound,
        "measured backward peak {} exceeds the analytic bound {bwd_bound}",
        ledger.backward.peak()
    );
    rows.push(format!("ledger_saved_bytes,{}", ledger.saved()));
    rows.push(format!("ledger_fwd_peak,{}", ledger.forward.peak()));
    rows.push(format!("ledger_bwd_peak,{}", ledger.backward.peak()));
    rows.push(format!("dense_saved_baseline,{dense}"));
    write_csv(format!("{out}/table7_native.csv"), "step,loss", &rows)?;
    println!("\nshape check: the saved column shrinks with k while fwd/bwd transient peaks track tile scratch and gradient slabs — the paper's Table 7 memory story, measured.");
    Ok(())
}
