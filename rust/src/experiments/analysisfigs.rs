//! Appendix-H analysis figures on *captured* activations: Fig 5 (PCA
//! cluster EDA), Fig 6 (relative L2 error grid), Fig 7 (coverage grid).
//!
//! Capture path: train `tiny` briefly through the PJRT stack, pull the
//! embedding + first-layer norm gain from the checkpointed params, and
//! compute `X₀ = rmsnorm(embed[tokens]) · g` natively — this is *exactly*
//! the input the first attention block's Q/K/V projections see. (The
//! paper uses layer 3 of LLaMA-60M at step 3000; layer-0 input at a
//! smaller step is the same tensor species — substitution recorded in
//! DESIGN.md.) The "gradient" matrix B for Fig 6 is synthetic Gaussian
//! (the real ∇K is not observable from outside the fused HLO step without
//! a dedicated capture artifact; error *shape* over (r, ε) is what the
//! figure demonstrates).

use anyhow::{Context, Result};

use crate::checkpoint::write_csv;
use crate::config::{RunConfig, Variant};
use crate::coordinator::session::TrainSession;
use crate::coordinator::pipeline::BatchPipeline;
use crate::data::batcher::BatchIterator;
use crate::pamm::{self, analysis, Eps};
use crate::runtime::Engine;
use crate::rngx::Xoshiro256;
use crate::tensor::Mat;

/// Train briefly and return X₀ = rmsnorm(embed[tokens]) ⊙ g₀  (b × d).
fn capture_activation(engine: &Engine, quick: bool) -> Result<Mat> {
    let cfg = RunConfig {
        model: "tiny".into(),
        variant: Variant::pamm(512),
        batch: 8,
        seq: 128,
        steps: if quick { 15 } else { 100 },
        seed: 42,
        ..Default::default()
    };
    let vocab = engine.manifest.config("tiny").context("tiny config")?.vocab;
    let mut session =
        TrainSession::new(engine, &cfg.train_artifact(), None, cfg.seed)?;
    let pipe = BatchPipeline::spawn(
        BatchIterator::from_seed(vocab, cfg.batch, cfg.seq, cfg.seed),
        2,
    );
    for _ in 0..cfg.steps {
        let b = pipe.next();
        session.step(&b.to_tensor())?;
    }
    let params = session.params_host()?;
    let embed = params.iter().find(|(n, _)| n == "embed").context("embed")?.1.as_f32()?.to_vec();
    let attn_norm =
        params.iter().find(|(n, _)| n == "attn_norm").context("attn_norm")?.1.as_f32()?.to_vec();
    let d = engine.manifest.config("tiny").unwrap().d_model;
    let g0 = &attn_norm[..d]; // layer-0 norm gain

    // One fresh batch through the embedding.
    let mut it = BatchIterator::from_seed(vocab, cfg.batch, cfg.seq, 0xF16);
    let batch = it.next_batch();
    let tokens: Vec<i32> = batch.tokens[..cfg.batch * cfg.seq].to_vec();
    let b_tokens = tokens.len();
    let mut x = Mat::zeros(b_tokens, d);
    for (i, &t) in tokens.iter().enumerate() {
        let emb = &embed[t as usize * d..(t as usize + 1) * d];
        // rmsnorm(e) ⊙ g  — the exact QKV projection input of block 0.
        let ms: f32 = emb.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = emb[j] * inv * g0[j];
        }
    }
    Ok(x)
}

/// Fig 5: PCA of X and of its PAMM reconstruction, colored by f(i).
pub fn fig5(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let x = capture_activation(engine, quick)?;
    let b = x.rows();
    let k = (b / 64).max(2);
    let mut rng = Xoshiro256::new(5);
    let idx = pamm::sample_generators(&mut rng, b, k);
    let comp = pamm::compress(&x, &idx, Eps::Inf);
    let recon = comp.reconstruct();

    let (_, proj_x) = analysis::pca_project(&x, 2, 40, 11);
    // Project the reconstruction into the SAME PCA basis (paper's setup):
    let (comps, _) = analysis::pca_project(&x, 2, 40, 11);
    let mut rows = Vec::new();
    for i in 0..b {
        let rrow = recon.row(i);
        let mut rp = [0f32; 2];
        for c in 0..2 {
            rp[c] = crate::tensor::dot(rrow, comps.row(c));
        }
        rows.push(format!(
            "{},{},{},{},{},{}",
            proj_x.get(i, 0),
            proj_x.get(i, 1),
            rp[0],
            rp[1],
            comp.assign[i],
            comp.alpha[i]
        ));
    }
    write_csv(format!("{out}/fig5.csv"), "pc1,pc2,recon_pc1,recon_pc2,assign,alpha", &rows)?;

    // Quantitative summary: within-cluster variance shrink (the visual
    // claim of Fig 5 — clusters collapse onto generator lines).
    let var_of = |m: &Mat| -> f64 {
        let (_, p) = analysis::pca_project(m, 2, 30, 13);
        (0..m.rows()).map(|i| (p.get(i, 0) as f64).powi(2) + (p.get(i, 1) as f64).powi(2)).sum::<f64>()
            / m.rows() as f64
    };
    let vx = var_of(&x);
    let vr = var_of(&recon);
    println!("fig5: b={b}, k={k}; PCA-plane variance X={vx:.4}, X̃={vr:.4} (ratio {:.2})", vr / vx);
    println!("      per-point rows written to {out}/fig5.csv");
    println!("\nshape check: overall variance preserved (ratio near 1), clusters → lines (paper Fig 5).");
    Ok(())
}

const RS: [f64; 5] = [1.0 / 8.0, 1.0 / 32.0, 1.0 / 128.0, 1.0 / 256.0, 1.0 / 512.0];
const EPSS: [Option<f64>; 5] = [Some(0.0), Some(0.2), Some(0.5), Some(1.0), None];

/// Fig 6: relative L2 error E(r, ε) on the captured activation.
pub fn fig6(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let x = capture_activation(engine, quick)?;
    let mut rng = Xoshiro256::new(6);
    let bmat = Mat::random_normal(x.rows(), x.cols(), 1.0, &mut rng);
    let trials = if quick { 2 } else { 5 };
    let cells = analysis::error_sweep(&x, &bmat, &RS, &EPSS, trials, 0xF16);
    let mut rows = Vec::new();
    println!("{:<10} {:<8} {:>10}", "1/r", "eps", "rel_err");
    for c in &cells {
        let etag = c.eps.map(|e| format!("{e}")).unwrap_or_else(|| "inf".into());
        println!("{:<10.0} {:<8} {:>10.4}", 1.0 / c.r, etag, c.value);
        rows.push(format!("{},{etag},{}", 1.0 / c.r, c.value));
    }
    write_csv(format!("{out}/fig6.csv"), "inv_r,eps,rel_err", &rows)?;
    println!("\nshape check: error ↓ with ε, grows only slowly as r shrinks; ε=∞ best (paper Fig 6; abs. values 0.5–1 at small r match App. H).");
    Ok(())
}

/// Fig 7: coverage over (r, ε) on the captured activation.
pub fn fig7(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let x = capture_activation(engine, quick)?;
    let trials = if quick { 2 } else { 5 };
    let cells = analysis::coverage_sweep(&x, &RS, &EPSS, trials, 0xF17);
    let mut rows = Vec::new();
    println!("{:<10} {:<8} {:>10}", "1/r", "eps", "coverage");
    for c in &cells {
        let etag = c.eps.map(|e| format!("{e}")).unwrap_or_else(|| "inf".into());
        println!("{:<10.0} {:<8} {:>10.4}", 1.0 / c.r, etag, c.value);
        rows.push(format!("{},{etag},{}", 1.0 / c.r, c.value));
    }
    write_csv(format!("{out}/fig7.csv"), "inv_r,eps,coverage", &rows)?;
    println!("\nshape check: coverage ↑ with ε and with r; ε=∞ ⇒ 1.0 (paper Fig 7).");
    Ok(())
}
