//! P9/P10 — native attention throughput + measured peak memory
//! (`pamm reproduce attention`; EXPERIMENTS.md §Perf P9–P10).
//!
//! Three end-to-end variants of one attention block, all starting from
//! the same projection input `x`:
//!
//! * **naive** — dense `x·W{q,k,v}`, then materialized-scores softmax
//!   (the memory worst case: 3 full Q/K/V tensors + an (L, L) score
//!   matrix per head).
//! * **flash** — dense projections, then the tiled online-softmax walk
//!   (`attention::flash_attention_with`): scores never materialize,
//!   Q/K/V still do.
//! * **fused pamm** — `attention::pamm_qkv_attention_tracked`: compress
//!   `x`, attend straight off the compressed representation. Q/K/V
//!   never materialize either; peak transient bytes are *measured* via
//!   `memory::MemoryTracker` (not the analytic `qkv_saved_bytes`
//!   model) and printed next to the bound
//!   `tile_bytes × threads + compressed_bytes`
//!   (`attention::fused_peak_bound`).
//!
//! Native-only: needs no artifacts, runs on the process-wide pool.

use anyhow::Result;

use crate::attention::{self, AttnShape};
use crate::benchx::{bench_fn, BenchOpts};
use crate::checkpoint::write_csv;
use crate::memory::{fmt_bytes, MemoryTracker};
use crate::pamm::{self, Eps};
use crate::poolx;
use crate::rngx::Xoshiro256;
use crate::tensor::kernels;
use crate::tensor::Mat;

fn opts(quick: bool) -> BenchOpts {
    if quick {
        BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            max_total: std::time::Duration::from_secs(10),
        }
    } else {
        BenchOpts {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 12,
            max_total: std::time::Duration::from_secs(60),
        }
    }
}

/// The P9/P10 table: per shape, time + peak bytes + relative error of
/// the three variants. CSV lands in `<out>/attention.csv`.
pub fn native_table(quick: bool, out: &str) -> Result<()> {
    // (batch, heads, seq, head_dim, generators k) — causal throughout
    // (the LM hot path). Full shapes keep the naive baseline in
    // fractions of a second on one core.
    let shapes: &[(usize, usize, usize, usize, usize)] = if quick {
        &[(1, 2, 128, 32, 16)]
    } else {
        &[(1, 4, 256, 64, 32), (2, 4, 512, 64, 64)]
    };
    let o = opts(quick);
    let pool = poolx::global();
    println!(
        "native attention (threads={}, dispatch={}, tiles Br={} Bc={}):",
        pool.threads(),
        kernels::active().name(),
        attention::br(),
        attention::bc()
    );
    println!(
        "{:<16} {:>10} {:>12} {:>14} {:>12}",
        "variant", "ms/iter", "tok/s", "peak bytes", "rel err"
    );

    let mut rows = Vec::new();
    for &(b, h, l, d, k) in shapes {
        let shape = AttnShape::new(b, h, l, d, true);
        let dm = shape.d_model();
        let toks = shape.tokens() as f64;
        let mut rng = Xoshiro256::new(0xA77E);
        let x = Mat::random_normal(shape.tokens(), dm, 1.0, &mut rng);
        let wq = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let wk = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let wv = Mat::random_normal(dm, dm, 0.05, &mut rng);
        let idx = pamm::sample_generators(&mut rng, shape.tokens(), k);
        println!("--- b={b} h={h} l={l} d={d} k={k} (d_model={dm}, causal) ---");

        // Dense exact output: the error reference for all variants.
        let project = |w: &Mat| attention::split_heads(&x.matmul_with(w, pool), &shape);
        let (q, kk, v) = (project(&wq), project(&wk), project(&wv));
        let exact = attention::naive_attention(&q, &kk, &v, &shape);
        let exact_norm =
            exact.iter().map(|e| (*e as f64) * (*e as f64)).sum::<f64>().sqrt().max(1e-12);
        let rel = |got: &[f32]| {
            let e2: f64 = got
                .iter()
                .zip(&exact)
                .map(|(g, w)| ((g - w) as f64) * ((g - w) as f64))
                .sum();
            e2.sqrt() / exact_norm
        };

        // Analytic resident set of the materialized paths: 3 Q/K/V
        // tensors, plus the per-head (L, L) score matrix for naive.
        let qkv_bytes = 3 * shape.tensor_bytes();
        let naive_bytes = qkv_bytes + l * l * 4;

        let t_naive = bench_fn("naive", &o, || {
            let (q, kk, v) = (project(&wq), project(&wk), project(&wv));
            std::hint::black_box(attention::naive_attention(&q, &kk, &v, &shape));
        });
        // The naive output IS the error reference — its rel err is 0 by
        // definition, no recompute needed.
        print_row("matmul+naive", &t_naive, toks, &fmt_bytes(naive_bytes), 0.0);
        rows.push(csv_row(b, h, l, d, k, "naive", &t_naive, naive_bytes as f64, 0.0));

        let t_flash = bench_fn("flash", &o, || {
            let (q, kk, v) = (project(&wq), project(&wk), project(&wv));
            std::hint::black_box(attention::flash_attention_with(&q, &kk, &v, &shape, pool));
        });
        let r_flash = rel(&attention::flash_attention_with(&q, &kk, &v, &shape, pool));
        print_row("matmul+flash", &t_flash, toks, &fmt_bytes(qkv_bytes), r_flash);
        rows.push(csv_row(b, h, l, d, k, "flash", &t_flash, qkv_bytes as f64, r_flash));

        // Timing on the (warm) shared pool, untracked — steady state.
        let t_fused = bench_fn("fused", &o, || {
            std::hint::black_box(attention::pamm_qkv_attention_with(
                &x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, pool,
            ));
        });
        let (comp, fused_out) =
            attention::pamm_qkv_attention_with(&x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, pool);
        // Peak measurement per the P10 protocol: a fresh pool (cold
        // worker TLS) AND a fresh caller thread (so the serial inline
        // path is cold too) — warm reuse reports zero growth, which is
        // the steady-state point but not the number the bound checks.
        let tracker = MemoryTracker::new();
        let threads = pool.threads();
        std::thread::scope(|sc| {
            sc.spawn(|| {
                let cold = poolx::Pool::new(threads);
                attention::pamm_qkv_attention_tracked(
                    &x,
                    &wq,
                    &wk,
                    &wv,
                    &idx,
                    Eps::Inf,
                    &shape,
                    &cold,
                    Some(&tracker),
                );
            });
        });
        let peak = tracker.peak();
        let r_fused = rel(&fused_out);
        print_row("pamm fused", &t_fused, toks, &fmt_bytes(peak), r_fused);
        rows.push(csv_row(b, h, l, d, k, "pamm_fused", &t_fused, peak as f64, r_fused));

        let bound = attention::fused_peak_bound(&comp, &shape, threads);
        println!(
            "  measured fused peak {} ≤ fused_peak_bound {} (tile×threads + compressed state + projection packing) — {:.1}% of the materialized Q/K/V set",
            fmt_bytes(peak),
            fmt_bytes(bound),
            100.0 * peak as f64 / qkv_bytes as f64
        );
        assert!(peak <= bound, "measured peak {peak} exceeds the analytic bound {bound}");
    }
    write_csv(
        format!("{out}/attention.csv"),
        "batch,heads,seq,head_dim,k,variant,ms,peak_bytes,rel_err",
        &rows,
    )?;
    println!("\nshape check: fused peak stays flat in seq while the materialized QKV set grows (paper composability claim, CompAct-style).");
    Ok(())
}

fn print_row(name: &str, r: &crate::benchx::BenchResult, toks: f64, peak: &str, rel: f64) {
    println!(
        "{:<16} {:>10.3} {:>12.0} {:>14} {:>12.2e}",
        name,
        r.median_secs() * 1e3,
        toks / r.median_secs().max(1e-12),
        peak,
        rel
    );
}

#[allow(clippy::too_many_arguments)]
fn csv_row(
    b: usize,
    h: usize,
    l: usize,
    d: usize,
    k: usize,
    variant: &str,
    r: &crate::benchx::BenchResult,
    peak: f64,
    rel: f64,
) -> String {
    format!("{b},{h},{l},{d},{k},{variant},{},{peak},{rel}", r.median_secs() * 1e3)
}
