//! Experiment harness — one entry per table & figure of the paper,
//! plus the native attention table P9/P10, the native train-step
//! harness P11 and the native quality loop P17 (DESIGN.md §12 maps
//! each id to modules and expectations).
//!
//! Every harness prints the paper-style rows AND writes a CSV under the
//! `--out` directory so EXPERIMENTS.md can cite machine-readable results.
//! `--quick` shrinks step counts/grids for CI; the full settings are the
//! ones recorded in EXPERIMENTS.md.
//!
//! Native PAMM compute inside the harnesses runs on the process-wide
//! poolx pool (sized by `--threads` / `PAMM_THREADS`); numbers are
//! bit-identical at any thread count, so a harness row is comparable
//! across hosts. Per-op timings also persist via `benchx::BenchSink`
//! from the bench binaries — see BENCHMARKS.md for the rendered trail.

pub mod ablation;
#[cfg(feature = "pjrt")]
pub mod analysisfigs;
pub mod attention;
#[cfg(feature = "pjrt")]
pub mod finetune;
pub mod kernels;
#[cfg(feature = "pjrt")]
pub mod pretrain;
pub mod throughput;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::bail;

#[cfg(feature = "pjrt")]
pub use kernels::validate_kernels;

#[cfg(feature = "pjrt")]
use crate::runtime::Engine;

/// Run a native-only experiment — one that needs no artifacts and no
/// PJRT engine (`table7`, `attention`, `ablation`, `finetune`).
/// Returns `None` when `name` is an engine-backed harness, so the CLI
/// can decide whether to load artifacts at all (this is what makes
/// `pamm reproduce attention --quick` a zero-dependency smoke drive).
///
/// `native_train` is the `--native` flag: for `table7` it switches
/// from the isolated per-op breakdown to the REAL optimization loop
/// (`throughput::table7_native`, P11) — fwd → loss → compressed bwd →
/// Adam update through `crate::autograd`, with the measured per-phase
/// memory ledger asserted against its analytic bounds. `ablation` and
/// `finetune` are always native (P17): the ε/k quality sweep and the
/// GLUE stand-in fine-tuning table run on synthetic corpora with no
/// artifacts in any build.
pub fn run_native(name: &str, quick: bool, native_train: bool, out: &str) -> Option<Result<()>> {
    match name {
        "table7" | "attention" | "ablation" | "finetune" => {}
        _ => return None,
    }
    let run = || -> Result<()> {
        std::fs::create_dir_all(out)?;
        match name {
            "table7" if native_train => throughput::table7_native(quick, out),
            "table7" => throughput::table7(quick, out),
            "attention" => attention::native_table(quick, out),
            "ablation" => ablation::ablation_table(quick, out),
            "finetune" => ablation::finetune_table(quick, out),
            _ => unreachable!("gated above"),
        }
    };
    Some(run())
}

#[cfg(feature = "pjrt")]
pub fn run(engine: &Engine, name: &str, quick: bool, out: &str) -> Result<()> {
    std::fs::create_dir_all(out)?;
    match name {
        "fig3a" => pretrain::fig3a(engine, quick, out),
        "fig3b" => pretrain::fig3b(engine, out),
        "table5" => pretrain::table5(engine, quick, out),
        "table3" => pretrain::table3(engine, quick, out),
        "fig4a" => pretrain::fig4a(engine, quick, out),
        "fig4b" => pretrain::fig4b(engine, quick, out),
        "table6" => pretrain::table6(engine, quick, out),
        "table2a" => throughput::table2a(engine, quick, out),
        "table2b" => throughput::table2b(engine, quick, out),
        "table7" => throughput::table7(quick, out),
        // Native-only (no artifacts): flash/fused attention throughput
        // + measured-peak-memory table (EXPERIMENTS.md P9–P10).
        "attention" => attention::native_table(quick, out),
        "table1" => finetune::table1(engine, quick, out),
        "table4" => finetune::table4(engine, quick, out),
        "fig5" => analysisfigs::fig5(engine, quick, out),
        "fig6" => analysisfigs::fig6(engine, quick, out),
        "fig7" => analysisfigs::fig7(engine, quick, out),
        "kernels" => {
            let n = validate_kernels(engine)?;
            println!("kernel validation OK ({n} artifacts)");
            Ok(())
        }
        "all" => {
            for exp in [
                "kernels", "fig3b", "table7", "attention", "fig5", "fig6", "fig7",
                "table2a", "table2b", "fig3a", "table5", "table3", "fig4a", "fig4b",
                "table6", "table1", "table4",
            ] {
                println!("\n================ {exp} ================");
                run(engine, exp, quick, out)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment `{other}` (see `pamm help`)"),
    }
}
