//! P17 — native ε/k ablation: the paper's quality-vs-compression
//! trade-off, reproduced without artifacts (DESIGN.md §11).
//!
//! The sweep fixes one pretraining shape (config, batch, seq, steps,
//! seed) and trains a fresh `coordinator::LmTrainer` per (ε, k) cell —
//! same seed everywhere, so every cell sees the same init, the same
//! batch stream and the same generator-sampling stream; the *only*
//! thing that varies is the compression geometry. Each cell reports
//! its final loss next to the **exact** saved-for-backward bytes of
//! its tape, cross-checked against a live `memory::MemoryLedger` on
//! the cell's last step (measured == analytic, asserted in-harness).
//!
//! Two more in-harness asserts pin the table's semantics
//! (`rust/tests/prop_ablation.rs` re-runs them as properties):
//!
//! * **all-generators == dense** — at k = batch·seq with ε = ∞ every
//!   row is its own generator (α = 1 exact copies), so the compressed
//!   forward/backward is the dense computation; the sweep's k = n cell
//!   must reproduce an independently-run dense baseline **bit for
//!   bit**.
//! * **saved bytes are monotone in k** — the compressed tape stores
//!   C (k×n) per block, so shrinking k must strictly shrink the cell's
//!   saved bytes.
//!
//! The table closes with the memory-zoo rows: analytic QKV vs PAMM
//! saved bytes per model size at the paper's 64×256 per-GPU shape
//! (`memory::qkv_saved_bytes` / `memory::pamm_saved_bytes`) — the
//! ×512 headline next to the measured small-shape cells.

use anyhow::{ensure, Result};

use crate::checkpoint::write_csv;
use crate::coordinator::{LmTrainer, NativeOpt};
use crate::data::BatchIterator;
use crate::memory::{self, MemoryLedger, ModelGeometry};
use crate::model::LmConfig;
use crate::pamm::Eps;
use crate::poolx::{self, Pool};

/// The fixed pretraining shape every cell of one sweep shares.
#[derive(Debug, Clone)]
pub struct AblationShape {
    pub cfg: LmConfig,
    pub batch: usize,
    pub seq: usize,
    pub steps: usize,
    pub opt: NativeOpt,
    pub seed: u64,
}

impl AblationShape {
    /// Tokens per step — the generator-count ceiling (k = n ⇒ dense).
    pub fn tokens(&self) -> usize {
        self.batch * self.seq
    }

    /// The CI shape (`--quick`): small enough that the full grid runs
    /// in seconds, big enough that k spans 1 … n across three octaves.
    pub fn quick() -> Self {
        AblationShape {
            cfg: LmConfig { vocab: 300, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 },
            batch: 2,
            seq: 32,
            steps: 8,
            opt: NativeOpt::adam(2e-3),
            seed: 42,
        }
    }

    /// The recorded EXPERIMENTS.md shape.
    pub fn full() -> Self {
        AblationShape {
            cfg: LmConfig { vocab: 1000, n_layers: 4, heads: 4, head_dim: 16, d_ff: 128 },
            batch: 4,
            seq: 64,
            steps: 60,
            opt: NativeOpt::adam(2e-3),
            seed: 42,
        }
    }
}

/// One cell of the quality-vs-saved-bytes table.
#[derive(Debug, Clone, PartialEq)]
pub struct AblationCell {
    pub eps_label: String,
    pub k: usize,
    pub final_loss: f32,
    /// Exact saved-for-backward bytes of the cell's tape (ledger ==
    /// tape inventory, asserted where the cell is produced).
    pub saved_bytes: usize,
}

/// Label an ε the way the paper writes it ("inf" = no condition).
pub fn eps_label(eps: Eps) -> String {
    match eps {
        Eps::Inf => "inf".to_string(),
        Eps::Val(v) => format!("{v}"),
    }
}

/// Train one (ε, k) cell from scratch: fresh trainer, fresh batch
/// stream, `shape.steps` optimizer steps. The last step runs with a
/// live ledger and the measured saved bytes are asserted against the
/// tape's own inventory — the cell's memory column is exact, not
/// sampled.
pub fn run_cell(shape: &AblationShape, eps: Eps, k: usize, pool: &Pool) -> Result<AblationCell> {
    ensure!(k >= 1 && k <= shape.tokens(), "ablation cell: k={k} outside 1..={}", shape.tokens());
    let mut t =
        LmTrainer::new(shape.cfg.clone(), shape.batch, shape.seq, k, shape.opt, shape.seed);
    t.eps = eps;
    let mut it = BatchIterator::from_seed(shape.cfg.vocab, shape.batch, shape.seq, shape.seed);
    let mut loss = f32::NAN;
    let mut saved_bytes = 0usize;
    for s in 0..shape.steps {
        let b = it.next_batch();
        if s + 1 == shape.steps {
            let ledger = MemoryLedger::new();
            let rep = t.step_report(
                crate::tensor::kernels::active(),
                &b.tokens,
                pool,
                Some(&ledger),
            )?;
            ensure!(
                ledger.saved() == rep.saved_bytes,
                "cell (eps={}, k={k}): ledger recorded {} saved bytes, tape inventory says {}",
                eps_label(eps),
                ledger.saved(),
                rep.saved_bytes
            );
            loss = rep.loss;
            saved_bytes = rep.saved_bytes;
        } else {
            loss = t.train_step(&b.tokens, pool, None)?;
        }
    }
    Ok(AblationCell { eps_label: eps_label(eps), k, final_loss: loss, saved_bytes })
}

/// The ε × k grid for a shape: k descends from all-generators (dense)
/// by octaves down to 1; ε covers ∞ plus the conditioned settings.
pub fn grids(shape: &AblationShape, quick: bool) -> (Vec<Eps>, Vec<usize>) {
    let eps_grid = if quick {
        vec![Eps::Inf, Eps::Val(0.5)]
    } else {
        vec![Eps::Inf, Eps::Val(0.5), Eps::Val(0.25)]
    };
    let n = shape.tokens();
    let mut k_grid = vec![n];
    let mut k = n / 8;
    while k >= 1 {
        k_grid.push(k);
        if k == 1 {
            break;
        }
        k /= 8;
        if k == 0 {
            k = 1;
        }
    }
    (eps_grid, k_grid)
}

/// Run the full sweep: one [`run_cell`] per (ε, k), row-major in grid
/// order. Pure function of `(shape, grids, dispatch)` — same inputs ⇒
/// a bitwise-identical table (`prop_ablation.rs` pins this).
pub fn sweep(
    shape: &AblationShape,
    eps_grid: &[Eps],
    k_grid: &[usize],
    pool: &Pool,
) -> Result<Vec<AblationCell>> {
    let mut cells = Vec::with_capacity(eps_grid.len() * k_grid.len());
    for &eps in eps_grid {
        for &k in k_grid {
            cells.push(run_cell(shape, eps, k, pool)?);
        }
    }
    Ok(cells)
}

/// The `pamm ablate` engine: sweep, assert the table's semantics,
/// print the quality-vs-saved-bytes table + the memory-zoo rows, write
/// the CSV.
pub fn ablation_table(quick: bool, out: &str) -> Result<()> {
    ablation_table_with(quick, None, None, out)
}

/// [`ablation_table`] with the CLI's `--epsilon E` / `--k K` extras:
/// each adds a row/column to the default grid (the dense anchor cell
/// is always swept, so the in-harness asserts keep their reference).
pub fn ablation_table_with(
    quick: bool,
    extra_eps: Option<f32>,
    extra_k: Option<usize>,
    out: &str,
) -> Result<()> {
    let shape = if quick { AblationShape::quick() } else { AblationShape::full() };
    let (mut eps_grid, mut k_grid) = grids(&shape, quick);
    if let Some(e) = extra_eps {
        let eps = Eps::Val(e);
        if !eps_grid.contains(&eps) {
            eps_grid.push(eps);
        }
    }
    if let Some(k) = extra_k {
        ensure!(
            k >= 1 && k <= shape.tokens(),
            "--k {k} outside 1..={} for this shape",
            shape.tokens()
        );
        if !k_grid.contains(&k) {
            k_grid.push(k);
            k_grid.sort_unstable_by(|a, b| b.cmp(a));
        }
    }
    let pool = poolx::global();
    let n = shape.tokens();
    println!(
        "epsilon/k ablation (vocab={} layers={} d_model={} b={} l={} steps={}, threads={}):",
        shape.cfg.vocab,
        shape.cfg.n_layers,
        shape.cfg.d_model(),
        shape.batch,
        shape.seq,
        shape.steps,
        pool.threads()
    );

    let cells = sweep(&shape, &eps_grid, &k_grid, pool)?;

    // The dense baseline, run independently (fresh trainer, same
    // seed). At k = n every row is its own generator, so the sweep's
    // all-generators cell must reproduce it bit for bit.
    let dense = run_cell(&shape, Eps::Inf, n, pool)?;
    let kn = cells
        .iter()
        .find(|c| c.k == n && c.eps_label == "inf")
        .expect("grid always contains the (inf, n) cell");
    ensure!(
        kn.final_loss.to_bits() == dense.final_loss.to_bits(),
        "all-generators cell (loss {}) must bit-match the dense baseline (loss {})",
        kn.final_loss,
        dense.final_loss
    );

    // Saved bytes must shrink strictly and monotonically with k at
    // every ε (C is k×n per block).
    for eps in &eps_grid {
        let lbl = eps_label(*eps);
        let row: Vec<&AblationCell> = cells.iter().filter(|c| c.eps_label == lbl).collect();
        for w in row.windows(2) {
            ensure!(
                w[0].k > w[1].k && w[0].saved_bytes > w[1].saved_bytes,
                "saved bytes not monotone in k at eps={lbl}: k={} saves {}, k={} saves {}",
                w[0].k,
                w[0].saved_bytes,
                w[1].k,
                w[1].saved_bytes
            );
        }
    }

    println!(
        "{:<6} {:>6} {:>8} {:>10} {:>12} {:>10}",
        "eps", "k", "r", "loss", "saved", "vs dense"
    );
    let mut rows = Vec::new();
    for c in &cells {
        let r = if c.k == n { "1".to_string() } else { format!("1/{}", n / c.k) };
        let factor = dense.saved_bytes as f64 / c.saved_bytes.max(1) as f64;
        println!(
            "{:<6} {:>6} {:>8} {:>10.6} {:>12} {:>9.1}x",
            c.eps_label,
            c.k,
            r,
            c.final_loss,
            memory::fmt_bytes(c.saved_bytes),
            factor
        );
        rows.push(format!("{},{},{},{},{}", c.eps_label, c.k, r, c.final_loss, c.saved_bytes));
    }
    println!("(all-generators cell bit-matches the dense baseline: loss {})", dense.final_loss);

    // Memory-zoo rows: the analytic saved-bytes story per model size
    // at the paper's 64×256 per-GPU shape, r = 1/512 headline.
    println!("\nmemory zoo (analytic, b=64 l=256, f32):");
    println!("{:<10} {:>12} {:>12} {:>8}", "model", "qkv dense", "pamm 1/512", "factor");
    for g in ModelGeometry::zoo() {
        let dense_b = memory::qkv_saved_bytes(&g, 64, 256, 4);
        let pamm_b = memory::pamm_saved_bytes(&g, 64, 256, 1.0 / 512.0, 4);
        println!(
            "{:<10} {:>12} {:>12} {:>7.0}x",
            g.name,
            memory::fmt_bytes(dense_b),
            memory::fmt_bytes(pamm_b),
            dense_b as f64 / pamm_b.max(1) as f64
        );
        rows.push(format!("zoo:{},{dense_b},{pamm_b},,", g.name));
    }

    write_csv(
        format!("{out}/ablation{}.csv", if quick { "_quick" } else { "" }),
        "eps,k,r,final_loss,saved_bytes",
        &rows,
    )?;
    println!("\nshape check: loss degrades gracefully as k shrinks while saved bytes fall by the same octaves — the paper's quality-vs-compression trade-off, measured natively.");
    Ok(())
}

/// The native `pamm reproduce finetune` engine: fine-tune the small
/// shape on a slice of the GLUE stand-in suite through
/// `coordinator::finetune_native` (synthetic corpora — no downloads),
/// assert the loss decreased, and print dev metric + analytic memory
/// per task.
pub fn finetune_table(quick: bool, out: &str) -> Result<()> {
    use crate::coordinator::{finetune_native, find_task, FtRunConfig};

    let tasks: &[&str] = if quick { &["SST2"] } else { &["SST2", "RTE", "MNLI"] };
    let (steps, examples, seq) = if quick { (12, 64, 16) } else { (80, 256, 32) };
    let pool = poolx::global();
    println!(
        "native fine-tuning (synthetic GLUE stand-ins, {} steps, threads={}):",
        steps,
        pool.threads()
    );
    println!("{:<8} {:>10} {:>10} {:>10} {:>10}", "task", "metric", "dev score", "dev acc", "loss");
    let mut rows = Vec::new();
    for name in tasks {
        let task = find_task(name)?;
        let rc = FtRunConfig {
            cfg: LmConfig { vocab: 300, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 },
            task: task.clone(),
            batch: 4,
            seq,
            steps,
            k: 8,
            opt: NativeOpt::adam(2e-3),
            seed: 42,
            corpus_examples: examples,
            dev_every: 5,
            eval_every: if quick { 0 } else { 20 },
            patience: 0,
            task_file: None,
            ckpt_every: 0,
            keep_last: 2,
            run_dir: format!("{out}/finetune_runs"),
            run_name: format!("ft_{}", name.to_lowercase().replace('-', "_")),
            resume: false,
        };
        let o = finetune_native(&rc, pool, true)?;
        let head: f32 =
            o.curve.iter().take(3).map(|&(_, l)| l).sum::<f32>() / o.curve.len().min(3) as f32;
        let tail: f32 = o.curve.iter().rev().take(3).map(|&(_, l)| l).sum::<f32>()
            / o.curve.len().min(3) as f32;
        ensure!(
            tail < head,
            "{name}: fine-tuning must reduce the loss ({head:.4} -> {tail:.4})"
        );
        let metric = crate::coordinator::finetune::metric_name(&task);
        println!(
            "{:<8} {:>10} {:>10.2} {:>9.1}% {:>10.4}",
            task.name,
            metric,
            o.dev.score,
            100.0 * o.dev.accuracy,
            o.final_loss
        );
        rows.push(format!(
            "{},{},{},{},{}",
            task.name, metric, o.dev.score, o.dev.accuracy, o.final_loss
        ));
    }
    write_csv(
        format!("{out}/finetune_native{}.csv", if quick { "_quick" } else { "" }),
        "task,metric,dev_score,dev_accuracy,final_loss",
        &rows,
    )?;
    println!("(loss decrease asserted per task; dev split disjoint by stride — no leakage)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_cover_dense_to_one() {
        let shape = AblationShape::quick();
        let (eps_grid, k_grid) = grids(&shape, true);
        assert_eq!(k_grid.first(), Some(&shape.tokens()));
        assert_eq!(k_grid.last(), Some(&1));
        assert!(k_grid.windows(2).all(|w| w[0] > w[1]), "k grid must descend");
        assert!(eps_grid.contains(&Eps::Inf));
    }

    #[test]
    fn cell_rejects_out_of_range_k() {
        let shape = AblationShape::quick();
        let pool = crate::poolx::Pool::serial();
        assert!(run_cell(&shape, Eps::Inf, 0, &pool).is_err());
        assert!(run_cell(&shape, Eps::Inf, shape.tokens() + 1, &pool).is_err());
    }
}
