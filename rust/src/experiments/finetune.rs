//! Finetuning experiments: Table 1 (GLUE stand-in suite) and Table 4
//! (AID / LoRA+PAMM stand-in — 30-class captions).

use anyhow::{Context, Result};

use crate::checkpoint::write_csv;
use crate::config::Variant;
use crate::coordinator::pipeline::LabeledPipeline;
use crate::coordinator::session::ClassifierSession;
use crate::data::glue::{self, TaskGenerator, TaskSpec};
use crate::memory::{self, ModelGeometry};
use crate::metrics::Stats;
use crate::runtime::{ArtifactMeta, Engine, HostTensor};

/// Geometry + vocab for a classifier artifact, derived from its param
/// spec (classifier configs are ad-hoc and not in the manifest's zoo).
fn geometry_from_spec(meta: &ArtifactMeta) -> Result<(ModelGeometry, usize)> {
    let find = |n: &str| {
        meta.param_spec
            .iter()
            .find(|p| p.name == n)
            .map(|p| p.shape.clone())
            .with_context(|| format!("param {n} missing"))
    };
    let embed = find("embed")?;
    let attn_norm = find("attn_norm")?;
    let w_gate = find("w_gate")?;
    Ok((
        ModelGeometry {
            name: meta.config.clone().unwrap_or_default(),
            vocab: embed[0],
            d_model: embed[1],
            n_layers: attn_norm[0],
            n_heads: 1, // unused by the memory accountant
            d_ff: w_gate[2],
        },
        embed[0],
    ))
}

/// Finetune one (task, variant, seed) cell; returns the task metric (%).
fn finetune_cell(
    engine: &Engine,
    model: &str,
    spec: &TaskSpec,
    variant: &Variant,
    steps: usize,
    seed: u64,
) -> Result<f64> {
    let meta = engine
        .find(|a| {
            a.kind == "cls_train_step"
                && a.config.as_deref() == Some(model)
                && a.variant_tag() == variant.tag()
        })
        .with_context(|| format!("no cls artifact {model}/{}", variant.tag()))?
        .clone();
    let eval_name = meta
        .name
        .replace("clstrain", "clseval")
        .replace(&format!("_{}_", variant.tag()), "_");
    let mut session = ClassifierSession::new(engine, &meta.name, &eval_name, seed)?;
    let (_, vocab) = geometry_from_spec(&meta)?;
    let pipe = LabeledPipeline::spawn(
        TaskGenerator::new(spec.clone(), vocab, seed),
        session.batch,
        session.seq,
        2,
    );
    for _ in 0..steps {
        let b = pipe.next();
        session.step(
            &HostTensor::i32(vec![b.batch, b.seq], b.tokens.clone()),
            &HostTensor::i32(vec![b.batch], b.labels.clone()),
        )?;
    }
    // Held-out evaluation stream.
    let mut gen = TaskGenerator::new(spec.clone(), vocab, seed ^ 0xEE);
    let (mut preds, mut golds) = (Vec::new(), Vec::new());
    for _ in 0..12 {
        let b = gen.batch(session.batch, session.seq);
        preds.extend(session.predict(&HostTensor::i32(vec![b.batch, b.seq], b.tokens.clone()))?);
        golds.extend(b.labels);
    }
    Ok(glue::score(spec, &preds, &golds))
}

/// Table 1: the 8-task GLUE stand-in, full FT vs PAMM r = 1/128, 1/256.
pub fn table1(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let suite = glue::glue_suite();
    let tasks: Vec<TaskSpec> =
        if quick { suite.into_iter().take(3).collect() } else { suite };
    let steps = if quick { 40 } else { 200 };
    let seeds: &[u64] = if quick { &[42] } else { &[42, 43, 44] };
    let variants = [Variant::baseline(), Variant::pamm(128), Variant::pamm(256)];

    // Memory column: the glue classifier geometry at its finetune shape.
    let meta = engine
        .find(|a| a.kind == "cls_train_step" && a.config.as_deref() == Some("glue"))
        .context("glue artifacts missing")?;
    let (b, l) = (meta.batch.unwrap(), meta.seq.unwrap());
    let (g, _) = geometry_from_spec(meta)?;

    let mut rows = Vec::new();
    print!("{:<14} {:>10}", "variant", "mem");
    for t in &tasks {
        print!(" {:>8}", t.name);
    }
    println!(" {:>8}", "avg");

    for var in &variants {
        let mem = match var.mode.as_str() {
            "baseline" => memory::qkv_saved_bytes(&g, b, l, 4),
            _ => memory::pamm_saved_bytes(&g, b, l, var.r, 4),
        };
        print!("{:<14} {:>10}", var.tag(), memory::fmt_bytes(mem));
        let mut avg = Stats::default();
        let mut row = format!("{},{}", var.tag(), mem);
        for t in &tasks {
            let mut s = Stats::default();
            for &seed in seeds {
                s.push(finetune_cell(engine, "glue", t, var, steps, seed)?);
            }
            print!(" {:>8.2}", s.mean());
            avg.push(s.mean());
            row.push_str(&format!(",{:.2}", s.mean()));
        }
        println!(" {:>8.2}", avg.mean());
        row.push_str(&format!(",{:.2}", avg.mean()));
        rows.push(row);
    }
    let header = format!(
        "variant,mem_bytes,{},avg",
        tasks.iter().map(|t| t.name).collect::<Vec<_>>().join(",")
    );
    write_csv(format!("{out}/table1.csv"), &header, &rows)?;
    println!("\nshape check: PAMM within ~1pt of full FT on average, memory ↓ ~97% (paper Table 1).");
    Ok(())
}

/// Table 4: AID stand-in — 30-class task, Macro/Weighted F1, memory saved.
/// (The model's QKV projections are PAMM-compressed exactly as the paper
/// compresses the LoRA-A input; see python pamm_layer.lora_pamm_linear for
/// the adapter-level composition, unit-tested in python/tests.)
pub fn table4(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let spec = glue::aid_task();
    let steps = if quick { 40 } else { 200 };
    let seeds: &[u64] = if quick { &[42] } else { &[42, 43, 44] };
    let variants = [Variant::baseline(), Variant::pamm(128), Variant::pamm(512)];

    let meta = engine
        .find(|a| a.kind == "cls_train_step" && a.config.as_deref() == Some("aid"))
        .context("aid artifacts missing")?;
    let (b, l) = (meta.batch.unwrap(), meta.seq.unwrap());
    let (g, aid_vocab) = geometry_from_spec(meta)?;
    let base_mem = memory::qkv_saved_bytes(&g, b, l, 4) as f64;

    let mut rows = Vec::new();
    println!(
        "{:<14} {:>10} {:>12} {:>12}",
        "variant", "macroF1", "weightedF1", "mem saved"
    );
    for var in &variants {
        let vocab = aid_vocab;
        let (mut mf1, mut wf1) = (Stats::default(), Stats::default());
        for &seed in seeds {
            // Train + predict, then compute both F1 flavors.
            let meta_v = engine
                .find(|a| {
                    a.kind == "cls_train_step"
                        && a.config.as_deref() == Some("aid")
                        && a.variant_tag() == var.tag()
                })
                .with_context(|| format!("aid/{}", var.tag()))?
                .clone();
            let eval_name = meta_v
                .name
                .replace("clstrain", "clseval")
                .replace(&format!("_{}_", var.tag()), "_");
            let mut session = ClassifierSession::new(engine, &meta_v.name, &eval_name, seed)?;
            let pipe = LabeledPipeline::spawn(
                TaskGenerator::new(spec.clone(), vocab, seed),
                session.batch,
                session.seq,
                2,
            );
            for _ in 0..steps {
                let bch = pipe.next();
                session.step(
                    &HostTensor::i32(vec![bch.batch, bch.seq], bch.tokens.clone()),
                    &HostTensor::i32(vec![bch.batch], bch.labels.clone()),
                )?;
            }
            let mut gen = TaskGenerator::new(spec.clone(), vocab, seed ^ 0xEE);
            let (mut preds, mut golds) = (Vec::new(), Vec::new());
            for _ in 0..16 {
                let bch = gen.batch(session.batch, session.seq);
                preds.extend(
                    session
                        .predict(&HostTensor::i32(vec![bch.batch, bch.seq], bch.tokens.clone()))?,
                );
                golds.extend(bch.labels);
            }
            mf1.push(glue::f1_macro(&preds, &golds, spec.n_classes));
            wf1.push(glue::f1_weighted(&preds, &golds, spec.n_classes));
        }
        let saved = match var.mode.as_str() {
            "baseline" => 0.0,
            _ => 100.0 * (1.0 - memory::pamm_saved_bytes(&g, b, l, var.r, 4) as f64 / base_mem),
        };
        println!(
            "{:<14} {:>9.4}±{:.3} {:>10.4}±{:.3} {:>10.2}%",
            var.tag(),
            mf1.mean(),
            mf1.std(),
            wf1.mean(),
            wf1.std(),
            saved
        );
        rows.push(format!(
            "{},{:.4},{:.4},{:.4},{:.4},{:.2}",
            var.tag(),
            mf1.mean(),
            mf1.std(),
            wf1.mean(),
            wf1.std(),
            saved
        ));
    }
    write_csv(
        format!("{out}/table4.csv"),
        "variant,macro_f1,macro_std,weighted_f1,weighted_std,mem_saved_pct",
        &rows,
    )?;
    println!("\nshape check: PAMM F1 ≈ baseline while saving ≳97% of QKV memory (paper Table 4).");
    Ok(())
}
