//! Kernel cross-validation: native Rust PAMM vs the AOT artifacts
//! (Pallas interpret kernels + jnp reference), on identical inputs.
//!
//! This is the three-implementation agreement check DESIGN.md promises:
//! jnp-ref == Pallas == native-Rust, executed through the *real* runtime
//! (HLO text → PJRT compile → execute), not a Python shortcut.
//!
//! The native side runs on the shared poolx pool (`--threads`); its
//! outputs are bit-identical at any thread count, so the agreement
//! thresholds below are independent of the host's parallelism.
//!
//! Also home of [`probe`] (`pamm kernels --probe`): the SIMD dispatch /
//! tile-parameter / GFLOP/s report that records which `tensor::kernels`
//! level a host actually runs — the provenance line for benchmark JSON.

use std::fmt::Write as _;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context};

use crate::attention::{self, AttnShape, AttnTiles};
use crate::benchx::{bench_fn, BenchOpts};
use crate::config::KernelTiles;
use crate::pamm::{self, Eps};
#[cfg(feature = "pjrt")]
use crate::runtime::{ArtifactMeta, Engine, HostTensor};
use crate::rngx::Xoshiro256;
use crate::tensor::kernels::{self, Dispatch, Tiles, MR, NR};
use crate::tensor::Mat;

#[cfg(feature = "pjrt")]
fn dims(meta: &ArtifactMeta, input: &str) -> Result<Vec<usize>> {
    Ok(meta
        .inputs
        .iter()
        .find(|i| i.name == input)
        .with_context(|| format!("{}: no input {input}", meta.name))?
        .shape
        .clone())
}

#[cfg(feature = "pjrt")]
fn mat_tensor(m: &Mat) -> HostTensor {
    HostTensor::f32(vec![m.rows(), m.cols()], m.data().to_vec())
}

#[cfg(feature = "pjrt")]
fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// `pamm kernels --probe`: report the detected SIMD dispatch ladder,
/// the tile/block parameters, a one-shot single-thread GFLOP/s spot
/// check of every available level on a 512³ `A·B`, and the attention
/// subsystem's tile parameters plus a spot flash-attention GFLOP/s per
/// level — so the provenance of a benchmark JSON ("which kernel
/// actually ran on this host") is one command away. Pure native
/// compute: needs no artifacts.
pub fn probe() -> String {
    let mut out = String::new();
    let env = std::env::var("PAMM_SIMD").ok();
    let avail: Vec<&str> =
        Dispatch::ALL_LEVELS.iter().filter(|d| d.available()).map(|d| d.name()).collect();
    let _ = writeln!(out, "tensor::kernels probe");
    let _ = writeln!(
        out,
        "  dispatch: {} (PAMM_SIMD={}; available: {})",
        kernels::active().name(),
        env.as_deref().unwrap_or("unset → native"),
        avail.join(" ")
    );
    let t = kernels::tiles();
    let defaults = Tiles::defaults();
    let _ = writeln!(
        out,
        "  tiles: MR={MR} NR={NR}  blocks: MC={} KC={} NC={}  ({}; scalar/sse2/avx2 bit-exact, \
         avx2fma/avx512 tolerance-checked)",
        t.mc,
        t.kc,
        t.nc,
        if t == defaults { "compiled-in defaults" } else { "tuned — see [kernels] config" },
    );

    let dim = 512usize;
    let flops = 2.0 * (dim as f64).powi(3);
    let mut rng = Xoshiro256::new(0x9086);
    let a = Mat::random_normal(dim, dim, 1.0, &mut rng);
    let b = Mat::random_normal(dim, dim, 1.0, &mut rng);
    let opts = BenchOpts {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: 5,
        max_total: std::time::Duration::from_secs(3),
    };
    let _ = writeln!(out, "  spot check: gemm_nn {dim}x{dim}x{dim}, single thread");
    let mut scalar_ns = None;
    for d in Dispatch::ALL_LEVELS {
        if !d.available() {
            continue;
        }
        let mut c = Mat::zeros(dim, dim);
        let r = bench_fn(d.name(), &opts, || {
            c.data_mut().fill(0.0);
            kernels::with_workspace(|ws| {
                kernels::gemm_into(
                    d,
                    false,
                    dim,
                    dim,
                    dim,
                    a.data(),
                    dim,
                    b.data(),
                    dim,
                    c.data_mut(),
                    dim,
                    &mut ws.packs,
                );
            });
            std::hint::black_box(c.data().first().copied());
        });
        let ns = r.median.as_nanos() as f64;
        let vs = match (d, scalar_ns) {
            (Dispatch::Scalar, _) => {
                scalar_ns = Some(ns);
                String::new()
            }
            (_, Some(s)) => format!("   ({:.2}x vs scalar)", s / ns.max(1.0)),
            _ => String::new(),
        };
        let _ = writeln!(
            out,
            "    {:<7} {:>12} /iter   {:>7.2} GFLOP/s{vs}",
            d.name(),
            format!("{:.2?}", r.median),
            flops / ns.max(1.0)
        );
    }

    // Attention tile parameters + spot GFLOP/s (same ladder, single
    // thread) — the provenance line for BENCH_tensor_attention.json.
    let threads = crate::poolx::global().threads();
    let shape = AttnShape::new(1, 4, 256, 64, false);
    let tasks = shape.batch * shape.heads;
    let _ = writeln!(
        out,
        "  attention: tiles Br={} Bc={}  grid: (batch·head) tasks, min-chunk {} → {} head(s) per task at {} thread(s)",
        attention::br(),
        attention::bc(),
        crate::poolx::TASK_MIN_CHUNK,
        tasks.div_ceil(tasks.min(threads).max(1)),
        threads
    );
    let aflops = shape.flops();
    let total = shape.qkv_len();
    let mk_qkv = |rng: &mut Xoshiro256| {
        let mut v = vec![0f32; total];
        rng.fill_normal_f32(&mut v, 1.0);
        v
    };
    let (q, k, v) = (mk_qkv(&mut rng), mk_qkv(&mut rng), mk_qkv(&mut rng));
    let serial = crate::poolx::Pool::serial();
    let _ = writeln!(
        out,
        "  spot check: flash fwd b={} h={} l={} d={}, single thread",
        shape.batch, shape.heads, shape.seq, shape.head_dim
    );
    let mut scalar_ns = None;
    for d in Dispatch::ALL_LEVELS {
        if !d.available() {
            continue;
        }
        let r = bench_fn(d.name(), &opts, || {
            std::hint::black_box(attention::flash_attention_on(d, &q, &k, &v, &shape, &serial));
        });
        let ns = r.median.as_nanos() as f64;
        let vs = match (d, scalar_ns) {
            (Dispatch::Scalar, _) => {
                scalar_ns = Some(ns);
                String::new()
            }
            (_, Some(s)) => format!("   ({:.2}x vs scalar)", s / ns.max(1.0)),
            _ => String::new(),
        };
        let _ = writeln!(
            out,
            "    {:<7} {:>12} /iter   {:>7.2} GFLOP/s{vs}",
            d.name(),
            format!("{:.2?}", r.median),
            aflops / ns.max(1.0)
        );
    }

    // Backward tile parameters + spot bwd GFLOP/s: the FA-2
    // recomputation walk runs 5 tile GEMMs against the forward's 2, so
    // its semantic flop count is 2.5× the forward's.
    let _ = writeln!(
        out,
        "  attention backward: same Br={}/Bc={} tiles, 5 GEMMs/tile, per-thread scratch {} (d={}, l={}; fwd {})",
        attention::br(),
        attention::bc(),
        crate::memory::fmt_bytes(attention::bwd_tile_scratch_bytes(shape.head_dim, shape.seq)),
        shape.head_dim,
        shape.seq,
        crate::memory::fmt_bytes(attention::tile_scratch_bytes(shape.head_dim)),
    );
    let bflops = 2.5 * aflops;
    let _ = writeln!(
        out,
        "  spot check: flash bwd b={} h={} l={} d={}, single thread",
        shape.batch, shape.heads, shape.seq, shape.head_dim
    );
    let (o, lse) =
        attention::flash_attention_fwd_on(Dispatch::Scalar, &q, &k, &v, &shape, &serial);
    let dout = mk_qkv(&mut rng);
    let mut scalar_ns = None;
    for d in Dispatch::ALL_LEVELS {
        if !d.available() {
            continue;
        }
        let r = bench_fn(d.name(), &opts, || {
            std::hint::black_box(attention::flash_attention_bwd_on(
                d, &q, &k, &v, &o, &dout, &lse, &shape, &serial,
            ));
        });
        let ns = r.median.as_nanos() as f64;
        let vs = match (d, scalar_ns) {
            (Dispatch::Scalar, _) => {
                scalar_ns = Some(ns);
                String::new()
            }
            (_, Some(s)) => format!("   ({:.2}x vs scalar)", s / ns.max(1.0)),
            _ => String::new(),
        };
        let _ = writeln!(
            out,
            "    {:<7} {:>12} /iter   {:>7.2} GFLOP/s{vs}",
            d.name(),
            format!("{:.2?}", r.median),
            bflops / ns.max(1.0)
        );
    }
    out
}

/// Single-thread GFLOP/s of one `dim³` GEMM under explicit tiles.
fn gemm_tile_gflops(d: Dispatch, t: Tiles, dim: usize, a: &Mat, b: &Mat, opts: &BenchOpts) -> f64 {
    let flops = 2.0 * (dim as f64).powi(3);
    let mut c = Mat::zeros(dim, dim);
    let r = bench_fn("tune", opts, || {
        c.data_mut().fill(0.0);
        kernels::with_workspace(|ws| {
            kernels::gemm_into_tiled(
                d,
                t,
                false,
                dim,
                dim,
                dim,
                a.data(),
                dim,
                b.data(),
                dim,
                c.data_mut(),
                dim,
                &mut ws.packs,
            );
        });
        std::hint::black_box(c.data().first().copied());
    });
    flops / (r.median.as_nanos() as f64).max(1.0)
}

/// One `dim³` GEMM under explicit tiles (result matrix, for the
/// winner's tolerance validation).
fn gemm_tile_once(d: Dispatch, t: Tiles, dim: usize, a: &Mat, b: &Mat) -> Vec<f32> {
    let mut c = Mat::zeros(dim, dim);
    kernels::with_workspace(|ws| {
        kernels::gemm_into_tiled(
            d,
            t,
            false,
            dim,
            dim,
            dim,
            a.data(),
            dim,
            b.data(),
            dim,
            c.data_mut(),
            dim,
            &mut ws.packs,
        );
    });
    c.data().to_vec()
}

/// `pamm kernels --tune`: runtime tile autotuning. Sweeps KC/MC/NC
/// candidates around the compiled-in defaults on a square GEMM and
/// attention Br/Bc candidates on a flash-forward spot shape, one sweep
/// per dispatch tier in play (the bit-exact [`Dispatch::native`] level
/// and, when different, the fast-tier [`Dispatch::fastest`]), picking
/// winners by measured single-thread GFLOP/s at the *active* level —
/// the one this process would actually run. Winners are
/// tolerance-validated against the default tiling's scalar result
/// ([`kernels::tol_check`] — KC regroups the k-panel accumulation, so
/// bit equality is deliberately not required), persisted as the
/// `[kernels]` section of `cfg_path` (other sections preserved
/// verbatim), and installed process-wide.
pub fn tune(cfg_path: &str, quick: bool) -> Result<String> {
    let mut out = String::new();
    let dim = if quick { 256 } else { 512 };
    let (kcs, mcs, ncs): (&[usize], &[usize], &[usize]) = if quick {
        (&[256, 384], &[128], &[2048])
    } else {
        (&[128, 256, 384, 512], &[64, 128, 256], &[1024, 2048, 4096])
    };
    let brbcs: &[usize] = if quick { &[32, 64] } else { &[32, 64, 128] };
    let opts = BenchOpts {
        warmup_iters: 1,
        min_iters: if quick { 2 } else { 3 },
        max_iters: if quick { 3 } else { 5 },
        max_total: std::time::Duration::from_secs(2),
    };
    let mut rng = Xoshiro256::new(0x7E5E);
    let a = Mat::random_normal(dim, dim, 1.0, &mut rng);
    let b = Mat::random_normal(dim, dim, 1.0, &mut rng);

    // The tiers worth measuring: the bit-exact default plus the fast
    // tier when the host has one. Winners are taken from the level the
    // process actually dispatches to (`active`), so PAMM_SIMD steers
    // what gets persisted.
    let active = kernels::active();
    let mut levels = vec![Dispatch::native()];
    if Dispatch::fastest() != Dispatch::native() {
        levels.push(Dispatch::fastest());
    }
    if !levels.contains(&active) {
        levels.push(active);
    }

    let _ = writeln!(out, "kernel tile autotune (gemm {dim}\u{b3}, single thread)");
    let mut winner = Tiles::defaults();
    let mut winner_gf = 0.0;
    for &d in &levels {
        let mut best = (Tiles::defaults(), 0.0f64);
        for &kc in kcs {
            for &mc in mcs {
                for &nc in ncs {
                    let t = Tiles { kc, mc, nc };
                    let gf = gemm_tile_gflops(d, t, dim, &a, &b, &opts);
                    if gf > best.1 {
                        best = (t, gf);
                    }
                }
            }
        }
        let _ = writeln!(
            out,
            "  {:<7} best KC={} MC={} NC={}  {:>7.2} GFLOP/s (default {:.2})",
            d.name(),
            best.0.kc,
            best.0.mc,
            best.0.nc,
            best.1,
            gemm_tile_gflops(d, Tiles::defaults(), dim, &a, &b, &opts),
        );
        if d == active {
            (winner, winner_gf) = best;
        }
    }
    // Winner must agree with the default-tiling scalar oracle within
    // the k-depth tolerance bound before it is allowed to persist.
    let want = gemm_tile_once(Dispatch::Scalar, Tiles::defaults(), dim, &a, &b);
    let got = gemm_tile_once(active, winner, dim, &a, &b);
    kernels::tol_check(&got, &want, dim).map_err(anyhow::Error::msg)?;

    // Attention Br/Bc sweep on the flash forward spot shape.
    let shape = AttnShape::new(1, 4, if quick { 128 } else { 256 }, 64, true);
    let total = shape.qkv_len();
    let mk_qkv = |rng: &mut Xoshiro256| {
        let mut v = vec![0f32; total];
        rng.fill_normal_f32(&mut v, 1.0);
        v
    };
    let (q, k, v) = (mk_qkv(&mut rng), mk_qkv(&mut rng), mk_qkv(&mut rng));
    let serial = crate::poolx::Pool::serial();
    let aflops = shape.flops();
    let _ = writeln!(
        out,
        "attention tile autotune (flash fwd b={} h={} l={} d={}, single thread)",
        shape.batch, shape.heads, shape.seq, shape.head_dim
    );
    let mut attn_winner = AttnTiles::defaults();
    let mut attn_gf = 0.0f64;
    for &br in brbcs {
        for &bc in brbcs {
            let t = AttnTiles { br, bc };
            let r = bench_fn("tune", &opts, || {
                std::hint::black_box(attention::flash_attention_tiled(
                    active, &q, &k, &v, &shape, &serial, t,
                ));
            });
            let gf = aflops / (r.median.as_nanos() as f64).max(1.0);
            if gf > attn_gf {
                (attn_winner, attn_gf) = (t, gf);
            }
        }
    }
    let _ = writeln!(
        out,
        "  {:<7} best Br={} Bc={}  {:>7.2} GFLOP/s",
        active.name(),
        attn_winner.br,
        attn_winner.bc,
        attn_gf
    );
    // Br/Bc regroup the online-softmax update order — validate the
    // winner against the default tiling within the same relative
    // tolerance (chain length ≈ seq dominates the bound's depth).
    let want = attention::flash_attention_tiled(
        Dispatch::Scalar,
        &q,
        &k,
        &v,
        &shape,
        &serial,
        AttnTiles::defaults(),
    );
    let got = attention::flash_attention_tiled(active, &q, &k, &v, &shape, &serial, attn_winner);
    kernels::tol_check(&got, &want, shape.seq + shape.head_dim).map_err(anyhow::Error::msg)?;

    // Persist as the [kernels] section (other sections untouched) and
    // install for the rest of this process.
    let tiles = KernelTiles {
        kc: Some(winner.kc),
        mc: Some(winner.mc),
        nc: Some(winner.nc),
        br: Some(attn_winner.br),
        bc: Some(attn_winner.bc),
    };
    persist_kernels_section(cfg_path, &tiles.toml_section())?;
    tiles.apply()?;
    let _ = writeln!(
        out,
        "tuned: KC={} MC={} NC={} Br={} Bc={} ({:.2} GFLOP/s gemm at {}) → {cfg_path} [kernels]",
        winner.kc,
        winner.mc,
        winner.nc,
        attn_winner.br,
        attn_winner.bc,
        winner_gf,
        active.name()
    );
    Ok(out)
}

/// Replace (or append) the `[kernels]` section of `path`, preserving
/// every other line verbatim. `toml_lite` only parses, so persistence
/// is a text-level section splice.
fn persist_kernels_section(path: &str, section: &str) -> Result<()> {
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let mut kept = String::new();
    let mut in_kernels = false;
    for line in existing.lines() {
        let t = line.trim();
        if t.starts_with('[') {
            in_kernels = t == "[kernels]";
        }
        if !in_kernels {
            kept.push_str(line);
            kept.push('\n');
        }
    }
    while kept.ends_with("\n\n") {
        kept.pop();
    }
    if !kept.is_empty() {
        kept.push('\n');
    }
    kept.push_str(section);
    std::fs::write(path, kept)?;
    Ok(())
}

/// Validate every kernel artifact in the manifest; returns count checked.
#[cfg(feature = "pjrt")]
pub fn validate_kernels(engine: &Engine) -> Result<usize> {
    let kernels: Vec<ArtifactMeta> = engine
        .manifest
        .artifacts
        .iter()
        .filter(|a| a.kind == "kernel")
        .cloned()
        .collect();
    if kernels.is_empty() {
        bail!("no kernel artifacts in manifest — run `make artifacts`");
    }
    let mut rng = Xoshiro256::new(0xC0FFEE);
    let mut checked = 0;

    for meta in &kernels {
        match meta.kernel.as_deref() {
            Some("pamm_compress") => {
                let a_shape = dims(meta, "a")?;
                let c_shape = dims(meta, "c")?;
                let (b, n, k) = (a_shape[0], a_shape[1], c_shape[0]);
                let a = Mat::random_normal(b, n, 1.0, &mut rng);
                let idx = pamm::sample_generators(&mut rng, b, k);
                let c = a.gather_rows(&idx);
                let exec = engine.executable(&meta.name)?;
                let out = exec.run(&[mat_tensor(&a), mat_tensor(&c)])?;
                let native = pamm::compress(&a, &idx, Eps::Inf);
                let f_hlo = out[0].as_i32()?;
                let al_hlo = out[1].as_f32()?;
                let beta_hlo = out[2].scalar()?;
                let f_nat: Vec<i32> = native.assign.iter().map(|&x| x as i32).collect();
                if f_hlo != f_nat.as_slice() {
                    bail!("{}: assignment mismatch", meta.name);
                }
                let d = max_diff(al_hlo, &native.alpha);
                if d > 1e-3 {
                    bail!("{}: alpha diff {d}", meta.name);
                }
                if (beta_hlo - native.beta).abs() > 1e-4 {
                    bail!("{}: beta {} vs {}", meta.name, beta_hlo, native.beta);
                }
                checked += 1;
            }
            Some("pamm_apply") => {
                let c_shape = dims(meta, "c")?;
                let b_shape = dims(meta, "b_mat")?;
                let (k, n) = (c_shape[0], c_shape[1]);
                let (b, m) = (b_shape[0], b_shape[1]);
                // Build a real compressed rep so f/alpha are realistic.
                let a = Mat::random_normal(b, n, 1.0, &mut rng);
                let idx = pamm::sample_generators(&mut rng, b, k);
                let comp = pamm::compress(&a, &idx, Eps::Inf);
                let bm = Mat::random_normal(b, m, 1.0, &mut rng);
                let exec = engine.executable(&meta.name)?;
                let out = exec.run(&[
                    mat_tensor(&comp.generators),
                    HostTensor::i32(
                        vec![b],
                        comp.assign.iter().map(|&x| x as i32).collect(),
                    ),
                    HostTensor::f32(vec![b], comp.alpha.clone()),
                    HostTensor::scalar_f32(comp.beta),
                    mat_tensor(&bm),
                ])?;
                let native = pamm::apply(&comp, &bm);
                let d = max_diff(out[0].as_f32()?, native.data());
                if d > 2e-2 {
                    bail!("{}: apply diff {d}", meta.name);
                }
                checked += 1;
            }
            Some("pamm_matmul") => {
                let a_shape = dims(meta, "a")?;
                let b_shape = dims(meta, "b_mat")?;
                let g_shape = dims(meta, "gen_idx")?;
                let (b, n, m, k) = (a_shape[0], a_shape[1], b_shape[1], g_shape[0]);
                let a = Mat::random_normal(b, n, 1.0, &mut rng);
                let bm = Mat::random_normal(b, m, 1.0, &mut rng);
                let idx = pamm::sample_generators(&mut rng, b, k);
                let exec = engine.executable(&meta.name)?;
                let out = exec.run(&[
                    mat_tensor(&a),
                    mat_tensor(&bm),
                    HostTensor::i32(vec![k], idx.iter().map(|&x| x as i32).collect()),
                ])?;
                let native = pamm::pamm_matmul(&a, &bm, &idx, Eps::Inf);
                let d = max_diff(out[0].as_f32()?, native.data());
                let scale = native.frob_norm() / ((n * m) as f32).sqrt();
                if d > 1e-2 * scale.max(1.0) {
                    bail!("{}: pipeline diff {d} (scale {scale})", meta.name);
                }
                checked += 1;
            }
            Some("exact_matmul") => {
                let a_shape = dims(meta, "a")?;
                let b_shape = dims(meta, "b_mat")?;
                let (b, n, m) = (a_shape[0], a_shape[1], b_shape[1]);
                let _ = n;
                let a = Mat::random_normal(b, a_shape[1], 1.0, &mut rng);
                let bm = Mat::random_normal(b, m, 1.0, &mut rng);
                let exec = engine.executable(&meta.name)?;
                let out = exec.run(&[mat_tensor(&a), mat_tensor(&bm)])?;
                let native = pamm::exact_matmul(&a, &bm);
                let d = max_diff(out[0].as_f32()?, native.data());
                if d > 2e-2 {
                    bail!("{}: exact matmul diff {d}", meta.name);
                }
                checked += 1;
            }
            Some("flash_attention") | Some("attention_ref") => {
                checked += 1; // compared pairwise below
            }
            other => bail!("unknown kernel artifact kind {other:?}"),
        }
    }

    // Flash vs exact attention artifact pair (composability witness).
    let flash = kernels.iter().find(|a| a.kernel.as_deref() == Some("flash_attention"));
    let exact = kernels.iter().find(|a| a.kernel.as_deref() == Some("attention_ref"));
    if let (Some(fl), Some(ex)) = (flash, exact) {
        let q_shape = dims(fl, "q")?;
        let total: usize = q_shape.iter().product();
        let mk = |rng: &mut Xoshiro256| {
            let mut v = vec![0f32; total];
            rng.fill_normal_f32(&mut v, 1.0);
            HostTensor::f32(q_shape.clone(), v)
        };
        let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let out_f = engine.executable(&fl.name)?.run(&[q.clone(), k.clone(), v.clone()])?;
        let out_e = engine.executable(&ex.name)?.run(&[q, k, v])?;
        let d = max_diff(out_f[0].as_f32()?, out_e[0].as_f32()?);
        if d > 1e-3 {
            bail!("flash vs exact attention diff {d}");
        }
    }

    Ok(checked)
}
