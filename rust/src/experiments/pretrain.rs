//! Pretraining-quality experiments: Fig 3a, Fig 3b, Tables 3/5/6 and the
//! Fig 4a/4b ablations — all driven through the PJRT stack.
//!
//! Quality runs are cached in `<out>/<exp>.json` keyed by run name, so
//! `table5` reuses `fig3a`'s trainings and re-running an experiment after
//! an interruption resumes where it left off.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::checkpoint::write_csv;
use crate::config::{RunConfig, Variant};
use crate::coordinator::train_run;
use crate::jsonx::{self, Value};
use crate::memory::{self, ModelGeometry};
use crate::metrics::perplexity;
use crate::runtime::Engine;

/// Steps per model size (full mode) — CPU-budget choices recorded in
/// EXPERIMENTS.md. `--quick` divides by 8.
fn steps_for(model: &str, quick: bool) -> usize {
    let full = match model {
        "tiny" => 400,
        "small" => 160,
        "medium" => 60,
        _ => 200,
    };
    if quick {
        (full / 8).max(20)
    } else {
        full
    }
}

/// Result cache: run-name → final eval loss (JSON file under out/).
pub struct Cache {
    path: String,
    map: BTreeMap<String, f64>,
}

impl Cache {
    pub fn open(out: &str, exp: &str) -> Cache {
        let path = format!("{out}/{exp}_cache.json");
        let map = std::fs::read_to_string(&path)
            .ok()
            .and_then(|t| jsonx::parse(&t).ok())
            .and_then(|v| {
                v.as_obj().map(|o| {
                    o.iter()
                        .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
                        .collect()
                })
            })
            .unwrap_or_default();
        Cache { path, map }
    }

    fn get(&self, key: &str) -> Option<f64> {
        self.map.get(key).copied()
    }

    fn put(&mut self, key: &str, val: f64) {
        self.map.insert(key.to_string(), val);
        let obj = Value::Obj(
            self.map.iter().map(|(k, v)| (k.clone(), jsonx::num(*v))).collect(),
        );
        let _ = std::fs::write(&self.path, obj.to_string());
    }
}

/// Train (or fetch cached) one cell; returns final eval loss.
pub fn train_cell(
    engine: &Engine,
    cache: &mut Cache,
    model: &str,
    variant: Variant,
    batch: usize,
    seq: usize,
    steps: usize,
    seed: u64,
) -> Result<f64> {
    let key = format!("{model}_{}_{batch}x{seq}_s{seed}_t{steps}", variant.tag());
    if let Some(v) = cache.get(&key) {
        return Ok(v);
    }
    let cfg = RunConfig {
        model: model.into(),
        variant,
        batch,
        seq,
        steps,
        seed,
        eval_every: 0, // single final eval below
        eval_batches: 8,
        run_dir: "runs/experiments".into(),
        ..Default::default()
    };
    let out = train_run(engine, &cfg, true)
        .with_context(|| format!("training cell {key}"))?;
    let loss = out.final_eval_loss.unwrap_or(out.final_loss) as f64;
    cache.put(&key, loss);
    Ok(loss)
}

fn geometry(model: &str) -> ModelGeometry {
    ModelGeometry::by_name(model).expect("model in zoo")
}

const PRETRAIN_SHAPE: (usize, usize) = (8, 128); // tiny/small batch×seq
const MEDIUM_SHAPE: (usize, usize) = (4, 256);

fn shape_for(model: &str) -> (usize, usize) {
    if model == "medium" {
        MEDIUM_SHAPE
    } else {
        PRETRAIN_SHAPE
    }
}

/// Fig 3a: validation ppl across model sizes, PAMM vs baseline.
pub fn fig3a(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let sizes: &[&str] = if quick { &["tiny"] } else { &["tiny", "small", "medium"] };
    let variants = [
        Variant::baseline(),
        Variant::pamm(128),
        Variant::pamm(256),
        Variant::pamm(512),
    ];
    let mut cache = Cache::open(out, "pretrain");
    let mut rows = Vec::new();
    println!("{:<8} {:<12} {:>10} {:>10}", "model", "variant", "eval loss", "ppl");
    for &model in sizes {
        let (b, l) = shape_for(model);
        let steps = steps_for(model, quick);
        for var in &variants {
            let loss = train_cell(engine, &mut cache, model, var.clone(), b, l, steps, 42)?;
            let ppl = perplexity(loss);
            println!("{:<8} {:<12} {:>10.4} {:>10.2}", model, var.tag(), loss, ppl);
            rows.push(format!("{model},{},{loss},{ppl}", var.tag()));
        }
    }
    write_csv(format!("{out}/fig3a.csv"), "model,variant,eval_loss,ppl", &rows)?;
    println!("\nshape check: PAMM ppl within a few % of baseline at every size (paper Fig 3a).");
    Ok(())
}

/// Fig 3b: peak QKV-activation memory across sizes — analytic at paper
/// scale, plus the runnable scales for cross-checking.
pub fn fig3b(_engine: &Engine, out: &str) -> Result<()> {
    let mut rows = Vec::new();
    println!(
        "{:<10} {:>14} {:>14} {:>9}",
        "model", "baseline", "pamm r=1/512", "saved%"
    );
    for model in ["tiny", "small", "medium", "llama60m", "llama350m", "llama1b", "llama7b"] {
        let g = geometry(model);
        // Paper shapes for llama*, runnable shapes otherwise.
        let (b, l) = if model.starts_with("llama") { (64, 256) } else { shape_for(model) };
        let rep = memory::report(&g, b, l, Some(1.0 / 512.0));
        let saved = rep.savings_pct().unwrap();
        println!(
            "{:<10} {:>14} {:>14} {:>8.2}%",
            model,
            memory::fmt_bytes(rep.baseline_bytes),
            memory::fmt_bytes(rep.pamm_bytes.unwrap()),
            saved
        );
        rows.push(format!(
            "{model},{b},{l},{},{},{saved}",
            rep.baseline_bytes,
            rep.pamm_bytes.unwrap()
        ));
    }
    write_csv(
        format!("{out}/fig3b.csv"),
        "model,batch,seq,baseline_bytes,pamm_bytes,saved_pct",
        &rows,
    )?;
    println!("\nshape check: >97% memory saved at every size (paper Fig 3b).");
    Ok(())
}

/// Table 5 = Fig 3a quality + memory columns at the same cells.
pub fn table5(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let sizes: &[&str] = if quick { &["tiny"] } else { &["tiny", "small", "medium"] };
    let mut cache = Cache::open(out, "pretrain");
    let mut rows = Vec::new();
    println!(
        "{:<8} {:<12} {:>10} {:>12} {:>12}",
        "model", "variant", "ppl", "mem", "paper-scale"
    );
    for &model in sizes {
        let (b, l) = shape_for(model);
        let steps = steps_for(model, quick);
        let g = geometry(model);
        let paper_g = geometry(match model {
            "tiny" => "llama60m",
            "small" => "llama350m",
            _ => "llama1b",
        });
        for (var, r) in [
            (Variant::baseline(), None),
            (Variant::pamm(128), Some(1.0 / 128.0)),
            (Variant::pamm(256), Some(1.0 / 256.0)),
            (Variant::pamm(512), Some(1.0 / 512.0)),
        ] {
            let loss = train_cell(engine, &mut cache, model, var.clone(), b, l, steps, 42)?;
            let ppl = perplexity(loss);
            let mem = match r {
                None => memory::qkv_saved_bytes(&g, b, l, 4),
                Some(r) => memory::pamm_saved_bytes(&g, b, l, r, 4),
            };
            let paper_mem = match r {
                None => memory::qkv_saved_bytes(&paper_g, 64, 256, 4),
                Some(r) => memory::pamm_saved_bytes(&paper_g, 64, 256, r, 4),
            };
            println!(
                "{:<8} {:<12} {:>10.2} {:>12} {:>12}",
                model,
                var.tag(),
                ppl,
                memory::fmt_bytes(mem),
                memory::fmt_bytes(paper_mem)
            );
            rows.push(format!("{model},{},{ppl},{mem},{paper_mem}", var.tag()));
        }
    }
    write_csv(
        format!("{out}/table5.csv"),
        "model,variant,ppl,mem_bytes,paper_scale_mem_bytes",
        &rows,
    )?;
    Ok(())
}

/// Table 3: batch/seq ablation on tiny at r = 1/512.
pub fn table3(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    // Paper's 7 (B, L) combos scaled /16 (same token-count ladder).
    let combos: &[(usize, usize)] = if quick {
        &[(8, 16), (16, 32)]
    } else {
        &[(8, 16), (8, 64), (16, 16), (16, 32), (32, 8), (32, 16), (32, 32)]
    };
    let steps = if quick { 30 } else { 250 };
    let mut cache = Cache::open(out, "table3");
    let mut rows = Vec::new();
    println!(
        "{:<6} {:<6} {:>12} {:>12} {:>10}",
        "batch", "seq", "baseline ppl", "pamm ppl", "rel"
    );
    for &(b, l) in combos {
        let base = train_cell(engine, &mut cache, "tiny", Variant::baseline(), b, l, steps, 42)?;
        let pamm = train_cell(engine, &mut cache, "tiny", Variant::pamm(512), b, l, steps, 42)?;
        let (bp, pp) = (perplexity(base), perplexity(pamm));
        let rel = 100.0 * (pp / bp - 1.0);
        println!("{b:<6} {l:<6} {bp:>12.2} {pp:>12.2} {rel:>+9.1}%");
        rows.push(format!("{b},{l},{bp},{pp},{rel}"));
    }
    write_csv(
        format!("{out}/table3.csv"),
        "batch,seq,baseline_ppl,pamm_ppl,rel_change_pct",
        &rows,
    )?;
    println!("\nshape check: PAMM within a few % of baseline at every (B, L) (paper Table 3).");
    Ok(())
}

/// Fig 4a: PAMM vs CompAct vs Uniform-CRS across compression rates.
pub fn fig4a(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let rs: &[u32] = if quick { &[16, 512] } else { &[16, 64, 128, 256, 512] };
    let steps = if quick { 30 } else { 250 };
    let (b, l) = PRETRAIN_SHAPE;
    let mut cache = Cache::open(out, "fig4a");
    let base = train_cell(engine, &mut cache, "tiny", Variant::baseline(), b, l, steps, 42)?;
    println!("baseline ppl: {:.2}", perplexity(base));
    let mut rows = vec![format!("baseline,0,{}", perplexity(base))];
    println!("{:<10} {:>8} {:>12}", "method", "1/r", "ppl");
    for mode in ["pamm", "crs", "compact"] {
        for &ri in rs {
            let mut v = Variant::pamm(ri);
            v.mode = mode.into();
            let loss = train_cell(engine, &mut cache, "tiny", v, b, l, steps, 42)?;
            let ppl = perplexity(loss);
            println!("{mode:<10} {ri:>8} {ppl:>12.2}");
            rows.push(format!("{mode},{ri},{ppl}"));
        }
    }
    write_csv(format!("{out}/fig4a.csv"), "method,inv_r,ppl", &rows)?;
    println!("\nshape check: PAMM flat in r; CRS/CompAct degrade sharply as r shrinks (paper Fig 4a).");
    Ok(())
}

/// Fig 4b: ε ablation (ε = 0 ≙ Uniform-CRS, ε = ∞ best).
pub fn fig4b(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    let rs: &[u32] = if quick { &[128] } else { &[32, 128, 512] };
    let steps = if quick { 30 } else { 250 };
    let (b, l) = PRETRAIN_SHAPE;
    let mut cache = Cache::open(out, "fig4b");
    let mut rows = Vec::new();
    println!("{:<8} {:<8} {:>12}", "1/r", "eps", "ppl");
    for &ri in rs {
        for eps in [Some(0.0), Some(0.5), None] {
            let mut v = Variant::pamm(ri);
            v.eps = eps;
            let loss = train_cell(engine, &mut cache, "tiny", v, b, l, steps, 42)?;
            let ppl = perplexity(loss);
            let etag = eps.map(|e| format!("{e}")).unwrap_or_else(|| "inf".into());
            println!("{ri:<8} {etag:<8} {ppl:>12.2}");
            rows.push(format!("{ri},{etag},{ppl}"));
        }
    }
    write_csv(format!("{out}/fig4b.csv"), "inv_r,eps,ppl", &rows)?;
    println!("\nshape check: ppl(eps=inf) <= ppl(eps=0.5) <= ppl(eps=0) per r (paper Fig 4b).");
    Ok(())
}

/// Table 6: ppl at step milestones, largest runnable model standing in
/// for LLaMA-7B (substitution documented in DESIGN.md).
pub fn table6(engine: &Engine, quick: bool, out: &str) -> Result<()> {
    use crate::coordinator::pipeline::BatchPipeline;
    use crate::coordinator::session::TrainSession;
    use crate::data::batcher::BatchIterator;

    let model = "medium";
    let (b, l) = MEDIUM_SHAPE;
    let steps = if quick { 24 } else { 80 };
    let milestones = [steps / 4, steps / 2, 3 * steps / 4, steps];
    let variants = [Variant::baseline(), Variant::pamm(256), Variant::pamm(512)];

    let vocab = engine.manifest.config(model).context("medium config")?.vocab;
    let eval: Vec<_> = {
        let mut it = BatchIterator::from_seed(vocab, b, l, 0xE7A1);
        (0..4).map(|_| it.next_batch().to_tensor()).collect()
    };

    let mut table: Vec<(String, Vec<f64>)> = Vec::new();
    for var in &variants {
        let train_name = format!("train_{model}_{}_{b}x{l}", var.tag());
        let eval_name = format!("eval_{model}_{b}x{l}");
        let mut session = TrainSession::new(engine, &train_name, Some(&eval_name), 42)?;
        let pipe = BatchPipeline::spawn(BatchIterator::from_seed(vocab, b, l, 42), 2);
        let mut ppls = Vec::new();
        for s in 1..=steps {
            let batch = pipe.next();
            session.step(&batch.to_tensor())?;
            if milestones.contains(&s) {
                ppls.push(perplexity(session.eval(&eval)? as f64));
            }
        }
        println!(
            "{:<12} {}",
            var.tag(),
            ppls.iter().map(|p| format!("{p:>9.2}")).collect::<String>()
        );
        table.push((var.tag(), ppls));
    }
    // 7B analytic memory footnote (the part of Table 6's context we can
    // state exactly).
    let g7 = geometry("llama7b");
    println!(
        "(llama7b analytic QKV memory @64×256/GPU: baseline {}, r=1/512 {})",
        memory::fmt_bytes(memory::qkv_saved_bytes(&g7, 64, 256, 4)),
        memory::fmt_bytes(memory::pamm_saved_bytes(&g7, 64, 256, 1.0 / 512.0, 4))
    );
    let rows: Vec<String> = table
        .iter()
        .map(|(tag, ppls)| {
            format!(
                "{tag},{}",
                ppls.iter().map(|p| p.to_string()).collect::<Vec<_>>().join(",")
            )
        })
        .collect();
    write_csv(format!("{out}/table6.csv"), "variant,m1,m2,m3,m4", &rows)?;
    println!("\nshape check: PAMM ppl tracks (or beats) baseline at every milestone (paper Table 6).");
    Ok(())
}
