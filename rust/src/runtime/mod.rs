//! PJRT runtime: load AOT artifacts, compile once, execute from Rust.
//!
//! The request path is: [`Engine::load`] parses `artifacts/manifest.json`,
//! then per artifact [`Engine::executable`] does
//! `HloModuleProto::from_text_file → XlaComputation → client.compile`
//! (cached), and [`Exec::run`]/[`Exec::run_literals`] executes. Steady-state
//! training keeps params/optimizer state as device buffers and threads them
//! from one step's outputs to the next — the only per-step host traffic is
//! the token batch in and the loss scalar out.
//!
//! The PJRT pieces ([`Exec`], [`Engine`], the literal conversions) are
//! gated behind the `pjrt` cargo feature — the default build carries
//! only the host-side types: [`HostTensor`] (the checkpoint / native
//! interchange value) and the [`manifest`] model (whose `ConfigMeta`
//! cards also drive the native `pamm generate` path via
//! `generate::config_from_manifest`).

pub mod manifest;

#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

pub use manifest::{ArtifactMeta, ConfigMeta, Dtype, IoSpec, Manifest, ParamSpec, VariantMeta};

/// Host-side tensor: the literal ↔ Rust interchange value.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }
    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elements", d.len());
        }
        Ok(d[0])
    }

    #[cfg(feature = "pjrt")]
    fn dims_i64(&self) -> Vec<i64> {
        self.shape().iter().map(|&d| d as i64).collect()
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&self.dims_i64())?,
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&self.dims_i64())?,
        };
        Ok(lit)
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

/// A compiled artifact plus its manifest row.
#[cfg(feature = "pjrt")]
pub struct Exec {
    pub meta: ArtifactMeta,
    exe: Rc<xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Exec {
    /// Execute with host tensors; returns host tensors (convenience path —
    /// tests, kernel validation, one-shot evals).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let outs = self.run_literals(&lits)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute literal-in / literal-out — the steady-state training path.
    ///
    /// Multi-output modules come back from this PJRT build as a *single
    /// tuple buffer*; we decompose it into per-output literals. On the
    /// TfrtCpu client "device" buffers are host memory, so the literal
    /// round-trip is a memcpy, not a transfer (§Perf quantifies it at
    /// <2% of step time for the shapes we train).
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute(inputs)?;
        let expected = self.meta.outputs.len();
        let bufs: Vec<xla::PjRtBuffer> = out.into_iter().flatten().collect();
        if bufs.len() == expected {
            return bufs.iter().map(|b| Ok(b.to_literal_sync()?)).collect();
        }
        if bufs.len() == 1 {
            let lit = bufs[0].to_literal_sync()?;
            let parts = lit.to_tuple()?;
            if parts.len() != expected {
                bail!(
                    "{}: tuple arity {} != manifest outputs {}",
                    self.meta.name,
                    parts.len(),
                    expected
                );
            }
            return Ok(parts);
        }
        bail!(
            "{}: executable returned {} buffers, manifest expects {}",
            self.meta.name,
            bufs.len(),
            expected
        )
    }

    fn check_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(self.meta.inputs.iter()) {
            if t.shape() != spec.shape.as_slice() || t.dtype() != spec.dtype {
                bail!(
                    "{}: input `{}` expects {:?}{:?}, got {:?}{:?}",
                    self.meta.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        Ok(())
    }
}

/// Artifact directory + PJRT client + compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", manifest_path.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "engine: platform={} artifacts={}",
            client.platform_name(),
            manifest.artifacts.len()
        );
        Ok(Engine { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.iter().map(|a| a.name.clone()).collect()
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact `{name}` not in manifest"))
    }

    /// Find the first artifact satisfying a predicate (harness helper).
    pub fn find(&self, pred: impl Fn(&ArtifactMeta) -> bool) -> Option<&ArtifactMeta> {
        self.manifest.artifacts.iter().find(|a| pred(a))
    }

    /// Compile (or fetch cached) and wrap an artifact.
    pub fn executable(&self, name: &str) -> Result<Exec> {
        let meta = self.meta(name)?.clone();
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Exec { meta, exe: exe.clone() });
        }
        let path = self.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        log::info!("compiled {} in {:.2}s", name, t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(Exec { meta, exe })
    }

    /// Upload a host tensor to the device.
    pub fn to_device(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let lit = t.to_literal()?;
        Ok(self.client.buffer_from_host_literal(None, &lit)?)
    }

    pub fn to_host(&self, b: &xla::PjRtBuffer) -> Result<HostTensor> {
        HostTensor::from_literal(&b.to_literal_sync()?)
    }
}
