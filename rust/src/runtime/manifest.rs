//! Typed view of `artifacts/manifest.json` (the AOT calling convention).
//!
//! aot.py is the single writer; this module is the single reader. Any
//! schema drift fails loudly here rather than as a shape error deep in
//! PJRT execution.

use anyhow::{bail, Context, Result};

use crate::jsonx::{self, Value};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype in manifest: {other}"),
        }
    }
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One positional input or output of an executable.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
    fn parse(v: &Value) -> Result<IoSpec> {
        Ok(IoSpec {
            name: v.req_str("name")?.to_string(),
            shape: parse_shape(v.req("shape")?)?,
            dtype: Dtype::parse(v.req_str("dtype")?)?,
        })
    }
}

/// One model parameter (init recipe; order defines the calling convention).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// Gaussian init std; negative means "init to ones" (norm gains).
    pub init_std: f64,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The compression variant an artifact was lowered with (paper §4.6 axes).
#[derive(Debug, Clone)]
pub struct VariantMeta {
    pub mode: String,
    pub r: f64,
    /// `None` = no neighborhood condition (paper's ε = ∞; JSON `-1`).
    pub eps: Option<f64>,
    pub use_pallas: bool,
}

impl VariantMeta {
    fn parse(v: &Value) -> Result<VariantMeta> {
        let eps = v.req_f64("eps")?;
        Ok(VariantMeta {
            mode: v.req_str("mode")?.to_string(),
            r: v.req_f64("r")?,
            eps: if eps < 0.0 { None } else { Some(eps) },
            use_pallas: v.get("use_pallas").as_bool().unwrap_or(false),
        })
    }
}

/// Training hyper-parameters baked into a train_step artifact.
#[derive(Debug, Clone)]
pub struct TrainMeta {
    pub lr: f64,
    pub steps: usize,
    pub pamm_lr_scale: f64,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub config: Option<String>,
    pub variant: Option<VariantMeta>,
    pub batch: Option<usize>,
    pub seq: Option<usize>,
    pub n_classes: Option<usize>,
    pub train: Option<TrainMeta>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub param_spec: Vec<ParamSpec>,
    /// Kernel-artifact extras (`kernel` name + dims map as JSON).
    pub kernel: Option<String>,
}

impl ArtifactMeta {
    /// Tag like "pamm512", "baseline", "crs64" — harness display key.
    pub fn variant_tag(&self) -> String {
        match &self.variant {
            None => "-".into(),
            Some(v) if v.mode == "baseline" => "baseline".into(),
            Some(v) => {
                let inv = (1.0 / v.r).round() as i64;
                let mut t = format!("{}{}", v.mode, inv);
                if v.use_pallas {
                    t.push_str("pl");
                }
                if let Some(e) = v.eps {
                    t.push_str(&format!("_eps{e}"));
                }
                t
            }
        }
    }
}

/// Model architecture row (`configs` manifest section) — cross-checked
/// against rust/src/memory's analytic model in tests.
#[derive(Debug, Clone)]
pub struct ConfigMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub param_count: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
    pub configs: Vec<ConfigMeta>,
}

fn parse_shape(v: &Value) -> Result<Vec<usize>> {
    v.as_arr()
        .context("shape must be an array")?
        .iter()
        .map(|d| d.as_usize().context("shape dim must be a number"))
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = jsonx::parse(text).context("manifest.json parse")?;
        let version = root.req_usize("version")?;
        if version != 1 {
            bail!("manifest version {version} unsupported (expected 1)");
        }

        let mut artifacts = Vec::new();
        for a in root.req_arr("artifacts")? {
            let variant = match a.get("variant") {
                Value::Null => None,
                v => Some(VariantMeta::parse(v)?),
            };
            let train = match a.get("train") {
                Value::Null => None,
                t => Some(TrainMeta {
                    lr: t.req_f64("lr")?,
                    steps: t.req_usize("steps")?,
                    pamm_lr_scale: t.req_f64("pamm_lr_scale")?,
                }),
            };
            let param_spec = match a.get("param_spec") {
                Value::Null => Vec::new(),
                ps => ps
                    .as_arr()
                    .context("param_spec must be array")?
                    .iter()
                    .map(|p| {
                        Ok(ParamSpec {
                            name: p.req_str("name")?.to_string(),
                            shape: parse_shape(p.req("shape")?)?,
                            init_std: p.req_f64("init_std")?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
            };
            artifacts.push(ArtifactMeta {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                config: a.get("config").as_str().map(String::from),
                variant,
                batch: a.get("batch").as_usize(),
                seq: a.get("seq").as_usize(),
                n_classes: a.get("n_classes").as_usize(),
                train,
                inputs: a
                    .req_arr("inputs")?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .req_arr("outputs")?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
                param_spec,
                kernel: a.get("kernel").as_str().map(String::from),
            });
        }

        let mut configs = Vec::new();
        if let Some(obj) = root.get("configs").as_obj() {
            for (name, c) in obj {
                configs.push(ConfigMeta {
                    name: name.clone(),
                    vocab: c.req_usize("vocab")?,
                    d_model: c.req_usize("d_model")?,
                    n_layers: c.req_usize("n_layers")?,
                    n_heads: c.req_usize("n_heads")?,
                    d_ff: c.req_usize("d_ff")?,
                    param_count: c.req_usize("param_count")?,
                });
            }
        }

        Ok(Manifest { artifacts, configs })
    }

    pub fn config(&self, name: &str) -> Option<&ConfigMeta> {
        self.configs.iter().find(|c| c.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {
          "name": "train_tiny_pamm512_8x128",
          "file": "train_tiny_pamm512_8x128.hlo.txt",
          "kind": "train_step",
          "config": "tiny",
          "variant": {"mode": "pamm", "r": 0.001953125, "eps": -1.0, "use_pallas": false},
          "batch": 8, "seq": 128,
          "train": {"lr": 0.003, "steps": 600, "pamm_lr_scale": 0.25},
          "inputs": [{"name": "param.embed", "shape": [512, 128], "dtype": "f32"}],
          "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
          "param_spec": [{"name": "embed", "shape": [512, 128], "init_std": 0.02}]
        }
      ],
      "configs": {"tiny": {"vocab": 512, "d_model": 128, "n_layers": 4,
                           "n_heads": 4, "d_ff": 344, "param_count": 1000000}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.kind, "train_step");
        assert_eq!(a.variant.as_ref().unwrap().mode, "pamm");
        assert!(a.variant.as_ref().unwrap().eps.is_none()); // -1 → ∞
        assert_eq!(a.variant_tag(), "pamm512");
        assert_eq!(a.inputs[0].shape, vec![512, 128]);
        assert_eq!(m.config("tiny").unwrap().d_ff, 344);
        assert_eq!(a.train.as_ref().unwrap().steps, 600);
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"dtype\": \"f32\"", "\"dtype\": \"f64\"");
        assert!(Manifest::parse(&bad).is_err());
    }
}
