//! Byte-pair-lite tokenizer.
//!
//! A word-piece-style greedy tokenizer trained from a corpus sample:
//! start from the byte alphabet, repeatedly merge the most frequent
//! adjacent symbol pair (classic BPE training), then encode new text by
//! greedy longest-match over the learned vocabulary. Small (< 300 lines),
//! deterministic, and fast enough to tokenize millions of words/s — the
//! data pipeline must stay off the training critical path (§Perf).
//!
//! Special ids: 0 = PAD, 1 = BOS, 2 = EOS, 3 = UNK; byte/merge tokens
//! start at 4.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const UNK: i32 = 3;
pub const SPECIAL_TOKENS: usize = 4;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// token id → string (ids ≥ SPECIAL_TOKENS).
    vocab: Vec<String>,
    /// Longest-match lookup.
    lookup: HashMap<String, i32>,
    max_piece_len: usize,
}

impl Tokenizer {
    /// Train on `sample` until the vocabulary reaches `vocab_size`.
    pub fn train(sample: &str, vocab_size: usize) -> Tokenizer {
        assert!(vocab_size > SPECIAL_TOKENS + 96, "vocab too small: {vocab_size}");

        // Seed vocabulary: printable ASCII bytes (the corpus alphabet).
        let mut vocab: Vec<String> =
            (0x20u8..0x7F).map(|b| (b as char).to_string()).collect();

        // Represent the sample as symbol sequences per word (space-split;
        // the space itself is re-attached as a word prefix marker so that
        // merges can cross into word boundaries like real BPE's "Ġ").
        let mut words: HashMap<Vec<String>, usize> = HashMap::new();
        for w in sample.split(' ') {
            if w.is_empty() {
                continue;
            }
            let mut syms: Vec<String> = vec![" ".to_string()];
            syms.extend(w.chars().map(|c| c.to_string()));
            *words.entry(syms).or_default() += 1;
        }

        while vocab.len() + SPECIAL_TOKENS < vocab_size {
            // Count adjacent pairs.
            let mut pair_counts: HashMap<(String, String), usize> = HashMap::new();
            for (syms, &cnt) in &words {
                for w in syms.windows(2) {
                    *pair_counts.entry((w[0].clone(), w[1].clone())).or_default() += cnt;
                }
            }
            // Deterministic argmax: count desc, then lexicographic.
            let best = pair_counts.into_iter().max_by(|a, b| {
                a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0))
            });
            let Some(((l, r), cnt)) = best else { break };
            if cnt < 2 {
                break; // nothing left worth merging
            }
            let merged = format!("{l}{r}");
            vocab.push(merged.clone());
            // Apply the merge everywhere.
            let mut new_words = HashMap::with_capacity(words.len());
            for (syms, cnt) in words.drain() {
                let mut out = Vec::with_capacity(syms.len());
                let mut i = 0;
                while i < syms.len() {
                    if i + 1 < syms.len() && syms[i] == l && syms[i + 1] == r {
                        out.push(merged.clone());
                        i += 2;
                    } else {
                        out.push(syms[i].clone());
                        i += 1;
                    }
                }
                *new_words.entry(out).or_default() += cnt;
            }
            words = new_words;
        }

        let mut lookup = HashMap::with_capacity(vocab.len());
        let mut max_len = 1;
        for (i, piece) in vocab.iter().enumerate() {
            lookup.insert(piece.clone(), (i + SPECIAL_TOKENS) as i32);
            max_len = max_len.max(piece.chars().count());
        }
        Tokenizer { vocab, lookup, max_piece_len: max_len }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len() + SPECIAL_TOKENS
    }

    /// Greedy longest-match encoding (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let chars: Vec<char> = text.chars().collect();
        let mut out = Vec::with_capacity(chars.len() / 2);
        let mut i = 0;
        while i < chars.len() {
            let mut matched = false;
            let max_len = self.max_piece_len.min(chars.len() - i);
            for len in (1..=max_len).rev() {
                let piece: String = chars[i..i + len].iter().collect();
                if let Some(&id) = self.lookup.get(&piece) {
                    out.push(id);
                    i += len;
                    matched = true;
                    break;
                }
            }
            if !matched {
                out.push(UNK);
                i += 1;
            }
        }
        out
    }

    /// Encode a document with sentence framing: BOS … EOS.
    pub fn encode_document(&self, text: &str) -> Vec<i32> {
        let mut out = vec![BOS];
        out.extend(self.encode(text));
        out.push(EOS);
        out
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            match id {
                PAD | BOS | EOS => {}
                UNK => out.push('\u{FFFD}'),
                id => {
                    let ix = id as usize - SPECIAL_TOKENS;
                    if ix < self.vocab.len() {
                        out.push_str(&self.vocab[ix]);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{CorpusConfig, CorpusGenerator};

    fn sample() -> String {
        let mut g = CorpusGenerator::new(CorpusConfig::default(), 42);
        g.document(3000)
    }

    #[test]
    fn roundtrip_lossless_on_corpus_text() {
        let s = sample();
        let tok = Tokenizer::train(&s, 512);
        let head: String = s.chars().take(500).collect();
        let ids = tok.encode(&head);
        assert_eq!(tok.decode(&ids), head);
    }

    #[test]
    fn vocab_size_respected() {
        let tok = Tokenizer::train(&sample(), 512);
        assert!(tok.vocab_size() <= 512);
        assert!(tok.vocab_size() > 200, "merges should have happened");
        let ids = tok.encode(&sample());
        assert!(ids.iter().all(|&t| (t as usize) < tok.vocab_size()));
    }

    #[test]
    fn merges_compress() {
        let s = sample();
        let small = Tokenizer::train(&s, 200);
        let large = Tokenizer::train(&s, 1024);
        let n_small = small.encode(&s).len();
        let n_large = large.encode(&s).len();
        assert!(
            n_large * 10 < n_small * 9,
            "larger vocab should compress better: {n_large} vs {n_small}"
        );
    }

    #[test]
    fn unknown_chars_map_to_unk() {
        let tok = Tokenizer::train(&sample(), 300);
        let ids = tok.encode("héllo"); // é is outside the ascii alphabet
        assert!(ids.contains(&UNK));
    }

    #[test]
    fn document_framing() {
        let tok = Tokenizer::train(&sample(), 300);
        let ids = tok.encode_document("abc");
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
    }

    #[test]
    fn deterministic_training() {
        let s = sample();
        let a = Tokenizer::train(&s, 400);
        let b = Tokenizer::train(&s, 400);
        assert_eq!(a.encode(&s), b.encode(&s));
    }
}
