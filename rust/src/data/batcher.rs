//! Sequence packing + batch iteration.
//!
//! Token streams from the corpus are packed into fixed (batch, seq+1) rows
//! — seq+1 because the LM step consumes `tokens[:, :-1]` as inputs and
//! `tokens[:, 1:]` as targets. Packing is dense (documents concatenated,
//! split at row boundaries): no padding waste, matching the paper's
//! pretraining setup. The iterator pre-generates ahead of the training
//! loop on a background thread (see `coordinator::pipeline`) so data never
//! stalls a step.

use crate::data::corpus::{CorpusConfig, CorpusGenerator};
use crate::data::tokenizer::Tokenizer;
use crate::runtime::HostTensor;

/// One training batch (decoder LM convention: seq+1 columns).
#[derive(Debug, Clone)]
pub struct TokenBatch {
    pub batch: usize,
    pub seq: usize,
    /// Row-major (batch, seq+1) token ids.
    pub tokens: Vec<i32>,
}

impl TokenBatch {
    pub fn to_tensor(&self) -> HostTensor {
        HostTensor::i32(vec![self.batch, self.seq + 1], self.tokens.clone())
    }

    pub fn n_tokens(&self) -> usize {
        self.batch * self.seq
    }
}

/// Streaming corpus → packed batches, deterministic per seed.
pub struct BatchIterator {
    gen: CorpusGenerator,
    tok: Tokenizer,
    batch: usize,
    seq: usize,
    /// Carry-over tokens from the previous document tail.
    buffer: Vec<i32>,
    vocab_cap: i32,
}

impl BatchIterator {
    pub fn new(tok: Tokenizer, batch: usize, seq: usize, seed: u64) -> Self {
        let vocab_cap = tok.vocab_size() as i32;
        Self {
            gen: CorpusGenerator::new(CorpusConfig::default(), seed),
            tok,
            batch,
            seq,
            buffer: Vec::new(),
            vocab_cap,
        }
    }

    /// Train a tokenizer of `vocab_size` and build the iterator — the
    /// one-call setup used by examples.
    ///
    /// The tokenizer is trained from a FIXED corpus sample independent of
    /// `seed`: different seeds must mean different *document streams* of
    /// the same language, not different token vocabularies (otherwise a
    /// held-out eval stream would be gibberish to the trained model).
    pub fn from_seed(vocab_size: usize, batch: usize, seq: usize, seed: u64) -> Self {
        Self::from_seed_with_tokenizer(vocab_size, batch, seq, seed, 0x70C)
    }

    /// As [`from_seed`], with an explicit tokenizer-sample seed (kept
    /// stable across train/eval streams of one run).
    pub fn from_seed_with_tokenizer(
        vocab_size: usize,
        batch: usize,
        seq: usize,
        seed: u64,
        tok_seed: u64,
    ) -> Self {
        let mut sampler = CorpusGenerator::new(CorpusConfig::default(), tok_seed);
        let sample = sampler.document(20_000);
        let tok = Tokenizer::train(&sample, vocab_size);
        Self::new(tok, batch, seq, seed)
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    /// Advance the stream by `n` batches without materializing tensors
    /// — how a resumed training run fast-forwards the deterministic
    /// token stream to its checkpointed step (`coordinator::lm`).
    /// `skip_batches(n)` followed by `next_batch()` yields exactly the
    /// `(n+1)`-th batch of a fresh iterator with the same seed.
    pub fn skip_batches(&mut self, n: usize) {
        for _ in 0..n {
            let _ = self.next_batch();
        }
    }

    /// Produce the next packed batch (never fails — the corpus is infinite).
    pub fn next_batch(&mut self) -> TokenBatch {
        let need = self.batch * (self.seq + 1);
        while self.buffer.len() < need {
            let doc = self.gen.document(1024);
            self.buffer.extend(self.tok.encode_document(&doc));
        }
        let mut tokens: Vec<i32> = self.buffer.drain(..need).collect();
        // Clamp (defensive: UNK and specials are < vocab; model vocab may
        // be smaller than tokenizer's if configured oddly).
        for t in tokens.iter_mut() {
            if *t >= self.vocab_cap {
                *t = self.vocab_cap - 1;
            }
        }
        TokenBatch { batch: self.batch, seq: self.seq, tokens }
    }
}

/// One worker rank's deterministic interleaved shard of the global
/// batch stream (`coordinator::dp`).
///
/// The global stream is the plain [`BatchIterator`] sequence
/// `j = 0, 1, 2, …`. With `ranks = R` workers and `accum = A`
/// microbatches per worker per optimizer step, step `s` consumes the
/// contiguous window `[s·R·A, (s+1)·R·A)` and rank `r` owns the slice
/// `[s·R·A + r·A, s·R·A + (r+1)·A)` — every global batch belongs to
/// exactly one rank, and the union of all ranks' streams is the global
/// stream in order. `R = 1, A = 1` degenerates to the plain iterator
/// bit for bit, which is what makes the single-worker data-parallel
/// trainer bit-match `train_lm_native`.
///
/// # Ragged-count contract
///
/// A *bounded* stream of `total` batches shards into exactly
/// [`BatchShard::complete_rounds`]`(total, R, A)` full optimizer
/// steps. The ragged tail of `total mod (R·A)` batches is **dropped
/// deterministically** — it is never assigned to any rank, and in
/// particular never duplicated across ranks (duplicating it would
/// silently bias the gradient toward the tail batches and break the
/// R-invariance of the trajectory). Tested below
/// (`ragged_tail_is_dropped_never_duplicated`).
pub struct BatchShard {
    it: BatchIterator,
    rank: usize,
    ranks: usize,
    accum: usize,
    /// Global-stream batches this shard has consumed *or skipped* —
    /// the shard cursor a sharded checkpoint persists; at an optimizer
    /// step boundary it equals `origin + s·R·A + rank·A`.
    cursor: usize,
    /// Batches taken in the current accumulation window (`0..accum`).
    taken: usize,
}

impl BatchShard {
    /// Rank `rank` of `ranks` workers over the seed's global stream,
    /// starting at global batch 0.
    pub fn new(
        vocab_size: usize,
        batch: usize,
        seq: usize,
        seed: u64,
        rank: usize,
        ranks: usize,
        accum: usize,
    ) -> Self {
        Self::at_origin(vocab_size, batch, seq, seed, rank, ranks, accum, 0)
    }

    /// A shard re-attached at global stream position `origin` — the
    /// elastic-reshard constructor: after a worker dies, survivors
    /// re-interleave the global stream from the checkpoint boundary's
    /// cursor, so the dead rank's data is redistributed instead of
    /// lost (`coordinator::dp` reshard contract).
    pub fn at_origin(
        vocab_size: usize,
        batch: usize,
        seq: usize,
        seed: u64,
        rank: usize,
        ranks: usize,
        accum: usize,
        origin: usize,
    ) -> Self {
        assert!(ranks >= 1 && accum >= 1, "shard: ranks/accum must be >= 1");
        assert!(rank < ranks, "shard: rank {rank} out of 0..{ranks}");
        let mut it = BatchIterator::from_seed(vocab_size, batch, seq, seed);
        let cursor = origin + rank * accum;
        it.skip_batches(cursor);
        Self { it, rank, ranks, accum, cursor, taken: 0 }
    }

    /// Exact restore from a persisted shard cursor (a sharded
    /// checkpoint's `meta.cursor`). Restoring replays the underlying
    /// stream to `cursor`, so the next batch is bit-identical to the
    /// one the checkpointed shard would have produced. Only optimizer
    /// step boundaries are checkpointed, so the accumulation window is
    /// always empty at restore.
    pub fn from_cursor(
        vocab_size: usize,
        batch: usize,
        seq: usize,
        seed: u64,
        rank: usize,
        ranks: usize,
        accum: usize,
        cursor: usize,
    ) -> Self {
        assert!(ranks >= 1 && accum >= 1, "shard: ranks/accum must be >= 1");
        assert!(rank < ranks, "shard: rank {rank} out of 0..{ranks}");
        let mut it = BatchIterator::from_seed(vocab_size, batch, seq, seed);
        it.skip_batches(cursor);
        Self { it, rank, ranks, accum, cursor, taken: 0 }
    }

    /// Full optimizer steps a bounded stream of `total` batches
    /// yields at `ranks × accum` microbatches per step — the ragged
    /// tail `total % (ranks·accum)` is dropped, never duplicated.
    pub fn complete_rounds(total: usize, ranks: usize, accum: usize) -> usize {
        total / (ranks.max(1) * accum.max(1))
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Global-stream position (consumed + skipped batches) — what a
    /// sharded checkpoint persists for exact restore.
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    /// The next batch this rank owns. After `accum` consecutive
    /// batches the shard skips the other `ranks − 1` workers' windows,
    /// landing on its slice of the next optimizer step.
    pub fn next_batch(&mut self) -> TokenBatch {
        let b = self.it.next_batch();
        self.cursor += 1;
        self.taken += 1;
        if self.taken == self.accum {
            let skip = (self.ranks - 1) * self.accum;
            self.it.skip_batches(skip);
            self.cursor += skip;
            self.taken = 0;
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iter(batch: usize, seq: usize) -> BatchIterator {
        BatchIterator::from_seed(512, batch, seq, 7)
    }

    #[test]
    fn batches_have_exact_shape() {
        let mut it = iter(4, 32);
        for _ in 0..3 {
            let b = it.next_batch();
            assert_eq!(b.tokens.len(), 4 * 33);
            assert_eq!(b.n_tokens(), 128);
            let t = b.to_tensor();
            assert_eq!(t.shape(), &[4, 33]);
        }
    }

    #[test]
    fn deterministic_stream() {
        let mut a = iter(2, 16);
        let mut b = iter(2, 16);
        for _ in 0..5 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn tokens_in_vocab_range() {
        let mut it = iter(4, 64);
        for _ in 0..5 {
            let b = it.next_batch();
            let cap = it.tokenizer().vocab_size() as i32;
            assert!(b.tokens.iter().all(|&t| t >= 0 && t < cap));
        }
    }

    #[test]
    fn packing_is_dense_no_padding() {
        let mut it = iter(8, 64);
        let b = it.next_batch();
        let pads = b.tokens.iter().filter(|&&t| t == crate::data::tokenizer::PAD).count();
        assert_eq!(pads, 0, "dense packing should emit no PAD tokens");
    }

    #[test]
    fn consecutive_batches_differ() {
        let mut it = iter(2, 32);
        assert_ne!(it.next_batch().tokens, it.next_batch().tokens);
    }

    /// First `n` global batches of the seed-7 stream.
    fn global_prefix(n: usize) -> Vec<Vec<i32>> {
        let mut it = iter(1, 8);
        (0..n).map(|_| it.next_batch().tokens).collect()
    }

    fn shard(rank: usize, ranks: usize, accum: usize) -> BatchShard {
        BatchShard::new(512, 1, 8, 7, rank, ranks, accum)
    }

    #[test]
    fn shards_partition_the_global_stream_exactly_once() {
        // 3 steps × (R=3 × A=2) = 18 global batches; rank r's 6
        // batches must be exactly its interleaved slices, and the
        // union must be the global prefix with no batch duplicated
        // or dropped.
        let (ranks, accum, steps) = (3usize, 2usize, 3usize);
        let global = global_prefix(steps * ranks * accum);
        let mut seen = vec![0usize; global.len()];
        for r in 0..ranks {
            let mut sh = shard(r, ranks, accum);
            for s in 0..steps {
                for a in 0..accum {
                    let j = s * ranks * accum + r * accum + a;
                    let b = sh.next_batch();
                    assert_eq!(b.tokens, global[j], "rank {r} step {s} accum {a} != global batch {j}");
                    seen[j] += 1;
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "every global batch exactly once: {seen:?}");
    }

    #[test]
    fn single_worker_shard_is_the_plain_iterator() {
        let mut plain = iter(1, 8);
        let mut sh = shard(0, 1, 1);
        for _ in 0..5 {
            assert_eq!(sh.next_batch().tokens, plain.next_batch().tokens);
        }
    }

    #[test]
    fn ragged_tail_is_dropped_never_duplicated() {
        // 10 batches across R=3, A=1: exactly 3 complete rounds
        // (batches 0..9 minus the ragged batch 9). The contract: the
        // tail is dropped — no rank's complete-round stream contains
        // it, and no batch appears twice.
        let (ranks, total) = (3usize, 10usize);
        let rounds = BatchShard::complete_rounds(total, ranks, 1);
        assert_eq!(rounds, 3);
        let global = global_prefix(total);
        let mut counts = vec![0usize; total];
        for r in 0..ranks {
            let mut sh = shard(r, ranks, 1);
            for _ in 0..rounds {
                let b = sh.next_batch();
                let j = global.iter().position(|g| g == &b.tokens).expect("batch from the global stream");
                counts[j] += 1;
            }
        }
        assert_eq!(&counts[..9], &[1; 9], "complete rounds cover batches 0..9 exactly once");
        assert_eq!(counts[9], 0, "the ragged batch must be dropped, not assigned");
    }

    #[test]
    fn cursor_restore_is_bit_exact() {
        let (ranks, accum) = (2usize, 2usize);
        let mut a = shard(1, ranks, accum);
        for _ in 0..accum * 3 {
            a.next_batch();
        }
        // Step boundary: cursor = 3·R·A + rank·A.
        assert_eq!(a.cursor(), 3 * ranks * accum + accum);
        let mut b = BatchShard::from_cursor(512, 1, 8, 7, 1, ranks, accum, a.cursor());
        for _ in 0..4 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
        assert_eq!(a.cursor(), b.cursor());
    }

    #[test]
    fn reshard_at_origin_reinterleaves_survivors() {
        // After 2 steps of R=2/A=1 (origin 4), a reshard to R=1 must
        // hand the single survivor the whole global stream from
        // batch 4 on — including batches the dead rank would have
        // owned.
        let global = global_prefix(8);
        let mut sh = BatchShard::at_origin(512, 1, 8, 7, 0, 1, 1, 4);
        for j in 4..8 {
            assert_eq!(sh.next_batch().tokens, global[j], "resharded stream must continue at batch {j}");
        }
    }
}
