//! Sequence packing + batch iteration.
//!
//! Token streams from the corpus are packed into fixed (batch, seq+1) rows
//! — seq+1 because the LM step consumes `tokens[:, :-1]` as inputs and
//! `tokens[:, 1:]` as targets. Packing is dense (documents concatenated,
//! split at row boundaries): no padding waste, matching the paper's
//! pretraining setup. The iterator pre-generates ahead of the training
//! loop on a background thread (see `coordinator::pipeline`) so data never
//! stalls a step.

use crate::data::corpus::{CorpusConfig, CorpusGenerator};
use crate::data::tokenizer::Tokenizer;
use crate::runtime::HostTensor;

/// One training batch (decoder LM convention: seq+1 columns).
#[derive(Debug, Clone)]
pub struct TokenBatch {
    pub batch: usize,
    pub seq: usize,
    /// Row-major (batch, seq+1) token ids.
    pub tokens: Vec<i32>,
}

impl TokenBatch {
    pub fn to_tensor(&self) -> HostTensor {
        HostTensor::i32(vec![self.batch, self.seq + 1], self.tokens.clone())
    }

    pub fn n_tokens(&self) -> usize {
        self.batch * self.seq
    }
}

/// Streaming corpus → packed batches, deterministic per seed.
pub struct BatchIterator {
    gen: CorpusGenerator,
    tok: Tokenizer,
    batch: usize,
    seq: usize,
    /// Carry-over tokens from the previous document tail.
    buffer: Vec<i32>,
    vocab_cap: i32,
}

impl BatchIterator {
    pub fn new(tok: Tokenizer, batch: usize, seq: usize, seed: u64) -> Self {
        let vocab_cap = tok.vocab_size() as i32;
        Self {
            gen: CorpusGenerator::new(CorpusConfig::default(), seed),
            tok,
            batch,
            seq,
            buffer: Vec::new(),
            vocab_cap,
        }
    }

    /// Train a tokenizer of `vocab_size` and build the iterator — the
    /// one-call setup used by examples.
    ///
    /// The tokenizer is trained from a FIXED corpus sample independent of
    /// `seed`: different seeds must mean different *document streams* of
    /// the same language, not different token vocabularies (otherwise a
    /// held-out eval stream would be gibberish to the trained model).
    pub fn from_seed(vocab_size: usize, batch: usize, seq: usize, seed: u64) -> Self {
        Self::from_seed_with_tokenizer(vocab_size, batch, seq, seed, 0x70C)
    }

    /// As [`from_seed`], with an explicit tokenizer-sample seed (kept
    /// stable across train/eval streams of one run).
    pub fn from_seed_with_tokenizer(
        vocab_size: usize,
        batch: usize,
        seq: usize,
        seed: u64,
        tok_seed: u64,
    ) -> Self {
        let mut sampler = CorpusGenerator::new(CorpusConfig::default(), tok_seed);
        let sample = sampler.document(20_000);
        let tok = Tokenizer::train(&sample, vocab_size);
        Self::new(tok, batch, seq, seed)
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    /// Advance the stream by `n` batches without materializing tensors
    /// — how a resumed training run fast-forwards the deterministic
    /// token stream to its checkpointed step (`coordinator::lm`).
    /// `skip_batches(n)` followed by `next_batch()` yields exactly the
    /// `(n+1)`-th batch of a fresh iterator with the same seed.
    pub fn skip_batches(&mut self, n: usize) {
        for _ in 0..n {
            let _ = self.next_batch();
        }
    }

    /// Produce the next packed batch (never fails — the corpus is infinite).
    pub fn next_batch(&mut self) -> TokenBatch {
        let need = self.batch * (self.seq + 1);
        while self.buffer.len() < need {
            let doc = self.gen.document(1024);
            self.buffer.extend(self.tok.encode_document(&doc));
        }
        let mut tokens: Vec<i32> = self.buffer.drain(..need).collect();
        // Clamp (defensive: UNK and specials are < vocab; model vocab may
        // be smaller than tokenizer's if configured oddly).
        for t in tokens.iter_mut() {
            if *t >= self.vocab_cap {
                *t = self.vocab_cap - 1;
            }
        }
        TokenBatch { batch: self.batch, seq: self.seq, tokens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iter(batch: usize, seq: usize) -> BatchIterator {
        BatchIterator::from_seed(512, batch, seq, 7)
    }

    #[test]
    fn batches_have_exact_shape() {
        let mut it = iter(4, 32);
        for _ in 0..3 {
            let b = it.next_batch();
            assert_eq!(b.tokens.len(), 4 * 33);
            assert_eq!(b.n_tokens(), 128);
            let t = b.to_tensor();
            assert_eq!(t.shape(), &[4, 33]);
        }
    }

    #[test]
    fn deterministic_stream() {
        let mut a = iter(2, 16);
        let mut b = iter(2, 16);
        for _ in 0..5 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn tokens_in_vocab_range() {
        let mut it = iter(4, 64);
        for _ in 0..5 {
            let b = it.next_batch();
            let cap = it.tokenizer().vocab_size() as i32;
            assert!(b.tokens.iter().all(|&t| t >= 0 && t < cap));
        }
    }

    #[test]
    fn packing_is_dense_no_padding() {
        let mut it = iter(8, 64);
        let b = it.next_batch();
        let pads = b.tokens.iter().filter(|&&t| t == crate::data::tokenizer::PAD).count();
        assert_eq!(pads, 0, "dense packing should emit no PAD tokens");
    }

    #[test]
    fn consecutive_batches_differ() {
        let mut it = iter(2, 32);
        assert_ne!(it.next_batch().tokens, it.next_batch().tokens);
    }
}
