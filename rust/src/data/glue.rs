//! Synthetic GLUE-like finetuning tasks (Table 1) and the AID-like
//! 30-class image-caption task (Table 4).
//!
//! Each task emits `(tokens (B, L), labels (B,))` with *learnable*
//! structure: every class owns a small set of signature tokens, examples
//! interleave signature tokens with Zipfian background noise, and task
//! difficulty is controlled by the signal density. This is a substitution
//! (we cannot ship GLUE/AID); what it preserves is the finetuning *code
//! path* — tiny b = B·L per step (k down to 1!), classifier head, per-task
//! metrics — which is what Table 1/4 exercise. See DESIGN.md.
//!
//! The eight tasks mirror GLUE's metric mix: F1 (MRPC-like), Matthews
//! correlation (CoLA-like), Pearson (STS-B-like, labels = ordered
//! buckets), accuracy (the rest).

use anyhow::{ensure, Context, Result};

use crate::rngx::{Xoshiro256, Zipf};

/// Metric a task is scored with (paper Table 1 conventions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    F1,
    Matthews,
    Pearson,
}

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: &'static str,
    pub metric: Metric,
    pub n_classes: usize,
    /// Fraction of positions carrying class signal (difficulty knob).
    pub signal_density: f64,
}

/// The GLUE stand-in suite (names follow the paper's Table 1 columns).
pub fn glue_suite() -> Vec<TaskSpec> {
    vec![
        TaskSpec { name: "CoLA", metric: Metric::Matthews, n_classes: 2, signal_density: 0.12 },
        TaskSpec { name: "STS-B", metric: Metric::Pearson, n_classes: 4, signal_density: 0.20 },
        TaskSpec { name: "MRPC", metric: Metric::F1, n_classes: 2, signal_density: 0.15 },
        TaskSpec { name: "RTE", metric: Metric::Accuracy, n_classes: 2, signal_density: 0.10 },
        TaskSpec { name: "SST2", metric: Metric::Accuracy, n_classes: 2, signal_density: 0.25 },
        TaskSpec { name: "MNLI", metric: Metric::Accuracy, n_classes: 3, signal_density: 0.15 },
        TaskSpec { name: "QNLI", metric: Metric::Accuracy, n_classes: 2, signal_density: 0.18 },
        TaskSpec { name: "QQP", metric: Metric::Accuracy, n_classes: 2, signal_density: 0.20 },
    ]
}

/// The AID stand-in (30-way satellite-scene classification by caption).
pub fn aid_task() -> TaskSpec {
    TaskSpec { name: "AID", metric: Metric::F1, n_classes: 30, signal_density: 0.25 }
}

/// One labeled batch.
#[derive(Debug, Clone)]
pub struct LabeledBatch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
}

/// Deterministic task-example generator.
pub struct TaskGenerator {
    spec: TaskSpec,
    vocab: usize,
    /// signature tokens per class (disjoint sets).
    signatures: Vec<Vec<i32>>,
    noise: Zipf,
    rng: Xoshiro256,
}

impl TaskGenerator {
    pub fn new(spec: TaskSpec, vocab: usize, seed: u64) -> Self {
        assert!(vocab > spec.n_classes * 8 + 16, "vocab too small for signatures");
        
        // Reserve the top of the vocab range for signature tokens so they
        // rarely collide with Zipfian noise (which favors low ids).
        let mut signatures = Vec::new();
        let per_class = 6;
        for c in 0..spec.n_classes {
            let base = vocab - (c + 1) * per_class;
            signatures.push((0..per_class).map(|i| (base + i) as i32).collect());
        }
        let noise = Zipf::new(vocab - spec.n_classes * per_class - 4, 1.05);
        let mut rng = Xoshiro256::fold_in(seed, 0x61, 1);
        let _ = &mut rng;
        Self { spec, vocab, signatures, noise, rng }
    }

    pub fn spec(&self) -> &TaskSpec {
        &self.spec
    }

    /// Generate a batch; labels uniform over classes.
    pub fn batch(&mut self, batch: usize, seq: usize) -> LabeledBatch {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let label = self.rng.next_below(self.spec.n_classes as u64) as usize;
            labels.push(label as i32);
            for _ in 0..seq {
                if self.rng.next_f64() < self.spec.signal_density {
                    let sig = &self.signatures[label];
                    tokens.push(sig[self.rng.next_below(sig.len() as u64) as usize]);
                } else {
                    tokens.push(4 + self.noise.sample(&mut self.rng) as i32);
                }
            }
        }
        LabeledBatch { batch, seq, tokens, labels }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

// ---------------------------------------------------------------------------
// Labeled corpora + streaming (the native fine-tuning data path)
// ---------------------------------------------------------------------------

/// One labeled task example: a fixed-length token row plus its class.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskExample {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// A fixed, fully materialized labeled example universe for one task —
/// the unit the train/dev split and the epoch shuffle operate on.
/// Built either synthetically ([`TaskCorpus::synthetic`]: the CI path,
/// no downloads) or from a GLUE-style task file
/// ([`TaskCorpus::from_task_file`]).
#[derive(Debug, Clone)]
pub struct TaskCorpus {
    pub spec: TaskSpec,
    pub vocab: usize,
    pub seq: usize,
    pub examples: Vec<TaskExample>,
}

impl TaskCorpus {
    /// Deterministic synthetic corpus: `n` examples drawn from
    /// [`TaskGenerator`] at `seed`. Same `(spec, vocab, seq, n, seed)`
    /// ⇒ bitwise the same corpus on every machine.
    pub fn synthetic(spec: TaskSpec, vocab: usize, seq: usize, n: usize, seed: u64) -> Self {
        let mut gen = TaskGenerator::new(spec.clone(), vocab, seed);
        let lb = gen.batch(n, seq);
        let examples = (0..n)
            .map(|i| TaskExample {
                tokens: lb.tokens[i * seq..(i + 1) * seq].to_vec(),
                label: lb.labels[i],
            })
            .collect();
        Self { spec, vocab, seq, examples }
    }

    /// Parse a GLUE-style pre-tokenized task file: one example per
    /// line, `label<TAB>space-separated token ids`; blank lines and
    /// `#` comments are skipped. Rows longer than `seq` are truncated,
    /// shorter rows are right-padded with token 0. Labels must sit in
    /// `0..n_classes` and ids in `0..vocab`.
    pub fn from_task_file(spec: TaskSpec, vocab: usize, seq: usize, path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("task file `{path}`"))?;
        let mut examples = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (lab, toks) = line
                .split_once('\t')
                .with_context(|| format!("{path}:{}: expected `label<TAB>ids`", ln + 1))?;
            let label: i32 = lab
                .trim()
                .parse()
                .with_context(|| format!("{path}:{}: bad label `{lab}`", ln + 1))?;
            ensure!(
                label >= 0 && (label as usize) < spec.n_classes,
                "{path}:{}: label {label} outside 0..{}",
                ln + 1,
                spec.n_classes
            );
            let mut tokens = Vec::with_capacity(seq);
            for t in toks.split_whitespace().take(seq) {
                let id: i32 =
                    t.parse().with_context(|| format!("{path}:{}: bad id `{t}`", ln + 1))?;
                ensure!(
                    id >= 0 && (id as usize) < vocab,
                    "{path}:{}: token id {id} outside 0..{vocab}",
                    ln + 1
                );
                tokens.push(id);
            }
            tokens.resize(seq, 0);
            examples.push(TaskExample { tokens, label });
        }
        ensure!(!examples.is_empty(), "{path}: no examples");
        Ok(Self { spec, vocab, seq, examples })
    }

    /// The task-file path when given, the synthetic fallback otherwise
    /// — so CI and offline runs need no downloads.
    pub fn load_or_synthetic(
        spec: TaskSpec,
        vocab: usize,
        seq: usize,
        n: usize,
        seed: u64,
        path: Option<&str>,
    ) -> Result<Self> {
        match path {
            Some(p) => Self::from_task_file(spec, vocab, seq, p),
            None => Ok(Self::synthetic(spec, vocab, seq, n, seed)),
        }
    }

    /// Deterministic, disjoint train/dev split by fixed index stride:
    /// every `dev_every`-th example (indices `dev_every−1, 2·dev_every−1, …`)
    /// goes to dev, the rest to train. No randomness, no leakage —
    /// train ∪ dev == the corpus, train ∩ dev == ∅.
    pub fn split(self, dev_every: usize) -> (TaskCorpus, TaskCorpus) {
        assert!(dev_every >= 2, "split: dev_every must be ≥ 2");
        let (mut train, mut dev) = (Vec::new(), Vec::new());
        for (i, ex) in self.examples.into_iter().enumerate() {
            if i % dev_every == dev_every - 1 {
                dev.push(ex);
            } else {
                train.push(ex);
            }
        }
        let mk = |examples| TaskCorpus {
            spec: self.spec.clone(),
            vocab: self.vocab,
            seq: self.seq,
            examples,
        };
        (mk(train), mk(dev))
    }

    /// Fixed-order evaluation batches over the whole corpus — no rng,
    /// no shuffle; the ragged tail (`len % batch` examples) is dropped
    /// under the same complete-rounds contract as the training stream.
    pub fn eval_batches(&self, batch: usize) -> Vec<LabeledBatch> {
        let full = self.examples.len() / batch;
        (0..full)
            .map(|b| self.pack(&(0..batch).map(|i| b * batch + i).collect::<Vec<_>>()))
            .collect()
    }

    fn pack(&self, idx: &[usize]) -> LabeledBatch {
        let mut tokens = Vec::with_capacity(idx.len() * self.seq);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            tokens.extend_from_slice(&self.examples[i].tokens);
            labels.push(self.examples[i].label);
        }
        LabeledBatch { batch: idx.len(), seq: self.seq, tokens, labels }
    }
}

/// Epoch-shuffled labeled batch stream over a [`TaskCorpus`] — the
/// labeled twin of `data::BatchIterator`, with the same two contracts
/// the trainer's checkpoint/resume relies on: same seed ⇒ same stream,
/// and [`LabeledStream::skip_batches`]`(n)` ≡ draining `n` batches.
/// Each epoch's permutation is a pure function of `(seed, epoch)`
/// (Fisher–Yates keyed by `fold_in`), so the fast-forward jumps to any
/// epoch without replay; the ragged tail (`len % batch` examples per
/// epoch) is **dropped**, matching `BatchShard::complete_rounds`.
#[derive(Debug, Clone)]
pub struct LabeledStream {
    corpus: TaskCorpus,
    batch: usize,
    seed: u64,
    epoch: usize,
    cursor: usize,
    perm: Vec<u32>,
}

impl LabeledStream {
    pub fn new(corpus: TaskCorpus, batch: usize, seed: u64) -> Self {
        assert!(
            corpus.examples.len() >= batch && batch > 0,
            "labeled stream: {} examples cannot fill a batch of {batch}",
            corpus.examples.len()
        );
        let mut s = Self { corpus, batch, seed, epoch: 0, cursor: 0, perm: Vec::new() };
        s.reshuffle();
        s
    }

    /// Complete batches per epoch — the ragged tail is dropped, never
    /// padded or duplicated (`BatchShard::complete_rounds` semantics).
    pub fn batches_per_epoch(&self) -> usize {
        self.corpus.examples.len() / self.batch
    }

    pub fn corpus(&self) -> &TaskCorpus {
        &self.corpus
    }

    fn reshuffle(&mut self) {
        let n = self.corpus.examples.len();
        let mut rng = Xoshiro256::fold_in(self.seed, 0x5F, self.epoch as u64);
        self.perm = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = rng.next_below((i + 1) as u64) as usize;
            self.perm.swap(i, j);
        }
    }

    pub fn next_batch(&mut self) -> LabeledBatch {
        let idx: Vec<usize> = (0..self.batch)
            .map(|i| self.perm[self.cursor * self.batch + i] as usize)
            .collect();
        let lb = self.corpus.pack(&idx);
        self.cursor += 1;
        if self.cursor >= self.batches_per_epoch() {
            self.cursor = 0;
            self.epoch += 1;
            self.reshuffle();
        }
        lb
    }

    /// Fast-forward `n` batches — bit-identical to `n` `next_batch`
    /// calls (the checkpoint-resume contract), O(epoch jump) thanks to
    /// the pure per-epoch permutation.
    pub fn skip_batches(&mut self, n: usize) {
        let bpe = self.batches_per_epoch();
        let abs = self.epoch * bpe + self.cursor + n;
        let (e, c) = (abs / bpe, abs % bpe);
        if e != self.epoch {
            self.epoch = e;
            self.reshuffle();
        }
        self.cursor = c;
    }
}

// ---------------------------------------------------------------------------
// Metrics (Table 1 scoring functions — all implemented, not imported)
// ---------------------------------------------------------------------------

/// Classification accuracy.
pub fn accuracy(pred: &[i32], gold: &[i32]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    let hit = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    hit as f64 / pred.len().max(1) as f64
}

/// Binary F1 with class 1 as positive (MRPC convention).
pub fn f1_binary(pred: &[i32], gold: &[i32]) -> f64 {
    let (mut tp, mut fp, mut fnn) = (0f64, 0f64, 0f64);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p == 1, g == 1) {
            (true, true) => tp += 1.0,
            (true, false) => fp += 1.0,
            (false, true) => fnn += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    2.0 * tp / (2.0 * tp + fp + fnn)
}

/// Macro-averaged F1 over all classes (Table 4's Macro F1).
pub fn f1_macro(pred: &[i32], gold: &[i32], n_classes: usize) -> f64 {
    let mut total = 0.0;
    for c in 0..n_classes as i32 {
        let (mut tp, mut fp, mut fnn) = (0f64, 0f64, 0f64);
        for (&p, &g) in pred.iter().zip(gold) {
            match (p == c, g == c) {
                (true, true) => tp += 1.0,
                (true, false) => fp += 1.0,
                (false, true) => fnn += 1.0,
                _ => {}
            }
        }
        if tp > 0.0 {
            total += 2.0 * tp / (2.0 * tp + fp + fnn);
        }
    }
    total / n_classes as f64
}

/// Class-frequency-weighted F1 (Table 4's Weighted F1).
pub fn f1_weighted(pred: &[i32], gold: &[i32], n_classes: usize) -> f64 {
    let mut total = 0.0;
    let n = gold.len().max(1) as f64;
    for c in 0..n_classes as i32 {
        let support = gold.iter().filter(|&&g| g == c).count() as f64;
        if support == 0.0 {
            continue;
        }
        let (mut tp, mut fp, mut fnn) = (0f64, 0f64, 0f64);
        for (&p, &g) in pred.iter().zip(gold) {
            match (p == c, g == c) {
                (true, true) => tp += 1.0,
                (true, false) => fp += 1.0,
                (false, true) => fnn += 1.0,
                _ => {}
            }
        }
        let f1 = if tp > 0.0 { 2.0 * tp / (2.0 * tp + fp + fnn) } else { 0.0 };
        total += f1 * support / n;
    }
    total
}

/// Matthews correlation coefficient (CoLA convention, binary).
pub fn matthews(pred: &[i32], gold: &[i32]) -> f64 {
    let (mut tp, mut tn, mut fp, mut fnn) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p == 1, g == 1) {
            (true, true) => tp += 1.0,
            (false, false) => tn += 1.0,
            (true, false) => fp += 1.0,
            (false, true) => fnn += 1.0,
        }
    }
    let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fnn) / denom
    }
}

/// Pearson correlation (STS-B convention; bucketed labels as reals).
pub fn pearson(pred: &[i32], gold: &[i32]) -> f64 {
    let n = pred.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let (mut sx, mut sy, mut sxx, mut syy, mut sxy) = (0f64, 0f64, 0f64, 0f64, 0f64);
    for (&p, &g) in pred.iter().zip(gold) {
        let (x, y) = (p as f64, g as f64);
        sx += x;
        sy += y;
        sxx += x * x;
        syy += y * y;
        sxy += x * y;
    }
    let cov = sxy / n - sx / n * (sy / n);
    let vx = sxx / n - (sx / n).powi(2);
    let vy = syy / n - (sy / n).powi(2);
    if vx <= 0.0 || vy <= 0.0 {
        0.0
    } else {
        cov / (vx * vy).sqrt()
    }
}

/// Score predictions with the task's own metric (percent scale like the
/// paper's Table 1).
pub fn score(spec: &TaskSpec, pred: &[i32], gold: &[i32]) -> f64 {
    let raw = match spec.metric {
        Metric::Accuracy => accuracy(pred, gold),
        Metric::F1 => f1_binary(pred, gold),
        Metric::Matthews => matthews(pred, gold),
        Metric::Pearson => pearson(pred, gold),
    };
    raw * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_learnable_by_counting() {
        // A trivial signature-counting classifier must beat chance by a
        // wide margin — guarantees the tasks are learnable for the model.
        let mut g = TaskGenerator::new(glue_suite()[4].clone(), 512, 3);
        let lb = g.batch(256, 64);
        let mut correct = 0;
        for ex in 0..lb.batch {
            let toks = &lb.tokens[ex * lb.seq..(ex + 1) * lb.seq];
            // count signature hits per class
            let mut best = (0, -1i64);
            for c in 0..2 {
                let base = 512 - (c + 1) * 6;
                let hits =
                    toks.iter().filter(|&&t| (t as usize) >= base && (t as usize) < base + 6).count()
                        as i64;
                if hits > best.1 {
                    best = (c as i32, hits);
                }
            }
            if best.0 == lb.labels[ex] {
                correct += 1;
            }
        }
        assert!(correct > 200, "counting classifier got {correct}/256");
    }

    #[test]
    fn metrics_perfect_prediction() {
        let gold = vec![0, 1, 1, 0, 1];
        assert_eq!(accuracy(&gold, &gold), 1.0);
        assert_eq!(f1_binary(&gold, &gold), 1.0);
        assert!((matthews(&gold, &gold) - 1.0).abs() < 1e-12);
        assert!((pearson(&gold, &gold) - 1.0).abs() < 1e-12);
        assert!((f1_macro(&gold, &gold, 2) - 1.0).abs() < 1e-12);
        assert!((f1_weighted(&gold, &gold, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn metrics_degenerate_cases() {
        let gold = vec![0, 1, 0, 1];
        let allzero = vec![0, 0, 0, 0];
        assert_eq!(f1_binary(&allzero, &gold), 0.0);
        assert_eq!(matthews(&allzero, &gold), 0.0);
        assert_eq!(pearson(&allzero, &gold), 0.0);
    }

    #[test]
    fn matthews_detects_anticorrelation() {
        let gold = vec![0, 1, 0, 1, 0, 1];
        let anti = vec![1, 0, 1, 0, 1, 0];
        assert!((matthews(&anti, &gold) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_monotone_labels() {
        let gold = vec![0, 1, 2, 3, 0, 1, 2, 3];
        let close = vec![0, 1, 2, 2, 0, 1, 3, 3];
        let far = vec![3, 2, 1, 0, 3, 2, 1, 0];
        assert!(pearson(&close, &gold) > 0.8);
        assert!(pearson(&far, &gold) < -0.99);
    }

    #[test]
    fn suite_covers_all_metrics() {
        let suite = glue_suite();
        assert_eq!(suite.len(), 8);
        for m in [Metric::Accuracy, Metric::F1, Metric::Matthews, Metric::Pearson] {
            assert!(suite.iter().any(|t| t.metric == m), "missing {m:?}");
        }
        assert_eq!(aid_task().n_classes, 30);
    }

    #[test]
    fn deterministic_batches() {
        let spec = glue_suite()[0].clone();
        let mut a = TaskGenerator::new(spec.clone(), 512, 9);
        let mut b = TaskGenerator::new(spec, 512, 9);
        let ba = a.batch(8, 16);
        let bb = b.batch(8, 16);
        assert_eq!(ba.tokens, bb.tokens);
        assert_eq!(ba.labels, bb.labels);
    }
}
