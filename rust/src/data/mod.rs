//! Data pipeline: synthetic corpus, tokenizer, packing, batching.
//!
//! The paper pretrains on C4 (Raffel et al., 2023). C4 isn't shippable in
//! this environment, so we build the closest synthetic equivalent that
//! exercises the same code paths *and the same statistical property PAMM
//! exploits*: heavy cross-token redundancy. The generator composes
//!
//! * a Zipfian unigram word distribution (natural-language rank law),
//! * an order-2 word-level Markov chain (local contextual similarity),
//! * a pool of repeated sentence templates (boilerplate/padding patterns —
//!   the paper's "repeated patterns, padding, or local contextual
//!   similarity"),
//!
//! then tokenizes with a byte-pair-lite greedy tokenizer trained on a
//! corpus sample, and packs token streams into fixed-length training rows
//! (sequence packing à la Krell et al., 2022 — no cross-doc attention
//! masking, matching the paper's plain-packing setup).
//!
//! Submodules: [`corpus`], [`tokenizer`], [`batcher`], [`glue`].

pub mod batcher;
pub mod corpus;
pub mod glue;
pub mod tokenizer;

pub use batcher::{BatchIterator, BatchShard, TokenBatch};
pub use corpus::CorpusGenerator;
pub use tokenizer::Tokenizer;
