//! Synthetic-C4 text generator (see module docs in data/mod.rs).
//!
//! Words are drawn from a closed vocabulary of pronounceable nonsense
//! words; the *distribution* (Zipf ranks, bigram chains, templated
//! sentences) is what matters for PAMM — the learner must find real
//! sequential structure for the loss to drop, and the token stream must be
//! redundant across rows for PAMM's clustering assumption to hold.

use crate::rngx::{Xoshiro256, Zipf};

/// Number of distinct words in the synthetic language.
pub const DEFAULT_WORDS: usize = 4096;

/// Deterministic pronounceable word for a rank (CV syllables).
fn word_for_rank(rank: usize) -> String {
    const CONS: &[&str] = &[
        "b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z", "ch", "sh",
    ];
    const VOW: &[&str] = &["a", "e", "i", "o", "u", "ai", "ou"];
    let mut w = String::new();
    let mut x = rank + 1;
    while x > 0 {
        w.push_str(CONS[x % CONS.len()]);
        x /= CONS.len();
        w.push_str(VOW[x % VOW.len()]);
        x /= VOW.len();
    }
    w
}

/// Sentence templates — boilerplate skeletons with slots (`{}`), mimicking
/// web-crawl repetition (cookie banners, listicles, navigation text).
const TEMPLATES: &[&str] = &[
    "the {} of {} is {} .",
    "a {} {} said that {} {} .",
    "in {} , {} and {} were {} .",
    "{} : {} , {} , {} and more .",
    "click {} to {} your {} .",
    "why {} {} matters for {} .",
];

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub n_words: usize,
    /// Zipf exponent for unigram draws (≈1.0–1.2 for natural text).
    pub zipf_s: f64,
    /// Probability a sentence comes from a template vs the Markov chain.
    pub template_prob: f64,
    /// Markov-chain sentence length range (words).
    pub min_len: usize,
    pub max_len: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self { n_words: DEFAULT_WORDS, zipf_s: 1.1, template_prob: 0.3, min_len: 4, max_len: 24 }
    }
}

/// Streaming document generator. Deterministic per seed.
pub struct CorpusGenerator {
    cfg: CorpusConfig,
    words: Vec<String>,
    zipf: Zipf,
    rng: Xoshiro256,
    /// order-2 chain state: hashed (prev2, prev1) perturbs the rank draw,
    /// creating consistent local continuations without a dense table.
    chain_salt: u64,
}

impl CorpusGenerator {
    pub fn new(cfg: CorpusConfig, seed: u64) -> Self {
        let words = (0..cfg.n_words).map(word_for_rank).collect();
        let zipf = Zipf::new(cfg.n_words, cfg.zipf_s);
        Self { words, zipf, rng: Xoshiro256::fold_in(seed, 0xC0D, 0), cfg, chain_salt: seed }
    }

    fn chain_next(&mut self, prev2: usize, prev1: usize) -> usize {
        // Order-2 Markov step: each context picks among a small, fixed set
        // of continuations (hash-derived), with Zipfian rank bias inside
        // the set. This yields learnable bigram/trigram structure.
        let ctx = (prev2 as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(prev1 as u64)
            .wrapping_mul(0xBF58476D1CE4E5B9)
            ^ self.chain_salt;
        let branch = self.rng.next_below(4); // 4 continuations per context
        let mut h = ctx.wrapping_add(branch.wrapping_mul(0x94D049BB133111EB));
        h ^= h >> 31;
        // Map into vocabulary with Zipf bias: low ranks more likely.
        let base = self.zipf.sample(&mut self.rng);
        ((h as usize) % 7 + base) % self.cfg.n_words
    }

    fn sentence(&mut self) -> String {
        if self.rng.next_f64() < self.cfg.template_prob {
            let t = TEMPLATES[self.rng.next_below(TEMPLATES.len() as u64) as usize];
            let mut out = String::new();
            for part in t.split("{}") {
                out.push_str(part);
                if out.len() < t.len() + 32 {
                    let w = self.zipf.sample(&mut self.rng);
                    out.push_str(&self.words[w]);
                }
            }
            out
        } else {
            let len = self.cfg.min_len
                + self.rng.next_below((self.cfg.max_len - self.cfg.min_len) as u64) as usize;
            let mut prev2 = self.zipf.sample(&mut self.rng);
            let mut prev1 = self.zipf.sample(&mut self.rng);
            let mut out = format!("{} {}", self.words[prev2], self.words[prev1]);
            for _ in 2..len {
                let next = self.chain_next(prev2, prev1);
                out.push(' ');
                out.push_str(&self.words[next]);
                prev2 = prev1;
                prev1 = next;
            }
            out.push_str(" .");
            out
        }
    }

    /// Generate one document of roughly `approx_words` words.
    pub fn document(&mut self, approx_words: usize) -> String {
        let mut doc = String::new();
        let mut count = 0;
        while count < approx_words {
            let s = self.sentence();
            count += s.split(' ').count();
            if !doc.is_empty() {
                doc.push(' ');
            }
            doc.push_str(&s);
        }
        doc
    }

    /// Vocabulary accessor (tokenizer training uses a corpus sample).
    pub fn words(&self) -> &[String] {
        &self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = CorpusGenerator::new(CorpusConfig::default(), 1);
        let mut b = CorpusGenerator::new(CorpusConfig::default(), 1);
        assert_eq!(a.document(100), b.document(100));
        let mut c = CorpusGenerator::new(CorpusConfig::default(), 2);
        assert_ne!(a.document(100), c.document(100));
    }

    #[test]
    fn documents_have_requested_size() {
        let mut g = CorpusGenerator::new(CorpusConfig::default(), 3);
        let doc = g.document(500);
        let words = doc.split(' ').count();
        assert!(words >= 500 && words < 700, "got {words} words");
    }

    #[test]
    fn zipfian_rank_law_visible() {
        // The most frequent word should dominate mid-rank words heavily.
        let mut g = CorpusGenerator::new(CorpusConfig::default(), 4);
        let doc = g.document(20_000);
        let mut counts = std::collections::HashMap::<&str, usize>::new();
        for w in doc.split(' ') {
            *counts.entry(w).or_default() += 1;
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        assert!(freqs[0] > freqs[50] * 4, "top={} rank50={}", freqs[0], freqs[50]);
    }

    #[test]
    fn templates_create_repetition() {
        // Repeated boilerplate should produce many duplicate trigrams —
        // the redundancy PAMM exploits.
        let mut g =
            CorpusGenerator::new(CorpusConfig { template_prob: 0.8, ..Default::default() }, 5);
        let doc = g.document(5_000);
        let toks: Vec<&str> = doc.split(' ').collect();
        let mut tri = std::collections::HashMap::<(&str, &str, &str), usize>::new();
        for w in toks.windows(3) {
            *tri.entry((w[0], w[1], w[2])).or_default() += 1;
        }
        let repeated = tri.values().filter(|&&c| c > 2).count();
        assert!(repeated > 20, "only {repeated} repeated trigrams");
    }

    #[test]
    fn word_ranks_unique() {
        let words: Vec<String> = (0..2000).map(word_for_rank).collect();
        let mut dedup = words.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), words.len());
    }
}
