//! Hand-rolled CLI argument parser (no `clap` offline).
//!
//! Grammar: `pamm <command> [positional…] [--flag] [--key value]`.
//! Flags may appear anywhere after the command; `--key=value` is accepted.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut it = raw.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut args = Args { command, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("--{name} expects an integer, got `{v}`")
            })?)),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("--{name} expects a number, got `{v}`")
            })?)),
        }
    }

    pub fn get_str(&self, name: &str) -> Option<String> {
        self.flag(name).map(String::from)
    }

    /// First positional or error with usage hint.
    pub fn pos(&self, ix: usize, what: &str) -> Result<&str> {
        self.positional
            .get(ix)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing {what} (positional #{ix})"))
    }
}

pub const USAGE: &str = "\
pamm — reproduction of 'QKV Projections Require a Fraction of Their Memory'

USAGE:
  pamm train [--preset NAME] [--config FILE] [--model M] [--variant V]
             [--r-inv N] [--steps N] [--batch N] [--seq N] [--seed N]
             [--workers N] [--grad-accum N] [--artifacts DIR] [--quiet]
  pamm train --native [--model M] [--steps N] [--batch N] [--seq N]
             [--k N | --r-inv N] [--lr F] [--seed N] [--ckpt-every N]
             [--keep-last N] [--resume] [--quiet]
             [--workers R] [--grad-accum A] [--elastic] [--stall-budget N]
                                      # checkpoints are written atomically
                                      # (tmp+fsync+rename, CRC-checksummed)
                                      # into a keep-last-N ring; --resume
                                      # falls back past corrupt entries to
                                      # the newest one that verifies.
                                      # --workers R runs the data-parallel
                                      # fleet: R logical workers on
                                      # deterministic interleaved shards,
                                      # fixed rank-order all-reduce (loss
                                      # trajectory bit-identical for any
                                      # R×A split of the effective batch;
                                      # R=1 A=1 == the single-process path),
                                      # sharded per-rank ring checkpoints;
                                      # --elastic degrades onto survivors
                                      # when a worker exceeds --stall-budget
  pamm train --quick                  # NATIVE multi-layer next-token
                                      # pretraining smoke (no artifacts):
                                      # model zoo geometry (default nano,
                                      # 2 layers), every block's QKV and
                                      # MLP activations PAMM-compressed,
                                      # loss-decrease asserted; --native
                                      # runs the full-length version with
                                      # periodic checkpoints + --resume
  pamm generate [--native] [--model M] [--prompt-len N] [--max-new N]
                [--k N | --r-inv N] [--eps F] [--seed N]
                [--ckpt NAME] [--ckpt-dir DIR] [--quick]
                                      # native greedy decoding with the
                                      # PAMM-compressed KV cache (dense K/V
                                      # never materialize); asserts one-shot
                                      # prefill == incremental decode BITWISE
                                      # and measured cache peak ≤ the
                                      # analytic bound on every run, then
                                      # prints the compressed-vs-dense
                                      # cache-bytes table. Weights: --ckpt
                                      # loads a `train --native` checkpoint,
                                      # otherwise fresh init from --seed
  pamm serve-sim [--requests N] [--max-concurrent N] [--model M]
                 [--k N] [--eps F] [--seed N] [--quick]
                 [--max-queue N] [--token-budget N]
                 [--deadline-steps N] [--deadline-ms N]
                                      # continuous-batching simulation over
                                      # a scripted load: FIFO admission by
                                      # (arrival, id), one token per active
                                      # session per step over the task pool
                                      # (streams bit-identical at any
                                      # worker count); prints per-request
                                      # schedule + status + latency
                                      # p50/p95/p99 + tok/s + KV-cache bytes
                                      # saved. The degradation knobs bound
                                      # the queue (overflow = shed), clamp
                                      # per-session tokens (truncated) and
                                      # impose deadlines (timed-out)
  pamm chaos [--quick] [--seed N] [--dir DIR] [--dp]
                                      # deterministic fault-injection
                                      # campaign: scripted kills at every
                                      # checkpoint boundary × phase (quick:
                                      # one seeded kill), checkpoint bitrot
                                      # + ring fallback, poisoned serve
                                      # sessions, burst overload — each
                                      # verified BITWISE against the
                                      # fault-free baseline; prints a
                                      # pass/fail table, exits non-zero on
                                      # any failure. --dp targets the
                                      # data-parallel fleet instead: worker
                                      # kills at every (rank × boundary ×
                                      # phase), shard corruption + fallback,
                                      # stragglers within/past the stall
                                      # budget, elastic degradation
  pamm finetune --native --task NAME [--model M] [--batch N] [--seq N]
               [--steps N] [--k N | --r-inv N] [--lr F] [--seed N]
               [--examples N] [--dev-every N] [--eval-every N]
               [--patience N] [--task-file PATH] [--ckpt-every N]
               [--keep-last N] [--dir DIR] [--resume] [--quick] [--quiet]
                                      # native GLUE-style fine-tuning, no
                                      # artifacts: classification head over
                                      # the LM trunk, deterministic synthetic
                                      # task corpus (or --task-file with
                                      # `label<TAB>token ids` rows), stride
                                      # train/dev split (no leakage),
                                      # dev-accuracy early stopping
                                      # (--eval-every + --patience), crash-
                                      # safe ring checkpoints + bit-exact
                                      # --resume; reports dev accuracy + the
                                      # task metric and ASSERTS the loss
                                      # decreased on every fresh run. Tasks:
                                      # CoLA STS-B MRPC RTE SST2 MNLI QNLI
                                      # QQP AID. Without --native (pjrt
                                      # builds) drives the artifact engine:
                                      # --task NAME [--r-inv N] [--steps N]
  pamm ablate [--epsilon F] [--k N] [--quick] [--out DIR]
                                      # native ε/k ablation sweep (P17): one
                                      # fresh LM pretraining run per (ε, k)
                                      # cell over a fixed shape, final loss
                                      # vs EXACT tape saved-bytes (ledger-
                                      # verified per cell), all-generators
                                      # cell asserted bit-equal to the dense
                                      # baseline, saved bytes asserted
                                      # monotone in k; closes with the
                                      # analytic memory-zoo rows. --epsilon/
                                      # --k add a row/column to the grid
  pamm reproduce <fig3a|fig3b|table1|table2a|table2b|table3|table4|table5|
                  table6|table7|fig4a|fig4b|fig5|fig6|fig7|attention|
                  ablation|finetune|all>
                 [--quick] [--native] [--artifacts DIR] [--out DIR]
                                      # `attention` is native-only (P9/P10):
                                      # flash/fused throughput + measured
                                      # peak memory, no artifacts needed
                                      # `table7 --native` runs REAL native
                                      # optimization (fwd+bwd+Adam through
                                      # the compressed-activation autograd)
                                      # + the measured memory ledger (P11)
                                      # `ablation` + `finetune` are native-
                                      # only too (P17): the ε/k quality
                                      # sweep and the GLUE stand-in
                                      # fine-tuning table, synthetic
                                      # corpora, no downloads
  pamm ledger [--shape BxHxLxD] [--k N | --r-inv N] [--no-causal]
                                      # one cold tracked native train step:
                                      # per-phase memory ledger (forward /
                                      # saved-for-backward / backward) with
                                      # the analytic bounds, no artifacts
  pamm ledger --layers N [--shape BxHxLxD] [--vocab N] [--d-ff N]
              [--k N | --r-inv N]     # whole-MODEL per-layer ledger: one
                                      # cold tracked N-layer LM train step,
                                      # per-block saved bytes vs dense,
                                      # model totals, backward peak checked
                                      # against the model-level bound
  pamm ledger --workers R [--grad-accum A] [--layers N] [--shape BxHxLxD]
              [--vocab N] [--d-ff N] [--k N | --r-inv N]
                                      # data-parallel FLEET ledger: one cold
                                      # tracked DP step, per-worker +
                                      # aggregate saved-for-backward vs the
                                      # dense baseline across R×A
                                      # microbatches (ranks reduce in fixed
                                      # order — peaks stay per-microbatch)
  pamm memory [--model M] [--batch N] [--seq N] [--r-inv N]
  pamm kernels [--artifacts DIR]      # validate native vs Pallas artifacts
  pamm kernels --probe                # print SIMD dispatch levels (incl.
                                      # the fast tier), tile parameters
                                      # (GEMM + attention Br/Bc), GFLOP/s
                                      # spot checks (no artifacts needed)
  pamm kernels --tune [--probe] [--quick] [--config FILE]
                                      # sweep KC/MC/NC + attention Br/Bc,
                                      # pick winners by measured GFLOP/s,
                                      # persist them as the [kernels]
                                      # section of FILE (default pamm.toml;
                                      # loaded at startup, env-overridable)
  pamm list [--artifacts DIR]         # list manifest artifacts
  pamm bench-report [--dir DIR] [--out FILE] [--history FILE]
                                      # render BENCH_*.json -> BENCHMARKS.md
                                      # (default: benchmarks/ -> BENCHMARKS.md;
                                      #  --out - prints to stdout) and append
                                      # the run to the commit-keyed history
                                      # (default benchmarks/history.json)
  pamm bench-report --compare A B [--history FILE]
                                      # diff two history entries (commit
                                      # prefixes, or latest/prev)
  pamm bench-report --gate PCT [--dir DIR] [--history FILE]
                                      # fail if any fresh timing regresses
                                      # >PCT% vs the newest history entry;
                                      # skips (with a notice) when the
                                      # baseline is a bootstrap estimate
  pamm help

GLOBAL FLAGS:
  --threads N    worker threads for the native compute pool (poolx);
                 0 or unset = auto (available parallelism, PAMM_THREADS
                 env respected). Results are bit-identical at any N.
  --config FILE  config file read at startup for the [kernels] tile
                 section (default pamm.toml; missing file = defaults).
  PAMM_SIMD      env var: scalar|sse2|avx2|avx2fma|avx512|native
                 (default native) — GEMM dispatch level. scalar/sse2/
                 avx2/native are bit-identical; avx2fma/avx512 are the
                 opt-in fast tier, validated against the scalar oracle
                 within a k-depth relative tolerance instead of bit
                 equality. Unknown values are rejected at startup.
  PAMM_KC/PAMM_MC/PAMM_NC
                 env vars: override the GEMM cache-tile sizes for this
                 run (beats the [kernels] config section).
  PAMM_BR/PAMM_BC
                 env vars: override the attention Br/Bc tile sizes.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("train --preset tiny --steps 100 --quiet");
        assert_eq!(a.command, "train");
        assert_eq!(a.flag("preset"), Some("tiny"));
        assert_eq!(a.get_usize("steps").unwrap(), Some(100));
        assert!(a.get_bool("quiet"));
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn equals_form_and_positionals() {
        let a = parse("reproduce fig3a --out=results --quick");
        assert_eq!(a.command, "reproduce");
        assert_eq!(a.pos(0, "experiment").unwrap(), "fig3a");
        assert_eq!(a.flag("out"), Some("results"));
        assert!(a.get_bool("quick"));
    }

    #[test]
    fn boolean_flag_before_flag_with_value() {
        let a = parse("train --quiet --steps 5");
        assert!(a.get_bool("quiet"));
        assert_eq!(a.get_usize("steps").unwrap(), Some(5));
    }

    #[test]
    fn type_errors_are_reported() {
        let a = parse("train --steps abc");
        assert!(a.get_usize("steps").is_err());
    }

    #[test]
    fn missing_positional_errors() {
        let a = parse("reproduce");
        assert!(a.pos(0, "experiment").is_err());
    }

    #[test]
    fn default_command_is_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }
}
