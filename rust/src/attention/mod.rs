//! Native fused flash-attention: tiled online-softmax forward that can
//! consume PAMM-compressed Q/K/V without ever materializing the full
//! projections.
//!
//! The paper's composability claim — "PAMM is fully composable with
//! efficient attention techniques such as FlashAttention" — existed in
//! this repo only as an XLA artifact pair diffed in
//! `experiments::kernels`. This module is the native realization: a
//! flash-style forward whose per-tile `Q·Kᵀ` and `P·V` contractions
//! route through the `tensor::kernels` microkernel (scalar→sse2→avx2,
//! no FMA), so the bit-identity ladder extends from GEMM to attention,
//! plus a fused entry point that produces Q/K/V strips on the fly from
//! a [`Compressed`] representation.
//!
//! # Tiling scheme
//!
//! Per (batch, head) task, the query dimension is walked in `BR`-row
//! tiles and, for each, the KV sequence in `BC`-row tiles:
//!
//! ```text
//! for i0 in seq by BR:                  // query tile, acc/m/l reset
//!   build Q strip (BR × d, pre-scaled by 1/√d)
//!   for j0 in kv_end(i0) by BC:         // kv tile walk
//!     Kᵀ panel (d × BC): dense transposes straight from the K slab
//!       and reads V in place; fused gather-scales K/V strips first
//!     S  = Qs·Kᵀ            (microkernel GEMM, zeroed tile)
//!     mask S where j > i    (causal boundary tiles only)
//!     online-softmax update (m, l, acc scaled by exp(m_prev − m_new))
//!     acc += P·V            (microkernel GEMM, accumulating)
//!   out rows = acc / l
//! ```
//!
//! Tile sizes ride the kernel's cache blocking: with `BR = BC = 64` and
//! head_dim ≤ 128, the live strips (Q, K, V, Kᵀ, S, acc ≈ 6·64·d·4 B)
//! stay inside L2 next to the kernel's packed panels, the S tile is
//! 16 KiB, and one KV strip packs into KC×NR panels that stay
//! L1-resident — the same budget reasoning as `tensor::kernels` MC/KC.
//! Causal walks skip KV tiles entirely above the diagonal (they
//! contribute exactly nothing: `exp(−1e30 − m) == 0` in f32).
//!
//! # Online-softmax recurrence
//!
//! The FlashAttention-2 form, matching the Pallas kernel
//! (`python/compile/kernels/flash_attention.py`) statement for
//! statement: `m_new = max(m, max_j S)`, `P = exp(S − m_new)`,
//! `corr = exp(m − m_new)`, `l ← l·corr + Σ P`, `acc ← acc·corr + P·V`.
//! All softmax arithmetic is portable scalar Rust; the only SIMD-level-
//! dependent work is inside the two tile GEMMs, which are bit-identical
//! across the dispatch ladder — therefore so is the whole forward.
//!
//! # Determinism contract
//!
//! * **Thread count**: parallelism only partitions the (batch·head)
//!   task grid (the attention analogue of the partition-only-M/N rule —
//!   the softmax/contraction dims are never split); each task's tile
//!   walk is a fixed serial order, and slabs are stitched by
//!   [`Pool::map_chunks_flat`] offsets. Bit-identical at any `--threads`.
//! * **Dispatch level**: the GEMM contract (no FMA, fixed accumulation
//!   order) plus scalar softmax gives `scalar == sse2 == avx2` bitwise.
//!
//! Both are property-tested on ragged shapes in
//! `rust/tests/prop_attention.rs`.
//!
//! # PAMM-fused Q/K/V
//!
//! [`pamm_qkv_attention`] takes the projection input `x`, the three
//! weight matrices and a compression budget, and never materializes
//! `Q = x·Wq` (nor K, V). Instead it uses
//! `Ã·W = diag(α)·1_f·(C·W)`: project the k generators once
//! (`G = C·W`, via [`Compressed::project_generators`]), then every
//! Q/K/V tile row is the gather-scale `α_i · G[f(i)][cols_of_head]`,
//! built directly into the per-thread tile scratch
//! (`tensor::kernels::AttnScratch`, riding the same `Workspace` TLS as
//! the GEMM packing buffers). Peak transient memory is
//! per-thread tile scratch × workers + the compressed-domain state —
//! measured, not modeled, via [`crate::memory::MemoryTracker`] and
//! bounded by [`fused_peak_bound`].

use crate::memory::MemoryTracker;
use crate::pamm::{self, Compressed, Eps};
use crate::poolx::{self, Pool};
use crate::tensor::kernels::{self, Dispatch, Workspace};
use crate::tensor::{dot, Mat};

/// Query-tile rows per online-softmax pass.
pub const BR: usize = 64;
/// KV-tile rows per inner walk step.
pub const BC: usize = 64;

/// Masked-score sentinel: finite (so `m − m_new` never forms NaN) yet
/// low enough that `exp(S − m_new)` underflows to exactly `+0.0` —
/// which is what makes skipping fully-masked KV tiles bit-identical to
/// walking them. Same value as the Pallas kernel's `_NEG_INF`.
const NEG_INF: f32 = -1e30;

/// Geometry of one attention call. Q/K/V (and the output) are flat
/// `f32` slices in row-major `(batch, heads, seq, head_dim)` layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnShape {
    pub batch: usize,
    pub heads: usize,
    pub seq: usize,
    pub head_dim: usize,
    pub causal: bool,
}

impl AttnShape {
    pub fn new(batch: usize, heads: usize, seq: usize, head_dim: usize, causal: bool) -> Self {
        Self { batch, heads, seq, head_dim, causal }
    }

    /// Total token rows (`batch · seq`) — the b of the PAMM papers.
    pub fn tokens(&self) -> usize {
        self.batch * self.seq
    }

    /// Width of the projected activation (`heads · head_dim`).
    pub fn d_model(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Elements of one (batch, heads, seq, head_dim) tensor.
    pub fn qkv_len(&self) -> usize {
        self.batch * self.heads * self.seq * self.head_dim
    }

    /// Bytes of ONE materialized Q/K/V tensor (×3 for all of them) —
    /// the figure the fused path's measured peak is compared against.
    pub fn tensor_bytes(&self) -> usize {
        self.qkv_len() * 4
    }

    /// Semantic flop count of the forward (`Q·Kᵀ` + `P·V`, 2 flops per
    /// MAC); the causal count sums the per-row unmasked lengths.
    pub fn flops(&self) -> f64 {
        let (b, h, l, d) = (
            self.batch as f64,
            self.heads as f64,
            self.seq as f64,
            self.head_dim as f64,
        );
        if self.causal {
            2.0 * b * h * d * l * (l + 1.0)
        } else {
            4.0 * b * h * d * l * l
        }
    }

    fn validate(&self) {
        assert!(self.head_dim >= 1, "attention: head_dim must be ≥ 1");
        assert!(
            self.head_dim <= kernels::NC,
            "attention: head_dim {} above the kernel NC block {}",
            self.head_dim,
            kernels::NC
        );
    }
}

/// Where one head's Q/K/V tile rows come from.
enum HeadSrc<'a> {
    /// Materialized `(seq × d)` slabs (the plain flash path).
    Dense { q: &'a [f32], k: &'a [f32], v: &'a [f32] },
    /// PAMM-compressed: row `i` of a strip is the gather-scale
    /// `α_t · G[f(t)][col0..col0+d]` with `t = tok0 + i` — the full
    /// projection never exists.
    Pamm {
        gq: &'a Mat,
        gk: &'a Mat,
        gv: &'a Mat,
        alpha: &'a [f32],
        assign: &'a [u32],
        /// First projected column of this head.
        col0: usize,
        /// First token row of this batch item.
        tok0: usize,
    },
}

/// Copy rows `[i0, i0+rows)` of a `(seq × d)` slab into `dst`,
/// multiplying by `scale` (1.0 for K/V, 1/√d for Q).
fn strip_dense(dst: &mut [f32], slab: &[f32], i0: usize, rows: usize, d: usize, scale: f32) {
    for r in 0..rows {
        let src = &slab[(i0 + r) * d..(i0 + r + 1) * d];
        let out = &mut dst[r * d..(r + 1) * d];
        if scale == 1.0 {
            out.copy_from_slice(src);
        } else {
            for (o, &s) in out.iter_mut().zip(src) {
                *o = s * scale;
            }
        }
    }
}

/// Build rows `[i0, i0+rows)` of a compressed head strip into `dst`:
/// `α_t · scale · G[f(t)][col0..col0+d]`; dropped rows (α = 0) are zero,
/// exactly like `Compressed::reconstruct`.
#[allow(clippy::too_many_arguments)]
fn strip_pamm(
    dst: &mut [f32],
    g: &Mat,
    alpha: &[f32],
    assign: &[u32],
    tok0: usize,
    col0: usize,
    i0: usize,
    rows: usize,
    d: usize,
    scale: f32,
) {
    for r in 0..rows {
        let t = tok0 + i0 + r;
        let out = &mut dst[r * d..(r + 1) * d];
        let a = alpha[t];
        if a == 0.0 {
            out.fill(0.0);
        } else {
            let gs = a * scale;
            let grow = &g.row(assign[t] as usize)[col0..col0 + d];
            for (o, &gv) in out.iter_mut().zip(grow) {
                *o = gs * gv;
            }
        }
    }
}

/// One (batch, head) slab: the full tile walk of the module docs.
/// Serial leaf computation — all parallelism lives one level up on the
/// task grid, which is exactly why thread count cannot change any
/// per-element order here.
fn attend_head(
    d: Dispatch,
    src: &HeadSrc<'_>,
    seq: usize,
    dh: usize,
    causal: bool,
    ws: &mut Workspace,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), seq * dh);
    let scale = 1.0 / (dh as f32).sqrt();
    let Workspace { packs, attn, .. } = ws;
    attn.ensure(BR.min(seq.max(1)), BC.min(seq.max(1)), dh);

    for i0 in (0..seq).step_by(BR) {
        let br = BR.min(seq - i0);
        match src {
            HeadSrc::Dense { q, .. } => strip_dense(&mut attn.qs, q, i0, br, dh, scale),
            HeadSrc::Pamm { gq, alpha, assign, col0, tok0, .. } => {
                strip_pamm(&mut attn.qs, gq, alpha, assign, *tok0, *col0, i0, br, dh, scale)
            }
        }
        attn.m[..br].fill(NEG_INF);
        attn.l[..br].fill(0.0);
        attn.acc[..br * dh].fill(0.0);

        // Causal: the last KV tile that can hold an unmasked column for
        // this query tile is the one containing row i0+br−1; tiles
        // beyond it are fully masked and contribute exactly nothing.
        let ntiles = if causal { (i0 + br).div_ceil(BC) } else { seq.div_ceil(BC) };
        for jt in 0..ntiles {
            let j0 = jt * BC;
            let bc = BC.min(seq - j0);
            // Kᵀ panel (d × bc): the GEMM B operand of S = Qs·Kᵀ. The
            // dense path transposes straight from the K slab (and will
            // read V in place below) — the strip copies exist for the
            // gather-scale of the compressed path only.
            match src {
                HeadSrc::Dense { k, .. } => {
                    for c in 0..dh {
                        for r in 0..bc {
                            attn.kt[c * bc + r] = k[(j0 + r) * dh + c];
                        }
                    }
                }
                HeadSrc::Pamm { gk, gv, alpha, assign, col0, tok0, .. } => {
                    strip_pamm(&mut attn.ks, gk, alpha, assign, *tok0, *col0, j0, bc, dh, 1.0);
                    strip_pamm(&mut attn.vs, gv, alpha, assign, *tok0, *col0, j0, bc, dh, 1.0);
                    for c in 0..dh {
                        for r in 0..bc {
                            attn.kt[c * bc + r] = attn.ks[r * dh + c];
                        }
                    }
                }
            }
            attn.s[..br * bc].fill(0.0);
            kernels::gemm_into(
                d,
                false,
                br,
                bc,
                dh,
                &attn.qs[..br * dh],
                dh,
                &attn.kt[..dh * bc],
                bc,
                &mut attn.s[..br * bc],
                bc,
                packs,
            );
            if causal && j0 + bc > i0 + 1 {
                for r in 0..br {
                    let first_masked = (i0 + r + 1).saturating_sub(j0);
                    if first_masked < bc {
                        attn.s[r * bc + first_masked..(r + 1) * bc].fill(NEG_INF);
                    }
                }
            }
            // Online-softmax update (scalar, fixed order — see docs).
            for r in 0..br {
                let srow = &mut attn.s[r * bc..(r + 1) * bc];
                let mut mx = NEG_INF;
                for &sv in srow.iter() {
                    mx = mx.max(sv);
                }
                let m_new = attn.m[r].max(mx);
                let corr = (attn.m[r] - m_new).exp();
                let mut psum = 0.0f32;
                for sv in srow.iter_mut() {
                    *sv = (*sv - m_new).exp();
                    psum += *sv;
                }
                attn.l[r] = attn.l[r] * corr + psum;
                attn.m[r] = m_new;
                if corr != 1.0 {
                    for av in &mut attn.acc[r * dh..(r + 1) * dh] {
                        *av *= corr;
                    }
                }
            }
            // acc += P·V through the same microkernel. Dense reads the
            // V slab in place; the compressed path uses its built strip.
            let vsrc: &[f32] = match src {
                HeadSrc::Dense { v, .. } => &v[j0 * dh..(j0 + bc) * dh],
                HeadSrc::Pamm { .. } => &attn.vs[..bc * dh],
            };
            kernels::gemm_into(
                d,
                false,
                br,
                dh,
                bc,
                &attn.s[..br * bc],
                bc,
                vsrc,
                dh,
                &mut attn.acc[..br * dh],
                dh,
                packs,
            );
        }
        for r in 0..br {
            let denom = attn.l[r].max(1e-30);
            let orow = &mut out[(i0 + r) * dh..(i0 + r + 1) * dh];
            for (o, &av) in orow.iter_mut().zip(&attn.acc[r * dh..(r + 1) * dh]) {
                *o = av / denom;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Dense flash entry points
// ---------------------------------------------------------------------------

/// Flash attention over materialized Q/K/V on the process-wide pool.
pub fn flash_attention(q: &[f32], k: &[f32], v: &[f32], shape: &AttnShape) -> Vec<f32> {
    flash_attention_with(q, k, v, shape, poolx::global())
}

/// [`flash_attention`] on an explicit pool (the bench thread sweeps).
pub fn flash_attention_with(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: &AttnShape,
    pool: &Pool,
) -> Vec<f32> {
    flash_attention_on(kernels::active(), q, k, v, shape, pool)
}

/// [`flash_attention`] on an explicit dispatch level — what the
/// property tests use to sweep the ladder without touching the
/// process-wide `kernels::force` state.
pub fn flash_attention_on(
    d: Dispatch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: &AttnShape,
    pool: &Pool,
) -> Vec<f32> {
    shape.validate();
    let n = shape.qkv_len();
    assert_eq!(q.len(), n, "attention: q length vs shape");
    assert_eq!(k.len(), n, "attention: k length vs shape");
    assert_eq!(v.len(), n, "attention: v length vs shape");
    let (sq, dh) = (shape.seq, shape.head_dim);
    let slab = sq * dh;
    let tasks = shape.batch * shape.heads;
    pool.for_tasks().map_chunks_flat(tasks, slab, |s, e, out| {
        kernels::with_workspace(|ws| {
            for t in s..e {
                let off = t * slab;
                let src = HeadSrc::Dense {
                    q: &q[off..off + slab],
                    k: &k[off..off + slab],
                    v: &v[off..off + slab],
                };
                attend_head(
                    d,
                    &src,
                    sq,
                    dh,
                    shape.causal,
                    ws,
                    &mut out[(t - s) * slab..(t - s + 1) * slab],
                );
            }
        })
    })
}

// ---------------------------------------------------------------------------
// PAMM-fused entry points
// ---------------------------------------------------------------------------

/// Fused PAMM → attention forward on the process-wide pool: compress
/// the projection input `x` under the given generator budget, then run
/// the whole attention block off the compressed representation — full
/// Q/K/V activations are never resident. Returns the [`Compressed`]
/// (the activation the training path saves for backward) alongside the
/// attention output.
pub fn pamm_qkv_attention(
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
) -> (Compressed, Vec<f32>) {
    pamm_qkv_attention_with(x, wq, wk, wv, gen_idx, eps, shape, poolx::global())
}

/// [`pamm_qkv_attention`] on an explicit pool.
#[allow(clippy::too_many_arguments)]
pub fn pamm_qkv_attention_with(
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
    pool: &Pool,
) -> (Compressed, Vec<f32>) {
    pamm_qkv_attention_tracked(x, wq, wk, wv, gen_idx, eps, shape, pool, None)
}

/// [`pamm_qkv_attention`] with measured-peak accounting: every
/// transient the fused path allocates (compressed state, projected
/// generators, per-worker tile scratch growth) is reported to
/// `tracker`; the returned output buffer — the caller's product — is
/// not. See [`fused_peak_bound`] for the ceiling the measurement obeys.
#[allow(clippy::too_many_arguments)]
pub fn pamm_qkv_attention_tracked(
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
    pool: &Pool,
    tracker: Option<&MemoryTracker>,
) -> (Compressed, Vec<f32>) {
    assert_eq!(x.rows(), shape.tokens(), "attention: x rows vs batch·seq");
    let comp = pamm::compress_with(x, gen_idx, eps, pool);
    let out = attend_compressed_on(kernels::active(), &comp, wq, wk, wv, shape, pool, tracker);
    (comp, out)
}

/// Attend straight off an existing [`Compressed`] representation, on
/// the process-wide pool (active dispatch, no tracking).
pub fn attend_compressed(
    comp: &Compressed,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    shape: &AttnShape,
) -> Vec<f32> {
    attend_compressed_on(kernels::active(), comp, wq, wk, wv, shape, poolx::global(), None)
}

/// The fused core: explicit dispatch level, pool and optional tracker.
///
/// Projects the generators once per weight (`G = C·W`, k rows), then
/// walks the (batch·head) grid exactly like [`flash_attention_on`],
/// except every Q/K/V strip is gather-scaled from G per tile inside the
/// worker's `AttnScratch`. The accounting contract: `comp` storage and
/// the three G matrices are alloc'd/freed around the call; per-worker
/// scratch *growth* is charged as it happens (TLS on long-lived workers
/// — a warm pool reports zero new bytes, so measure cold peaks on a
/// fresh pool).
#[allow(clippy::too_many_arguments)]
pub fn attend_compressed_on(
    d: Dispatch,
    comp: &Compressed,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    shape: &AttnShape,
    pool: &Pool,
    tracker: Option<&MemoryTracker>,
) -> Vec<f32> {
    shape.validate();
    assert_eq!(comp.b(), shape.tokens(), "attention: compressed rows vs batch·seq");
    let n_in = comp.generators.cols();
    let dm = shape.d_model();
    for (name, w) in [("wq", wq), ("wk", wk), ("wv", wv)] {
        assert_eq!(w.rows(), n_in, "attention: {name} rows vs x width");
        assert_eq!(w.cols(), dm, "attention: {name} cols vs heads·head_dim");
    }
    if let Some(t) = tracker {
        t.alloc(comp.stored_bytes());
    }
    // The projections run on the caller thread and grow ITS workspace
    // packing buffers — a real transient of the fused path, charged
    // like the worker scratch (TLS, so only growth is new bytes).
    let packs_before = tracker.map(|_| kernels::with_workspace(|ws| ws_bytes(ws)));
    let gq = comp.project_generators(wq);
    let gk = comp.project_generators(wk);
    let gv = comp.project_generators(wv);
    let gbytes = 3 * comp.k() * dm * 4;
    if let Some(t) = tracker {
        t.alloc(gbytes);
        if let Some(before) = packs_before {
            t.alloc(kernels::with_workspace(|ws| ws_bytes(ws)).saturating_sub(before));
        }
    }

    let (sq, dh) = (shape.seq, shape.head_dim);
    let slab = sq * dh;
    let tasks = shape.batch * shape.heads;
    let out = pool.for_tasks().map_chunks_flat(tasks, slab, |s, e, out| {
        kernels::with_workspace(|ws| {
            let before = ws_bytes(ws);
            for t in s..e {
                let (b, h) = (t / shape.heads, t % shape.heads);
                let src = HeadSrc::Pamm {
                    gq: &gq,
                    gk: &gk,
                    gv: &gv,
                    alpha: &comp.alpha,
                    assign: &comp.assign,
                    col0: h * dh,
                    tok0: b * sq,
                };
                attend_head(
                    d,
                    &src,
                    sq,
                    dh,
                    shape.causal,
                    ws,
                    &mut out[(t - s) * slab..(t - s + 1) * slab],
                );
            }
            if let Some(tr) = tracker {
                tr.alloc(ws_bytes(ws).saturating_sub(before));
            }
        })
    });
    if let Some(t) = tracker {
        t.free(gbytes);
        t.free(comp.stored_bytes());
    }
    out
}

/// The workspace bytes the fused path charges per worker: attention
/// tile scratch + the kernel packing panels it can grow.
fn ws_bytes(ws: &Workspace) -> usize {
    ws.attn.bytes() + ws.packs.capacity_bytes()
}

// ---------------------------------------------------------------------------
// Memory model
// ---------------------------------------------------------------------------

/// Per-thread tile-scratch ceiling of one attention tile walk, in
/// bytes: the `AttnScratch` buffers at full (BR, BC, d) tiles plus the
/// packing panels the two per-tile GEMMs can reserve (`Q·Kᵀ` packs
/// BR×kc / kc×BC-strips with kc = min(d, KC); `P·V` packs BR×BC /
/// BC-deep d-wide strips). Valid for head_dim ≤ NC (asserted at every
/// entry point). The model counts capacities, which is sound because
/// both the scratch (`fit`) and the packing buffers (`zero_fit`) grow
/// with `reserve_exact` — never amortized doubling.
pub fn tile_scratch_bytes(head_dim: usize) -> usize {
    use kernels::{KC, MR, NR};
    let d = head_dim;
    let tiles = BR * d        // qs
        + BC * d              // ks
        + BC * d              // vs
        + d * BC              // kt
        + BR * BC             // s
        + BR * d              // acc
        + 2 * BR;             // m, l
    let dp = d.div_ceil(NR) * NR; // zero-padded strip width of the P·V pack
    let kc = d.min(KC); //          deepest k panel of the Q·Kᵀ pack
    let pa = BR.div_ceil(MR) * MR * kc.max(BC);
    let pb = BC.div_ceil(NR) * NR * kc.max(dp);
    4 * (tiles + pa + pb)
}

/// Ceiling for the *tracked* peak of [`pamm_qkv_attention_tracked`]:
/// per-worker tile scratch × thread count, plus the compressed-domain
/// state (stored compression + the three projected generator matrices,
/// k rows each), plus the caller-thread packing panels the `G = C·W`
/// projections reserve. The acceptance test asserts
/// `measured peak ≤ this bound < materialized Q/K/V`.
pub fn fused_peak_bound(comp: &Compressed, shape: &AttnShape, threads: usize) -> usize {
    use kernels::{KC, MC, MR, NC, NR};
    let n_in = comp.generators.cols();
    let dm = shape.d_model();
    // G = C·W packing: pa holds ≤ min(k, MC) MR-padded rows × one KC
    // panel of n_in; pb holds ≤ min(dm, NC) NR-padded columns × the
    // same panel depth (exact capacities — see `tile_scratch_bytes`).
    let kc = n_in.min(KC);
    let proj_pa = comp.k().min(MC).div_ceil(MR) * MR * kc;
    let proj_pb = dm.min(NC).div_ceil(NR) * NR * kc;
    tile_scratch_bytes(shape.head_dim) * threads
        + comp.stored_bytes()
        + 3 * comp.k() * dm * 4
        + 4 * (proj_pa + proj_pb)
}

// ---------------------------------------------------------------------------
// Layout + reference helpers
// ---------------------------------------------------------------------------

/// Reshape a `(tokens × d_model)` projection into the flat
/// `(batch, heads, seq, head_dim)` slab layout the attention entry
/// points take — the materialize-then-attend path of the equivalence
/// tests and the experiment baselines.
pub fn split_heads(m: &Mat, shape: &AttnShape) -> Vec<f32> {
    assert_eq!(m.rows(), shape.tokens(), "split_heads: rows vs batch·seq");
    assert_eq!(m.cols(), shape.d_model(), "split_heads: cols vs heads·head_dim");
    let (h, l, d) = (shape.heads, shape.seq, shape.head_dim);
    let mut out = vec![0f32; shape.qkv_len()];
    for b in 0..shape.batch {
        for i in 0..l {
            let row = m.row(b * l + i);
            for hh in 0..h {
                out[((b * h + hh) * l + i) * d..((b * h + hh) * l + i + 1) * d]
                    .copy_from_slice(&row[hh * d..(hh + 1) * d]);
            }
        }
    }
    out
}

/// Materialized-scores reference attention: one `(seq × seq)` score
/// matrix per head, plain f32 softmax. This is the *baseline* the
/// experiment table and bench time against (the memory the flash walk
/// erases); the test oracle is an independent f64 implementation in
/// `rust/tests/prop_attention.rs`.
pub fn naive_attention(q: &[f32], k: &[f32], v: &[f32], shape: &AttnShape) -> Vec<f32> {
    shape.validate();
    let n = shape.qkv_len();
    assert_eq!(q.len(), n);
    assert_eq!(k.len(), n);
    assert_eq!(v.len(), n);
    let (l, d) = (shape.seq, shape.head_dim);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0f32; n];
    let mut scores = vec![0f32; l * l];
    for t in 0..shape.batch * shape.heads {
        let off = t * l * d;
        let (qh, kh, vh) = (&q[off..off + l * d], &k[off..off + l * d], &v[off..off + l * d]);
        for i in 0..l {
            for j in 0..l {
                scores[i * l + j] = if shape.causal && j > i {
                    NEG_INF
                } else {
                    scale * dot(&qh[i * d..(i + 1) * d], &kh[j * d..(j + 1) * d])
                };
            }
        }
        for i in 0..l {
            let srow = &mut scores[i * l..(i + 1) * l];
            let mx = srow.iter().fold(NEG_INF, |a, &b| a.max(b));
            let mut sum = 0.0f32;
            for s in srow.iter_mut() {
                *s = (*s - mx).exp();
                sum += *s;
            }
            let denom = sum.max(1e-30);
            let orow = &mut out[off + i * d..off + (i + 1) * d];
            for (j, &p) in srow.iter().enumerate() {
                let pv = p / denom;
                if pv != 0.0 {
                    for (o, &vv) in orow.iter_mut().zip(&vh[j * d..(j + 1) * d]) {
                        *o += pv * vv;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Xoshiro256;

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        let mut v = vec![0f32; len];
        rng.fill_normal_f32(&mut v, 1.0);
        v
    }

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        Mat::random_normal(rows, cols, 1.0, &mut rng)
    }

    #[test]
    fn flash_matches_naive_on_small_shapes() {
        for &(b, h, l, d, causal) in
            &[(1usize, 1usize, 5usize, 4usize, false), (2, 2, 9, 8, true), (1, 2, BR + 1, 8, true)]
        {
            let shape = AttnShape::new(b, h, l, d, causal);
            let q = rand_vec(shape.qkv_len(), 1);
            let k = rand_vec(shape.qkv_len(), 2);
            let v = rand_vec(shape.qkv_len(), 3);
            let want = naive_attention(&q, &k, &v, &shape);
            let got = flash_attention_with(&q, &k, &v, &shape, &Pool::serial());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "b={b} h={h} l={l} d={d} causal={causal} elem {i}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn fused_matches_materialize_then_attend() {
        let shape = AttnShape::new(2, 2, 33, 8, true);
        let dm = shape.d_model();
        let x = rand_mat(shape.tokens(), dm, 10);
        let wq = rand_mat(dm, dm, 11);
        let wk = rand_mat(dm, dm, 12);
        let wv = rand_mat(dm, dm, 13);
        let mut rng = Xoshiro256::new(14);
        let idx = pamm::sample_generators(&mut rng, shape.tokens(), 12);
        let pool = Pool::serial();
        let (comp, fused) =
            pamm_qkv_attention_with(&x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, &pool);
        // Materialize Ã, project densely, attend — must agree with the
        // fused gather-scale path up to GEMM association rounding.
        let xr = comp.reconstruct();
        let q = split_heads(&xr.matmul(&wq), &shape);
        let k = split_heads(&xr.matmul(&wk), &shape);
        let v = split_heads(&xr.matmul(&wv), &shape);
        let want = flash_attention_with(&q, &k, &v, &shape, &pool);
        for (i, (g, w)) in fused.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                "elem {i}: fused {g} vs materialized {w}"
            );
        }
    }

    #[test]
    fn split_heads_layout() {
        let shape = AttnShape::new(2, 2, 3, 2, false);
        // m[token][col] = token·100 + col; check head hh picks cols [2hh, 2hh+2).
        let m = Mat::from_fn(6, 4, |i, j| (i * 100 + j) as f32);
        let s = split_heads(&m, &shape);
        // (b=1, h=0, i=2) → token 1·3+2 = 5, cols 0..2.
        let off = ((1 * 2 + 0) * 3 + 2) * 2;
        assert_eq!(&s[off..off + 2], &[500.0, 501.0]);
        // (b=0, h=1, i=1) → token 1, cols 2..4.
        let off = ((0 * 2 + 1) * 3 + 1) * 2;
        assert_eq!(&s[off..off + 2], &[102.0, 103.0]);
    }

    #[test]
    fn flops_and_bounds_sanity() {
        let sh = AttnShape::new(1, 2, 128, 32, false);
        assert_eq!(sh.flops(), 4.0 * 2.0 * 32.0 * 128.0 * 128.0);
        let causal = AttnShape { causal: true, ..sh };
        assert!(causal.flops() < sh.flops());
        assert!(tile_scratch_bytes(64) > tile_scratch_bytes(32));
        // The scratch model is far below one materialized tensor at
        // real sequence lengths.
        assert!(tile_scratch_bytes(64) < AttnShape::new(1, 1, 2048, 64, true).tensor_bytes());
    }
}
